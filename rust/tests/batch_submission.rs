//! Batched write-family coverage, cross-runtime:
//!
//! * batch/loop equivalence at the ENGINE level — the same logical
//!   workload submitted as ONE `submit_batch_templated` call or as N
//!   sequential `submit_single_write_templated` calls produces, on
//!   identically-seeded DES clusters, byte-identical per-NIC streams
//!   (tx and rx of every NIC) and identical landed payloads: the
//!   batch's single `bump_n(N)` rotation commit routes exactly like N
//!   single `bump()`s;
//! * the same equivalence observed through payloads + imm totals on
//!   BOTH runtimes (per-NIC counters are a DES-only observable);
//! * all-or-nothing rejection — a batch with one bad entry routes
//!   NOTHING and does not advance the rotation cursor;
//! * `chaos_` mid-batch failover — a NIC dies while a batch is in
//!   flight; only the failed WRs are resubmitted (Resubmit/retarget
//!   contract) and every entry lands exactly once, proven by the
//!   count-gated immediate retiring at exactly N.

use fabric_lib::engine::api::{MrDesc, ScatterDst, TemplatedDst};
use fabric_lib::engine::traits::{
    expect_flag, run_on_both, Cluster, Notify, RuntimeKind, TransferEngine,
};
use fabric_lib::fabric::chaos::ChaosProfile;
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};

/// The shared workload: four templated writes to two peers (mixed
/// lengths, offsets, both peers interleaved) with a count-gated
/// immediate, then three untemplated writes without one.
const IMM: u32 = 0x8A7;

fn templated_entries() -> Vec<TemplatedDst> {
    vec![
        TemplatedDst { peer: 0, len: 300, src: 0, dst: 100 },
        TemplatedDst { peer: 1, len: 1024, src: 512, dst: 0 },
        TemplatedDst { peer: 0, len: 200, src: 1536, dst: 3000 },
        TemplatedDst { peer: 1, len: 64, src: 2048, dst: 4096 },
    ]
}

/// Run the workload on a fresh DES cluster, batched or looped, and
/// return (landed payloads, per-NIC (tx, rx) counters).
fn run_workload(batched: bool) -> (Vec<Vec<u8>>, Vec<(u64, u64)>) {
    let mut cluster = Cluster::new(RuntimeKind::Des, 3, 1, 2, 0xBA7C);
    let net = cluster.des_net().expect("DES cluster");
    let payloads = {
        let (mut cx, engines) = cluster.parts();
        let sender = engines[0];
        let (src, _) = sender.alloc_mr(0, 4096);
        let fill: Vec<u8> = (0..4096u32).map(|i| (i % 249) as u8 + 1).collect();
        src.buf.write(0, &fill);
        let regions: Vec<_> = engines[1..].iter().map(|e| e.alloc_mr(0, 8192)).collect();
        let descs: Vec<MrDesc> = regions.iter().map(|(_, d)| d.clone()).collect();
        let group =
            sender.add_peer_group(engines[1..].iter().map(|e| e.main_address()).collect());
        sender.bind_peer_group_mrs(0, group, &descs).unwrap();

        // Phase 1: templated, imm-gated (2 entries per receiver).
        let got0 = expect_flag(engines[1], &mut cx, 0, IMM, 2);
        let got1 = expect_flag(engines[2], &mut cx, 0, IMM, 2);
        let entries = templated_entries();
        if batched {
            sender
                .submit_batch_templated(&mut cx, &src, group, &entries, Some(IMM), Notify::Noop)
                .unwrap();
        } else {
            for d in &entries {
                sender
                    .submit_single_write_templated(
                        &mut cx,
                        (&src, d.src),
                        d.len,
                        group,
                        d.peer,
                        d.dst,
                        Some(IMM),
                        Notify::Noop,
                    )
                    .unwrap();
            }
        }
        cx.wait(&got0);
        cx.wait(&got1);

        // Phase 2: untemplated, no imm — exercises
        // `submit_write_batch` against the same rotation cursor.
        let scatter: Vec<ScatterDst> = vec![
            ScatterDst { len: 512, src: 0, dst: (descs[0].clone(), 5000) },
            ScatterDst { len: 256, src: 1024, dst: (descs[1].clone(), 6000) },
            ScatterDst { len: 128, src: 3000, dst: (descs[0].clone(), 7000) },
        ];
        if batched {
            sender
                .submit_write_batch(&mut cx, &src, &scatter, None, Notify::Noop)
                .unwrap();
        } else {
            for d in &scatter {
                sender
                    .submit_single_write(
                        &mut cx,
                        (&src, d.src),
                        d.len,
                        (&d.dst.0, d.dst.1),
                        None,
                        Notify::Noop,
                    )
                    .unwrap();
            }
        }
        cx.settle();
        // Exactly-once: the satisfied expectations retired at 2 each.
        assert_eq!(engines[1].imm_value(0, IMM), 0);
        assert_eq!(engines[2].imm_value(0, IMM), 0);
        assert!(sender.remove_peer_group(group));
        regions.iter().map(|(h, _)| h.buf.to_vec()).collect::<Vec<_>>()
    };
    let mut nic_bytes = Vec::new();
    for node in 0..3u16 {
        for nic in 0..2u8 {
            nic_bytes.push(net.nic_bytes(NicAddr { node, gpu: 0, nic }));
        }
    }
    cluster.shutdown();
    (payloads, nic_bytes)
}

/// Acceptance gate for the batch fast path: one batch must emit the
/// SAME WR stream as N sequential singles — identical per-NIC byte
/// counters and identical landed payloads under a deterministic,
/// identically-seeded fabric.
#[test]
fn batch_emits_identical_wr_stream_to_loop() {
    let (loop_payloads, loop_nics) = run_workload(false);
    let (batch_payloads, batch_nics) = run_workload(true);
    assert_eq!(loop_payloads, batch_payloads, "landed bytes diverged");
    assert_eq!(loop_nics, batch_nics, "per-NIC byte streams diverged");
}

/// The same batch/loop equivalence on BOTH runtimes, through the
/// observables both share: landed payloads and imm totals. One
/// cluster, two destination region sets — the loop writes one, the
/// batch the other, and the landed images must agree byte for byte.
#[test]
fn batch_equals_loop_on_both_runtimes() {
    run_on_both(3, 1, 2, 0xB07C, |cx, engines| {
        let sender = engines[0];
        let (src, _) = sender.alloc_mr(0, 4096);
        let fill: Vec<u8> = (0..4096u32).map(|i| (i % 193) as u8 + 1).collect();
        src.buf.write(0, &fill);
        let entries = templated_entries();

        let mut images: Vec<Vec<Vec<u8>>> = Vec::new();
        for (imm, batched) in [(0xA1u32, false), (0xB1u32, true)] {
            let regions: Vec<_> =
                engines[1..].iter().map(|e| e.alloc_mr(0, 8192)).collect();
            let descs: Vec<MrDesc> = regions.iter().map(|(_, d)| d.clone()).collect();
            let group = sender
                .add_peer_group(engines[1..].iter().map(|e| e.main_address()).collect());
            sender.bind_peer_group_mrs(0, group, &descs).unwrap();
            let got0 = expect_flag(engines[1], cx, 0, imm, 2);
            let got1 = expect_flag(engines[2], cx, 0, imm, 2);
            if batched {
                sender
                    .submit_batch_templated(cx, &src, group, &entries, Some(imm), Notify::Noop)
                    .unwrap();
            } else {
                for d in &entries {
                    sender
                        .submit_single_write_templated(
                            cx,
                            (&src, d.src),
                            d.len,
                            group,
                            d.peer,
                            d.dst,
                            Some(imm),
                            Notify::Noop,
                        )
                        .unwrap();
                }
            }
            cx.wait(&got0);
            cx.wait(&got1);
            assert_eq!(engines[1].imm_value(0, imm), 0, "retired at exactly 2");
            assert_eq!(engines[2].imm_value(0, imm), 0, "retired at exactly 2");
            assert!(sender.remove_peer_group(group));
            images.push(regions.iter().map(|(h, _)| h.buf.to_vec()).collect());
        }
        assert_eq!(images[0], images[1], "loop and batch landed different bytes");
    });
}

/// All-or-nothing: a batch with one out-of-range entry is rejected as
/// a whole — nothing routes, nothing posts, and the rotation cursor
/// does not move (the next good submission routes exactly as if the
/// bad batch had never been offered).
#[test]
fn rejected_batch_routes_nothing_and_freezes_rotation() {
    run_on_both(2, 1, 2, 0x0BAD, |cx, engines| {
        let sender = engines[0];
        let (src, _) = sender.alloc_mr(0, 1024);
        src.buf.write(0, &[7u8; 1024]);
        let (dst_h, dst_d) = engines[1].alloc_mr(0, 4096);
        let group = sender.add_peer_group(vec![engines[1].main_address()]);
        sender.bind_peer_group_mrs(0, group, &[dst_d.clone()]).unwrap();

        // Entry 1 overruns the bound region: the whole batch must err.
        let bad = vec![
            TemplatedDst { peer: 0, len: 64, src: 0, dst: 0 },
            TemplatedDst { peer: 0, len: 64, src: 0, dst: 4095 },
        ];
        assert!(sender
            .submit_batch_templated(cx, &src, group, &bad, Some(0xE1), Notify::Noop)
            .is_err());
        // A bad untemplated batch (fanout mismatch) is equally atomic.
        let mut short = dst_d.clone();
        short.rkeys.truncate(1);
        let bad_scatter = vec![
            ScatterDst { len: 64, src: 0, dst: (dst_d.clone(), 0) },
            ScatterDst { len: 64, src: 64, dst: (short, 0) },
        ];
        assert!(sender
            .submit_write_batch(cx, &src, &bad_scatter, None, Notify::Noop)
            .is_err());
        cx.settle();
        // Nothing landed, no imm ticked.
        assert!(dst_h.buf.to_vec().iter().all(|&b| b == 0), "rejected batch leaked a write");
        assert_eq!(engines[1].imm_value(0, 0xE1), 0);

        // The cursor did not move: a good batch still lands cleanly.
        let good = vec![
            TemplatedDst { peer: 0, len: 64, src: 0, dst: 0 },
            TemplatedDst { peer: 0, len: 64, src: 64, dst: 2048 },
        ];
        let got = expect_flag(engines[1], cx, 0, 0xE2, 2);
        sender
            .submit_batch_templated(cx, &src, group, &good, Some(0xE2), Notify::Noop)
            .unwrap();
        cx.wait(&got);
        let v = dst_h.buf.to_vec();
        assert!(v[..64].iter().all(|&b| b == 7));
        assert!(v[2048..2112].iter().all(|&b| b == 7));
        assert!(sender.remove_peer_group(group));
    });
}

/// Mid-batch failover: one of the sender's two NICs dies while a
/// batched submission's WRs are in flight. The Resubmit/retarget
/// contract resubmits ONLY the failed WRs onto surviving lanes —
/// every entry lands exactly once (the count-gated immediate retires
/// at exactly N; a lost WR would hang the wait, a duplicate would
/// leave a nonzero residue) with intact payloads.
#[test]
fn chaos_mid_batch_nic_death_resubmits_only_failed_wrs() {
    let entries = 8u32;
    let len = 256u64 << 10;
    let mut cluster = Cluster::new_with(
        RuntimeKind::Des,
        2,
        1,
        2,
        0xC4A5,
        NicProfile::efa(),
        GpuProfile::h100(),
    );
    let engines = cluster.engines_rc();
    {
        let (mut cx, _) = cluster.parts();
        let sender = &engines[0];
        let receiver = &engines[1];
        let region = (entries as u64 * len) as usize;
        let (src, _) = sender.alloc_mr(0, region);
        for i in 0..entries {
            src.buf
                .write((i as u64 * len) as usize, &vec![i as u8 + 1; len as usize]);
        }
        let (dst_h, dst_d) = receiver.alloc_mr(0, region);

        // Kill the sender's second NIC 20 µs in — deep inside the
        // batch's multi-WR flight (8 × 256 KiB ≈ 80 µs on this NIC).
        let dying = sender.group_address(0).nics[1];
        sender.inject_chaos(&mut cx, &ChaosProfile::new(0xC4A6).nic_down(20_000, dying));

        let group = sender.add_peer_group(vec![receiver.main_address()]);
        sender.bind_peer_group_mrs(0, group, &[dst_d]).unwrap();
        let dsts: Vec<TemplatedDst> = (0..entries)
            .map(|i| TemplatedDst {
                peer: 0,
                len,
                src: i as u64 * len,
                dst: i as u64 * len,
            })
            .collect();
        let got = expect_flag(&**receiver, &mut cx, 0, 0x77, entries);
        sender
            .submit_batch_templated(&mut cx, &src, group, &dsts, Some(0x77), Notify::Noop)
            .unwrap();
        cx.wait(&got);
        cx.settle();

        // Exactly once: retired at exactly `entries`, zero residue.
        assert_eq!(receiver.imm_value(0, 0x77), 0);
        // Zero lost payload: every entry's bytes are intact.
        let v = dst_h.buf.to_vec();
        for i in 0..entries {
            let off = (i as u64 * len) as usize;
            assert!(
                v[off..off + len as usize].iter().all(|&b| b == i as u8 + 1),
                "entry {i} corrupted across failover"
            );
        }
        // The outage was real and the dead lane is masked out.
        assert!(sender.transport_errors() >= 1, "no WR was in flight at the kill");
        assert_eq!(sender.nic_health_mask(0), 0b01, "NIC 1 masked out");
        assert!(sender.remove_peer_group(group));
    }
    cluster.shutdown();
}

/// Same-seed chaos runs agree exactly — failover resubmission is as
/// deterministic as the happy path.
#[test]
fn chaos_mid_batch_failover_is_deterministic() {
    let run = || {
        let mut cluster = Cluster::new_with(
            RuntimeKind::Des,
            2,
            1,
            2,
            0xC4A7,
            NicProfile::efa(),
            GpuProfile::h100(),
        );
        let net = cluster.des_net().expect("DES cluster");
        let errors = {
            let (mut cx, engines) = cluster.parts();
            let sender = engines[0];
            let (src, _) = sender.alloc_mr(0, 1 << 20);
            let (_h, dst_d) = engines[1].alloc_mr(0, 1 << 20);
            let dying = sender.group_address(0).nics[1];
            sender.inject_chaos(&mut cx, &ChaosProfile::new(0xC4A8).nic_down(10_000, dying));
            let group = sender.add_peer_group(vec![engines[1].main_address()]);
            sender.bind_peer_group_mrs(0, group, &[dst_d]).unwrap();
            let dsts: Vec<TemplatedDst> = (0..8u64)
                .map(|i| TemplatedDst { peer: 0, len: 128 << 10, src: 0, dst: i * (128 << 10) })
                .collect();
            let got = expect_flag(engines[1], &mut cx, 0, 0x78, 8);
            sender
                .submit_batch_templated(&mut cx, &src, group, &dsts, Some(0x78), Notify::Noop)
                .unwrap();
            cx.wait(&got);
            cx.settle();
            sender.transport_errors()
        };
        let bytes: Vec<_> = (0..2u16)
            .flat_map(|node| {
                (0..2u8).map(move |nic| NicAddr { node, gpu: 0, nic })
            })
            .map(|a| net.nic_bytes(a))
            .collect();
        cluster.shutdown();
        (errors, bytes)
    };
    assert_eq!(run(), run(), "same-seed failover runs must agree exactly");
}
