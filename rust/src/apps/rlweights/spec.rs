//! Parameter metadata and the static transfer routing (paper §5.1 +
//! Appendix B).
//!
//! The controller gathers parameter metadata (name, shape, dtype,
//! DTensor sharding) from training and inference workers, computes a
//! static schedule mapping which training GPU sends which parameter
//! to which inference GPUs, and broadcasts it to trainers. Here the
//! metadata is generated synthetically to match the Kimi-K2-1T
//! deployment shape (256 training GPUs bf16, FSDP/PP/EP = 16/2/8 →
//! 128 inference GPUs fp8, EP=32); actual tensor payloads are
//! size-faithful but unbacked.

use crate::sim::Rng;

/// One parameter tensor's metadata.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub id: u32,
    /// Elements in the **full** (unsharded) tensor.
    pub elems: u64,
    /// MoE expert weight (vs dense/attention).
    pub moe: bool,
    /// Mesh group (FSDP sharding strategy partition, §5.2): groups
    /// transfer sequentially, params within a group in parallel.
    pub mesh_group: u32,
    /// Owning training rank (the rank that reconstructs + sends it).
    pub owner: u32,
}

impl ParamMeta {
    /// bf16 bytes of the full tensor (training side).
    pub fn bf16_bytes(&self) -> u64 {
        self.elems * 2
    }

    /// fp8 bytes (inference side).
    pub fn fp8_bytes(&self) -> u64 {
        self.elems
    }
}

/// Deployment-shape description.
#[derive(Debug, Clone)]
pub struct RlModelSpec {
    pub name: &'static str,
    /// Total parameters (elements) across the model.
    pub total_params: u64,
    /// Training ranks.
    pub t_ranks: u32,
    /// Inference ranks.
    pub r_ranks: u32,
    /// Params owned (sent) per training rank.
    pub params_per_rank: u32,
    /// Fraction of parameters in MoE experts.
    pub moe_frac: f64,
    /// Inference replication factor (how many r_ranks receive each
    /// param; DP replicas at EP=32 over 128 GPUs → 4).
    pub replicas: u32,
    /// Mesh groups (different FSDP sharding strategies).
    pub mesh_groups: u32,
    /// FSDP shard group size (full_tensor allgather width).
    pub fsdp: u32,
}

impl RlModelSpec {
    /// Kimi-K2-1T (paper Table 5 headline).
    pub fn kimi_k2_1t() -> Self {
        RlModelSpec {
            name: "Kimi-K2-1T",
            total_params: 1_000_000_000_000,
            t_ranks: 256,
            r_ranks: 128,
            params_per_rank: 487,
            moe_frac: 0.96,
            replicas: 4,
            mesh_groups: 2,
            fsdp: 16,
        }
    }

    /// DeepSeek-V3-671B shape.
    pub fn deepseek_v3_671b() -> Self {
        RlModelSpec {
            total_params: 671_000_000_000,
            name: "DeepSeek-V3-671B",
            ..Self::kimi_k2_1t()
        }
    }

    /// Qwen3-235B shape.
    pub fn qwen3_235b() -> Self {
        RlModelSpec {
            total_params: 235_000_000_000,
            name: "Qwen3-235B",
            t_ranks: 128,
            r_ranks: 64,
            params_per_rank: 380,
            ..Self::kimi_k2_1t()
        }
    }

    /// Tiny spec for integration tests (backed buffers, few events).
    pub fn tiny() -> Self {
        RlModelSpec {
            name: "tiny",
            total_params: 4 << 20,
            t_ranks: 4,
            r_ranks: 2,
            params_per_rank: 8,
            moe_frac: 0.5,
            replicas: 1,
            mesh_groups: 2,
            fsdp: 2,
        }
    }

    /// Mean full-tensor size in elements.
    pub fn mean_elems(&self) -> u64 {
        self.total_params / (self.t_ranks as u64 * self.params_per_rank as u64)
    }

    /// Generate the synthetic parameter set for one training rank.
    ///
    /// Sizes are log-spread around the mean (big expert blocks, small
    /// norms) and deterministic per rank.
    pub fn params_of_rank(&self, rank: u32) -> Vec<ParamMeta> {
        let mut rng = Rng::new(0xB16_B00B5 ^ rank as u64);
        let mean = self.mean_elems() as f64;
        let mut out = Vec::with_capacity(self.params_per_rank as usize);
        // Budget-preserving sizes: alternate big/small around the mean.
        let mut budget = (mean * self.params_per_rank as f64) as i64;
        for i in 0..self.params_per_rank {
            let remaining = (self.params_per_rank - i) as i64;
            let size = if remaining == 1 {
                budget.max(1) as u64
            } else {
                let f = 0.5 + rng.f64(); // 0.5x..1.5x mean
                let s = ((mean * f) as i64).min(budget - (remaining - 1)).max(1);
                s as u64
            };
            budget -= size as i64;
            out.push(ParamMeta {
                id: rank * self.params_per_rank + i,
                elems: size,
                moe: rng.f64() < self.moe_frac,
                mesh_group: i % self.mesh_groups,
                owner: rank,
            });
        }
        out
    }
}

/// One scheduled transfer: `param` (full tensor, fp8) from its owner
/// to inference rank `dst`.
#[derive(Debug, Clone)]
pub struct TransferTask {
    pub param: ParamMeta,
    pub dst: u32,
    /// Byte offset inside `dst`'s weight region.
    pub dst_offset: u64,
}

/// Compute the static routing for one training rank: each owned param
/// goes to `replicas` inference ranks, chosen round-robin so load
/// balances across the inference cluster; destination offsets are
/// assigned densely per destination region (paper: static schedule,
/// no re-planning per step).
pub fn compute_routing(spec: &RlModelSpec, rank: u32) -> Vec<TransferTask> {
    let params = spec.params_of_rank(rank);
    let mut tasks = Vec::new();
    // Per-destination offset cursors must be deterministic across the
    // cluster: derive from (param id, replica index).
    for p in &params {
        let stride = spec.r_ranks / spec.replicas.min(spec.r_ranks);
        for r in 0..spec.replicas.min(spec.r_ranks) {
            let dst = (p.id + r * stride.max(1)) % spec.r_ranks;
            tasks.push(TransferTask {
                param: p.clone(),
                dst,
                // Dense per-dst placement is computed by the receiver
                // in reality; here a hash-spread offset inside a
                // region sized for the full model keeps writes
                // disjoint enough for the simulator.
                dst_offset: (p.id as u64 % 1024) * (spec.mean_elems() * 2),
            });
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_params_preserve_budget() {
        let spec = RlModelSpec::kimi_k2_1t();
        let params = spec.params_of_rank(3);
        assert_eq!(params.len(), 487);
        let total: u64 = params.iter().map(|p| p.elems).sum();
        let expect = spec.mean_elems() * 487;
        let err = (total as f64 - expect as f64).abs() / expect as f64;
        assert!(err < 0.01, "rank budget drift {err}");
    }

    #[test]
    fn params_deterministic_per_rank() {
        let spec = RlModelSpec::kimi_k2_1t();
        let a = spec.params_of_rank(7);
        let b = spec.params_of_rank(7);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.elems == y.elems));
        let c = spec.params_of_rank(8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.elems != y.elems));
    }

    #[test]
    fn routing_covers_all_replicas_and_balances() {
        let spec = RlModelSpec::kimi_k2_1t();
        let tasks = compute_routing(&spec, 0);
        assert_eq!(tasks.len(), 487 * spec.replicas as usize);
        // Destination load across the inference cluster from this one
        // rank is roughly balanced.
        let mut load = vec![0u64; spec.r_ranks as usize];
        for t in &tasks {
            load[t.dst as usize] += t.param.fp8_bytes();
        }
        let max = *load.iter().max().unwrap() as f64;
        let mean = load.iter().sum::<u64>() as f64 / load.len() as f64;
        assert!(max < mean * 3.0, "dst imbalance: max {max} mean {mean}");
    }

    #[test]
    fn moe_fraction_respected() {
        let spec = RlModelSpec::kimi_k2_1t();
        let params = spec.params_of_rank(0);
        let moe = params.iter().filter(|p| p.moe).count() as f64 / params.len() as f64;
        assert!((moe - spec.moe_frac).abs() < 0.08, "moe frac {moe}");
    }
}
