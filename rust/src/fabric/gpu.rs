//! Simulated GPU: streams, kernel timing, UVM watch words, GDRCopy
//! visibility, and the intra-node NVLink fabric.
//!
//! The engine and the MoE/KvCache apps interact with GPUs exactly the
//! way the paper's do: they launch kernels (whose runtimes follow an
//! HBM roofline), watch UVM words for GPU-side progress (visible to the
//! CPU only after a PCIe delay — GDRCopy semantics), and move intra-node
//! payloads over NVLink with store/flag synchronization.

use std::cell::RefCell;
use std::rc::Rc;

use super::profile::GpuProfile;
use super::topology::DeviceId;
use crate::engine::model::{ComputeModel, NvlinkModel};
use crate::sim::time::{Duration, Instant};
use crate::sim::Sim;

/// A UVM word: written by device-side code at virtual times, observed
/// by the CPU with PCIe visibility delay (GDRCopy-style polling).
#[derive(Clone, Default)]
pub struct UvmWord {
    hist: Rc<RefCell<Vec<(Instant, u64)>>>,
}

impl UvmWord {
    /// New word, initial value 0 at t=0.
    pub fn new() -> Self {
        UvmWord {
            hist: Rc::new(RefCell::new(vec![(0, 0)])),
        }
    }

    /// Device-side write of `value` at time `at`.
    ///
    /// Writes must be appended in nondecreasing time order (device
    /// streams are ordered); out-of-order appends panic.
    pub fn write_at(&self, at: Instant, value: u64) {
        let mut h = self.hist.borrow_mut();
        if let Some(&(t, _)) = h.last() {
            assert!(at >= t, "UVM writes must be time-ordered ({at} < {t})");
        }
        h.push((at, value));
    }

    /// Device-side increment at time `at`; returns the new value.
    pub fn inc_at(&self, at: Instant, by: u64) -> u64 {
        let cur = self.hist.borrow().last().unwrap().1;
        let new = cur + by;
        self.write_at(at, new);
        new
    }

    /// CPU-side read at time `now` with PCIe visibility delay
    /// `pcie_ns`: returns the newest value written at or before
    /// `now - pcie_ns`.
    pub fn read_visible(&self, now: Instant, pcie_ns: Duration) -> u64 {
        let cutoff = now.saturating_sub(pcie_ns);
        let h = self.hist.borrow();
        h.iter()
            .rev()
            .find(|&&(t, _)| t <= cutoff)
            .map(|&(_, v)| v)
            .unwrap_or(0)
    }

    /// Latest device-side value regardless of visibility (device-side
    /// reads).
    pub fn device_value(&self) -> u64 {
        self.hist.borrow().last().unwrap().1
    }
}

/// One simulated GPU. Timing delegates to the shared
/// [`crate::engine::model::ComputeModel`] rule, so the DES fabric and
/// the runtime-neutral scenario layer cannot drift apart.
#[derive(Clone)]
pub struct GpuSim {
    id: DeviceId,
    model: ComputeModel,
}

impl GpuSim {
    /// Create a GPU with the given profile.
    pub fn new(id: DeviceId, profile: GpuProfile) -> Self {
        GpuSim {
            id,
            model: ComputeModel::new(profile),
        }
    }

    /// Device identity.
    pub fn id(&self) -> DeviceId {
        self.id
    }

    /// Timing profile.
    pub fn profile(&self) -> GpuProfile {
        self.model.profile()
    }

    /// Enqueue a kernel of `duration` on `stream`; `on_done(sim, end)`
    /// fires at completion. Returns the scheduled (start, end).
    ///
    /// `graph_launch` skips the host launch overhead (CUDA-graph
    /// captured kernels, which the KvCache path relies on).
    pub fn launch(
        &self,
        sim: &mut Sim,
        stream: u32,
        duration: Duration,
        graph_launch: bool,
        on_done: impl FnOnce(&mut Sim, Instant) + 'static,
    ) -> (Instant, Instant) {
        let (start, end) = self.model.reserve(sim.now(), stream, duration, graph_launch);
        sim.at(end, move |s| on_done(s, end));
        (start, end)
    }

    /// Convenience: HBM-roofline kernel moving `bytes` through HBM.
    pub fn launch_hbm(
        &self,
        sim: &mut Sim,
        stream: u32,
        bytes: u64,
        graph_launch: bool,
        on_done: impl FnOnce(&mut Sim, Instant) + 'static,
    ) -> (Instant, Instant) {
        let d = self.profile().hbm_ns(bytes);
        self.launch(sim, stream, d, graph_launch, on_done)
    }

    /// Time when `stream` becomes idle.
    pub fn stream_free(&self, stream: u32) -> Instant {
        self.model.stream_free(stream)
    }
}

/// Intra-node NVLink fabric: point-to-point serialized links between
/// GPU pairs, plus release/acquire flag words.
///
/// The paper's kernels push payloads (stores are fire-and-forget) and
/// synchronize via flags; loads from peers stall. We model the
/// bandwidth/latency of pushes and expose flag words with the same
/// visibility rule as UVM (but NVLink latency, not PCIe). Timing
/// delegates to the shared [`crate::engine::model::NvlinkModel`] rule.
#[derive(Clone, Default)]
pub struct NvlinkFabric {
    model: NvlinkModel,
}

impl NvlinkFabric {
    /// Fresh fabric (one per node).
    pub fn new() -> Self {
        Self::default()
    }

    /// Push `bytes` from `src` to `dst`; returns the completion time
    /// (stores visible at the peer).
    pub fn push(
        &self,
        sim: &Sim,
        profile: &GpuProfile,
        src: u8,
        dst: u8,
        bytes: u64,
    ) -> Instant {
        self.model.push_at(sim.now(), profile, src, dst, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::DeviceId;
    use crate::sim::time::US;

    fn gpu() -> GpuSim {
        GpuSim::new(DeviceId { node: 0, gpu: 0 }, GpuProfile::h100())
    }

    #[test]
    fn uvm_visibility_delay() {
        let w = UvmWord::new();
        w.write_at(1000, 1);
        w.write_at(2000, 2);
        // Before any write is visible.
        assert_eq!(w.read_visible(500, 2000), 0);
        // First write visible only after +pcie.
        assert_eq!(w.read_visible(2999, 2000), 0);
        assert_eq!(w.read_visible(3000, 2000), 1);
        assert_eq!(w.read_visible(4000, 2000), 2);
        // Device sees its own writes immediately.
        assert_eq!(w.device_value(), 2);
    }

    #[test]
    #[should_panic(expected = "time-ordered")]
    fn uvm_rejects_time_travel() {
        let w = UvmWord::new();
        w.write_at(100, 1);
        w.write_at(50, 2);
    }

    #[test]
    fn kernels_serialize_per_stream() {
        let g = gpu();
        let mut sim = Sim::new();
        let mut ends = Vec::new();
        let (s1, e1) = g.launch(&mut sim, 0, 10 * US, true, |_, _| {});
        let (s2, e2) = g.launch(&mut sim, 0, 5 * US, true, |_, _| {});
        ends.push((s1, e1, s2, e2));
        assert_eq!(s1, 0);
        assert_eq!(e1, 10 * US);
        assert_eq!(s2, e1, "same stream is in-order");
        assert_eq!(e2, 15 * US);
        // Different stream runs concurrently.
        let (s3, _) = g.launch(&mut sim, 1, 5 * US, true, |_, _| {});
        assert_eq!(s3, 0);
        sim.run();
    }

    #[test]
    fn launch_overhead_outside_graphs() {
        let g = gpu();
        let mut sim = Sim::new();
        let (start, _) = g.launch(&mut sim, 0, 1000, false, |_, _| {});
        assert_eq!(start, g.profile().launch_ns);
        sim.run();
    }

    #[test]
    fn nvlink_serializes_per_link() {
        let nv = NvlinkFabric::new();
        let sim = Sim::new();
        let p = GpuProfile::h100();
        let t1 = nv.push(&sim, &p, 0, 1, 450_000); // ~1.55 µs
        let t2 = nv.push(&sim, &p, 0, 1, 450_000);
        assert!(t2 >= t1 + 1000, "same link serializes");
        let t3 = nv.push(&sim, &p, 0, 2, 450_000);
        assert!(t3 < t2, "different link is independent");
    }
}
