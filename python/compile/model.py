"""L2: MoE transformer (prefill + decode) in JAX, calling the L1
Pallas kernels.

This is the model served by the disaggregated-inference example: the
prefiller node runs :func:`prefill` (producing the KV cache that
fabric-lib transfers), the decoder node runs :func:`decode_step`
against the received cache. A deterministic seed builds synthetic
weights, so prefiller, decoder and the non-disaggregated reference all
agree bit-for-bit — which is how the end-to-end test validates the
transfer path.

Everything here runs at build time only: `aot.py` lowers these
functions to HLO text executed from Rust via PJRT.
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention
from .kernels.moe_expert import moe_expert
from .kernels import ref


@dataclass(frozen=True)
class ModelConfig:
    """Small MoE transformer; defaults sized for CPU-PJRT serving."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 256
    n_experts: int = 4
    top_k: int = 2
    max_seq: int = 160

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def kv_bytes_per_token_layer(self) -> int:
        """f32 K+V bytes per (token, layer) — the transfer unit maths
        used by the KvCache app."""
        return 2 * self.d_model * 4

    def param_count(self) -> int:
        c = self
        per_layer = (
            4 * c.d_model * c.d_model  # qkv + out proj
            + c.n_experts * 2 * c.d_model * c.d_ff  # expert mlps
            + c.d_model * c.n_experts  # router
        )
        return c.vocab * c.d_model * 2 + c.n_layers * per_layer


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic synthetic weights (shared by all nodes)."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3 + cfg.n_layers)
    scale = 0.1

    def rnd(key, shape, s=scale):
        return (jax.random.normal(key, shape, jnp.float32) * s)

    params = {
        "embed": rnd(ks[0], (cfg.vocab, cfg.d_model), 1.0 / cfg.d_model**0.5),
        "unembed": rnd(ks[1], (cfg.d_model, cfg.vocab)),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        lk = jax.random.split(ks[3 + li], 6)
        params["layers"].append(
            {
                "wqkv": rnd(lk[0], (cfg.d_model, 3 * cfg.d_model)),
                "wo": rnd(lk[1], (cfg.d_model, cfg.d_model)),
                "router": rnd(lk[2], (cfg.d_model, cfg.n_experts)),
                "w1": rnd(lk[3], (cfg.n_experts, cfg.d_model, cfg.d_ff)),
                "w2": rnd(lk[4], (cfg.n_experts, cfg.d_ff, cfg.d_model)),
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            }
        )
    return params


def _rms_norm(x, g):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _moe_ffn(cfg: ModelConfig, layer, x):
    """Top-k routed MoE FFN over [N, D] tokens.

    Dense-capacity formulation: every expert computes every token via
    the grouped Pallas kernel; the router's top-k gates mask the
    combine. At these sizes the dense form is MXU-friendly and keeps
    the AOT graph static (no dynamic shapes in HLO).
    """
    n, d = x.shape
    logits = x @ layer["router"]  # [N, E]
    gates = jax.nn.softmax(logits, axis=-1)
    # Top-k via sort: xla_extension 0.5.1's HLO text parser predates
    # the dedicated `topk` op jax.lax.top_k now lowers to, but it
    # parses `sort` fine.
    thresh = jnp.sort(gates, axis=-1)[:, cfg.n_experts - cfg.top_k][:, None]
    mask = (gates >= thresh).astype(x.dtype)  # [N, E]
    gates = gates * mask
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    xe = jnp.broadcast_to(x[None], (cfg.n_experts, n, d))
    ye = moe_expert(xe, layer["w1"], layer["w2"])  # [E, N, D]
    return jnp.einsum("end,ne->nd", ye, gates)


def _attn_prefill(cfg: ModelConfig, layer, x):
    """Causal self-attention over [S, D]; returns (out, k, v) with
    k/v shaped [H, S, Dh] for the cache."""
    s, d = x.shape
    qkv = x @ layer["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = k.reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    v = v.reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("hqk,hkd->hqd", p, v)
    o = o.transpose(1, 0, 2).reshape(s, d) @ layer["wo"]
    return o, k, v


def prefill(cfg: ModelConfig, params, tokens):
    """Run the prefill phase over ``tokens`` [S].

    Returns:
      (logits [vocab] for the last position,
       k_cache [L, H, S, Dh], v_cache [L, H, S, Dh]).
    """
    x = params["embed"][tokens]  # [S, D]
    ks, vs = [], []
    for layer in params["layers"]:
        a, k, v = _attn_prefill(cfg, layer, _rms_norm(x, layer["ln1"]))
        x = x + a
        x = x + _moe_ffn(cfg, layer, _rms_norm(x, layer["ln2"]))
        ks.append(k)
        vs.append(v)
    logits = _rms_norm(x[-1], jnp.ones((cfg.d_model,))) @ params["unembed"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(cfg: ModelConfig, params, token, k_cache, v_cache, pos):
    """Decode one token given caches padded to ``max_seq``.

    Args:
      token: scalar i32; k_cache/v_cache: [L, H, max_seq, Dh];
      pos: scalar i32, number of valid cache positions.

    Returns:
      (logits [vocab], k_cache, v_cache) with position ``pos``
      filled in.
    """
    x = params["embed"][token]  # [D]
    s_max = k_cache.shape[2]
    del s_max
    n_valid = pos + 1  # cache rows valid after inserting the new token
    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        xn = _rms_norm(x, layer["ln1"])
        qkv = xn @ layer["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(cfg.n_heads, cfg.d_head)
        k = k.reshape(cfg.n_heads, cfg.d_head)
        v = v.reshape(cfg.n_heads, cfg.d_head)
        kc = jax.lax.dynamic_update_slice(
            k_cache[li], k[:, None, :], (0, pos, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            v_cache[li], v[:, None, :], (0, pos, 0)
        )
        new_k.append(kc)
        new_v.append(vc)
        # L1 Pallas decode attention over the padded cache; padded
        # rows are masked to -inf inside the kernel via n_valid.
        o = decode_attention(q[None], kc[None], vc[None], n_valid)[0]
        x = x + o.reshape(cfg.d_model) @ layer["wo"]
        x = x + _moe_ffn(cfg, layer, _rms_norm(x, layer["ln2"])[None])[0]
    logits = _rms_norm(x, jnp.ones((cfg.d_model,))) @ params["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def greedy_generate(cfg: ModelConfig, params, tokens, n_new: int):
    """Reference: prefill + greedy decode, all in one process (used to
    validate the disaggregated path end-to-end)."""
    logits, kc, vc = prefill(cfg, params, tokens)
    s = tokens.shape[0]
    pad = cfg.max_seq - s
    kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = []
    tok = jnp.argmax(logits).astype(jnp.int32)
    pos = s
    for _ in range(n_new):
        out.append(int(tok))
        logits, kc, vc = decode_step(cfg, params, tok, kc, vc, jnp.asarray(pos, jnp.int32))
        tok = jnp.argmax(logits).astype(jnp.int32)
        pos += 1
    return out


def moe_block(cfg: ModelConfig, params, x):
    """Standalone MoE block [N, D] -> [N, D] (layer 0), exported for
    the MoE dispatch/combine example's expert compute."""
    return _moe_ffn(cfg, params["layers"][0], x)


def flat_params(params):
    """Flatten parameters into (name, array) pairs, stable order (the
    RL weight-transfer metadata path)."""
    out = [("embed", params["embed"]), ("unembed", params["unembed"])]
    for i, layer in enumerate(params["layers"]):
        for name in ["wqkv", "wo", "router", "w1", "w2", "ln1", "ln2"]:
            out.append((f"layers.{i}.{name}", layer[name]))
    return out
