"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept with
hypothesis across shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention
from compile.kernels.moe_expert import moe_expert, vmem_bytes
from compile.kernels.quantize import quantize_fp8

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@settings(**SETTINGS)
@given(
    e=st.integers(1, 4),
    c=st.integers(1, 40),
    d=st.sampled_from([16, 32, 64]),
    f=st.sampled_from([32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_expert_matches_ref(e, c, d, f, dtype, seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    x = rand(k1, (e, c, d), dtype)
    w1 = rand(k2, (e, d, f), dtype, 0.2)
    w2 = rand(k3, (e, f, d), dtype, 0.2)
    got = moe_expert(x, w1, w2)
    want = ref.moe_expert_ref(
        x.astype(jnp.float32), w1.astype(jnp.float32), w2.astype(jnp.float32)
    )
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=tol, atol=tol
    )
    assert got.dtype == dtype


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=st.integers(1, 48),
    dh=st.sampled_from([16, 32]),
    n_valid=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s, dh, n_valid, seed):
    n_valid = min(n_valid, s)
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    q = rand(k1, (b, h, dh), jnp.float32)
    kk = rand(k2, (b, h, s, dh), jnp.float32)
    vv = rand(k3, (b, h, s, dh), jnp.float32)
    got = decode_attention(q, kk, vv, n_valid)
    want = ref.decode_attention_ref(q, kk, vv, n_valid)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_decode_attention_masks_padding():
    """Padded rows must not influence the output at all."""
    k = jax.random.PRNGKey(0)
    q = rand(k, (1, 2, 16), jnp.float32)
    kk = rand(k, (1, 2, 8, 16), jnp.float32)
    vv = rand(k, (1, 2, 8, 16), jnp.float32)
    base = decode_attention(q, kk, vv, 5)
    # Garbage in padded region.
    kk2 = kk.at[:, :, 5:].set(1e6)
    vv2 = vv.at[:, :, 5:].set(-1e6)
    np.testing.assert_allclose(base, decode_attention(q, kk2, vv2, 5), rtol=1e-6)


@settings(**SETTINGS)
@given(
    r=st.integers(1, 64),
    c=st.integers(2, 128),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_fp8_matches_ref(r, c, scale, seed):
    k = jax.random.PRNGKey(seed)
    x = rand(k, (r, c), jnp.float32, scale)
    q, s = quantize_fp8(x)
    rq, rs = ref.quantize_fp8_ref(x)
    np.testing.assert_allclose(s, rs, rtol=1e-6)
    # Quantized values agree with the oracle bit-for-bit.
    np.testing.assert_array_equal(
        np.asarray(q).view(np.uint8), np.asarray(rq).view(np.uint8)
    )
    # Round-trip error bounded by fp8-e4m3 resolution.
    deq = ref.dequantize_fp8_ref(q, s)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    bound = np.abs(np.asarray(x)) * 0.07 + np.asarray(s)[:, :1] * 0.6
    assert (err <= bound).all()


def test_quantize_zero_rows():
    x = jnp.zeros((4, 8), jnp.float32)
    q, s = quantize_fp8(x)
    assert np.asarray(q.astype(jnp.float32)).sum() == 0
    assert (np.asarray(s) > 0).all()  # no division by zero


def test_vmem_budget_reported():
    # Perf-reporting helper: default tile fits a 16 MiB VMEM budget for
    # the model's dimensions.
    assert vmem_bytes(32, 128, 256, 4) < 16 * 1024 * 1024


@pytest.mark.parametrize("c", [1, 31, 32, 33, 95])
def test_moe_expert_ragged_capacity(c):
    """Capacities that don't divide the tile exercise the pad path."""
    k = jax.random.PRNGKey(1)
    x = rand(k, (2, c, 16), jnp.float32)
    w1 = rand(k, (2, 16, 32), jnp.float32, 0.2)
    w2 = rand(k, (2, 32, 16), jnp.float32, 0.2)
    np.testing.assert_allclose(
        moe_expert(x, w1, w2),
        ref.moe_expert_ref(x, w1, w2),
        rtol=5e-5,
        atol=5e-5,
    )
