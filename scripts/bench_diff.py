#!/usr/bin/env python3
"""Compare a regenerated bench report against a committed baseline.

Usage: bench_diff.py BASELINE.json NEW.json [--tolerance PCT]

Both files are the section/headline JSON the benches emit via
`--json` (see README "Benches"). Every numeric headline present in
BOTH files is compared; relative deviations beyond the tolerance
(default 30%) are printed as warnings. Non-numeric fields (e.g.
`provenance`) and headlines present on only one side are reported
informationally.

Warn-only by design: always exits 0. The perf gates that should FAIL
CI live inside the benches themselves (proxy_overhead asserts
batched < looped); this script is the trend report for the pinned
BENCH_*.json trajectory.
"""

import json
import sys


def flatten(doc, prefix=""):
    out = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(flatten(v, prefix + k + "."))
    else:
        out[prefix.rstrip(".")] = doc
    return out


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    tol = 0.30
    if "--tolerance" in sys.argv:
        tol = float(sys.argv[sys.argv.index("--tolerance") + 1]) / 100.0
    if len(args) != 2:
        print(__doc__)
        return
    try:
        with open(args[0]) as fh:
            base = flatten(json.load(fh))
        with open(args[1]) as fh:
            new = flatten(json.load(fh))
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read reports ({e}); skipping (warn-only)")
        return

    warned = 0
    for key in sorted(set(base) | set(new)):
        b, n = base.get(key), new.get(key)
        if key.endswith("provenance"):
            continue
        if b is None or n is None:
            side = "baseline" if n is None else "regenerated"
            print(f"  note: {key} only in {side} report")
            continue
        if not isinstance(b, (int, float)) or not isinstance(n, (int, float)):
            continue
        if b == 0:
            if n != 0:
                print(f"  WARN {key}: baseline 0, now {n}")
                warned += 1
            continue
        dev = (n - b) / abs(b)
        marker = "WARN" if abs(dev) > tol else "  ok"
        if abs(dev) > tol:
            warned += 1
        print(f"  {marker} {key}: {b} -> {n} ({dev:+.1%})")

    if warned:
        print(
            f"bench_diff: {warned} headline(s) deviate more than "
            f"{tol:.0%} from the committed baseline (warn-only; update "
            f"BENCH_*.json deliberately if the change is intended)"
        )
    else:
        print("bench_diff: all shared headlines within tolerance")


if __name__ == "__main__":
    main()
