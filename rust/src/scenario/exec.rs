//! Scenario executor: materialize a [`ScenarioSpec`] into a live
//! [`Cluster`] + [`ChaosProfile`](crate::fabric::chaos::ChaosProfile)
//! on either runtime, drive its workload steps, and check the
//! declarative assertions against engine telemetry.
//!
//! Assertion failures are **recorded**, not panicked: a spec whose
//! postconditions do not hold still produces a [`ScenarioReport`]
//! with a non-empty `failures` list, so `fabricctl run` can print
//! them and the fuzzer can shrink the spec that caused them.
//! Workload steps themselves still use the app harnesses' internal
//! integrity asserts (payload equality, protocol invariants) — a
//! panic there means the *engine* misbehaved, which the fuzzer
//! treats as a failure via `catch_unwind`.
//!
//! Determinism contract: on the DES runtime, `run_scenario` on equal
//! specs produces equal [`ScenarioReport::fingerprint`]s — the same
//! property the hand-written chaos harnesses pin, now available for
//! every spec the fuzzer can sample.

use std::rc::Rc;

use crate::apps::kvcache::{
    run_generic_kv_push, run_kv_fleet_on, run_kv_request_on, run_serving, Arrivals,
    PoissonArrivals, ServingConfig,
};
use crate::apps::moe::run_generic_dispatch_round;
use crate::apps::rlweights::run_generic_rank0_fanout;
use crate::engine::traits::{new_flag, Cluster, Cx, Notify, OnRecv, RuntimeKind, TransferEngine};
use crate::scenario::spec::{AssertionSpec, ScenarioSpec, WorkloadStep};
use crate::sim::Summary;
use crate::util::err::Result;
use crate::util::json::Json;
use crate::util::telemetry::EngineSnapshot;

/// How to run a scenario.
#[derive(Debug, Clone, Copy)]
pub struct RunOptions {
    /// Which runtime backs the cluster.
    pub runtime: RuntimeKind,
    /// Clamp workload magnitudes to CI-sized budgets (used by the
    /// `scenario-sweep` CI job and the fuzzer's inner runs).
    pub quick: bool,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            runtime: RuntimeKind::Des,
            quick: false,
        }
    }
}

/// Everything a scenario run observed, plus the assertion verdicts.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    /// Scenario name (from the spec).
    pub name: String,
    /// Runtime the run executed on.
    pub runtime: RuntimeKind,
    /// Requests served to completion (kv_fleet + serving steps).
    pub served: u64,
    /// Supervisor re-dispatches across kv_fleet steps.
    pub redispatched: u64,
    /// Prefillers alive at drain of the last kv_fleet step (0 when no
    /// fleet step ran).
    pub live_prefillers: u64,
    /// True when every KV step returned its pages to the pool (and
    /// trivially true when no KV step ran).
    pub no_lost_pages: bool,
    /// TTFT distribution of the last serving step, if any.
    pub ttft: Option<Summary>,
    /// `transport_errors()` per engine, post-settle.
    pub transport_errors: Vec<u64>,
    /// `nic_health_mask(0)` per engine, post-settle.
    pub nic_masks: Vec<u64>,
    /// Full telemetry snapshot per engine, post-settle.
    pub snapshots: Vec<EngineSnapshot>,
    /// Human-readable assertion/step failures; empty means the
    /// scenario passed.
    pub failures: Vec<String>,
    /// Virtual end-of-run time (fabric clock, ns).
    pub end_ns: u64,
}

fn mix(h: &mut u64, v: u64) {
    *h ^= v;
    *h = h.wrapping_mul(0x100_0000_01b3);
}

impl ScenarioReport {
    /// True when every declarative assertion (and every step) held.
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// FNV-style digest of everything observable: two same-seed DES
    /// runs of the same spec must agree on this exactly (the fuzzer's
    /// determinism check).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        mix(&mut h, self.served);
        mix(&mut h, self.redispatched);
        mix(&mut h, self.live_prefillers);
        mix(&mut h, self.no_lost_pages as u64);
        mix(&mut h, self.end_ns);
        if let Some(t) = &self.ttft {
            mix(&mut h, t.n as u64);
            mix(&mut h, t.p50);
            mix(&mut h, t.p99);
        }
        let lanes = self
            .transport_errors
            .iter()
            .zip(&self.nic_masks)
            .zip(&self.snapshots);
        for ((te, mask), s) in lanes {
            mix(&mut h, *te);
            mix(&mut h, *mask);
            mix(&mut h, s.imm_bumps);
            mix(&mut h, s.wr_err_total);
            mix(&mut h, s.rejected_all_down);
            mix(&mut h, s.total_wrs());
            mix(&mut h, s.total_bytes());
        }
        for f in &self.failures {
            for b in f.as_bytes() {
                mix(&mut h, *b as u64);
            }
        }
        h
    }

    /// Machine-readable report (what `fabricctl run --json` prints).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::from(self.name.as_str()));
        m.insert(
            "runtime".to_string(),
            Json::from(match self.runtime {
                RuntimeKind::Des => "des",
                RuntimeKind::Threaded => "threaded",
            }),
        );
        m.insert("passed".to_string(), Json::Bool(self.passed()));
        m.insert("served".to_string(), Json::from(self.served));
        m.insert("redispatched".to_string(), Json::from(self.redispatched));
        m.insert("no_lost_pages".to_string(), Json::Bool(self.no_lost_pages));
        m.insert(
            "transport_errors".to_string(),
            Json::Arr(self.transport_errors.iter().map(|&e| Json::from(e)).collect()),
        );
        m.insert(
            "nic_masks".to_string(),
            Json::Arr(self.nic_masks.iter().map(|&e| Json::from(e)).collect()),
        );
        m.insert(
            "failures".to_string(),
            Json::Arr(self.failures.iter().map(|f| Json::from(f.as_str())).collect()),
        );
        m.insert("end_ns".to_string(), Json::from(self.end_ns));
        m.insert(
            "ttft".to_string(),
            match &self.ttft {
                Some(t) => t.headline_json(),
                None => Json::Null,
            },
        );
        m.insert(
            "fingerprint".to_string(),
            Json::from(format!("{:016x}", self.fingerprint())),
        );
        Json::Obj(m)
    }
}

/// Clamp a spec's workload magnitudes to CI-sized budgets. Topology,
/// seeds, chaos schedule and assertions are untouched — only the
/// *volume* knobs shrink, so a `--quick` run exercises the same
/// code paths in bounded virtual time.
pub fn clamp_quick(spec: &ScenarioSpec) -> ScenarioSpec {
    let mut s = spec.clone();
    for step in &mut s.workload {
        match step {
            WorkloadStep::PostRecvs { len, count, .. } => {
                *len = (*len).min(4096);
                *count = (*count).min(16);
            }
            WorkloadStep::Write { bytes, .. } => *bytes = (*bytes).min(1 << 20),
            WorkloadStep::KvPush {
                pages, page_len, ..
            } => {
                *pages = (*pages).min(8);
                *page_len = (*page_len).min(1 << 16);
            }
            WorkloadStep::KvRequest { seq, .. } => *seq = (*seq).min(128),
            WorkloadStep::KvFleet { requests } => *requests = (*requests).min(4),
            WorkloadStep::MoeDispatch {
                tokens_per_peer,
                token_bytes,
            } => {
                *tokens_per_peer = (*tokens_per_peer).min(4);
                *token_bytes = (*token_bytes).min(4096);
            }
            WorkloadStep::RlFanout { bytes } => *bytes = (*bytes).min(1 << 20),
            WorkloadStep::Serving { requests, seqs, .. } => {
                *requests = (*requests).min(8);
                for q in seqs.iter_mut() {
                    *q = (*q).min(1024);
                }
            }
        }
    }
    s
}

/// Materialize and run one scenario. `Err` means the spec could not
/// be *run* (invalid references, unknown profile); assertion failures
/// are reported in [`ScenarioReport::failures`] instead.
pub fn run_scenario(spec: &ScenarioSpec, opts: &RunOptions) -> Result<ScenarioReport> {
    spec.validate()?;
    let spec = if opts.quick {
        clamp_quick(spec)
    } else {
        spec.clone()
    };
    let t = &spec.topology;
    let gpu_profile = t.gpu()?;
    let mut cluster = Cluster::new_with(
        opts.runtime,
        t.nodes,
        t.gpus,
        t.nics_per_gpu,
        t.seed,
        t.nic()?,
        gpu_profile.clone(),
    );
    let rc_engines = cluster.engines_rc();
    let report = {
        let (mut cx, engines) = cluster.parts();
        let mut failures: Vec<String> = Vec::new();
        let mut served: u64 = 0;
        let mut redispatched: u64 = 0;
        let mut live_prefillers: u64 = 0;
        let mut no_lost_pages = true;
        let mut ttft: Option<Summary> = None;

        for g in &spec.gossip {
            let peers = g
                .peers
                .iter()
                .map(|&p| engines[p as usize].group_address(0))
                .collect();
            engines[g.from as usize].set_gossip_peers(0, peers);
        }
        // Chaos is fabric-wide: injecting on engine 0 arms every
        // engine's failover bookkeeping and schedules the shared
        // event timeline (same contract the hand harnesses rely on).
        if !spec.chaos.is_quiet() {
            engines[0].inject_chaos(&mut cx, &spec.chaos.profile());
        }

        for (i, step) in spec.workload.iter().enumerate() {
            match step {
                WorkloadStep::PostRecvs { node, len, count } => {
                    engines[*node as usize].submit_recvs(
                        &mut cx,
                        0,
                        *len as usize,
                        *count as usize,
                        OnRecv::handler(|_m| {}),
                    );
                }
                WorkloadStep::Write { src, dst, bytes } => {
                    let sender = engines[*src as usize];
                    let receiver = engines[*dst as usize];
                    let len = *bytes as usize;
                    let pat: Vec<u8> = (0..len).map(|b| (b * 3 % 251) as u8).collect();
                    let (src_mr, _) = sender.alloc_mr(0, len);
                    let (dst_h, dst_d) = receiver.alloc_mr(0, len);
                    src_mr.buf.write(0, &pat);
                    let done = new_flag();
                    match sender.submit_single_write(
                        &mut cx,
                        (&src_mr, 0),
                        *bytes,
                        (&dst_d, 0),
                        None,
                        Notify::Flag(done.clone()),
                    ) {
                        Ok(()) => {
                            cx.wait(&done);
                            cx.settle();
                            if dst_h.buf.to_vec() != pat {
                                failures.push(format!(
                                    "workload[{i}] write: payload mismatch after delivery"
                                ));
                            }
                        }
                        Err(e) => {
                            failures.push(format!("workload[{i}] write: rejected: {e}"));
                        }
                    }
                }
                WorkloadStep::KvPush {
                    prefiller,
                    decoder,
                    pages,
                    page_len,
                } => {
                    run_generic_kv_push(
                        &mut cx,
                        engines[*prefiller as usize],
                        engines[*decoder as usize],
                        *pages,
                        *page_len,
                    );
                }
                WorkloadStep::KvRequest {
                    prefiller,
                    decoder,
                    seq,
                } => {
                    let out = run_kv_request_on(
                        &mut cx,
                        rc_engines[*prefiller as usize].clone(),
                        rc_engines[*decoder as usize].clone(),
                        gpu_profile.clone(),
                        *seq,
                    );
                    no_lost_pages &= out.no_lost_pages;
                }
                WorkloadStep::KvFleet { requests } => {
                    let out = run_kv_fleet_on(
                        &mut cx,
                        &rc_engines,
                        gpu_profile.clone(),
                        *requests as usize,
                    );
                    served += out.served as u64;
                    redispatched += out.redispatched as u64;
                    live_prefillers = out.live_prefillers as u64;
                    no_lost_pages &= out.no_lost_pages;
                }
                WorkloadStep::MoeDispatch {
                    tokens_per_peer,
                    token_bytes,
                } => {
                    run_generic_dispatch_round(&mut cx, &engines, *tokens_per_peer, *token_bytes);
                }
                WorkloadStep::RlFanout { bytes } => {
                    run_generic_rank0_fanout(&mut cx, &engines, *bytes);
                }
                WorkloadStep::Serving {
                    requests,
                    rate_ns,
                    seqs,
                } => {
                    // Model-level sweep on its own DES scheduler —
                    // independent of the cluster fabric, seeded from
                    // the topology seed so it replays with the spec.
                    let cfg = ServingConfig::small(*requests as usize);
                    let arrivals = Arrivals::Poisson(PoissonArrivals::new(
                        t.seed,
                        *rate_ns,
                        seqs.clone(),
                    ));
                    let rep = run_serving(cfg, arrivals);
                    served += rep.completed;
                    ttft = Some(rep.ttft);
                }
            }
        }
        cx.settle();
        let end_ns = cx.now();

        let transport_errors: Vec<u64> = engines.iter().map(|e| e.transport_errors()).collect();
        let nic_masks: Vec<u64> = engines.iter().map(|e| e.nic_health_mask(0)).collect();
        let snapshots: Vec<EngineSnapshot> = engines.iter().map(|e| e.telemetry()).collect();

        for (i, a) in spec.assertions.iter().enumerate() {
            check_assertion(
                a,
                i,
                &engines,
                &transport_errors,
                &nic_masks,
                &snapshots,
                served,
                redispatched,
                no_lost_pages,
                &ttft,
                &mut failures,
            );
        }

        ScenarioReport {
            name: spec.name.clone(),
            runtime: opts.runtime,
            served,
            redispatched,
            live_prefillers,
            no_lost_pages,
            ttft,
            transport_errors,
            nic_masks,
            snapshots,
            failures,
            end_ns,
        }
    };
    cluster.shutdown();
    Ok(report)
}

#[allow(clippy::too_many_arguments)]
fn check_assertion(
    a: &AssertionSpec,
    i: usize,
    engines: &[&dyn TransferEngine],
    transport_errors: &[u64],
    nic_masks: &[u64],
    snapshots: &[EngineSnapshot],
    served: u64,
    redispatched: u64,
    no_lost_pages: bool,
    ttft: &Option<Summary>,
    failures: &mut Vec<String>,
) {
    let mut fail = |msg: String| failures.push(format!("assertions[{i}]: {msg}"));
    match a {
        AssertionSpec::TransportErrorsMax { node, value } => {
            let got = transport_errors[*node as usize];
            if got > *value {
                fail(format!("transport_errors of node {node} is {got}, want <= {value}"));
            }
        }
        AssertionSpec::TransportErrorsMin { node, value } => {
            let got = transport_errors[*node as usize];
            if got < *value {
                fail(format!("transport_errors of node {node} is {got}, want >= {value}"));
            }
        }
        AssertionSpec::NicMask { node, value } => {
            let got = nic_masks[*node as usize];
            if got != *value {
                fail(format!(
                    "nic_health_mask of node {node} is {got:#b}, want {value:#b}"
                ));
            }
        }
        AssertionSpec::LinkMask {
            node,
            toward,
            value,
        } => {
            let got = engines[*node as usize].link_health_mask(0, *toward);
            if got != *value {
                fail(format!(
                    "link_health_mask of node {node} toward {toward:?} is {got:#b}, want {value:#b}"
                ));
            }
        }
        AssertionSpec::ZeroLostPages => {
            if !no_lost_pages {
                fail("a KV step leaked pages from the decoder pool".to_string());
            }
        }
        AssertionSpec::Served { value } => {
            if served != *value {
                fail(format!("served {served} requests, want exactly {value}"));
            }
        }
        AssertionSpec::RedispatchedMin { value } => {
            if redispatched < *value {
                fail(format!("redispatched {redispatched}, want >= {value}"));
            }
        }
        AssertionSpec::RedispatchedMax { value } => {
            if redispatched > *value {
                fail(format!("redispatched {redispatched}, want <= {value}"));
            }
        }
        AssertionSpec::ImmTotalMin { node, value } => {
            let got = snapshots[*node as usize].imm_bumps;
            if got < *value {
                fail(format!("imm_bumps of node {node} is {got}, want >= {value}"));
            }
        }
        AssertionSpec::TtftP50MaxMs { value } => match ttft {
            Some(t) => {
                let got_ms = t.p50 as f64 / 1e6;
                if got_ms > *value {
                    fail(format!("TTFT p50 is {got_ms:.3} ms, want <= {value} ms"));
                }
            }
            None => fail("no serving step produced a TTFT distribution".to_string()),
        },
        AssertionSpec::TtftP99MaxMs { value } => match ttft {
            Some(t) => {
                let got_ms = t.p99 as f64 / 1e6;
                if got_ms > *value {
                    fail(format!("TTFT p99 is {got_ms:.3} ms, want <= {value} ms"));
                }
            }
            None => fail("no serving step produced a TTFT distribution".to_string()),
        },
        AssertionSpec::LedgerIdentities => {
            for (n, s) in snapshots.iter().enumerate() {
                if s.resubmits + s.error_outs != s.wr_err_total {
                    fail(format!(
                        "node {n}: resubmits({}) + error_outs({}) != wr_err_total({})",
                        s.resubmits, s.error_outs, s.wr_err_total
                    ));
                }
                if s.wr_err_link + s.wr_err_nic != s.wr_err_total {
                    fail(format!(
                        "node {n}: wr_err_link({}) + wr_err_nic({}) != wr_err_total({})",
                        s.wr_err_link, s.wr_err_nic, s.wr_err_total
                    ));
                }
                let te = transport_errors[n];
                if te != s.wr_err_total + s.rejected_all_down {
                    fail(format!(
                        "node {n}: transport_errors({te}) != wr_err_total({}) + rejected_all_down({})",
                        s.wr_err_total, s.rejected_all_down
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::{ChaosSpec, TopologySpec};

    fn quiet_write_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "exec-smoke".to_string(),
            topology: TopologySpec {
                nodes: 2,
                gpus: 1,
                nics_per_gpu: 2,
                seed: 0xE0E0,
                nic_profile: "efa".to_string(),
                gpu_profile: "h100".to_string(),
            },
            gossip: vec![],
            chaos: ChaosSpec::quiet(1),
            workload: vec![WorkloadStep::Write {
                src: 0,
                dst: 1,
                bytes: 1 << 16,
            }],
            assertions: vec![
                AssertionSpec::TransportErrorsMax { node: 0, value: 0 },
                AssertionSpec::LedgerIdentities,
            ],
        }
    }

    #[test]
    fn executor_runs_quiet_write_spec_clean() {
        let report = run_scenario(&quiet_write_spec(), &RunOptions::default()).unwrap();
        assert!(report.passed(), "failures: {:?}", report.failures);
        assert_eq!(report.transport_errors, vec![0, 0]);
        assert!(report.end_ns > 0);
    }

    #[test]
    fn executor_records_assertion_failures_instead_of_panicking() {
        let mut spec = quiet_write_spec();
        spec.assertions.push(AssertionSpec::TransportErrorsMin {
            node: 0,
            value: 1_000,
        });
        let report = run_scenario(&spec, &RunOptions::default()).unwrap();
        assert!(!report.passed());
        assert_eq!(report.failures.len(), 1);
        assert!(
            report.failures[0].contains("want >= 1000"),
            "{:?}",
            report.failures
        );
    }

    #[test]
    fn executor_is_deterministic_on_des() {
        let spec = quiet_write_spec();
        let a = run_scenario(&spec, &RunOptions::default()).unwrap();
        let b = run_scenario(&spec, &RunOptions::default()).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.end_ns, b.end_ns);
    }

    #[test]
    fn executor_runs_on_both_runtimes() {
        for runtime in [RuntimeKind::Des, RuntimeKind::Threaded] {
            let opts = RunOptions {
                runtime,
                quick: true,
            };
            let report = run_scenario(&quiet_write_spec(), &opts).unwrap();
            assert!(report.passed(), "{runtime:?}: {:?}", report.failures);
        }
    }

    #[test]
    fn quick_clamp_bounds_magnitudes_only() {
        let mut spec = quiet_write_spec();
        spec.workload = vec![
            WorkloadStep::Write {
                src: 0,
                dst: 1,
                bytes: 1 << 30,
            },
            WorkloadStep::Serving {
                requests: 10_000,
                rate_ns: 200_000,
                seqs: vec![8192],
            },
        ];
        let clamped = clamp_quick(&spec);
        assert_eq!(
            clamped.workload[0],
            WorkloadStep::Write {
                src: 0,
                dst: 1,
                bytes: 1 << 20
            }
        );
        assert_eq!(
            clamped.workload[1],
            WorkloadStep::Serving {
                requests: 8,
                rate_ns: 200_000,
                seqs: vec![1024]
            }
        );
        // Topology, chaos and assertions untouched.
        assert_eq!(clamped.topology, spec.topology);
        assert_eq!(clamped.chaos, spec.chaos);
        assert_eq!(clamped.assertions.len(), spec.assertions.len());
    }
}
