//! Sample statistics used by the bench harness: exact-percentile
//! histograms (store-all, fine at bench sample counts) and printable
//! summaries matching the paper's percentile columns.

/// A collection of samples with exact percentile queries.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<u64>,
    sorted: bool,
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram {
            samples: Vec::new(),
            sorted: true,
        }
    }

    /// Record one sample.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True if no samples recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_unstable();
            self.sorted = true;
        }
    }

    /// Percentile in `[0, 100]` (nearest-rank). Panics on empty.
    pub fn percentile(&mut self, p: f64) -> u64 {
        assert!(!self.samples.is_empty(), "percentile of empty histogram");
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.samples[rank.min(n) - 1]
    }

    /// The samples, sorted ascending. The chaos-invariance tests use
    /// this to compare whole distributions bit-for-bit (a perturbed
    /// run must produce the identical multiset of clock-independent
    /// outputs).
    pub fn sorted_samples(&mut self) -> &[u64] {
        self.ensure_sorted();
        &self.samples
    }

    /// Minimum sample.
    pub fn min(&mut self) -> u64 {
        self.ensure_sorted();
        self.samples[0]
    }

    /// Maximum sample.
    pub fn max(&mut self) -> u64 {
        self.ensure_sorted();
        *self.samples.last().unwrap()
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|&v| v as f64).sum::<f64>() / self.samples.len() as f64
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|&v| (v as f64 - m).powi(2))
            .sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }

    /// Build a frozen `Summary`.
    pub fn summary(&mut self) -> Summary {
        assert!(!self.samples.is_empty(), "summary of empty histogram");
        Summary {
            n: self.samples.len(),
            mean: self.mean(),
            stddev: self.stddev(),
            min: self.min(),
            p01: self.percentile(1.0),
            p25: self.percentile(25.0),
            p50: self.percentile(50.0),
            p75: self.percentile(75.0),
            p90: self.percentile(90.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            p999: self.percentile(99.9),
            max: self.max(),
        }
    }
}

/// Frozen percentile summary (all values in the sample's native unit,
/// ns for latencies).
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: u64,
    pub p01: u64,
    pub p25: u64,
    pub p50: u64,
    pub p75: u64,
    pub p90: u64,
    pub p95: u64,
    pub p99: u64,
    pub p999: u64,
    pub max: u64,
}

impl Summary {
    /// Format as the paper's latency-row style, converting ns → µs.
    pub fn us_row(&self) -> String {
        let us = |v: u64| v as f64 / 1000.0;
        format!(
            "{:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
            us(self.p50),
            us(self.p90),
            us(self.p99),
            us(self.p999),
            us(self.max),
        )
    }

    /// Bench-JSON headline object: tail percentiles (p50/p99/p99.9)
    /// plus count and max, all in ns. This is the schema the pinned
    /// `BENCH_*.json` baselines use for latency distributions —
    /// million-sample runs are what make the p99.9 column meaningful.
    pub fn headline_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("n".to_string(), Json::from(self.n as u64));
        m.insert("p50_ns".to_string(), Json::from(self.p50));
        m.insert("p99_ns".to_string(), Json::from(self.p99));
        m.insert("p999_ns".to_string(), Json::from(self.p999));
        m.insert("max_ns".to_string(), Json::from(self.max));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(50.0), 50);
        assert_eq!(h.percentile(90.0), 90);
        assert_eq!(h.percentile(99.0), 99);
        assert_eq!(h.percentile(100.0), 100);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 100);
    }

    #[test]
    fn mean_and_stddev() {
        let mut h = Histogram::new();
        for v in [2u64, 4, 4, 4, 5, 5, 7, 9] {
            h.record(v);
        }
        assert!((h.mean() - 5.0).abs() < 1e-9);
        assert!((h.stddev() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn single_sample() {
        let mut h = Histogram::new();
        h.record(42);
        let s = h.summary();
        assert_eq!(s.p50, 42);
        assert_eq!(s.p999, 42);
        assert_eq!(s.n, 1);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn unsorted_input() {
        let mut h = Histogram::new();
        for v in [9u64, 1, 5, 3, 7] {
            h.record(v);
        }
        assert_eq!(h.percentile(50.0), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 9);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_percentile_panics() {
        Histogram::new().percentile(50.0);
    }

    #[test]
    fn headline_json_includes_tail() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let j = h.summary().headline_json();
        assert_eq!(j.get("n").unwrap().u64(), Some(1000));
        assert_eq!(j.get("p50_ns").unwrap().u64(), Some(500));
        assert_eq!(j.get("p99_ns").unwrap().u64(), Some(990));
        assert_eq!(j.get("p999_ns").unwrap().u64(), Some(999));
        assert_eq!(j.get("max_ns").unwrap().u64(), Some(1000));
    }
}
