//! Plain-text table rendering for the benchmark harness: every bench
//! prints its paper table/figure as aligned rows.

/// A simple left/right-aligned text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title (e.g. "Table 2. EFA and ConnectX-7 ...").
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn rowd(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                // First column left-aligned, the rest right-aligned.
                if i == 0 {
                    line.push_str(&format!("{:<w$}  ", c, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}  ", c, w = widths[i]));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + widths.len() * 2;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Test", &["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== Test =="));
        assert!(r.contains("longer"));
        // right-aligned numbers
        let lines: Vec<&str> = r.lines().collect();
        let a_line = lines.iter().find(|l| l.starts_with("a")).unwrap();
        assert!(a_line.ends_with(" 1"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn float_format() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(f(1.0, 3), "1.000");
    }
}
