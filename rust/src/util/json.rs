//! Minimal JSON parser and serializer (serde is unavailable offline;
//! the runtime needs to read `artifacts/manifest.json`, and the
//! benches emit machine-readable `BENCH_*.json` result files).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers
//! parse as f64. Strict: trailing garbage, unterminated strings and
//! bad escapes are errors, reported with 1-based line/column context
//! (scenario spec files are hand-edited; "offset 417" is useless in
//! an editor). Serialization is deterministic: object keys come out
//! in `BTreeMap` order, so the same value always renders the same
//! bytes (diffable bench baselines and committed scenario specs).

use std::collections::BTreeMap;

use crate::bail;
use crate::util::err::Result;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.pos(p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// String value.
    pub fn str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value as u64.
    pub fn u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => Some(*n as u64),
            _ => None,
        }
    }

    /// Numeric value as f64.
    pub fn f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Object map.
    pub fn obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize with `indent`-space indentation and a trailing
    /// newline — the format the committed `BENCH_*.json` baselines
    /// use, so regenerated files diff cleanly against them.
    pub fn to_pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(indent), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * d));
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Merge one top-level `section` into the JSON report at `path`
/// (read-modify-write): other sections are preserved, so several
/// bench binaries can share one `BENCH_*.json` file. Creates the file
/// (and an enclosing object) when missing; writes 2-space pretty form
/// so regenerated reports diff cleanly against committed baselines.
pub fn update_report(path: &str, section: &str, value: Json) -> Result<()> {
    let mut root = match std::fs::read_to_string(path) {
        Ok(s) => Json::parse(&s)?,
        Err(_) => Json::Obj(BTreeMap::new()),
    };
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(BTreeMap::new());
    }
    if let Json::Obj(m) = &mut root {
        m.insert(section.to_string(), value);
    }
    std::fs::write(path, root.to_pretty(2))?;
    Ok(())
}

/// Compact serialization (no whitespace). Round-trips through
/// [`Json::parse`]; integral numbers render without a fraction.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        f.write_str(&out)
    }
}

/// Render a number the way the parser reads it back: integers (the
/// common case — counts, nanoseconds) without a fraction, everything
/// else via f64 round-trip formatting.
fn fmt_num(n: f64) -> String {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        format!("{}", n as i64)
    } else if n.is_finite() {
        format!("{n}")
    } else {
        // JSON has no Inf/NaN; null is the conventional stand-in.
        "null".to_string()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building bench-report documents.
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    /// Human-readable position of byte offset `i`: 1-based line and
    /// (byte) column. Computed only on the error path by rescanning
    /// the prefix, so the happy path carries no bookkeeping.
    fn pos(&self, i: usize) -> String {
        let upto = &self.b[..i.min(self.b.len())];
        let line = 1 + upto.iter().filter(|&&c| c == b'\n').count();
        let col = 1 + upto.iter().rev().take_while(|&&c| c != b'\n').count();
        format!("line {line} col {col}")
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at {}, found {:?}",
                c as char,
                self.pos(self.i),
                self.peek().map(|x| x as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!(
                "unexpected {:?} at {}",
                other.map(|x| x as char),
                self.pos(self.i)
            ),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.pos(self.i))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => bail!("bad number {:?} at {}", s, self.pos(start)),
        }
    }

    fn string(&mut self) -> Result<String> {
        let start = self.i;
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string starting at {}", self.pos(start)),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape at {}", self.pos(self.i - 1));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!(
                            "bad escape {:?} at {}",
                            other.map(|x| x as char),
                            self.pos(self.i - 1)
                        ),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Consume one UTF-8 code point.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let s = std::str::from_utf8(&self.b[self.i..self.i + len])?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!(
                    "expected , or ] at {}, found {:?}",
                    self.pos(self.i),
                    other.map(|x| x as char)
                ),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!(
                    "expected , or }} at {}, found {:?}",
                    self.pos(self.i),
                    other.map(|x| x as char)
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "model": {"vocab": 512, "d_model": 128.0},
          "entries": {
            "decode": {"file": "decode.hlo.txt",
                       "inputs": [{"shape": [], "dtype": "int32"},
                                  {"shape": [4, 4, 160, 32], "dtype": "float32"}]}
          }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("model").unwrap().get("vocab").unwrap().u64(), Some(512));
        let e = j.get("entries").unwrap().get("decode").unwrap();
        assert_eq!(e.get("file").unwrap().str(), Some("decode.hlo.txt"));
        let shape = e.get("inputs").unwrap().items()[1].get("shape").unwrap();
        let dims: Vec<u64> = shape.items().iter().map(|d| d.u64().unwrap()).collect();
        assert_eq!(dims, vec![4, 4, 160, 32]);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\n\"bA""#).unwrap(),
            Json::Str("a\n\"bA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3],[]]").unwrap();
        assert_eq!(j.items().len(), 3);
        assert_eq!(j.items()[0].items()[1].u64(), Some(2));
        assert!(j.items()[2].items().is_empty());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo — ünïcode""#).unwrap();
        assert_eq!(j.str(), Some("héllo — ünïcode"));
    }

    #[test]
    fn serializer_round_trips() {
        let doc = r#"{"arr":[1,2.5,null],"esc":"a\"b\nc","n":-3,"ok":true}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.to_string(), doc, "keys sort, integers stay integral");
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(Json::parse(&j.to_pretty(2)).unwrap(), j);
    }

    #[test]
    fn pretty_is_stable_and_indented() {
        let mut m = BTreeMap::new();
        m.insert("b".to_string(), Json::Num(2.0));
        m.insert("a".to_string(), Json::Arr(vec![Json::Num(1.0)]));
        let j = Json::Obj(m);
        assert_eq!(j.to_pretty(2), "{\n  \"a\": [\n    1\n  ],\n  \"b\": 2\n}\n");
        assert_eq!(Json::Obj(BTreeMap::new()).to_pretty(2), "{}\n");
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    /// Every parse error names the line and (byte) column where the
    /// grammar broke — scenario specs are hand-edited multi-line
    /// files, so "offset 417" is not an acceptable diagnostic.
    #[test]
    fn errors_carry_line_and_column() {
        let cases: &[(&str, &str)] = &[
            // Missing ':' after the "b" key on line 3, column 7.
            ("{\n  \"a\": 1,\n  \"b\" 2\n}", "expected ':' at line 3 col 7"),
            // Trailing comma: value() hits ']' on line 2, column 4.
            ("[1,\n 2,]", "unexpected Some(']') at line 2 col 4"),
            // Two top-level values.
            ("1 2", "trailing bytes at line 1 col 3"),
            // The opening quote is the useful anchor for runaways.
            ("{\n \"a\": \"runs off", "unterminated string starting at line 2 col 7"),
            ("nul", "bad literal at line 1 col 1"),
            ("[1, 1e+]", "bad number \"1e+\" at line 1 col 5"),
            ("\"a\\q\"", "bad escape Some('q') at line 1 col 3"),
            ("\"a\\u00", "truncated \\u escape at line 1 col 3"),
            ("[1 2]", "expected , or ] at line 1 col 4"),
            ("{\"a\": 1 \"b\": 2}", "expected , or } at line 1 col 9"),
        ];
        for (doc, want) in cases {
            let err = Json::parse(doc).unwrap_err().to_string();
            assert!(
                err.contains(want),
                "doc {doc:?}: error {err:?} should contain {want:?}"
            );
        }
    }

    /// Random `Json` value generator for the round-trip property:
    /// finite numbers only (non-finite serialize as null by design),
    /// strings stressing the escape table and multi-byte UTF-8.
    fn gen_value(rng: &mut crate::sim::Rng, depth: u32) -> Json {
        let choices = if depth == 0 { 4 } else { 6 };
        match rng.below(choices) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => match rng.below(3) {
                // Integral (the common case: counts, nanoseconds) …
                0 => Json::Num(rng.below(1 << 40) as f64 - (1 << 39) as f64),
                // … small fractions, and full-range finite doubles
                // (f64 Display is shortest-round-trip, so these must
                // survive parse ∘ serialize bit-exactly too).
                1 => Json::Num(rng.below(1_000_000) as f64 / 1024.0),
                _ => Json::Num(f64::from_bits(rng.next_u64() >> 2)),
            },
            3 => {
                let alphabet: &[char] =
                    &['a', 'Z', '0', ' ', '"', '\\', '\n', '\t', '\u{1}', 'é', '—', '日'];
                let n = rng.below(12) as usize;
                Json::Str(
                    (0..n)
                        .map(|_| alphabet[rng.below(alphabet.len() as u64) as usize])
                        .collect(),
                )
            }
            4 => {
                let n = rng.below(4) as usize;
                Json::Arr((0..n).map(|_| gen_value(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.below(4) as usize;
                Json::Obj(
                    (0..n)
                        .map(|i| {
                            let key = format!("k{}", rng.below(10) * 10 + i as u64);
                            (key, gen_value(rng, depth - 1))
                        })
                        .collect(),
                )
            }
        }
    }

    /// parse ∘ serialize ≡ id, for both the compact and the pretty
    /// form, over randomly generated documents.
    #[test]
    fn prop_parse_serialize_round_trips() {
        crate::util::prop::check(
            "json parse∘serialize ≡ id",
            |rng| gen_value(rng, 3),
            |j| {
                let compact = Json::parse(&j.to_string())
                    .map_err(|e| format!("compact reparse failed: {e}"))?;
                if &compact != j {
                    return Err(format!("compact round trip drifted: {compact:?}"));
                }
                let pretty = Json::parse(&j.to_pretty(2))
                    .map_err(|e| format!("pretty reparse failed: {e}"))?;
                if &pretty != j {
                    return Err(format!("pretty round trip drifted: {pretty:?}"));
                }
                Ok(())
            },
        );
    }
}
