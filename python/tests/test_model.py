"""L2 model invariants: shapes, prefill/decode consistency (the
correctness core of disaggregated serving), routing behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(n_layers=2, max_seq=48, vocab=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG)


def test_param_shapes_and_count(params):
    flat = M.flat_params(params)
    names = [n for n, _ in flat]
    assert names[0] == "embed"
    assert f"layers.{CFG.n_layers - 1}.w2" in names
    total = sum(int(np.prod(a.shape)) for _, a in flat)
    # param_count counts matmul params only (no LN vectors).
    assert abs(total - CFG.param_count()) <= CFG.n_layers * 2 * CFG.d_model


def test_prefill_shapes(params):
    toks = jnp.arange(10, dtype=jnp.int32) % CFG.vocab
    logits, k, v = M.prefill(CFG, params, toks)
    assert logits.shape == (CFG.vocab,)
    assert k.shape == (CFG.n_layers, CFG.n_heads, 10, CFG.d_head)
    assert v.shape == k.shape
    assert bool(jnp.isfinite(logits).all())


def test_decode_matches_prefill_reference(params):
    """decode_step(cache(prefill(t)), t_next) == prefill(t + t_next):
    the invariant that makes KvCache transfer sound."""
    toks = jnp.asarray([5, 3, 8, 13, 21, 34], jnp.int32) % CFG.vocab
    logits, kc, vc = M.prefill(CFG, params, toks)
    nxt = jnp.argmax(logits).astype(jnp.int32)

    full = jnp.concatenate([toks, nxt[None]])
    want, _, _ = M.prefill(CFG, params, full)

    pad = CFG.max_seq - toks.shape[0]
    kcp = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vcp = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    got, k2, v2 = M.decode_step(
        CFG, params, nxt, kcp, vcp, jnp.asarray(toks.shape[0], jnp.int32)
    )
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
    # Cache updated in place at the right position.
    np.testing.assert_allclose(
        k2[:, :, toks.shape[0], :].sum(), k2[:, :, toks.shape[0], :].sum()
    )
    assert bool((k2[:, :, toks.shape[0] + 1 :, :] == 0).all())


def test_greedy_generation_deterministic(params):
    toks = jnp.asarray([1, 2, 3], jnp.int32)
    a = M.greedy_generate(CFG, params, toks, 5)
    b = M.greedy_generate(CFG, params, toks, 5)
    assert a == b
    assert all(0 <= t < CFG.vocab for t in a)


def test_cache_garbage_beyond_pos_is_ignored(params):
    toks = jnp.asarray([7, 9, 11], jnp.int32)
    _, kc, vc = M.prefill(CFG, params, toks)
    pad = CFG.max_seq - 3
    kcp = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vcp = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
    tok = jnp.asarray(4, jnp.int32)
    base, _, _ = M.decode_step(CFG, params, tok, kcp, vcp, jnp.asarray(3, jnp.int32))
    # Poison the padding (positions > pos): result must be unchanged.
    kcp2 = kcp.at[:, :, 5:, :].set(1e5)
    vcp2 = vcp.at[:, :, 5:, :].set(-1e5)
    got, _, _ = M.decode_step(CFG, params, tok, kcp2, vcp2, jnp.asarray(3, jnp.int32))
    np.testing.assert_allclose(base, got, rtol=1e-5, atol=1e-5)


def test_moe_routing_uses_top_k(params):
    """Gate mass concentrates on exactly top_k experts per token."""
    x = jax.random.normal(jax.random.PRNGKey(3), (16, CFG.d_model), jnp.float32)
    layer = params["layers"][0]
    gates = jax.nn.softmax(x @ layer["router"], axis=-1)
    thresh = jnp.sort(gates, axis=-1)[:, CFG.n_experts - CFG.top_k][:, None]
    mask = gates >= thresh
    counts = mask.sum(-1)
    assert bool((counts >= CFG.top_k).all())
    # moe_block output is finite and shaped.
    y = M.moe_block(CFG, params, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_kv_bytes_accounting():
    assert CFG.kv_bytes_per_token_layer() == 2 * CFG.d_model * 4
