//! Rank0 gather-broadcast baseline (paper §5.1, Fig 4 left).
//!
//! Existing RL frameworks form one collective world over training and
//! inference GPUs: weights are gathered to training Rank0, then
//! broadcast to each inference sub-group's Rank0 — every byte of the
//! model squeezes through Rank0's NIC (twice), which is why weight
//! sync takes tens to hundreds of seconds at trillion-parameter
//! scale.

use std::cell::Cell;
use std::rc::Rc;

use crate::collectives::CollectiveWorld;
use crate::engine::api::TemplatedDst;
use crate::engine::des_engine::Engine;
use crate::engine::traits::{expect_flag, Cluster, Cx, Notify, RuntimeKind, TransferEngine};
use crate::fabric::profile::{GpuProfile, NicProfile};
use crate::sim::time::MS;

use super::spec::RlModelSpec;

/// Result of the baseline run.
#[derive(Debug, Clone, Copy)]
pub struct BaselineReport {
    pub gather_ms: f64,
    pub broadcast_ms: f64,
    pub total_ms: f64,
}

/// Run the gather→broadcast weight sync for `spec` and report wall
/// times. `world_scale` shrinks the simulated world (ranks) while
/// keeping total bytes — the bottleneck is Rank0's NIC, so the time
/// is world-size-insensitive (which this models faithfully).
pub fn run_rank0_broadcast(spec: &RlModelSpec, nic: NicProfile, world_scale: u32) -> BaselineReport {
    let t_ranks = (spec.t_ranks / world_scale).max(2) as usize;
    let r_groups = (spec.r_ranks / world_scale).max(2) as usize;

    // The collectives model needs the DES fabric's timing; build the
    // cluster through the shared harness and borrow its simulator.
    let n_nodes = (t_ranks + r_groups) as u16;
    let mut cluster = Cluster::new_with(
        RuntimeKind::Des,
        n_nodes,
        1,
        1,
        0xBA5E,
        nic,
        GpuProfile::h200(),
    );
    let ranks: Vec<(Engine, u8)> = (0..n_nodes as usize)
        .map(|n| (cluster.des_engine(n).expect("DES cluster"), 0u8))
        .collect();
    let (mut cx, _) = cluster.parts();
    let sim = cx.sim();

    // Training world: gather bf16 shards to rank0.
    let total_bf16 = spec.total_params * 2;
    let shard = total_bf16 / t_ranks as u64;
    let region = 48usize << 30;
    let t_world = CollectiveWorld::new(ranks[..t_ranks].to_vec(), region);

    let gather_done = Rc::new(Cell::new(0u64));
    let gd = gather_done.clone();
    t_world.gather(sim, 0, shard, move |_s, t| gd.set(t));
    sim.run();
    let gather_ns = gather_done.get();

    // Broadcast the full (quantized fp8) model from training rank0 to
    // every inference sub-group rank0, ring-pipelined.
    let mut bcast_ranks = vec![ranks[0].clone()];
    bcast_ranks.extend_from_slice(&ranks[t_ranks..t_ranks + r_groups]);
    let b_world = CollectiveWorld::new(bcast_ranks, region);
    let bcast_done = Rc::new(Cell::new(0u64));
    let bd = bcast_done.clone();
    let total_fp8 = spec.total_params;
    b_world.broadcast_ring(sim, 0, total_fp8, 8 << 20, move |_s, t| bd.set(t));
    sim.run();
    let bcast_ns = bcast_done.get() - gather_ns;

    BaselineReport {
        gather_ms: gather_ns as f64 / MS as f64,
        broadcast_ms: bcast_ns as f64 / MS as f64,
        total_ms: bcast_done.get() as f64 / MS as f64,
    }
}

/// Runtime-agnostic rank0 fan-out (the baseline's broadcast leg as a
/// pure transfer protocol): rank0 writes `bytes` of weights to every
/// peer with a WRITEIMM, each peer gates on `expect_imm_count(_, 1)`
/// — runs on whichever runtime backs `cx`, unlike the timing-bound
/// [`run_rank0_broadcast`] which needs the DES collectives model.
/// The fan-out set is a long-lived peer group, so the writes run on
/// the §3.5 templated path (peer regions bound once) — and the whole
/// fan-out is ONE batched submission: a single engine crossing routes
/// all peers in one pass.
pub fn run_generic_rank0_fanout(cx: &mut Cx, engines: &[&dyn TransferEngine], bytes: u64) {
    assert!(engines.len() >= 2);
    const IMM_WEIGHTS: u32 = 0x510;
    let rank0 = engines[0];
    let (src, _) = rank0.alloc_mr(0, bytes as usize);
    let fill: Vec<u8> = (0..bytes).map(|i| (i % 251) as u8).collect();
    src.buf.write(0, &fill);

    let mut flags = Vec::new();
    let mut regions = Vec::new();
    for peer in &engines[1..] {
        let (h, d) = peer.alloc_mr(0, bytes as usize);
        flags.push(expect_flag(*peer, cx, 0, IMM_WEIGHTS, 1));
        regions.push((h, d));
    }
    let group = rank0.add_peer_group(engines[1..].iter().map(|e| e.main_address()).collect());
    let descs: Vec<_> = regions.iter().map(|(_, d)| d.clone()).collect();
    rank0
        .bind_peer_group_mrs(0, group, &descs)
        .expect("weight region bind");
    let dsts: Vec<TemplatedDst> = (0..regions.len())
        .map(|peer| TemplatedDst { peer, len: bytes, src: 0, dst: 0 })
        .collect();
    rank0
        .submit_batch_templated(cx, &src, group, &dsts, Some(IMM_WEIGHTS), Notify::Noop)
        .expect("batched weight fan-out");
    cx.wait_all(&flags);
    for (i, (h, _)) in regions.iter().enumerate() {
        assert_eq!(h.buf.to_vec(), fill, "peer {i} weight payload corrupted");
    }
    assert!(rank0.remove_peer_group(group), "group registered above");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::traits::run_on_both;

    #[test]
    fn generic_rank0_fanout_runs_on_both_runtimes() {
        run_on_both(4, 1, 1, 0xBA5E, |cx, engines| {
            run_generic_rank0_fanout(cx, engines, 32 * 1024);
        });
    }

    #[test]
    fn baseline_is_nic_bound_at_rank0() {
        let spec = RlModelSpec {
            total_params: 10_000_000_000, // 10B for test speed
            ..RlModelSpec::kimi_k2_1t()
        };
        let r = run_rank0_broadcast(&spec, NicProfile::connectx7(), 16);
        // Gather: 20 GB bf16 through one 400 Gbps NIC ≥ 400 ms.
        assert!(r.gather_ms > 350.0, "{r:?}");
        // Broadcast: 10 GB fp8 ≥ 200 ms.
        assert!(r.broadcast_ms > 180.0, "{r:?}");
        assert!(r.total_ms >= r.gather_ms + r.broadcast_ms - 1.0);
    }
}
