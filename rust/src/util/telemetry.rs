//! Zero-dependency engine telemetry shared by BOTH runtimes: a
//! counter/gauge/histogram registry ([`EngineMetrics`]), an immutable
//! point-in-time view of it ([`EngineSnapshot`]), and a bounded ring
//! of submission trace spans ([`TraceRing`] of [`TraceEvent`])
//! exportable as chrome://tracing JSON ([`chrome_trace_json`]).
//!
//! Design constraints, in order:
//!
//! * **Allocation-free on the steady-state hot path.** Every counter
//!   is a fixed field of a flat struct; per-lane accounting is a
//!   fixed-size array indexed by NIC lane; the trace ring reaches its
//!   capacity once and then recycles slots. No map lookups, no string
//!   keys, no boxing per event.
//! * **One generic registry, two cell types.** The DES runtime is
//!   single-threaded behind `Rc<RefCell<..>>`, so its counters are
//!   plain [`Cell`]s ([`PlainCell`]). The threaded runtime bumps
//!   counters from the submit thread and per-GPU workers
//!   concurrently, so its cells are cache-line-padded relaxed
//!   atomics ([`PaddedAtomic`]) — one counter per line, no false
//!   sharing with its neighbors. [`EngineMetrics`] is generic over
//!   [`Cell64`] so both runtimes share one field list and one
//!   snapshot path, and the two can never drift apart.
//! * **Error accounting is NOT optional.** `set_enabled(false)`
//!   (surfaced as `set_telemetry(false)` on the engines) turns off
//!   the hot-path instrumentation — submission-kind counters, wire
//!   counters, imm/recv/latency accounting, trace capture — but the
//!   error ledger (`wr_err_*`, `rejected_all_down`, `resubmits`,
//!   `error_outs`) always counts: `transport_errors()` is derived
//!   from it and is part of the failover contract
//!   (`docs/ARCHITECTURE.md`, "The failover/gossip contract").
//!
//! Accounting identities the engines maintain (pinned by
//! `tests/telemetry_accounting.rs` on both runtimes):
//!
//! * `transport_errors() == wr_err_total + rejected_all_down`;
//! * every WrError CQE resolves to exactly one of `resubmits` /
//!   `error_outs`, so `resubmits + error_outs == wr_err_total`;
//! * every WrError CQE is attributed, so
//!   `wr_err_link + wr_err_nic == wr_err_total` (`wr_err_remote` is
//!   an *additional* conclusion drawn on top of link evidence, not a
//!   third bucket) — fabric-lint rule R8 statically enforces that
//!   every `CqeKind::WrError` handling path reaches an attribution
//!   increment;
//! * on a clean (chaos-free) run, `sum(lane_bytes) ==` total payload
//!   bytes of every write-family submission, and batched vs looped
//!   submission of the same workload produce identical
//!   [`EngineSnapshot::wire_footprint`]s on same-seed DES clusters.

use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Per-lane accounting width. Lanes at index `>= MAX_LANES` (no
/// shipped profile has them; the paper testbeds top out at 4) lump
/// into the last slot rather than growing the registry.
pub const MAX_LANES: usize = 8;

/// Submit→retire latency histogram width: power-of-two microsecond
/// buckets, `bucket b` covering `[2^b, 2^(b+1))` µs (bucket 0 is
/// `< 2` µs, the last bucket is open-ended).
pub const HIST_BUCKETS: usize = 16;

/// Sentinel trace sequence for untraced work (SENDs, runs with
/// telemetry disabled): [`TraceRing::close`] ignores it.
pub const NO_TRACE: u64 = u64::MAX;

/// Default capacity of an engine's trace ring: big enough to hold
/// every span of the shipped benches/scenarios, small enough
/// (~100 B/span) that a thousand-engine DES cluster stays cheap.
pub const DEFAULT_TRACE_CAP: usize = 4096;

/// One monotonically-growing 64-bit telemetry cell. The two impls
/// pick the cheapest primitive their runtime allows; both are
/// interior-mutable so the registry is bumped through `&self` from
/// hot paths that never take `&mut`.
pub trait Cell64: Default {
    /// Add `v` (relaxed on the atomic impl).
    fn add(&self, v: u64);
    /// Overwrite with `v` (gauges, the enable flag).
    fn set(&self, v: u64);
    /// Raise to `v` if `v` is larger (high-water gauges).
    fn set_max(&self, v: u64);
    /// Current value. Relaxed on the atomic impl: exact once the
    /// reader synchronizes with the writers (join/settle/lock), a
    /// monotonic lower bound while they are still running.
    fn get(&self) -> u64;
}

/// Single-threaded cell for the DES runtime: a plain [`Cell`], one
/// untyped load/store per bump.
#[derive(Default)]
pub struct PlainCell(Cell<u64>);

impl Cell64 for PlainCell {
    fn add(&self, v: u64) {
        self.0.set(self.0.get().wrapping_add(v));
    }
    fn set(&self, v: u64) {
        self.0.set(v);
    }
    fn set_max(&self, v: u64) {
        if v > self.0.get() {
            self.0.set(v);
        }
    }
    fn get(&self) -> u64 {
        self.0.get()
    }
}

/// Cache-line-padded relaxed atomic for the threaded runtime: the
/// alignment gives every counter its own line so submit-thread and
/// worker-thread bumps never false-share (same idiom as the padded
/// rotation shards in `engine/threaded.rs`).
#[derive(Default)]
#[repr(align(64))]
pub struct PaddedAtomic(AtomicU64);

impl Cell64 for PaddedAtomic {
    fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Which engine entry point a submission came through — the write
/// family only (SEND/RECV are control-plane and stay untraced).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitKind {
    /// `submit_single_write`.
    Single,
    /// `submit_paged_writes`.
    Paged,
    /// `submit_scatter`.
    Scatter,
    /// `submit_barrier`.
    Barrier,
    /// `submit_write_batch`.
    Batch,
    /// `submit_single_write_templated`.
    SingleTpl,
    /// `submit_paged_writes_templated`.
    PagedTpl,
    /// `submit_scatter_templated`.
    ScatterTpl,
    /// `submit_barrier_templated`.
    BarrierTpl,
    /// `submit_batch_templated`.
    BatchTpl,
}

impl SubmitKind {
    /// Stable label used as the chrome-trace event name.
    pub fn as_str(self) -> &'static str {
        match self {
            SubmitKind::Single => "single",
            SubmitKind::Paged => "paged",
            SubmitKind::Scatter => "scatter",
            SubmitKind::Barrier => "barrier",
            SubmitKind::Batch => "batch",
            SubmitKind::SingleTpl => "single_tpl",
            SubmitKind::PagedTpl => "paged_tpl",
            SubmitKind::ScatterTpl => "scatter_tpl",
            SubmitKind::BarrierTpl => "barrier_tpl",
            SubmitKind::BatchTpl => "batch_tpl",
        }
    }
}

/// Lifecycle state of a trace span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOutcome {
    /// WRs posted; completion not (yet) observed.
    Posted,
    /// Every WR of the transfer completed.
    Retired,
    /// The transfer's `on_done` fired on the error path (error-out or
    /// exhausted retargeting).
    Failed,
}

impl TraceOutcome {
    /// Stable label for JSON export.
    pub fn as_str(self) -> &'static str {
        match self {
            TraceOutcome::Posted => "posted",
            TraceOutcome::Retired => "retired",
            TraceOutcome::Failed => "failed",
        }
    }
}

/// One submission span, runtime-neutral. Timestamps are nanoseconds
/// on the owning engine's clock — virtual sim time on DES, epoch-
/// relative monotonic time on the threaded runtime — so deltas are
/// meaningful, absolutes are only comparable within one engine.
///
/// The five submit-phase stamps mirror the paper's Table 8 pipeline
/// (API call → queue → worker pickup → first/last ibv_post): on the
/// threaded runtime the queue hop is not separately observable, so
/// `enqueued == submitted` there. `retired`/`outcome` close the span
/// when the transfer's last CQE lands (0/`Posted` while in flight or
/// if the ring recycled the slot first).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Entry point the submission came through.
    pub kind: SubmitKind,
    /// Lane (NIC index in the group) of the first WR; with sharding
    /// a span covers sibling lanes too — per-lane totals live in the
    /// counters, the trace keeps the primary egress.
    pub lane: u8,
    /// Work requests posted for this submission.
    pub wrs: u32,
    /// Total payload bytes across those WRs.
    pub bytes: u64,
    /// API entry timestamp.
    pub submitted: u64,
    /// Queued to the proxy/worker (DES model stage; `== submitted`
    /// on the threaded runtime).
    pub enqueued: u64,
    /// Worker picked the submission up.
    pub worker_start: u64,
    /// First WR posted to the NIC.
    pub first_post: u64,
    /// Last WR posted to the NIC.
    pub last_post: u64,
    /// Last CQE of the transfer (0 while in flight).
    pub retired: u64,
    /// Span lifecycle state.
    pub outcome: TraceOutcome,
}

/// Bounded ring of [`TraceEvent`]s. Push never allocates once the
/// ring has filled: the oldest span is recycled and counted in
/// [`TraceRing::dropped`] — bounded memory with an explicit loss
/// ledger instead of the old unbounded `Vec<SubmitTrace>` sink.
///
/// Spans are addressed by a monotonically increasing sequence number
/// so completion handlers can [`close`](TraceRing::close) a span
/// later without holding a reference into the ring; closing a span
/// the ring already recycled (or [`NO_TRACE`]) is a silent no-op.
#[derive(Default)]
pub struct TraceRing {
    buf: VecDeque<TraceEvent>,
    /// Sequence number the next push will get.
    next_seq: u64,
    dropped: u64,
    cap: usize,
}

impl TraceRing {
    /// Ring with room for `cap` spans (`cap == 0` drops everything).
    pub fn new(cap: usize) -> Self {
        TraceRing { buf: VecDeque::with_capacity(cap.min(DEFAULT_TRACE_CAP)), next_seq: 0, dropped: 0, cap }
    }

    /// Append a span, recycling the oldest if full; returns the new
    /// span's sequence number for a later [`close`](TraceRing::close).
    pub fn push(&mut self, e: TraceEvent) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        if self.cap == 0 {
            self.dropped += 1;
            return seq;
        }
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(e);
        seq
    }

    /// Close span `seq` with its retire timestamp and final outcome,
    /// returning the span's submit stamp so the caller can observe a
    /// submit→retire latency. No-op (returning `None`) for
    /// [`NO_TRACE`] and for spans the ring has recycled.
    pub fn close(&mut self, seq: u64, retired: u64, outcome: TraceOutcome) -> Option<u64> {
        if seq == NO_TRACE {
            return None;
        }
        let base = self.next_seq - self.buf.len() as u64;
        if seq < base || seq >= self.next_seq {
            return None;
        }
        let e = &mut self.buf[(seq - base) as usize];
        e.retired = retired;
        e.outcome = outcome;
        Some(e.submitted)
    }

    /// Remove and return every buffered span, oldest first. The drop
    /// counter and sequence numbering carry on across drains.
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        self.buf.drain(..).collect()
    }

    /// Spans recycled (or refused at `cap == 0`) so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Buffered span count.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Resize the ring, recycling oldest spans if it shrinks below
    /// the buffered count.
    pub fn set_capacity(&mut self, cap: usize) {
        self.cap = cap;
        while self.buf.len() > cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
    }
}

/// The engine-wide counter registry. One instance per engine;
/// per-lane arrays are indexed by NIC lane within a GPU group and
/// aggregate across groups. All fields are public so the engines'
/// hot paths bump them directly (and so fabric-lint R8 can see the
/// attribution tokens); everything else should read them through
/// [`EngineMetrics::snapshot`].
#[derive(Default)]
pub struct EngineMetrics<C: Cell64> {
    // -- submissions accepted by the routing core, by entry point --
    /// `submit_single_write` submissions routed.
    pub sub_single: C,
    /// `submit_paged_writes` submissions routed.
    pub sub_paged: C,
    /// `submit_scatter` submissions routed.
    pub sub_scatter: C,
    /// `submit_barrier` submissions routed.
    pub sub_barrier: C,
    /// `submit_write_batch` submissions routed.
    pub sub_batch: C,
    /// `submit_single_write_templated` submissions routed.
    pub sub_single_tpl: C,
    /// `submit_paged_writes_templated` submissions routed.
    pub sub_paged_tpl: C,
    /// `submit_scatter_templated` submissions routed.
    pub sub_scatter_tpl: C,
    /// `submit_barrier_templated` submissions routed.
    pub sub_barrier_tpl: C,
    /// `submit_batch_templated` submissions routed.
    pub sub_batch_tpl: C,

    // -- wire traffic (write-family WRs actually posted) --
    /// WRs posted per lane, retries included.
    pub lane_wrs: [C; MAX_LANES],
    /// Payload bytes posted per lane, retries included.
    pub lane_bytes: [C; MAX_LANES],

    // -- error ledger: ALWAYS counted, never gated on `enabled` --
    /// WrError CQEs observed (one per failed WR attempt).
    pub wr_err_total: C,
    /// WrErrors attributed to a directed link (the WR carried a
    /// routable destination; the link was masked).
    pub wr_err_link: C,
    /// Remote-death conclusions drawn from link evidence (counted in
    /// addition to `wr_err_link` for the triggering WR).
    pub wr_err_remote: C,
    /// WrErrors with nothing to attribute beyond the egress NIC
    /// (unarmed WRs, SEND-path failures).
    pub wr_err_nic: C,
    /// Submissions rejected synchronously because every NIC of the
    /// group was masked out.
    pub rejected_all_down: C,
    /// Failed WRs transparently reposted on a surviving lane
    /// (`FailoverPolicy::Resubmit`).
    pub resubmits: C,
    /// Failed WRs surfaced to `on_done` as errors (ErrorOut policy or
    /// exhausted retargeting).
    pub error_outs: C,

    // -- remote-health gossip --
    /// Gossip SENDs posted toward peers.
    pub gossip_sent: C,
    /// Gossip messages intercepted off the RECV path.
    pub gossip_received: C,
    /// Gossip messages applied to the remote-health table.
    pub gossip_applied: C,

    // -- imm counter lifecycle --
    /// `expect_imm_count` expectations armed.
    pub imm_arms: C,
    /// WRITEIMM immediates delivered to an armed counter.
    pub imm_bumps: C,
    /// Expectations satisfied (counter reached target and retired).
    pub imm_retires: C,

    // -- recv pool --
    /// RECV buffers posted (initial posts + app posts + reposts).
    pub recv_posted: C,
    /// RECVs completed with a delivered message.
    pub recv_completed: C,
    /// High-water mark of outstanding RECV buffers.
    pub recv_pool_hwm: C,

    // -- MR registry --
    /// Per-NIC rkeys registered through the engine API.
    pub mr_regs: C,
    /// Rkeys deregistered. Double-dereg is a safe no-op at the fabric
    /// but still counts here, so `deregs` may exceed `regs`.
    pub mr_deregs: C,

    /// Submit→retire latency histogram, power-of-two µs buckets.
    pub lat_us_pow2: [C; HIST_BUCKETS],

    /// 1 while hot-path instrumentation is on (the default).
    enabled: C,
}

impl<C: Cell64> EngineMetrics<C> {
    /// Fresh registry with instrumentation enabled.
    pub fn new() -> Self {
        let m = Self::default();
        m.enabled.set(1);
        m
    }

    /// True while hot-path instrumentation is on.
    pub fn enabled(&self) -> bool {
        self.enabled.get() != 0
    }

    /// Toggle hot-path instrumentation. The error ledger is exempt —
    /// see the module docs.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.set(on as u64);
    }

    /// Count one accepted submission of `kind` (gated on `enabled`).
    pub fn submission(&self, kind: SubmitKind) {
        if !self.enabled() {
            return;
        }
        let c = match kind {
            SubmitKind::Single => &self.sub_single,
            SubmitKind::Paged => &self.sub_paged,
            SubmitKind::Scatter => &self.sub_scatter,
            SubmitKind::Barrier => &self.sub_barrier,
            SubmitKind::Batch => &self.sub_batch,
            SubmitKind::SingleTpl => &self.sub_single_tpl,
            SubmitKind::PagedTpl => &self.sub_paged_tpl,
            SubmitKind::ScatterTpl => &self.sub_scatter_tpl,
            SubmitKind::BarrierTpl => &self.sub_barrier_tpl,
            SubmitKind::BatchTpl => &self.sub_batch_tpl,
        };
        c.add(1);
    }

    /// Account `wrs` posted WRs carrying `bytes` payload on `lane`
    /// (gated on `enabled`; lanes past the array lump into the last
    /// slot).
    pub fn wire(&self, lane: usize, wrs: u64, bytes: u64) {
        if !self.enabled() {
            return;
        }
        let l = lane.min(MAX_LANES - 1);
        self.lane_wrs[l].add(wrs);
        self.lane_bytes[l].add(bytes);
    }

    /// Bucket a submit→retire latency (gated on `enabled`).
    pub fn observe_latency(&self, ns: u64) {
        if !self.enabled() {
            return;
        }
        self.lat_us_pow2[latency_bucket(ns)].add(1);
    }

    /// Account `n` freshly posted RECV buffers and refresh the
    /// outstanding high-water mark (gated on `enabled`).
    pub fn recv_posts(&self, n: u64) {
        if !self.enabled() {
            return;
        }
        self.recv_posted.add(n);
        let outstanding = self.recv_posted.get().saturating_sub(self.recv_completed.get());
        self.recv_pool_hwm.set_max(outstanding);
    }

    /// `wr_err_total + rejected_all_down` — the failover contract's
    /// single error counter, now derived instead of stored.
    pub fn transport_errors(&self) -> u64 {
        self.wr_err_total.get() + self.rejected_all_down.get()
    }

    /// Materialize a point-in-time copy of every counter.
    pub fn snapshot(&self) -> EngineSnapshot {
        let lanes = |a: &[C; MAX_LANES]| {
            let mut out = [0u64; MAX_LANES];
            for (o, c) in out.iter_mut().zip(a.iter()) {
                *o = c.get();
            }
            out
        };
        let mut hist = [0u64; HIST_BUCKETS];
        for (o, c) in hist.iter_mut().zip(self.lat_us_pow2.iter()) {
            *o = c.get();
        }
        EngineSnapshot {
            sub_single: self.sub_single.get(),
            sub_paged: self.sub_paged.get(),
            sub_scatter: self.sub_scatter.get(),
            sub_barrier: self.sub_barrier.get(),
            sub_batch: self.sub_batch.get(),
            sub_single_tpl: self.sub_single_tpl.get(),
            sub_paged_tpl: self.sub_paged_tpl.get(),
            sub_scatter_tpl: self.sub_scatter_tpl.get(),
            sub_barrier_tpl: self.sub_barrier_tpl.get(),
            sub_batch_tpl: self.sub_batch_tpl.get(),
            lane_wrs: lanes(&self.lane_wrs),
            lane_bytes: lanes(&self.lane_bytes),
            wr_err_total: self.wr_err_total.get(),
            wr_err_link: self.wr_err_link.get(),
            wr_err_remote: self.wr_err_remote.get(),
            wr_err_nic: self.wr_err_nic.get(),
            rejected_all_down: self.rejected_all_down.get(),
            resubmits: self.resubmits.get(),
            error_outs: self.error_outs.get(),
            gossip_sent: self.gossip_sent.get(),
            gossip_received: self.gossip_received.get(),
            gossip_applied: self.gossip_applied.get(),
            imm_arms: self.imm_arms.get(),
            imm_bumps: self.imm_bumps.get(),
            imm_retires: self.imm_retires.get(),
            recv_posted: self.recv_posted.get(),
            recv_completed: self.recv_completed.get(),
            recv_pool_hwm: self.recv_pool_hwm.get(),
            mr_regs: self.mr_regs.get(),
            mr_deregs: self.mr_deregs.get(),
            lat_us_pow2: hist,
            trace_dropped: 0,
        }
    }
}

/// Power-of-two µs bucket index for a nanosecond latency.
fn latency_bucket(ns: u64) -> usize {
    let mut us = ns / 1000;
    let mut b = 0;
    while us >= 2 && b < HIST_BUCKETS - 1 {
        us >>= 1;
        b += 1;
    }
    b
}

/// Point-in-time copy of an engine's [`EngineMetrics`] (plain `u64`s,
/// `Clone`/`Eq` — safe to hold across engine shutdown, cheap to diff
/// in tests). Produced by `TransferEngine::telemetry()`; the engine
/// fills [`trace_dropped`](EngineSnapshot::trace_dropped) from its
/// trace ring(s) on the way out.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
#[allow(missing_docs)] // field-for-field mirror of EngineMetrics, documented there
pub struct EngineSnapshot {
    pub sub_single: u64,
    pub sub_paged: u64,
    pub sub_scatter: u64,
    pub sub_barrier: u64,
    pub sub_batch: u64,
    pub sub_single_tpl: u64,
    pub sub_paged_tpl: u64,
    pub sub_scatter_tpl: u64,
    pub sub_barrier_tpl: u64,
    pub sub_batch_tpl: u64,
    pub lane_wrs: [u64; MAX_LANES],
    pub lane_bytes: [u64; MAX_LANES],
    pub wr_err_total: u64,
    pub wr_err_link: u64,
    pub wr_err_remote: u64,
    pub wr_err_nic: u64,
    pub rejected_all_down: u64,
    pub resubmits: u64,
    pub error_outs: u64,
    pub gossip_sent: u64,
    pub gossip_received: u64,
    pub gossip_applied: u64,
    pub imm_arms: u64,
    pub imm_bumps: u64,
    pub imm_retires: u64,
    pub recv_posted: u64,
    pub recv_completed: u64,
    pub recv_pool_hwm: u64,
    pub mr_regs: u64,
    pub mr_deregs: u64,
    pub lat_us_pow2: [u64; HIST_BUCKETS],
    /// Trace spans the bounded ring recycled before they were read.
    pub trace_dropped: u64,
}

/// The wire-observable projection of a snapshot: what actually hit
/// the fabric, independent of HOW it was submitted. Batched vs
/// looped submission of the same workload on same-seed DES clusters
/// must agree on this exactly (submission-kind counters legitimately
/// differ: one batch is one submission, N singles are N).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
#[allow(missing_docs)] // field subset of EngineSnapshot
pub struct WireFootprint {
    pub lane_wrs: [u64; MAX_LANES],
    pub lane_bytes: [u64; MAX_LANES],
    pub imm_bumps: u64,
    pub imm_retires: u64,
    pub wr_err_total: u64,
    pub rejected_all_down: u64,
    pub resubmits: u64,
    pub error_outs: u64,
}

impl EngineSnapshot {
    /// `wr_err_total + rejected_all_down`, matching
    /// `TransferEngine::transport_errors()` at snapshot time.
    pub fn transport_errors(&self) -> u64 {
        self.wr_err_total + self.rejected_all_down
    }

    /// Total write-family WRs posted across all lanes.
    pub fn total_wrs(&self) -> u64 {
        self.lane_wrs.iter().sum()
    }

    /// Total payload bytes posted across all lanes.
    pub fn total_bytes(&self) -> u64 {
        self.lane_bytes.iter().sum()
    }

    /// Total submissions accepted across every entry point.
    pub fn total_submissions(&self) -> u64 {
        self.sub_single
            + self.sub_paged
            + self.sub_scatter
            + self.sub_barrier
            + self.sub_batch
            + self.sub_single_tpl
            + self.sub_paged_tpl
            + self.sub_scatter_tpl
            + self.sub_barrier_tpl
            + self.sub_batch_tpl
    }

    /// Project the wire-observable counters (see [`WireFootprint`]).
    pub fn wire_footprint(&self) -> WireFootprint {
        WireFootprint {
            lane_wrs: self.lane_wrs,
            lane_bytes: self.lane_bytes,
            imm_bumps: self.imm_bumps,
            imm_retires: self.imm_retires,
            wr_err_total: self.wr_err_total,
            rejected_all_down: self.rejected_all_down,
            resubmits: self.resubmits,
            error_outs: self.error_outs,
        }
    }

    /// Structured JSON view (the `--metrics-json` payload): counters
    /// grouped by taxonomy, lanes as parallel arrays trimmed to the
    /// highest lane that saw traffic.
    pub fn to_json(&self) -> Json {
        let obj = |pairs: Vec<(&str, Json)>| {
            Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
        };
        let used = (0..MAX_LANES)
            .rev()
            .find(|&l| self.lane_wrs[l] != 0 || self.lane_bytes[l] != 0)
            .map_or(0, |l| l + 1);
        let arr = |a: &[u64]| Json::Arr(a.iter().map(|&v| Json::from(v)).collect());
        obj(vec![
            (
                "submissions",
                obj(vec![
                    ("single", self.sub_single.into()),
                    ("paged", self.sub_paged.into()),
                    ("scatter", self.sub_scatter.into()),
                    ("barrier", self.sub_barrier.into()),
                    ("batch", self.sub_batch.into()),
                    ("single_tpl", self.sub_single_tpl.into()),
                    ("paged_tpl", self.sub_paged_tpl.into()),
                    ("scatter_tpl", self.sub_scatter_tpl.into()),
                    ("barrier_tpl", self.sub_barrier_tpl.into()),
                    ("batch_tpl", self.sub_batch_tpl.into()),
                    ("total", self.total_submissions().into()),
                ]),
            ),
            (
                "lanes",
                obj(vec![
                    ("wrs", arr(&self.lane_wrs[..used])),
                    ("bytes", arr(&self.lane_bytes[..used])),
                ]),
            ),
            (
                "errors",
                obj(vec![
                    ("wr_err_total", self.wr_err_total.into()),
                    ("wr_err_link", self.wr_err_link.into()),
                    ("wr_err_remote", self.wr_err_remote.into()),
                    ("wr_err_nic", self.wr_err_nic.into()),
                    ("rejected_all_down", self.rejected_all_down.into()),
                    ("resubmits", self.resubmits.into()),
                    ("error_outs", self.error_outs.into()),
                    ("transport_errors", self.transport_errors().into()),
                ]),
            ),
            (
                "gossip",
                obj(vec![
                    ("sent", self.gossip_sent.into()),
                    ("received", self.gossip_received.into()),
                    ("applied", self.gossip_applied.into()),
                ]),
            ),
            (
                "imm",
                obj(vec![
                    ("arms", self.imm_arms.into()),
                    ("bumps", self.imm_bumps.into()),
                    ("retires", self.imm_retires.into()),
                ]),
            ),
            (
                "recv",
                obj(vec![
                    ("posted", self.recv_posted.into()),
                    ("completed", self.recv_completed.into()),
                    ("hwm", self.recv_pool_hwm.into()),
                ]),
            ),
            (
                "mr",
                obj(vec![
                    ("regs", self.mr_regs.into()),
                    ("deregs", self.mr_deregs.into()),
                    ("live", self.mr_regs.saturating_sub(self.mr_deregs).into()),
                ]),
            ),
            ("latency_us_pow2", arr(&self.lat_us_pow2)),
            ("trace_dropped", self.trace_dropped.into()),
        ])
    }
}

/// Render trace spans as a chrome://tracing `trace_event` document
/// (JSON object format). Each span becomes one complete ("X") event:
/// `ts`/`dur` in microseconds as the format requires, lanes mapped
/// to tids so the per-NIC timelines stack, and the full nanosecond
/// phase breakdown preserved under `args`.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut evs = Vec::with_capacity(events.len());
    for e in events {
        let end = if e.retired > 0 { e.retired } else { e.last_post };
        let mut args = BTreeMap::new();
        args.insert("wrs".to_string(), Json::from(e.wrs as u64));
        args.insert("bytes".to_string(), Json::from(e.bytes));
        args.insert("outcome".to_string(), Json::from(e.outcome.as_str()));
        args.insert("submitted_ns".to_string(), Json::from(e.submitted));
        args.insert("enqueued_ns".to_string(), Json::from(e.enqueued));
        args.insert("worker_start_ns".to_string(), Json::from(e.worker_start));
        args.insert("first_post_ns".to_string(), Json::from(e.first_post));
        args.insert("last_post_ns".to_string(), Json::from(e.last_post));
        args.insert("retired_ns".to_string(), Json::from(e.retired));
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::from(e.kind.as_str()));
        o.insert("cat".to_string(), Json::from("submit"));
        o.insert("ph".to_string(), Json::from("X"));
        o.insert("ts".to_string(), Json::from(e.submitted as f64 / 1000.0));
        o.insert(
            "dur".to_string(),
            Json::from(end.saturating_sub(e.submitted) as f64 / 1000.0),
        );
        o.insert("pid".to_string(), Json::from(0u64));
        o.insert("tid".to_string(), Json::from(e.lane as u64));
        o.insert("args".to_string(), Json::Obj(args));
        evs.push(Json::Obj(o));
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(evs));
    root.insert("displayTimeUnit".to_string(), Json::from("ns"));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SubmitKind, t: u64) -> TraceEvent {
        TraceEvent {
            kind,
            lane: 1,
            wrs: 2,
            bytes: 4096,
            submitted: t,
            enqueued: t + 10,
            worker_start: t + 20,
            first_post: t + 30,
            last_post: t + 40,
            retired: 0,
            outcome: TraceOutcome::Posted,
        }
    }

    #[test]
    fn plain_cell_semantics() {
        let c = PlainCell::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
        c.set_max(5);
        assert_eq!(c.get(), 7, "set_max never lowers");
        c.set_max(9);
        assert_eq!(c.get(), 9);
        c.set(1);
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn padded_atomic_semantics_and_layout() {
        let c = PaddedAtomic::default();
        c.add(3);
        c.set_max(10);
        c.set_max(2);
        assert_eq!(c.get(), 10);
        assert_eq!(std::mem::align_of::<PaddedAtomic>(), 64, "one counter per cache line");
    }

    #[test]
    fn metrics_enable_gates_hot_path_but_not_errors() {
        let m: EngineMetrics<PlainCell> = EngineMetrics::new();
        m.set_enabled(false);
        m.submission(SubmitKind::BatchTpl);
        m.wire(0, 4, 1 << 20);
        m.recv_posts(8);
        m.observe_latency(10_000);
        // The error ledger is bumped directly at the engines' error
        // sites, so it is unaffected by the flag by construction.
        m.wr_err_total.add(1);
        m.wr_err_link.add(1);
        let s = m.snapshot();
        assert_eq!(s.total_submissions(), 0);
        assert_eq!(s.total_wrs(), 0);
        assert_eq!(s.recv_posted, 0);
        assert_eq!(s.lat_us_pow2.iter().sum::<u64>(), 0);
        assert_eq!(s.wr_err_total, 1);
        assert_eq!(s.transport_errors(), 1);
    }

    #[test]
    fn lane_overflow_lumps_into_last_slot() {
        let m: EngineMetrics<PlainCell> = EngineMetrics::new();
        m.wire(MAX_LANES + 5, 1, 100);
        let s = m.snapshot();
        assert_eq!(s.lane_wrs[MAX_LANES - 1], 1);
        assert_eq!(s.lane_bytes[MAX_LANES - 1], 100);
    }

    #[test]
    fn latency_buckets_are_pow2_us() {
        assert_eq!(latency_bucket(0), 0);
        assert_eq!(latency_bucket(1_999), 0); // < 2 µs
        assert_eq!(latency_bucket(2_000), 1); // [2, 4) µs
        assert_eq!(latency_bucket(3_999), 1);
        assert_eq!(latency_bucket(250_000), 7); // [128, 256) µs
        assert_eq!(latency_bucket(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn ring_bounds_recycles_and_counts_drops() {
        let mut r = TraceRing::new(3);
        for i in 0..5u64 {
            r.push(span(SubmitKind::Single, i * 100));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let spans = r.drain();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].submitted, 200, "oldest surviving span is #2");
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 2, "drain does not count as loss");
    }

    #[test]
    fn ring_close_patches_live_spans_and_ignores_recycled() {
        let mut r = TraceRing::new(2);
        let s0 = r.push(span(SubmitKind::Scatter, 0));
        let s1 = r.push(span(SubmitKind::Scatter, 100));
        let s2 = r.push(span(SubmitKind::Scatter, 200)); // evicts s0
        r.close(s0, 999, TraceOutcome::Retired); // recycled: no-op
        r.close(NO_TRACE, 999, TraceOutcome::Retired); // sentinel: no-op
        r.close(s1, 500, TraceOutcome::Retired);
        r.close(s2, 600, TraceOutcome::Failed);
        let spans = r.drain();
        assert_eq!(spans[0].retired, 500);
        assert_eq!(spans[0].outcome, TraceOutcome::Retired);
        assert_eq!(spans[1].retired, 600);
        assert_eq!(spans[1].outcome, TraceOutcome::Failed);
    }

    #[test]
    fn ring_shrink_recycles_oldest() {
        let mut r = TraceRing::new(4);
        for i in 0..4u64 {
            r.push(span(SubmitKind::Paged, i));
        }
        r.set_capacity(1);
        assert_eq!(r.len(), 1);
        assert_eq!(r.dropped(), 3);
        assert_eq!(r.drain()[0].submitted, 3);
    }

    #[test]
    fn wire_footprint_ignores_submission_shape() {
        let batched: EngineMetrics<PlainCell> = EngineMetrics::new();
        batched.submission(SubmitKind::BatchTpl);
        batched.wire(0, 4, 4096);
        let looped: EngineMetrics<PlainCell> = EngineMetrics::new();
        for _ in 0..4 {
            looped.submission(SubmitKind::SingleTpl);
            looped.wire(0, 1, 1024);
        }
        let (b, l) = (batched.snapshot(), looped.snapshot());
        assert_ne!(b, l, "kind counters differ");
        assert_eq!(b.wire_footprint(), l.wire_footprint(), "wire view agrees");
    }

    #[test]
    fn snapshot_json_shape() {
        let m: EngineMetrics<PlainCell> = EngineMetrics::new();
        m.submission(SubmitKind::Scatter);
        m.wire(1, 3, 3000);
        m.wr_err_total.add(2);
        m.wr_err_link.add(2);
        let mut s = m.snapshot();
        s.trace_dropped = 7;
        let j = s.to_json();
        // Round-trip through the parser: the export is valid JSON.
        let back = Json::parse(&j.to_pretty(2)).expect("valid JSON");
        let field = |a: &str, b: &str| back.get(a).and_then(|o| o.get(b)).and_then(Json::u64);
        assert_eq!(field("submissions", "scatter"), Some(1));
        assert_eq!(field("errors", "transport_errors"), Some(2));
        assert_eq!(back.get("trace_dropped").and_then(Json::u64), Some(7));
        let lanes = back.get("lanes").and_then(|l| l.get("wrs")).expect("lanes.wrs");
        assert_eq!(lanes.items().len(), 2, "trimmed to highest used lane");
    }

    #[test]
    fn chrome_trace_export_shape() {
        let mut done = span(SubmitKind::BatchTpl, 1000);
        done.retired = 9000;
        done.outcome = TraceOutcome::Retired;
        let j = chrome_trace_json(&[done, span(SubmitKind::Single, 2000)]);
        let back = Json::parse(&j.to_pretty(2)).expect("valid JSON");
        let evs = back.get("traceEvents").expect("traceEvents").items();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("ph").and_then(Json::str), Some("X"));
        assert_eq!(evs[0].get("name").and_then(Json::str), Some("batch_tpl"));
        assert_eq!(evs[0].get("ts").and_then(Json::f64), Some(1.0));
        assert_eq!(
            evs[0].get("dur").and_then(Json::f64),
            Some(8.0),
            "retired span runs to retire"
        );
        assert_eq!(
            evs[1].get("dur").and_then(Json::f64),
            Some(0.04),
            "open span runs to last post"
        );
        let outcome = evs[0].get("args").and_then(|a| a.get("outcome")).and_then(Json::str);
        assert_eq!(outcome, Some("retired"));
        assert_eq!(evs[0].get("tid").and_then(Json::u64), Some(1), "lane maps to tid");
    }
}
