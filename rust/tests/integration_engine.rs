//! Cross-module integration: the DES TransferEngine over multi-node,
//! multi-GPU fabrics — payload integrity, scatter/barrier fan-out,
//! and mixed EFA/multi-NIC behavior.

use std::cell::Cell;
use std::rc::Rc;

use fabric_lib::engine::api::{EngineCosts, Pages, ScatterDst};
use fabric_lib::engine::des_engine::{Engine, OnDone};
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};
use fabric_lib::fabric::simnet::SimNet;
use fabric_lib::sim::Sim;

fn cluster(nodes: u16, gpus: u8, nics: u8, profile: fn() -> NicProfile) -> (SimNet, Vec<Engine>) {
    let net = SimNet::new(99);
    for node in 0..nodes {
        for gpu in 0..gpus {
            for x in 0..nics {
                net.add_nic(NicAddr { node, gpu, nic: x }, profile());
            }
        }
    }
    let engines = (0..nodes)
        .map(|n| {
            Engine::new(
                &net,
                n,
                gpus,
                nics,
                GpuProfile::h100(),
                EngineCosts::default(),
                n as u64,
            )
        })
        .collect();
    (net, engines)
}

#[test]
fn all_to_all_scatter_integrity_efa() {
    // 8 ranks (2 nodes × 4 GPUs), each scatters a distinct pattern to
    // every other rank; all payloads must land intact despite SRD
    // reordering.
    let (_net, engines) = cluster(2, 4, 2, NicProfile::efa);
    let mut sim = Sim::new();
    let ranks: Vec<(usize, u8)> = (0..8).map(|r| (r / 4, (r % 4) as u8)).collect();
    let regions: Vec<_> = ranks
        .iter()
        .map(|&(n, g)| engines[n].alloc_mr(g, 8 * 256))
        .collect();
    let done = Rc::new(Cell::new(0u32));
    for (src_rank, &(n, g)) in ranks.iter().enumerate() {
        let (src_h, _) = engines[n].alloc_mr(g, 8 * 256);
        for i in 0..8 * 256 {
            src_h.buf.write(i, &[src_rank as u8 + 1]);
        }
        let dsts: Vec<ScatterDst> = regions
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != src_rank)
            .map(|(_, (_, desc))| ScatterDst {
                len: 256,
                src: 0,
                dst: (desc.clone(), (src_rank as u64) * 256),
            })
            .collect();
        let dn = done.clone();
        engines[n]
            .submit_scatter(
                &mut sim,
                None,
                &src_h,
                &dsts,
                Some(77),
                OnDone::Callback(Box::new(move |_| dn.set(dn.get() + 1))),
            )
            .unwrap();
    }
    sim.run();
    assert_eq!(done.get(), 8);
    for (dst_rank, (h, _)) in regions.iter().enumerate() {
        let v = h.buf.to_vec();
        for src_rank in 0..8 {
            if src_rank == dst_rank {
                continue;
            }
            let seg = &v[src_rank * 256..(src_rank + 1) * 256];
            assert!(
                seg.iter().all(|&b| b == src_rank as u8 + 1),
                "rank {dst_rank} slot {src_rank} corrupted"
            );
        }
        // Each receiver saw 7 imms.
        let (n, g) = ranks[dst_rank];
        assert_eq!(engines[n].imm_value(g, 77), 7);
    }
}

#[test]
fn barrier_all_to_all() {
    let (_net, engines) = cluster(2, 2, 1, NicProfile::connectx7);
    let mut sim = Sim::new();
    let ranks: Vec<(usize, u8)> = (0..4).map(|r| (r / 2, (r % 2) as u8)).collect();
    let regions: Vec<_> = ranks
        .iter()
        .map(|&(n, g)| engines[n].alloc_mr(g, 64))
        .collect();
    let released = Rc::new(Cell::new(0u32));
    for (me, &(n, g)) in ranks.iter().enumerate() {
        let descs: Vec<_> = regions
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != me)
            .map(|(_, (_, d))| d.clone())
            .collect();
        let rl = released.clone();
        engines[n].expect_imm_count(&mut sim, g, 5, 3, move |_| rl.set(rl.get() + 1));
        engines[n]
            .submit_barrier(&mut sim, g, None, &descs, 5, OnDone::Noop)
            .unwrap();
    }
    sim.run();
    assert_eq!(released.get(), 4, "all ranks pass the barrier");
}

#[test]
fn paged_write_cross_gpu_same_node() {
    // GPU0 -> GPU1 within a node still goes through the fabric
    // (engine-level path); integrity + per-page imm counting.
    let (_net, engines) = cluster(1, 2, 1, NicProfile::connectx7);
    let e = &engines[0];
    let mut sim = Sim::new();
    let page = 1024u64;
    let (src, _) = e.alloc_mr(0, 16 * 1024);
    let (dst_h, dst_d) = e.alloc_mr(1, 16 * 1024);
    for p in 0..16 {
        src.buf.write(p * 1024, &[(p as u8) ^ 0xA5; 1024]);
    }
    let rev: Vec<u32> = (0..16).rev().collect();
    e.submit_paged_writes(
        &mut sim,
        page,
        (&src, &Pages::contiguous(0, 16, page)),
        (&dst_d, &Pages { indices: rev.clone(), stride: page, offset: 0 }),
        Some(3),
        OnDone::Noop,
    )
    .unwrap();
    sim.run();
    assert_eq!(e.imm_value(1, 3), 16);
    let v = dst_h.buf.to_vec();
    for (i, &slot) in rev.iter().enumerate() {
        let seg = &v[slot as usize * 1024..(slot as usize + 1) * 1024];
        assert!(seg.iter().all(|&b| b == (i as u8) ^ 0xA5), "page {i}");
    }
}

#[test]
fn recv_pool_handles_burst_beyond_pool_size() {
    let (_net, engines) = cluster(2, 1, 1, NicProfile::efa);
    let mut sim = Sim::new();
    let got = Rc::new(Cell::new(0u32));
    let g = got.clone();
    engines[1].submit_recvs(&mut sim, 0, 512, 4, move |_s, m| {
        assert!(m.poison.is_none());
        assert_eq!(m.data.len(), 100);
        g.set(g.get() + 1);
    });
    for _ in 0..50 {
        engines[0].submit_send(
            &mut sim,
            0,
            &engines[1].group_address(0),
            &[9u8; 100],
            OnDone::Noop,
        );
    }
    sim.run();
    assert_eq!(got.get(), 50, "rotating pool must re-post and drain the burst");
}
