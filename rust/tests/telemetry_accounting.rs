//! Accounting identities of the engine-wide telemetry registry,
//! cross-runtime:
//!
//! * conservation — on a clean (chaos-free) parity workload the
//!   per-lane byte counters sum to exactly the payload bytes the app
//!   submitted, on BOTH runtimes;
//! * batching transparency — batched vs looped submission of the same
//!   workload on identically-seeded DES clusters produce identical
//!   [`WireFootprint`]s (submission-kind counters legitimately
//!   differ: one batch is one submission, N singles are N);
//! * `chaos_` error-ledger reconciliation — every `WrError` is
//!   attributed to exactly one of link/NIC, every error either
//!   resubmits or errors out, and `transport_errors()` equals the
//!   derived `wr_err_total + rejected_all_down` on BOTH runtimes;
//! * the bounded trace ring drops oldest-first into an exact overflow
//!   counter, on BOTH runtimes.

use fabric_lib::engine::api::{MrDesc, ScatterDst, TemplatedDst};
use fabric_lib::engine::traits::{
    expect_flag, new_flag, run_on_both, Cluster, Notify, RuntimeKind, TransferEngine,
};
use fabric_lib::fabric::chaos::ChaosProfile;
use fabric_lib::util::telemetry::{EngineSnapshot, TraceOutcome};

const IMM: u32 = 0xACC;

fn templated_entries() -> Vec<TemplatedDst> {
    vec![
        TemplatedDst { peer: 0, len: 300, src: 0, dst: 100 },
        TemplatedDst { peer: 1, len: 1024, src: 512, dst: 0 },
        TemplatedDst { peer: 0, len: 200, src: 1536, dst: 3000 },
        TemplatedDst { peer: 1, len: 64, src: 2048, dst: 4096 },
    ]
}

/// Conservation: whatever mix of entry points the app used — a
/// sharded single write, a templated batch, an untemplated scatter
/// batch — the lane byte counters account for every submitted payload
/// byte exactly, and for nothing else. Runs on both runtimes.
#[test]
fn accounting_lane_bytes_sum_to_payload_on_both_runtimes() {
    run_on_both(3, 1, 2, 0xACC0, |cx, engines| {
        let sender = engines[0];
        let (src, _) = sender.alloc_mr(0, 4096);
        src.buf.write(0, &vec![3u8; 4096]);
        let regions: Vec<_> = engines[1..].iter().map(|e| e.alloc_mr(0, 8192)).collect();
        let descs: Vec<MrDesc> = regions.iter().map(|(_, d)| d.clone()).collect();
        let group =
            sender.add_peer_group(engines[1..].iter().map(|e| e.main_address()).collect());
        sender.bind_peer_group_mrs(0, group, &descs).unwrap();

        // 4096 B sharded single write + 1588 B templated batch +
        // 896 B untemplated scatter batch.
        let done = new_flag();
        sender
            .submit_single_write(
                cx,
                (&src, 0),
                4096,
                (&descs[0], 0),
                None,
                Notify::Flag(done.clone()),
            )
            .unwrap();
        cx.wait(&done);
        let got0 = expect_flag(engines[1], cx, 0, IMM, 2);
        let got1 = expect_flag(engines[2], cx, 0, IMM, 2);
        sender
            .submit_batch_templated(cx, &src, group, &templated_entries(), Some(IMM), Notify::Noop)
            .unwrap();
        cx.wait(&got0);
        cx.wait(&got1);
        let scatter: Vec<ScatterDst> = vec![
            ScatterDst { len: 512, src: 0, dst: (descs[0].clone(), 5000) },
            ScatterDst { len: 256, src: 1024, dst: (descs[1].clone(), 6000) },
            ScatterDst { len: 128, src: 3000, dst: (descs[0].clone(), 7000) },
        ];
        sender.submit_write_batch(cx, &src, &scatter, None, Notify::Noop).unwrap();
        cx.settle();

        let snap = sender.telemetry();
        let payload = 4096 + (300 + 1024 + 200 + 64) + (512 + 256 + 128);
        assert_eq!(snap.total_bytes(), payload, "lane bytes lost or invented payload");
        assert_eq!(
            snap.lane_bytes.iter().sum::<u64>(),
            snap.total_bytes(),
            "total_bytes is the lane sum by definition"
        );
        assert_eq!(snap.sub_single, 1);
        assert_eq!(snap.sub_batch_tpl, 1);
        assert_eq!(snap.sub_batch, 1);
        assert_eq!(snap.total_submissions(), 3);
        // Clean run: the error ledger is all zeros and the identities
        // hold trivially.
        assert_eq!(snap.transport_errors(), 0);
        assert_eq!(snap.transport_errors(), sender.transport_errors());
        assert_eq!(snap.resubmits + snap.error_outs, snap.wr_err_total);
        assert!(sender.remove_peer_group(group));
    });
}

/// Run the shared workload on a fresh same-seed DES cluster, batched
/// or looped, and return (landed payloads, sender snapshot).
fn run_des_workload(batched: bool) -> (Vec<Vec<u8>>, EngineSnapshot) {
    let mut cluster = Cluster::new(RuntimeKind::Des, 3, 1, 2, 0xACC1);
    let out = {
        let (mut cx, engines) = cluster.parts();
        let sender = engines[0];
        let (src, _) = sender.alloc_mr(0, 4096);
        src.buf.write(0, &(0..4096u32).map(|i| (i % 249) as u8 + 1).collect::<Vec<_>>());
        let regions: Vec<_> = engines[1..].iter().map(|e| e.alloc_mr(0, 8192)).collect();
        let descs: Vec<MrDesc> = regions.iter().map(|(_, d)| d.clone()).collect();
        let group =
            sender.add_peer_group(engines[1..].iter().map(|e| e.main_address()).collect());
        sender.bind_peer_group_mrs(0, group, &descs).unwrap();
        let got0 = expect_flag(engines[1], &mut cx, 0, IMM, 2);
        let got1 = expect_flag(engines[2], &mut cx, 0, IMM, 2);
        let entries = templated_entries();
        if batched {
            sender
                .submit_batch_templated(&mut cx, &src, group, &entries, Some(IMM), Notify::Noop)
                .unwrap();
        } else {
            for d in &entries {
                sender
                    .submit_single_write_templated(
                        &mut cx,
                        (&src, d.src),
                        d.len,
                        group,
                        d.peer,
                        d.dst,
                        Some(IMM),
                        Notify::Noop,
                    )
                    .unwrap();
            }
        }
        cx.wait(&got0);
        cx.wait(&got1);
        cx.settle();
        assert!(sender.remove_peer_group(group));
        let payloads: Vec<Vec<u8>> = regions.iter().map(|(h, _)| h.buf.to_vec()).collect();
        (payloads, sender.telemetry())
    };
    cluster.shutdown();
    out
}

/// Batching transparency at the counter level: one batch and N looped
/// singles put the SAME thing on the wire — identical
/// `wire_footprint()`s on identically-seeded DES clusters — while the
/// submission-kind counters tell the two apart.
#[test]
fn accounting_batch_vs_loop_footprint_identical_des() {
    let (loop_payloads, loop_snap) = run_des_workload(false);
    let (batch_payloads, batch_snap) = run_des_workload(true);
    assert_eq!(loop_payloads, batch_payloads, "landed bytes diverged");
    assert_eq!(
        loop_snap.wire_footprint(),
        batch_snap.wire_footprint(),
        "batched and looped submission diverged on the wire"
    );
    assert_eq!(loop_snap.sub_single_tpl, 4);
    assert_eq!(loop_snap.sub_batch_tpl, 0);
    assert_eq!(batch_snap.sub_batch_tpl, 1);
    assert_eq!(batch_snap.sub_single_tpl, 0);
}

/// Error-ledger reconciliation under chaos, on BOTH runtimes: cut one
/// directed link from t=0, push a sharded write across it, and check
/// that every WrError was attributed exactly once
/// (`wr_err_link + wr_err_nic == wr_err_total`), dispatched exactly
/// once (`resubmits + error_outs == wr_err_total`), and that the
/// derived `transport_errors()` agrees with the engine's own — then
/// that an all-remote-NICs-down rejection lands in
/// `rejected_all_down` and reconciles too.
#[test]
fn chaos_accounting_reconciles_wr_error_ledger_on_both_runtimes() {
    run_on_both(2, 1, 2, 0xACC2, |cx, engines| {
        let sender = engines[0];
        let receiver = engines[1];
        let a1 = sender.group_address(0).nics[1];
        let b0 = receiver.group_address(0).nics[0];
        let b1 = receiver.group_address(0).nics[1];
        // Cut A's lane-1 path to B's NIC 1: not locally observable, so
        // the lane-1 WRs post, die with WrError, and resubmit over the
        // surviving link.
        sender.inject_chaos(cx, &ChaosProfile::new(0xACC3).link_down(0, (a1, b1)));

        let len = 2usize << 20;
        let (src, _) = sender.alloc_mr(0, len);
        let pat: Vec<u8> = (0..len).map(|i| (i % 239) as u8 + 1).collect();
        src.buf.write(0, &pat);
        let (dst_h, dst_d) = receiver.alloc_mr(0, len);
        let done = new_flag();
        sender
            .submit_single_write(
                cx,
                (&src, 0),
                len as u64,
                (&dst_d, 0),
                None,
                Notify::Flag(done.clone()),
            )
            .unwrap();
        cx.wait(&done);
        cx.settle();
        assert_eq!(dst_h.buf.to_vec(), pat, "failover lost payload");

        let snap = sender.telemetry();
        assert!(snap.wr_err_total >= 1, "the cut link produced no WrError: {snap:?}");
        assert_eq!(
            snap.wr_err_link + snap.wr_err_nic,
            snap.wr_err_total,
            "every WrError attributes to exactly one of link|nic: {snap:?}"
        );
        assert_eq!(
            snap.resubmits + snap.error_outs,
            snap.wr_err_total,
            "every WrError either resubmits or errors out: {snap:?}"
        );
        assert_eq!(snap.rejected_all_down, 0);
        assert_eq!(snap.transport_errors(), sender.transport_errors());

        // Believe BOTH remote NICs dead: the next submission has no
        // lane toward the destination and is rejected whole — a
        // transport failure the derived counter must cover.
        sender.report_remote_health(0, b0, false);
        sender.report_remote_health(0, b1, false);
        assert!(sender
            .submit_single_write(cx, (&src, 0), 64, (&dst_d, 0), None, Notify::Noop)
            .is_err());
        let snap2 = sender.telemetry();
        assert_eq!(snap2.rejected_all_down, 1, "{snap2:?}");
        assert_eq!(snap2.transport_errors(), snap2.wr_err_total + 1);
        assert_eq!(snap2.transport_errors(), sender.transport_errors());
    });
}

/// The bounded trace ring keeps the newest spans and counts evictions
/// exactly, on BOTH runtimes.
#[test]
fn trace_ring_overflow_accounts_drops_on_both_runtimes() {
    run_on_both(2, 1, 2, 0xACC4, |cx, engines| {
        let sender = engines[0];
        sender.set_trace_capacity(2);
        let (src, _) = sender.alloc_mr(0, 1024);
        src.buf.write(0, &[9u8; 1024]);
        let (_dst_h, dst_d) = engines[1].alloc_mr(0, 4096);
        let mut flags = Vec::new();
        for i in 0..5u64 {
            let done = new_flag();
            sender
                .submit_single_write(
                    cx,
                    (&src, 0),
                    64,
                    (&dst_d, i * 64),
                    None,
                    Notify::Flag(done.clone()),
                )
                .unwrap();
            flags.push(done);
        }
        for f in &flags {
            cx.wait(f);
        }
        cx.settle();
        let spans = sender.take_traces();
        assert_eq!(spans.len(), 2, "capacity-2 ring holds the newest 2 spans");
        for s in &spans {
            assert_eq!(s.outcome, TraceOutcome::Retired, "{s:?}");
            assert_eq!(s.bytes, 64);
        }
        let snap = sender.telemetry();
        assert_eq!(snap.trace_dropped, 3, "3 oldest spans evicted");
        // Submission counting is ring-independent.
        assert_eq!(snap.sub_single, 5);
        // Drained is drained.
        assert!(sender.take_traces().is_empty());
    });
}
