//! The TransferEngine: fabric-lib's core component (paper §3).
//!
//! Two runtimes share the same vocabulary and pure logic:
//! * [`des_engine::Engine`] — deterministic, timing-faithful engine on
//!   the discrete-event fabric (benchmarks, integration tests);
//! * [`threaded::ThreadedEngine`] — real pinned threads over the
//!   in-process fabric (runnable examples, real CPU-overhead
//!   measurements).

pub mod api;
pub mod des_engine;
pub mod imm_counter;
pub mod sharding;
pub mod threaded;
pub mod wire;

pub use api::{EngineCosts, MrDesc, MrHandle, NetAddr, Pages, PeerGroupHandle, ScatterDst};
pub use des_engine::{Engine, OnDone, SubmitTrace, UvmWatcherHandle};
pub use imm_counter::{ImmCounter, ImmEvent};
