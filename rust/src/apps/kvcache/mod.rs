//! KvCache transfer for disaggregated inference (paper §4).
//!
//! A decoder pre-allocates KV pages, registers an IMMCOUNTER
//! expectation, and dispatches the request to a prefiller over
//! SEND/RECV. The prefiller runs chunked prefill; after each layer's
//! attention output projection a UVM watcher increments, and the
//! engine writes that layer's pages to the decoder with
//! `submit_paged_writes` — layer-by-layer transfer hidden behind
//! compute. The tail context (logits/hidden states) goes last via
//! `submit_single_write`. No explicit completion message exists: the
//! decoder knows the expected immediate count in advance.
//!
//! Also implemented, as in production (§4 last paragraph):
//! cancellation with explicit confirmation (pages are quarantined
//! until the prefiller acks, because a stale WRITE could clobber
//! them), and heartbeat-based failure detection.

pub mod arrivals;
pub mod decoder;
pub mod harness;
pub mod layout;
pub mod prefiller;
pub mod proto;
pub mod scheduler;
pub mod serving;
pub mod workload;

pub use arrivals::{Arrival, Arrivals, PoissonArrivals, TraceArrivals};
pub use decoder::Decoder;
pub use harness::{
    run_generic_kv_push, run_kv_failover, run_kv_failover_on, run_kv_fleet_on,
    run_kv_link_partition, run_kv_link_partition_on, run_kv_nic_failover_on, run_kv_request_on,
    run_table3_row, run_table3_row_on, run_table3_row_with_telemetry, FailoverOutcome,
    KvRequestOutcome, Table3Row,
};
pub use layout::KvLayout;
pub use prefiller::Prefiller;
pub use proto::DispatchReq;
pub use scheduler::Scheduler;
pub use serving::{run_serving, ServingConfig, ServingReport};
pub use workload::{PrefillComputeModel, ServingWorkload};
