//! Threaded in-process fabric: real threads, real memcpy, no virtual
//! clock.
//!
//! This backend gives the *semantics* of the simulated fabric (reliable
//! delivery, SRD-style reordering, payload-before-immediate, RNR
//! queueing) at real-time speed. It backs the production-shaped
//! threaded TransferEngine used by the examples and by the real-CPU
//! overhead measurements (paper Table 8); timing-faithful benchmarks
//! use [`super::simnet::SimNet`] instead.
//!
//! One delivery thread per fabric plays the role of the wire + remote
//! NIC: it drains posted WRs, optionally reorders them (SRD), commits
//! payload DMA, then exposes completions — in that order, preserving
//! the PCIe invariant.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::mem::{DmaSlice, MemRegistry};
use super::nic::{Cqe, CqeKind, NicAddr, WorkRequest, WrOp};
use super::profile::TransportKind;
use crate::sim::Rng;

/// Default SRD reorder-window size (messages buffered and released in
/// shuffled order); chaos profiles may widen it via
/// [`LocalFabric::set_reorder_window`].
const DEFAULT_WINDOW: usize = 8;

struct LocalNic {
    cq: VecDeque<Cqe>,
    recvs: VecDeque<(u64, DmaSlice)>,
    pending_sends: VecDeque<(Vec<u8>, NicAddr)>,
}

impl LocalNic {
    fn new() -> Self {
        LocalNic {
            cq: VecDeque::new(),
            recvs: VecDeque::new(),
            pending_sends: VecDeque::new(),
        }
    }
}

struct Shared {
    nics: Mutex<HashMap<NicAddr, LocalNic>>,
    cq_signal: Condvar,
    mem: MemRegistry,
    /// NICs currently down (chaos NicDown): WRs from or to them fail
    /// with [`CqeKind::WrError`] instead of delivering.
    down: Mutex<HashSet<NicAddr>>,
    /// Directed `(src, dst)` links currently partitioned (chaos
    /// LinkDown): WRs traversing one fail with [`CqeKind::WrError`]
    /// while both endpoint NICs keep serving every other path.
    cut: Mutex<HashSet<(NicAddr, NicAddr)>>,
    /// Link-state hooks, called synchronously from `set_nic_up` with
    /// the new state (the threaded engine keeps its `NicHealth` table
    /// in sync through these).
    health_hooks: Mutex<HashMap<NicAddr, Box<dyn Fn(bool) + Send + Sync>>>,
    /// Per-link hooks keyed by the SRC NIC, called synchronously from
    /// `set_link_up` with `(dst, up)`. Observability for
    /// scenarios/tests; engines learn about partitions from `WrError`
    /// attribution + gossip (path failures are not locally observable
    /// at a real sender port).
    link_hooks: Mutex<HashMap<NicAddr, Box<dyn Fn(NicAddr, bool) + Send + Sync>>>,
    /// SRD reorder-window size (see [`DEFAULT_WINDOW`]).
    window: AtomicUsize,
}

enum Msg {
    Wr { src: NicAddr, wr: WorkRequest },
    Shutdown,
}

/// Threaded loopback fabric. Clone handles freely.
#[derive(Clone)]
pub struct LocalFabric {
    shared: Arc<Shared>,
    tx: Sender<Msg>,
    worker: Arc<Mutex<Option<JoinHandle<()>>>>,
}

impl LocalFabric {
    /// Create the fabric and spawn its delivery thread.
    ///
    /// `transport` selects ordering semantics: `Rc` delivers WRs in
    /// posting order, `Srd` randomly reorders within a small window
    /// (deterministic per `seed`).
    pub fn new(transport: TransportKind, seed: u64) -> Self {
        let shared = Arc::new(Shared {
            nics: Mutex::new(HashMap::new()),
            cq_signal: Condvar::new(),
            mem: MemRegistry::new(),
            down: Mutex::new(HashSet::new()),
            cut: Mutex::new(HashSet::new()),
            health_hooks: Mutex::new(HashMap::new()),
            link_hooks: Mutex::new(HashMap::new()),
            window: AtomicUsize::new(DEFAULT_WINDOW),
        });
        let (tx, rx) = mpsc::channel::<Msg>();
        let s2 = shared.clone();
        let worker = std::thread::Builder::new()
            .name("fabric-delivery".into())
            .spawn(move || {
                let mut rng = Rng::new(seed);
                // SRD reorder window: buffer up to the configured
                // window of WRs and release them in random order.
                let mut window: Vec<(NicAddr, WorkRequest)> = Vec::new();
                let flush = |w: &mut Vec<(NicAddr, WorkRequest)>, rng: &mut Rng| {
                    let mut order: Vec<usize> = (0..w.len()).collect();
                    if transport == TransportKind::Srd {
                        rng.shuffle(&mut order);
                    }
                    let mut items: Vec<Option<(NicAddr, WorkRequest)>> =
                        w.drain(..).map(Some).collect();
                    for i in order {
                        let (src, wr) = items[i].take().unwrap();
                        deliver(&s2, src, wr);
                    }
                };
                loop {
                    // Block for one message, then opportunistically
                    // batch whatever else is queued (fills the reorder
                    // window under load without adding idle latency).
                    match rx.recv() {
                        Ok(Msg::Wr { src, wr }) => window.push((src, wr)),
                        _ => break,
                    }
                    let cap = s2.window.load(Ordering::Relaxed).max(1);
                    while window.len() < cap {
                        match rx.try_recv() {
                            Ok(Msg::Wr { src, wr }) => window.push((src, wr)),
                            Ok(Msg::Shutdown) => {
                                flush(&mut window, &mut rng);
                                return;
                            }
                            Err(_) => break,
                        }
                    }
                    flush(&mut window, &mut rng);
                }
                flush(&mut window, &mut rng);
            })
            .expect("spawn fabric delivery thread");
        LocalFabric {
            shared,
            tx,
            worker: Arc::new(Mutex::new(Some(worker))),
        }
    }

    /// The shared memory registry.
    pub fn mem(&self) -> MemRegistry {
        self.shared.mem.clone()
    }

    /// Install a NIC.
    pub fn add_nic(&self, addr: NicAddr) {
        self.shared
            .nics
            .lock()
            .unwrap()
            .insert(addr, LocalNic::new());
    }

    /// Post a WR. RECVs take effect immediately; SENDs/WRITEs are
    /// handed to the delivery thread.
    pub fn post(&self, local: NicAddr, wr: WorkRequest) {
        match wr.op {
            WrOp::Recv { ref buf } => {
                let delivered = {
                    let mut nics = self.shared.nics.lock().unwrap();
                    let nic = nics.get_mut(&local).expect("unknown NIC");
                    if let Some((payload, src)) = nic.pending_sends.pop_front() {
                        let n = payload.len().min(buf.len);
                        buf.buf.write(buf.offset, &payload[..n]);
                        nic.cq.push_back(Cqe {
                            wr_id: wr.id,
                            kind: CqeKind::RecvDone {
                                len: payload.len() as u32,
                                src,
                            },
                        });
                        true
                    } else {
                        nic.recvs.push_back((wr.id, buf.clone()));
                        false
                    }
                };
                if delivered {
                    self.shared.cq_signal.notify_all();
                }
            }
            _ => {
                self.tx
                    .send(Msg::Wr { src: local, wr })
                    .expect("fabric delivery thread gone");
            }
        }
    }

    /// Drain up to `max` CQEs from `addr`.
    pub fn poll_cq(&self, addr: NicAddr, max: usize, out: &mut Vec<Cqe>) {
        let mut nics = self.shared.nics.lock().unwrap();
        let nic = nics.get_mut(&addr).expect("unknown NIC");
        for _ in 0..max {
            match nic.cq.pop_front() {
                Some(c) => out.push(c),
                None => break,
            }
        }
    }

    /// Block until `addr` has at least one CQE or the timeout elapses;
    /// returns true if CQEs are available.
    pub fn wait_cq(&self, addr: NicAddr, timeout: std::time::Duration) -> bool {
        let nics = self.shared.nics.lock().unwrap();
        if !nics[&addr].cq.is_empty() {
            return true;
        }
        let (nics, _res) = self
            .shared
            .cq_signal
            .wait_timeout_while(nics, timeout, |n| n[&addr].cq.is_empty())
            .unwrap();
        !nics[&addr].cq.is_empty()
    }

    /// Flip `addr`'s link state and notify its health hook (if any).
    /// Down NICs fail WRs from or to them with [`CqeKind::WrError`].
    pub fn set_nic_up(&self, addr: NicAddr, up: bool) {
        {
            let mut d = self.shared.down.lock().unwrap();
            if up {
                d.remove(&addr);
            } else {
                d.insert(addr);
            }
        }
        if let Some(h) = self.shared.health_hooks.lock().unwrap().get(&addr) {
            h(up);
        }
    }

    /// Current link state of `addr`.
    pub fn nic_up(&self, addr: NicAddr) -> bool {
        !self.shared.down.lock().unwrap().contains(&addr)
    }

    /// Register a link-state hook for `addr` (the threaded engine's
    /// `NicHealth` sync).
    pub fn set_health_hook(&self, addr: NicAddr, hook: Box<dyn Fn(bool) + Send + Sync>) {
        self.shared.health_hooks.lock().unwrap().insert(addr, hook);
    }

    /// Partition (`up = false`) or heal the directed link `src → dst`
    /// in real time, while both endpoint NICs stay up. WRs traversing
    /// a cut link fail with [`CqeKind::WrError`] (exactly-once: the
    /// payload provably did not commit); `src`'s registered link hook
    /// (if any) is notified synchronously with `(dst, up)`.
    pub fn set_link_up(&self, src: NicAddr, dst: NicAddr, up: bool) {
        {
            let mut c = self.shared.cut.lock().unwrap();
            if up {
                c.remove(&(src, dst));
            } else {
                c.insert((src, dst));
            }
        }
        if let Some(h) = self.shared.link_hooks.lock().unwrap().get(&src) {
            h(dst, up);
        }
    }

    /// Current state of the directed link `src → dst` (false while
    /// partitioned).
    pub fn link_up(&self, src: NicAddr, dst: NicAddr) -> bool {
        !self.shared.cut.lock().unwrap().contains(&(src, dst))
    }

    /// Register a per-link hook for paths originating at `src`, called
    /// synchronously with `(dst, up)` on every [`LocalFabric::set_link_up`]
    /// flip (observability for scenarios/tests).
    pub fn set_link_hook(&self, src: NicAddr, hook: Box<dyn Fn(NicAddr, bool) + Send + Sync>) {
        self.shared.link_hooks.lock().unwrap().insert(src, hook);
    }

    /// Re-notify every health hook with its NIC's current state.
    /// Chaos injection calls this to arm the failover bookkeeping of
    /// EVERY engine on the fabric — a remote NIC death must be
    /// resubmittable by senders that never saw their own links flip.
    pub fn arm_all(&self) {
        let down: HashSet<NicAddr> = self.shared.down.lock().unwrap().clone();
        for (addr, h) in self.shared.health_hooks.lock().unwrap().iter() {
            h(!down.contains(addr));
        }
    }

    /// Resize the SRD reorder window (chaos profiles widen it to
    /// stress ordering-independence harder).
    pub fn set_reorder_window(&self, n: usize) {
        self.shared.window.store(n.max(1), Ordering::Relaxed);
    }

    /// Stop the delivery thread (flushes queued WRs first).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Commit one WR: DMA first, completion second. If either end is down
/// — or the directed `src → dst` link is partitioned — the WR fails
/// with a [`CqeKind::WrError`] to the sender and nothing commits
/// (exactly-once: failed WRs are safe to resubmit).
fn deliver(shared: &Shared, src: NicAddr, wr: WorkRequest) {
    let dst = wr.op.dst().expect("delivery of non-outgoing WR");
    {
        let down = shared.down.lock().unwrap();
        let dead = down.contains(&src)
            || down.contains(&dst)
            || shared.cut.lock().unwrap().contains(&(src, dst));
        if dead {
            drop(down);
            shared
                .nics
                .lock()
                .unwrap()
                .get_mut(&src)
                .expect("unknown src NIC")
                .cq
                .push_back(Cqe {
                    wr_id: wr.id,
                    kind: CqeKind::WrError,
                });
            shared.cq_signal.notify_all();
            return;
        }
    }
    match wr.op {
        WrOp::Write {
            dst_rkey,
            dst_va,
            src: slice,
            imm,
            ..
        } => {
            if slice.len > 0 {
                let (dbuf, off) = shared
                    .mem
                    .resolve(dst_rkey, dst_va, slice.len)
                    .expect("remote protection fault in WRITE");
                slice.buf.copy_to(slice.offset, &dbuf, off, slice.len);
            }
            let mut nics = shared.nics.lock().unwrap();
            if let Some(imm) = imm {
                nics.get_mut(&dst).expect("unknown dst NIC").cq.push_back(Cqe {
                    wr_id: 0,
                    kind: CqeKind::ImmRecvd {
                        imm,
                        len: slice.len as u32,
                        src,
                    },
                });
            }
            nics.get_mut(&src).unwrap().cq.push_back(Cqe {
                wr_id: wr.id,
                kind: CqeKind::WriteDone,
            });
        }
        WrOp::Send { payload, .. } => {
            let mut nics = shared.nics.lock().unwrap();
            let nic = nics.get_mut(&dst).expect("unknown dst NIC");
            if let Some((rid, rbuf)) = nic.recvs.pop_front() {
                let n = payload.len().min(rbuf.len);
                rbuf.buf.write(rbuf.offset, &payload[..n]);
                nic.cq.push_back(Cqe {
                    wr_id: rid,
                    kind: CqeKind::RecvDone {
                        len: payload.len() as u32,
                        src,
                    },
                });
            } else {
                nic.pending_sends.push_back((payload, src));
            }
            nics.get_mut(&src).unwrap().cq.push_back(Cqe {
                wr_id: wr.id,
                kind: CqeKind::SendDone,
            });
        }
        WrOp::Recv { .. } => unreachable!(),
    }
    shared.cq_signal.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::nic::QpId;
    use std::time::Duration;

    fn addr(node: u16) -> NicAddr {
        NicAddr { node, gpu: 0, nic: 0 }
    }

    fn drain(fabric: &LocalFabric, a: NicAddr, want: usize) -> Vec<Cqe> {
        let mut out = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while out.len() < want && std::time::Instant::now() < deadline {
            fabric.wait_cq(a, Duration::from_millis(50));
            fabric.poll_cq(a, want - out.len(), &mut out);
        }
        out
    }

    #[test]
    fn threaded_write_roundtrip() {
        let f = LocalFabric::new(TransportKind::Rc, 1);
        let (a, b) = (addr(0), addr(1));
        f.add_nic(a);
        f.add_nic(b);
        let (sbuf, _) = f.mem().alloc(256);
        let (dbuf, drkey) = f.mem().alloc(256);
        sbuf.write(0, b"threaded fabric");
        f.post(
            a,
            WorkRequest {
                id: 3,
                qp: QpId(1),
                op: WrOp::Write {
                    dst: b,
                    dst_rkey: drkey,
                    dst_va: dbuf.base(),
                    src: DmaSlice::new(&sbuf, 0, 15),
                    imm: Some(42),
                },
                chained: false,
            },
        );
        let cqes = drain(&f, b, 1);
        assert!(matches!(cqes[0].kind, CqeKind::ImmRecvd { imm: 42, len: 15, .. }));
        assert_eq!(&dbuf.to_vec()[..15], b"threaded fabric");
        let acks = drain(&f, a, 1);
        assert_eq!(acks[0].kind, CqeKind::WriteDone);
        f.shutdown();
    }

    #[test]
    fn threaded_send_recv_and_rnr() {
        let f = LocalFabric::new(TransportKind::Srd, 2);
        let (a, b) = (addr(0), addr(1));
        f.add_nic(a);
        f.add_nic(b);
        // Send first (no recv posted): must be queued, not dropped.
        f.post(
            a,
            WorkRequest {
                id: 1,
                qp: QpId(0),
                op: WrOp::Send {
                    dst: b,
                    payload: b"hello".to_vec(),
                },
                chained: false,
            },
        );
        let _ = drain(&f, a, 1); // sender completion
        let rbuf = crate::fabric::mem::DmaBuf::new(0x50_0000, 64);
        f.post(
            b,
            WorkRequest {
                id: 2,
                qp: QpId(0),
                op: WrOp::Recv {
                    buf: DmaSlice::whole(&rbuf),
                },
                chained: false,
            },
        );
        let cqes = drain(&f, b, 1);
        assert_eq!(cqes[0].wr_id, 2);
        assert_eq!(&rbuf.to_vec()[..5], b"hello");
        f.shutdown();
    }

    #[test]
    fn payload_visible_before_imm() {
        // For every imm CQE observed, the payload must already be in
        // memory — poll aggressively while writes stream in.
        let f = LocalFabric::new(TransportKind::Srd, 3);
        let (a, b) = (addr(0), addr(1));
        f.add_nic(a);
        f.add_nic(b);
        let (sbuf, _) = f.mem().alloc(64);
        let (dbuf, drkey) = f.mem().alloc(8 * 64);
        for i in 0..64u64 {
            sbuf.write(0, &i.to_le_bytes());
            // One write per imm, to distinct slots.
            f.post(
                a,
                WorkRequest {
                    id: i,
                    qp: QpId(1),
                    op: WrOp::Write {
                        dst: b,
                        dst_rkey: drkey,
                        dst_va: dbuf.base() + i * 8,
                        src: DmaSlice::new(&sbuf, 0, 8),
                        imm: Some(i as u32),
                    },
                    chained: false,
                },
            );
            // The source buffer is reused, so wait for the sender ack
            // before rewriting it (as a real app must).
            let _ = drain(&f, a, 1);
        }
        let cqes = drain(&f, b, 64);
        assert_eq!(cqes.len(), 64);
        for c in &cqes {
            if let CqeKind::ImmRecvd { imm, .. } = c.kind {
                let mut v = [0u8; 8];
                dbuf.read(imm as usize * 8, &mut v);
                assert_eq!(u64::from_le_bytes(v), imm as u64, "payload before imm");
            }
        }
        f.shutdown();
    }

    #[test]
    fn chaos_threaded_nic_down_errors_and_recovers() {
        let f = LocalFabric::new(TransportKind::Rc, 6);
        let (a, b) = (addr(0), addr(1));
        f.add_nic(a);
        f.add_nic(b);
        let flips = Arc::new(Mutex::new(Vec::new()));
        let fl = flips.clone();
        f.set_health_hook(b, Box::new(move |up| fl.lock().unwrap().push(up)));
        let (sbuf, _) = f.mem().alloc(32);
        let (dbuf, drkey) = f.mem().alloc(32);
        sbuf.write(0, &[5u8; 32]);
        let wr = |id| WorkRequest {
            id,
            qp: QpId(1),
            op: WrOp::Write {
                dst: b,
                dst_rkey: drkey,
                dst_va: dbuf.base(),
                src: DmaSlice::new(&sbuf, 0, 32),
                imm: Some(1),
            },
            chained: false,
        };
        f.set_nic_up(b, false);
        f.post(a, wr(1));
        let cqes = drain(&f, a, 1);
        assert_eq!(cqes[0].kind, CqeKind::WrError);
        assert_eq!(dbuf.to_vec(), vec![0u8; 32], "nothing commits to a dead NIC");
        // Recovery: the same WR delivers after NicUp.
        f.set_nic_up(b, true);
        f.post(a, wr(2));
        let acks = drain(&f, a, 1);
        assert_eq!(acks[0].kind, CqeKind::WriteDone);
        assert_eq!(dbuf.to_vec(), vec![5u8; 32]);
        assert_eq!(*flips.lock().unwrap(), vec![false, true]);
        f.shutdown();
    }

    #[test]
    fn chaos_threaded_link_partition_errors_and_heals() {
        let f = LocalFabric::new(TransportKind::Rc, 16);
        let (a, b, c) = (addr(0), addr(1), addr(2));
        for n in [a, b, c] {
            f.add_nic(n);
        }
        let flips = Arc::new(Mutex::new(Vec::new()));
        let fl = flips.clone();
        f.set_link_hook(a, Box::new(move |dst, up| fl.lock().unwrap().push((dst, up))));
        let (sbuf, _) = f.mem().alloc(32);
        sbuf.write(0, &[4u8; 32]);
        let (dbuf_b, rkey_b) = f.mem().alloc(32);
        let (dbuf_c, rkey_c) = f.mem().alloc(32);
        let wr = |id, dst, rkey: RKey, va| WorkRequest {
            id,
            qp: QpId(1),
            op: WrOp::Write {
                dst,
                dst_rkey: rkey,
                dst_va: va,
                src: DmaSlice::new(&sbuf, 0, 32),
                imm: None,
            },
            chained: false,
        };
        f.set_link_up(a, b, false);
        assert!(!f.link_up(a, b));
        assert!(f.nic_up(a) && f.nic_up(b), "both endpoints stay up");
        f.post(a, wr(1, b, rkey_b, dbuf_b.base()));
        let cqes = drain(&f, a, 1);
        assert_eq!(cqes[0].kind, CqeKind::WrError);
        assert_eq!(dbuf_b.to_vec(), vec![0u8; 32], "nothing commits across a cut link");
        // The a → c path is untouched.
        f.post(a, wr(2, c, rkey_c, dbuf_c.base()));
        let cqes = drain(&f, a, 1);
        assert_eq!(cqes[0].kind, CqeKind::WriteDone);
        assert_eq!(dbuf_c.to_vec(), vec![4u8; 32]);
        // Heal: the same route delivers again.
        f.set_link_up(a, b, true);
        f.post(a, wr(3, b, rkey_b, dbuf_b.base()));
        let cqes = drain(&f, a, 1);
        assert_eq!(cqes[0].kind, CqeKind::WriteDone);
        assert_eq!(dbuf_b.to_vec(), vec![4u8; 32]);
        assert_eq!(*flips.lock().unwrap(), vec![(b, false), (b, true)]);
        f.shutdown();
    }

    use crate::fabric::mem::RKey;

    #[test]
    fn srd_reorders_under_load() {
        let f = LocalFabric::new(TransportKind::Srd, 4);
        let (a, b) = (addr(0), addr(1));
        f.add_nic(a);
        f.add_nic(b);
        let (sbuf, _) = f.mem().alloc(8);
        let (dbuf, drkey) = f.mem().alloc(4096);
        for i in 0..256u64 {
            f.post(
                a,
                WorkRequest {
                    id: i,
                    qp: QpId(1),
                    op: WrOp::Write {
                        dst: b,
                        dst_rkey: drkey,
                        dst_va: dbuf.base(),
                        src: DmaSlice::new(&sbuf, 0, 8),
                        imm: Some(i as u32),
                    },
                    chained: false,
                },
            );
        }
        let cqes = drain(&f, b, 256);
        let imms: Vec<u32> = cqes
            .iter()
            .filter_map(|c| match c.kind {
                CqeKind::ImmRecvd { imm, .. } => Some(imm),
                _ => None,
            })
            .collect();
        assert_eq!(imms.len(), 256);
        let sorted = {
            let mut s = imms.clone();
            s.sort_unstable();
            s
        };
        assert_ne!(imms, sorted, "SRD should reorder under load");
        f.shutdown();
    }
}
