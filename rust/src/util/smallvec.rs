//! Inline small-vector for the engine hot path.
//!
//! Every submission allocated one or more `Vec`s per call (the routed
//! WR list, the worker-side post list); at the common 2–4 lane fanout
//! those vectors hold a handful of elements, so the allocator round
//! trip dominates. [`SmallVec`] keeps up to `N` elements inline on the
//! stack and spills to a heap `Vec` only past that — the spill path is
//! the cold one (large sharded writes, wide scatters).
//!
//! Deliberately minimal: the engine needs push/collect/iterate/index
//! and nothing else. No `insert`/`remove`, no capacity tuning.

use std::fmt;
use std::mem::{ManuallyDrop, MaybeUninit};
use std::ops::{Deref, DerefMut};

/// A vector holding up to `N` elements inline (no heap allocation)
/// and transparently spilling to a `Vec<T>` beyond that.
///
/// Invariant: when `heap` is `Some`, every element lives in the heap
/// `Vec` and `len == 0`; when `heap` is `None`, the first `len` slots
/// of `inline` are initialized.
pub struct SmallVec<T, const N: usize> {
    inline: [MaybeUninit<T>; N],
    len: usize,
    heap: Option<Vec<T>>,
}

impl<T, const N: usize> SmallVec<T, N> {
    /// An empty vector; allocates nothing.
    pub fn new() -> Self {
        SmallVec {
            // SAFETY: an array of `MaybeUninit` is always "initialized"
            // — each slot is itself allowed to be uninitialized.
            inline: unsafe { MaybeUninit::uninit().assume_init() },
            len: 0,
            heap: None,
        }
    }

    /// True when the elements have spilled to the heap.
    pub fn spilled(&self) -> bool {
        self.heap.is_some()
    }

    /// Append an element, spilling to the heap when the inline
    /// capacity `N` is exceeded.
    pub fn push(&mut self, v: T) {
        if let Some(vec) = &mut self.heap {
            vec.push(v);
            return;
        }
        if self.len < N {
            self.inline[self.len].write(v);
            self.len += 1;
        } else {
            self.spill(N + 1).push(v);
        }
    }

    /// Drop every element; keeps the heap allocation if one exists.
    pub fn clear(&mut self) {
        if let Some(vec) = &mut self.heap {
            vec.clear();
        } else {
            for slot in &mut self.inline[..self.len] {
                // SAFETY: the first `len` inline slots are initialized;
                // `len` is reset below so they are never touched again.
                unsafe { slot.assume_init_drop() };
            }
            self.len = 0;
        }
    }

    /// Move the inline elements into a fresh heap `Vec` (cold path).
    fn spill(&mut self, cap: usize) -> &mut Vec<T> {
        debug_assert!(self.heap.is_none());
        let mut vec = Vec::with_capacity(cap.max(self.len));
        for slot in &self.inline[..self.len] {
            // SAFETY: the first `len` inline slots are initialized and
            // ownership moves into `vec`; `len` is reset immediately so
            // the slots are never read or dropped again.
            vec.push(unsafe { slot.assume_init_read() });
        }
        self.len = 0;
        self.heap = Some(vec);
        self.heap.as_mut().expect("just set")
    }
}

impl<T, const N: usize> Default for SmallVec<T, N> {
    fn default() -> Self {
        SmallVec::new()
    }
}

impl<T, const N: usize> Drop for SmallVec<T, N> {
    fn drop(&mut self) {
        if self.heap.is_none() {
            for slot in &mut self.inline[..self.len] {
                // SAFETY: per the struct invariant these slots are
                // initialized and this is the final owner.
                unsafe { slot.assume_init_drop() };
            }
        }
    }
}

impl<T, const N: usize> Deref for SmallVec<T, N> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        match &self.heap {
            Some(vec) => vec,
            // SAFETY: the first `len` inline slots are initialized and
            // `MaybeUninit<T>` is layout-identical to `T`.
            None => unsafe {
                std::slice::from_raw_parts(self.inline.as_ptr().cast::<T>(), self.len)
            },
        }
    }
}

impl<T, const N: usize> DerefMut for SmallVec<T, N> {
    fn deref_mut(&mut self) -> &mut [T] {
        match &mut self.heap {
            Some(vec) => vec,
            // SAFETY: as in `deref`, plus exclusive access via `&mut`.
            None => unsafe {
                std::slice::from_raw_parts_mut(self.inline.as_mut_ptr().cast::<T>(), self.len)
            },
        }
    }
}

impl<T, const N: usize> FromIterator<T> for SmallVec<T, N> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        let mut sv = SmallVec::new();
        sv.extend(iter);
        sv
    }
}

impl<T, const N: usize> Extend<T> for SmallVec<T, N> {
    fn extend<I: IntoIterator<Item = T>>(&mut self, iter: I) {
        let iter = iter.into_iter();
        // Spill up front when the iterator is provably too large for
        // the inline buffer, so we don't move elements twice.
        let (lower, _) = iter.size_hint();
        if self.heap.is_none() && self.len + lower > N {
            self.spill(self.len + lower);
        }
        for v in iter {
            self.push(v);
        }
    }
}

impl<T, const N: usize> From<Vec<T>> for SmallVec<T, N> {
    /// Wraps an existing `Vec` as the heap storage (the allocation is
    /// already paid; moving elements inline would gain nothing).
    fn from(vec: Vec<T>) -> Self {
        SmallVec {
            // SAFETY: see `SmallVec::new`.
            inline: unsafe { MaybeUninit::uninit().assume_init() },
            len: 0,
            heap: Some(vec),
        }
    }
}

impl<T: Clone, const N: usize> Clone for SmallVec<T, N> {
    fn clone(&self) -> Self {
        self.iter().cloned().collect()
    }
}

impl<T: fmt::Debug, const N: usize> fmt::Debug for SmallVec<T, N> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<T: PartialEq, const N: usize> PartialEq for SmallVec<T, N> {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

impl<T: Eq, const N: usize> Eq for SmallVec<T, N> {}

/// Consuming iterator over a [`SmallVec`].
pub enum IntoIter<T, const N: usize> {
    /// Elements had spilled; delegate to the `Vec` iterator.
    Heap(std::vec::IntoIter<T>),
    /// Elements live inline; `[next, len)` are still owned here.
    Inline {
        /// The inline buffer, moved out of the `SmallVec`.
        buf: [MaybeUninit<T>; N],
        /// Index of the next element to yield.
        next: usize,
        /// One past the last initialized slot.
        len: usize,
    },
}

impl<T, const N: usize> IntoIterator for SmallVec<T, N> {
    type Item = T;
    type IntoIter = IntoIter<T, N>;
    fn into_iter(self) -> IntoIter<T, N> {
        // `SmallVec` has a `Drop` impl, so fields cannot be moved out
        // directly; `ManuallyDrop` suppresses the drop while ownership
        // of the storage transfers to the iterator.
        let mut me = ManuallyDrop::new(self);
        match me.heap.take() {
            Some(vec) => IntoIter::Heap(vec.into_iter()),
            None => IntoIter::Inline {
                // SAFETY: `me` is ManuallyDrop, so the buffer has a
                // single owner (the iterator) from here on.
                buf: unsafe { std::ptr::read(&me.inline) },
                next: 0,
                len: me.len,
            },
        }
    }
}

impl<'a, T, const N: usize> IntoIterator for &'a SmallVec<T, N> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl<'a, T, const N: usize> IntoIterator for &'a mut SmallVec<T, N> {
    type Item = &'a mut T;
    type IntoIter = std::slice::IterMut<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter_mut()
    }
}

impl<T, const N: usize> Iterator for IntoIter<T, N> {
    type Item = T;
    fn next(&mut self) -> Option<T> {
        match self {
            IntoIter::Heap(it) => it.next(),
            IntoIter::Inline { buf, next, len } => {
                if next < len {
                    // SAFETY: slots `[next, len)` are initialized and
                    // owned by the iterator; advancing `next` transfers
                    // this one out exactly once.
                    let v = unsafe { buf[*next].assume_init_read() };
                    *next += 1;
                    Some(v)
                } else {
                    None
                }
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = match self {
            IntoIter::Heap(it) => it.len(),
            IntoIter::Inline { next, len, .. } => len - next,
        };
        (n, Some(n))
    }
}

impl<T, const N: usize> ExactSizeIterator for IntoIter<T, N> {}

impl<T, const N: usize> Drop for IntoIter<T, N> {
    fn drop(&mut self) {
        if let IntoIter::Inline { buf, next, len } = self {
            for slot in &mut buf[*next..*len] {
                // SAFETY: the un-yielded tail `[next, len)` is still
                // initialized and owned by the iterator.
                unsafe { slot.assume_init_drop() };
            }
            *len = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::rc::Rc;

    type Sv4 = SmallVec<u32, 4>;

    #[test]
    fn stays_inline_up_to_capacity() {
        let mut v = Sv4::new();
        assert!(v.is_empty() && !v.spilled());
        for i in 0..4 {
            v.push(i);
        }
        assert_eq!(&*v, &[0, 1, 2, 3]);
        assert!(!v.spilled(), "4 elements must fit inline in SmallVec<_, 4>");
        v.push(4);
        assert!(v.spilled());
        assert_eq!(&*v, &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn collect_iterate_index_mutate() {
        let mut v: SmallVec<u32, 2> = (0..6).collect();
        assert!(v.spilled());
        assert_eq!(v.len(), 6);
        assert_eq!(v[3], 3);
        v[3] = 33;
        for x in v.iter_mut() {
            *x += 1;
        }
        let out: Vec<u32> = v.into_iter().collect();
        assert_eq!(out, vec![1, 2, 3, 34, 5, 6]);

        let small: Sv4 = (0..3).collect();
        assert!(!small.spilled());
        assert_eq!(small.iter().sum::<u32>(), 3);
        assert_eq!(small.into_iter().collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn clone_eq_debug_from_vec() {
        let a: Sv4 = (10..13).collect();
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(format!("{a:?}"), "[10, 11, 12]");
        let c: Sv4 = vec![10, 11, 12].into();
        assert!(c.spilled());
        assert_eq!(&*a, &*c, "From<Vec> preserves contents");
    }

    #[test]
    fn clear_resets_both_modes() {
        let mut v: Sv4 = (0..3).collect();
        v.clear();
        assert!(v.is_empty());
        v.extend(0..10);
        assert!(v.spilled());
        v.clear();
        assert!(v.is_empty());
        v.push(7);
        assert_eq!(&*v, &[7]);
    }

    /// Every element is dropped exactly once in every mode: inline
    /// drop, heap drop, fully-consumed iterator, and a half-consumed
    /// iterator whose tail the iterator's own Drop must release.
    #[test]
    fn drops_each_element_exactly_once() {
        let probe = Rc::new(());
        {
            let mut inline: SmallVec<Rc<()>, 4> = SmallVec::new();
            let mut heap: SmallVec<Rc<()>, 2> = SmallVec::new();
            for _ in 0..3 {
                inline.push(probe.clone());
                heap.push(probe.clone());
            }
            assert!(!inline.spilled() && heap.spilled());
            assert_eq!(Rc::strong_count(&probe), 7);

            let mut it = inline.into_iter();
            assert!(it.next().is_some()); // yielded value dropped here
            drop(it); // tail of 2 dropped by IntoIter::drop
            assert_eq!(Rc::strong_count(&probe), 4);

            for rc in heap {
                drop(rc);
            }
            assert_eq!(Rc::strong_count(&probe), 1);
        }
        assert_eq!(Rc::strong_count(&probe), 1);
    }

    #[test]
    fn extend_pre_spills_on_size_hint() {
        let mut v: Sv4 = SmallVec::new();
        v.push(1);
        v.extend(vec![2, 3, 4, 5]); // 1 + 4 > 4 → single spill up front
        assert!(v.spilled());
        assert_eq!(&*v, &[1, 2, 3, 4, 5]);
    }
}
