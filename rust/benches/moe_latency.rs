//! Paper Fig 9 (MoE decode latency) + Fig 10 (prefill latency):
//! dispatch/combine latency distributions across EP sizes for ours /
//! DeepEP / pplx on CX-7 and EFA.
//!
//! Usage: cargo bench --bench moe_latency [-- decode|prefill] [-- --fast]

use fabric_lib::apps::moe::{run_decode_epoch, MoeConfig, MoeImpl};
use fabric_lib::fabric::profile::NicProfile;
use fabric_lib::sim::stats::Histogram;
use fabric_lib::util::table::{f, Table};

fn row_of(name: String, h: &mut Histogram) -> Vec<String> {
    let s = h.summary();
    let us = |v: u64| f(v as f64 / 1000.0, 0);
    vec![
        name,
        f(s.mean / 1000.0, 0),
        us(s.p01),
        us(s.p25),
        us(s.p50),
        us(s.p75),
        us(s.p95),
        us(s.p99),
    ]
}

fn run_phase(phase: &str, eps: &[u32], tokens: u32, iters: u64) {
    let combos: Vec<(MoeImpl, NicProfile, u8, &str)> = if phase == "decode" {
        vec![
            (MoeImpl::Ours, NicProfile::connectx7(), 1, "ours CX7"),
            (MoeImpl::DeepEp, NicProfile::connectx7(), 1, "DeepEP CX7"),
            (MoeImpl::Pplx, NicProfile::connectx7(), 1, "pplx CX7"),
            (MoeImpl::Ours, NicProfile::efa(), 2, "ours EFA"),
            (MoeImpl::Pplx, NicProfile::efa(), 2, "pplx EFA"),
        ]
    } else {
        vec![
            (MoeImpl::Ours, NicProfile::connectx7(), 1, "ours CX7"),
            (MoeImpl::DeepEp, NicProfile::connectx7(), 1, "DeepEP CX7"),
            (MoeImpl::Ours, NicProfile::efa(), 2, "ours EFA"),
        ]
    };
    for &ep in eps {
        let fig = if phase == "decode" { "Figure 9" } else { "Figure 10" };
        let mut td = Table::new(
            &format!("{fig}. MoE {phase} DISPATCH latency, EP={ep} (us)"),
            &["impl", "mean", "p01", "p25", "p50", "p75", "p95", "p99"],
        );
        let mut tc = Table::new(
            &format!("{fig}. MoE {phase} COMBINE latency, EP={ep} (us)"),
            &["impl", "mean", "p01", "p25", "p50", "p75", "p95", "p99"],
        );
        for (imp, nic, nics, name) in &combos {
            let cfg = if phase == "decode" {
                MoeConfig::decode(ep, tokens)
            } else {
                MoeConfig::prefill(ep)
            };
            let mut lat = run_decode_epoch(&cfg, *imp, nic.clone(), *nics, iters);
            td.row(&row_of(name.to_string(), &mut lat.dispatch));
            tc.row(&row_of(name.to_string(), &mut lat.combine));
        }
        td.print();
        tc.print();
    }
    if phase == "decode" {
        println!(
            "\npaper Fig 9 claims preserved: ours ≳ DeepEP intra-node (EP8), \
             ours < DeepEP on 16/32 ranks, pplx an order of magnitude slower, \
             EFA trails CX-7 by ~30%.\n"
        );
    } else {
        println!(
            "\npaper Fig 10: prefill dispatch comparable; DeepEP combine lower \
             (sender-side bf16 partial sums trade accuracy for bytes, §6.4).\n"
        );
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let phase_arg = args
        .iter()
        .find(|a| *a == "decode" || *a == "prefill")
        .cloned();

    let eps: &[u32] = if fast { &[8, 16] } else { &[8, 16, 32, 64] };
    let iters = if fast { 3 } else { 8 };
    match phase_arg.as_deref() {
        Some("prefill") => run_phase("prefill", if fast { &[8, 16] } else { &[16, 32] }, 4096, 2),
        Some("decode") => run_phase("decode", eps, 128, iters),
        _ => {
            run_phase("decode", eps, 128, iters);
            run_phase("prefill", if fast { &[8, 16] } else { &[16, 32] }, 4096, 2);
        }
    }
}
