//! Runtime-neutral compute/clock model: the "GPU side" of full-model
//! scenarios, written once over [`super::traits::Cx`] so the KvCache
//! Table-3 harness, MoE decode epochs and the RL weight-update
//! pipeline run unchanged on the DES virtual clock *and* on real
//! threads/`std::time`.
//!
//! The submission layer became runtime-agnostic in the PR-1 trait
//! unification; this module does the same for everything a scenario
//! schedules *outside* the fabric:
//!
//! * [`Cont`] / [`Fired`] / [`WakeSender`] — runtime-neutral
//!   continuations. Scenario state machines live in `Rc<RefCell<..>>`
//!   cells on the driving thread; engine completions reach them either
//!   directly inside the DES event loop (with `&mut Sim` in hand) or,
//!   on the threaded runtime, via a `Send` wake token that the
//!   [`Reactor`] dispatches back on the driving thread.
//! * [`Reactor`] — the threaded runtime's clock: a timer heap over
//!   `std::time::Instant` plus the cross-thread wake queue, pumped by
//!   `Cx::wait`/`Cx::drive_until`/`Cx::settle`.
//! * [`ComputeModel`] — per-stream in-order kernel execution (the
//!   [`crate::fabric::gpu::GpuSim`] timing rules over any clock).
//! * [`NvlinkModel`] — per-link serialized intra-node pushes.
//! * [`SerialResource`] — a serial engine with a free-cursor (H2D copy
//!   engine, prep stream, submit thread) charging byte/fixed costs.
//! * [`BarrierModel`] — N-party barrier arrival with a release delay
//!   (the RL pipeline's per-mesh-group GLOO barrier).

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::rc::Rc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration as StdDuration;
use std::time::Instant as StdInstant;

use super::traits::Cx;
use crate::fabric::profile::GpuProfile;
use crate::sim::time::{Duration, Instant};
use crate::sim::{Sim, SimStats};

// ---------------------------------------------------------------------
// Continuations
// ---------------------------------------------------------------------

/// Payload delivered to a fired continuation: two scalar slots (UVM
/// watchers use `(old, new)`), an optional byte payload (SEND/RECV
/// messages — delivered by value, so the receive path hands the pooled
/// buffer's extracted bytes over without an extra copy), and a poison
/// marker for completions that carry an error (e.g. a truncated
/// receive on the threaded runtime). Completion-only events leave
/// everything empty.
#[derive(Debug, Default, Clone)]
pub struct Fired {
    pub a: u64,
    pub b: u64,
    pub data: Vec<u8>,
    /// Set when the event completed abnormally: the diagnostic the
    /// submitter should see. `data` still carries whatever payload
    /// survived (e.g. the truncated prefix of an oversized SEND).
    pub poison: Option<String>,
}

impl Fired {
    /// Payload carrying the two scalar slots.
    pub fn pair(a: u64, b: u64) -> Self {
        Fired {
            a,
            b,
            ..Fired::default()
        }
    }

    /// Payload carrying bytes.
    pub fn bytes(data: Vec<u8>) -> Self {
        Fired {
            data,
            ..Fired::default()
        }
    }

    /// Poisoned payload: `data` holds what survived, `msg` says what
    /// went wrong.
    pub fn poisoned(data: Vec<u8>, msg: String) -> Self {
        Fired {
            data,
            poison: Some(msg),
            ..Fired::default()
        }
    }

    /// The payload bytes as a `Result`: `Err` with the poison
    /// diagnostic when the event completed abnormally — how receive
    /// callbacks distinguish a truncated message from a completion.
    pub fn ok(&self) -> crate::util::err::Result<&[u8]> {
        match &self.poison {
            Some(msg) => Err(crate::util::err::Error::msg(msg.clone())),
            None => Ok(&self.data),
        }
    }
}

type DesHandler = Rc<RefCell<Box<dyn FnMut(&mut Sim, Fired)>>>;

/// `Send` half of a threaded continuation: pushing a [`Fired`] enqueues
/// the token on the owning [`Reactor`]'s wake queue and wakes it.
/// The sender (and its clones) also keep the handler registered: once
/// every clone is dropped, the reactor reclaims the handler slot.
#[derive(Clone)]
pub struct WakeSender {
    token: u64,
    queue: WakeQueue,
    /// Liveness token; the reactor holds the matching `Weak`.
    _live: Arc<()>,
}

impl WakeSender {
    /// Fire the continuation with `payload` (callable from any thread).
    pub fn send(&self, payload: Fired) {
        let (m, cv) = &*self.queue;
        m.lock().unwrap().push_back((self.token, payload));
        cv.notify_all();
    }
}

enum ContInner {
    /// DES: invoked synchronously inside the event loop, at the
    /// completion's virtual time, with the simulator in hand.
    Des(DesHandler),
    /// Threaded: a wake token dispatched by the driving thread's
    /// [`Reactor`].
    Threaded(WakeSender),
}

/// A runtime-neutral, multi-shot continuation produced by
/// [`Cx::cont`]: the handler runs on the scenario's driving context
/// (so it may hold `Rc` state and submit further work), regardless of
/// which thread observed the completion.
pub struct Cont {
    inner: ContInner,
}

impl Clone for Cont {
    fn clone(&self) -> Self {
        Cont {
            inner: match &self.inner {
                ContInner::Des(f) => ContInner::Des(f.clone()),
                ContInner::Threaded(tx) => ContInner::Threaded(tx.clone()),
            },
        }
    }
}

impl Cont {
    /// Build the DES flavor from a sim-level handler.
    pub(crate) fn des(f: impl FnMut(&mut Sim, Fired) + 'static) -> Self {
        let f: Box<dyn FnMut(&mut Sim, Fired)> = Box::new(f);
        Cont {
            inner: ContInner::Des(Rc::new(RefCell::new(f))),
        }
    }

    /// Build the threaded flavor from a registered reactor token.
    pub(crate) fn threaded(tx: WakeSender) -> Self {
        Cont {
            inner: ContInner::Threaded(tx),
        }
    }

    /// Fire on the DES runtime (engine-internal; called inside the
    /// event loop).
    pub fn fire_des(&self, sim: &mut Sim, payload: Fired) {
        match &self.inner {
            ContInner::Des(f) => {
                let mut f = f.borrow_mut();
                (*f)(sim, payload)
            }
            ContInner::Threaded(_) => {
                panic!("threaded continuation fired on the DES runtime")
            }
        }
    }

    /// Extract the `Send` wake half (threaded engines only).
    pub fn into_sender(self) -> WakeSender {
        match self.inner {
            ContInner::Threaded(tx) => tx,
            ContInner::Des(_) => {
                panic!("DES continuation passed to a threaded engine")
            }
        }
    }
}

// ---------------------------------------------------------------------
// The threaded runtime's reactor
// ---------------------------------------------------------------------

type WakeQueue = Arc<(Mutex<VecDeque<(u64, Fired)>>, Condvar)>;
type LocalHandler = Rc<RefCell<Box<dyn FnMut(&mut Cx, Fired)>>>;

struct HandlerEntry {
    /// Dead once every [`WakeSender`] clone for this token is dropped.
    live: std::sync::Weak<()>,
    f: LocalHandler,
}

struct ReactorState {
    epoch: StdInstant,
    next_token: u64,
    next_timer: u64,
    handlers: HashMap<u64, HandlerEntry>,
    /// (deadline ns, seq) min-heap; thunks keyed by seq.
    timers: BinaryHeap<Reverse<(u64, u64)>>,
    thunks: HashMap<u64, Box<dyn FnOnce(&mut Cx)>>,
    /// Idle-step counter throttling handler reclamation sweeps.
    idle_steps: u32,
    /// Timer counters mirroring the DES scheduler's `Sim::stats` so
    /// `Cx::stats` works on both runtimes (the reactor has no timer
    /// cancellation, so `cancelled` stays 0).
    stats: SimStats,
}

/// The threaded runtime's clock and dispatcher. Timers fire in real
/// time relative to the reactor's epoch; wake tokens posted from
/// worker/watcher threads are dispatched on the driving thread, so
/// scenario state needs no locks. Cloning yields another handle to the
/// same reactor.
#[derive(Clone)]
pub struct Reactor {
    state: Rc<RefCell<ReactorState>>,
    queue: WakeQueue,
}

impl Default for Reactor {
    fn default() -> Self {
        Self::new()
    }
}

impl Reactor {
    /// Fresh reactor; its clock starts now.
    pub fn new() -> Self {
        Reactor {
            state: Rc::new(RefCell::new(ReactorState {
                epoch: StdInstant::now(),
                next_token: 1,
                next_timer: 1,
                handlers: HashMap::new(),
                timers: BinaryHeap::new(),
                thunks: HashMap::new(),
                idle_steps: 0,
                stats: SimStats::default(),
            })),
            queue: Arc::new((Mutex::new(VecDeque::new()), Condvar::new())),
        }
    }

    /// ns since the reactor's epoch (the threaded runtime's `now`).
    pub fn now_ns(&self) -> u64 {
        self.state.borrow().epoch.elapsed().as_nanos() as u64
    }

    /// Register a local handler; returns its `Send` wake half. The
    /// handler slot is reclaimed once every clone of the returned
    /// sender has been dropped.
    pub fn register(&self, h: impl FnMut(&mut Cx, Fired) + 'static) -> WakeSender {
        let h: Box<dyn FnMut(&mut Cx, Fired)> = Box::new(h);
        let live = Arc::new(());
        let token = {
            let mut st = self.state.borrow_mut();
            let t = st.next_token;
            st.next_token += 1;
            st.handlers.insert(
                t,
                HandlerEntry {
                    live: Arc::downgrade(&live),
                    f: Rc::new(RefCell::new(h)),
                },
            );
            t
        };
        WakeSender {
            token,
            queue: self.queue.clone(),
            _live: live,
        }
    }

    /// Schedule `k` at absolute reactor time `at_ns` (past deadlines
    /// fire on the next pump).
    pub fn schedule_at(&self, at_ns: u64, k: Box<dyn FnOnce(&mut Cx)>) {
        let mut st = self.state.borrow_mut();
        let seq = st.next_timer;
        st.next_timer += 1;
        st.timers.push(Reverse((at_ns, seq)));
        st.thunks.insert(seq, k);
        st.stats.scheduled += 1;
        let pending = st.timers.len() as u64;
        if pending > st.stats.peak_pending {
            st.stats.peak_pending = pending;
        }
    }

    /// Timer counters (scheduled/executed/peak pending), mirroring
    /// [`crate::sim::Sim::stats`] for the DES runtime.
    pub fn stats(&self) -> SimStats {
        self.state.borrow().stats
    }

    /// Dispatch one due timer or one queued wake. Returns false when
    /// nothing was runnable.
    pub fn step(&self) -> bool {
        let now = self.now_ns();
        let due = {
            let mut st = self.state.borrow_mut();
            match st.timers.peek() {
                Some(&Reverse((at, seq))) if at <= now => {
                    st.timers.pop();
                    st.stats.executed += 1;
                    st.thunks.remove(&seq)
                }
                _ => None,
            }
        };
        if let Some(k) = due {
            k(&mut Cx::Threaded(self.clone()));
            return true;
        }
        let wake = {
            let (m, _) = &*self.queue;
            m.lock().unwrap().pop_front()
        };
        if let Some((token, payload)) = wake {
            let h = self.state.borrow().handlers.get(&token).map(|e| e.f.clone());
            if let Some(h) = h {
                let mut f = h.borrow_mut();
                (*f)(&mut Cx::Threaded(self.clone()), payload);
            }
            return true;
        }
        // Nothing runnable: occasionally reclaim handlers whose
        // senders are all gone. Holding the queue lock while sweeping
        // makes this safe — a dead token can never send again, and a
        // still-alive sender blocked in `send` keeps its strong count
        // up until its wake is visible in the queue.
        {
            let mut st = self.state.borrow_mut();
            st.idle_steps = st.idle_steps.wrapping_add(1);
            if st.idle_steps % 64 == 0 && !st.handlers.is_empty() {
                let (m, _) = &*self.queue;
                let guard = m.lock().unwrap();
                if guard.is_empty() {
                    st.handlers.retain(|_, e| e.live.strong_count() > 0);
                }
            }
        }
        false
    }

    /// Block briefly until the next timer deadline or an incoming wake
    /// (bounded by `max`).
    pub fn idle_wait(&self, max: StdDuration) {
        let now = self.now_ns();
        let next = self
            .state
            .borrow()
            .timers
            .peek()
            .map(|&Reverse((at, _))| at);
        let sleep_ns = match next {
            Some(at) if at <= now => return,
            Some(at) => (at - now).min(max.as_nanos() as u64),
            None => max.as_nanos() as u64,
        };
        let (m, cv) = &*self.queue;
        let guard = m.lock().unwrap();
        if guard.is_empty() {
            let _ = cv
                .wait_timeout(guard, StdDuration::from_nanos(sleep_ns))
                .unwrap();
        }
    }
}

// ---------------------------------------------------------------------
// Compute model: per-stream kernels
// ---------------------------------------------------------------------

/// Per-stream in-order kernel execution over any clock — the
/// runtime-neutral twin of [`crate::fabric::gpu::GpuSim`], with the
/// same timing rules (per-stream free cursor, launch overhead skipped
/// for CUDA-graph launches).
#[derive(Clone)]
pub struct ComputeModel {
    profile: GpuProfile,
    streams: Rc<RefCell<HashMap<u32, Instant>>>,
}

impl ComputeModel {
    /// A GPU's worth of streams with the given timing profile.
    pub fn new(profile: GpuProfile) -> Self {
        ComputeModel {
            profile,
            streams: Rc::new(RefCell::new(HashMap::new())),
        }
    }

    /// Timing profile (cloned, mirroring `GpuSim::profile`).
    pub fn profile(&self) -> GpuProfile {
        self.profile.clone()
    }

    /// The pure timing rule: occupy `stream` for `duration` starting
    /// no earlier than `now` (+ launch overhead outside CUDA graphs),
    /// returning the scheduled (start, end). This is the single copy
    /// of the kernel-timing model — [`crate::fabric::gpu::GpuSim`]
    /// delegates here too, so the DES fabric and the scenario layer
    /// cannot drift apart.
    pub fn reserve(
        &self,
        now: Instant,
        stream: u32,
        duration: Duration,
        graph_launch: bool,
    ) -> (Instant, Instant) {
        let mut streams = self.streams.borrow_mut();
        let launch = if graph_launch { 0 } else { self.profile.launch_ns };
        let free = streams.entry(stream).or_insert(0);
        let start = (now + launch).max(*free);
        let end = start + duration;
        *free = end;
        (start, end)
    }

    /// Enqueue a kernel of `duration` on `stream`; `on_done(cx, end)`
    /// fires at completion. Returns the scheduled (start, end).
    pub fn launch(
        &self,
        cx: &mut Cx,
        stream: u32,
        duration: Duration,
        graph_launch: bool,
        on_done: impl FnOnce(&mut Cx, Instant) + 'static,
    ) -> (Instant, Instant) {
        let (start, end) = self.reserve(cx.now(), stream, duration, graph_launch);
        cx.at(end, move |cx: &mut Cx| on_done(cx, end));
        (start, end)
    }

    /// Time when `stream` becomes idle.
    pub fn stream_free(&self, stream: u32) -> Instant {
        *self.streams.borrow().get(&stream).unwrap_or(&0)
    }
}

// ---------------------------------------------------------------------
// NVLink model: per-link serialized pushes
// ---------------------------------------------------------------------

/// Intra-node NVLink pushes, serialized per (src, dst) link — the
/// runtime-neutral twin of [`crate::fabric::gpu::NvlinkFabric`].
#[derive(Clone, Default)]
pub struct NvlinkModel {
    links: Rc<RefCell<HashMap<(u8, u8), Instant>>>,
}

impl NvlinkModel {
    /// Fresh fabric (one per node).
    pub fn new() -> Self {
        Self::default()
    }

    /// The pure timing rule: serialize `bytes` on link (src, dst)
    /// starting no earlier than `now`; returns the completion time.
    /// Single copy of the NVLink model —
    /// [`crate::fabric::gpu::NvlinkFabric`] delegates here too.
    pub fn push_at(
        &self,
        now: Instant,
        profile: &GpuProfile,
        src: u8,
        dst: u8,
        bytes: u64,
    ) -> Instant {
        let mut links = self.links.borrow_mut();
        let free = links.entry((src, dst)).or_insert(0);
        let start = now.max(*free);
        let end = start + profile.nvlink_transfer_ns(bytes);
        *free = end;
        end
    }

    /// Push `bytes` from `src` to `dst`; returns the completion time
    /// (stores visible at the peer).
    pub fn push(&self, cx: &Cx, profile: &GpuProfile, src: u8, dst: u8, bytes: u64) -> Instant {
        self.push_at(cx.now(), profile, src, dst, bytes)
    }
}

// ---------------------------------------------------------------------
// Serial resources: H2D copy engine, prep stream, submit thread
// ---------------------------------------------------------------------

/// A serial engine (one H2D copy engine, one preparation stream, one
/// submit thread): work queues behind earlier work, `reserve` returns
/// the (start, end) the caller should schedule against.
#[derive(Clone, Default)]
pub struct SerialResource {
    free: Rc<RefCell<Instant>>,
}

impl SerialResource {
    /// Idle resource.
    pub fn new() -> Self {
        Self::default()
    }

    /// Occupy the resource for `cost` ns starting no earlier than now.
    pub fn reserve(&self, cx: &Cx, cost: Duration) -> (Instant, Instant) {
        let mut free = self.free.borrow_mut();
        let start = cx.now().max(*free);
        let end = start + cost;
        *free = end;
        (start, end)
    }
}

// ---------------------------------------------------------------------
// Barrier model
// ---------------------------------------------------------------------

struct BarrierState {
    expected: usize,
    release_delay: Duration,
    arrived: Vec<(u32, Instant)>,
    waiters: Vec<(u32, Box<dyn FnOnce(&mut Cx, Instant)>)>,
}

/// N-party barrier with a release delay (the RL pipeline's GLOO
/// barrier over Ethernet): every arrival parks a continuation; when
/// the last party arrives, all continuations run `release_delay` after
/// the final arrival. One-shot per instance.
#[derive(Clone)]
pub struct BarrierModel {
    s: Rc<RefCell<BarrierState>>,
}

impl BarrierModel {
    /// Barrier over `expected` parties with the given release delay.
    pub fn new(expected: usize, release_delay: Duration) -> Self {
        BarrierModel {
            s: Rc::new(RefCell::new(BarrierState {
                expected,
                release_delay,
                arrived: Vec::new(),
                waiters: Vec::new(),
            })),
        }
    }

    /// When `rank` arrived, if it has.
    pub fn arrival_of(&self, rank: u32) -> Option<Instant> {
        self.s
            .borrow()
            .arrived
            .iter()
            .find(|&&(r, _)| r == rank)
            .map(|&(_, t)| t)
    }

    /// Arrive at the barrier; `k(cx, released_at)` runs once all
    /// parties arrived plus the release delay.
    pub fn arrive(&self, cx: &mut Cx, rank: u32, k: impl FnOnce(&mut Cx, Instant) + 'static) {
        let release = {
            let mut b = self.s.borrow_mut();
            b.arrived.push((rank, cx.now()));
            b.waiters.push((rank, Box::new(k)));
            if b.arrived.len() == b.expected {
                let max_t = b.arrived.iter().map(|&(_, t)| t).max().unwrap();
                Some((max_t + b.release_delay, std::mem::take(&mut b.waiters)))
            } else {
                None
            }
        };
        if let Some((release_at, waiters)) = release {
            for (_, w) in waiters {
                cx.at(release_at, move |cx: &mut Cx| w(cx, release_at));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::US;

    #[test]
    fn compute_model_serializes_streams_on_des() {
        let mut sim = Sim::new();
        let mut cx = Cx::Des(&mut sim);
        let cm = ComputeModel::new(GpuProfile::h100());
        let (s1, e1) = cm.launch(&mut cx, 0, 10 * US, true, |_, _| {});
        let (s2, e2) = cm.launch(&mut cx, 0, 5 * US, true, |_, _| {});
        assert_eq!(s1, 0);
        assert_eq!(e1, 10 * US);
        assert_eq!(s2, e1, "same stream is in-order");
        assert_eq!(e2, 15 * US);
        let (s3, _) = cm.launch(&mut cx, 1, 5 * US, true, |_, _| {});
        assert_eq!(s3, 0, "different stream runs concurrently");
        cx.settle();
    }

    #[test]
    fn compute_model_fires_on_done_on_threaded_clock() {
        let reactor = Reactor::new();
        let mut cx = Cx::Threaded(reactor.clone());
        let cm = ComputeModel::new(GpuProfile::h100());
        let hits = Rc::new(RefCell::new(Vec::new()));
        let h = hits.clone();
        cm.launch(&mut cx, 0, 50_000, true, move |_cx: &mut Cx, end| {
            h.borrow_mut().push(end);
        });
        cx.drive_until("kernel completion", || !hits.borrow().is_empty());
        assert_eq!(hits.borrow().len(), 1);
    }

    #[test]
    fn reactor_dispatches_wakes_from_other_threads() {
        let reactor = Reactor::new();
        let got = Rc::new(RefCell::new(Vec::new()));
        let g = got.clone();
        let tx = reactor.register(move |_cx, fired| g.borrow_mut().push((fired.a, fired.b)));
        let t = std::thread::spawn(move || {
            tx.send(Fired::pair(3, 7));
            tx.send(Fired::pair(7, 9));
        });
        t.join().unwrap();
        let mut cx = Cx::Threaded(reactor);
        cx.drive_until("both wakes", || got.borrow().len() == 2);
        assert_eq!(*got.borrow(), vec![(3, 7), (7, 9)]);
    }

    #[test]
    fn barrier_releases_after_last_arrival_plus_delay() {
        let mut sim = Sim::new();
        let released: Rc<RefCell<Vec<(u32, Instant)>>> = Rc::default();
        let barrier = BarrierModel::new(2, 1000);
        {
            let b1 = barrier.clone();
            let b2 = barrier.clone();
            let r1 = released.clone();
            let r2 = released.clone();
            sim.at(10, move |s| {
                let mut cx = Cx::Des(s);
                b1.arrive(&mut cx, 0, move |cx: &mut Cx, at| {
                    r1.borrow_mut().push((0, at.max(cx.now())))
                });
            });
            sim.at(500, move |s| {
                let mut cx = Cx::Des(s);
                b2.arrive(&mut cx, 1, move |cx: &mut Cx, at| {
                    r2.borrow_mut().push((1, at.max(cx.now())))
                });
            });
        }
        sim.run();
        let rel = released.borrow();
        assert_eq!(rel.len(), 2);
        assert!(rel.iter().all(|&(_, t)| t == 1500), "{rel:?}");
        assert_eq!(barrier.arrival_of(0), Some(10));
        assert_eq!(barrier.arrival_of(1), Some(500));
    }

    #[test]
    fn serial_resource_queues_work() {
        let mut sim = Sim::new();
        let cx = Cx::Des(&mut sim);
        let r = SerialResource::new();
        assert_eq!(r.reserve(&cx, 100), (0, 100));
        assert_eq!(r.reserve(&cx, 50), (100, 150));
    }
}
