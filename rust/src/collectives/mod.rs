//! Baseline collective layer (gather/broadcast world) — the approach
//! the paper's P2P weight transfer replaces (Fig 4 left).
pub mod world;
pub use world::CollectiveWorld;
