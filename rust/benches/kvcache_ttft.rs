//! Paper Table 3: KvCache transfer impact on TTFT
//! (Qwen3-235B-shaped workload, H200, 2×200 Gbps EFA).
//!
//! Usage: cargo bench --bench kvcache_ttft [-- --quick] [--json PATH]
//!
//! `--quick` (alias `--fast`) shrinks the seqlen sweep for CI smoke
//! runs; `--json PATH` merges the 4K-row headline into the report at
//! PATH under the `kvcache_ttft` section (see BENCH_p2p.json).

use std::collections::BTreeMap;

use fabric_lib::apps::kvcache::{run_table3_row, run_table3_row_with_telemetry};
use fabric_lib::util::json::{update_report, Json};
use fabric_lib::util::table::{f, Table};
use fabric_lib::util::telemetry::EngineSnapshot;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast" || a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let seqs: &[u32] = if fast {
        &[4096, 8192, 16384]
    } else {
        &[4096, 8192, 16384, 32768, 65536, 131072]
    };
    let mut headlines: BTreeMap<String, Json> = BTreeMap::new();
    let mut t = Table::new(
        "Table 3. KvCache transfer impact on TTFT (Qwen3-235B-shaped, 2x200G EFA)",
        &[
            "seqlen",
            "TTFT non (ms)",
            "TTFT disagg (ms)",
            "layer compute (ms)",
            "layer transfer (ms)",
            "steps",
            "pages",
        ],
    );
    let mut snap_4k: Option<EngineSnapshot> = None;
    for &seq in seqs {
        let r = if seq == 4096 {
            let (r, snap, _) = run_table3_row_with_telemetry(seq);
            headlines.insert("ttft_non_4k_ms".to_string(), Json::Num(r.ttft_non_ms));
            headlines.insert("ttft_disagg_4k_ms".to_string(), Json::Num(r.ttft_disagg_ms));
            snap_4k = Some(snap);
            r
        } else {
            run_table3_row(seq)
        };
        t.row(&[
            format!("{}K", seq / 1024),
            f(r.ttft_non_ms, 0),
            f(r.ttft_disagg_ms, 0),
            f(r.per_layer_compute_ms, 3),
            f(r.per_layer_transfer_ms, 3),
            r.steps.to_string(),
            r.pages.to_string(),
        ]);
    }
    t.print();
    if let Some(s) = &snap_4k {
        println!(
            "\n4K-row prefiller telemetry: {} submissions, {} WRs / {} bytes \
             on the wire, per-lane bytes {:?}, transport errors {}",
            s.total_submissions(),
            s.total_wrs(),
            s.total_bytes(),
            &s.lane_bytes[..2],
            s.transport_errors(),
        );
    }
    println!(
        "\npaper — 4K: 214/260 ms, compute 2.267 / transfer 0.661 ms; \
         128K: 16735/17056 ms, 34.895 / 1.609 ms. Claim preserved: transfer \
         hidden by compute; TTFT overhead ≈ one extra decode pass.\n"
    );

    if let Some(path) = json_path {
        headlines.insert(
            "provenance".to_string(),
            Json::from("measured by kvcache_ttft (DES, deterministic)"),
        );
        update_report(&path, "kvcache_ttft", Json::Obj(headlines)).expect("write bench report");
        println!("wrote kvcache_ttft section to {path}");
    }
}
