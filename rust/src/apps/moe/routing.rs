//! Token→expert routing generation and the derived transfer matrix.
//!
//! Each token picks `top_k` distinct random experts (the paper's
//! microbenchmark routes each token to 8 random experts). From the
//! assignment we derive, per (src, dst) rank pair, how many token
//! copies flow — the quantity that sizes every buffer and write.

use crate::sim::Rng;

use super::config::MoeConfig;

/// Routing outcome for one iteration.
#[derive(Debug, Clone)]
pub struct RoutingPlan {
    /// `tokens_to[src][dst]` = token copies src must deliver to dst.
    pub tokens_to: Vec<Vec<u32>>,
    /// Per-destination total received tokens.
    pub recv_totals: Vec<u64>,
}

impl RoutingPlan {
    /// Generate routing for `cfg` with `iter_seed` mixed into the
    /// config seed.
    pub fn generate(cfg: &MoeConfig, iter_seed: u64) -> RoutingPlan {
        let n = cfg.ranks as usize;
        let mut rng = Rng::new(cfg.seed ^ iter_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut tokens_to = vec![vec![0u32; n]; n];
        for src in 0..n {
            for _tok in 0..cfg.tokens {
                // top_k distinct experts; a token is sent once per
                // *rank* owning at least one of its experts.
                let experts = rng.choose_distinct(cfg.experts as usize, cfg.top_k as usize);
                let mut dst_ranks: Vec<usize> = experts
                    .iter()
                    .map(|&e| e / cfg.local_experts() as usize)
                    .collect();
                dst_ranks.sort_unstable();
                dst_ranks.dedup();
                for d in dst_ranks {
                    tokens_to[src][d] += 1;
                }
            }
        }
        let recv_totals = (0..n)
            .map(|d| tokens_to.iter().map(|row| row[d] as u64).sum())
            .collect();
        RoutingPlan {
            tokens_to,
            recv_totals,
        }
    }

    /// Ranks count.
    pub fn ranks(&self) -> usize {
        self.tokens_to.len()
    }

    /// Copies src sends to dst.
    pub fn count(&self, src: usize, dst: usize) -> u32 {
        self.tokens_to[src][dst]
    }

    /// Inter-node peers of `rank` that receive at least one token.
    pub fn inter_peers_with_tokens(&self, cfg: &MoeConfig, rank: usize) -> Vec<usize> {
        (0..self.ranks())
            .filter(|&d| {
                d != rank
                    && !cfg.same_node(rank as u32, d as u32)
                    && self.count(rank, d) > 0
            })
            .collect()
    }

    /// Intra-node peers (NVLink) of `rank` with tokens.
    pub fn intra_peers_with_tokens(&self, cfg: &MoeConfig, rank: usize) -> Vec<usize> {
        (0..self.ranks())
            .filter(|&d| {
                d != rank
                    && cfg.same_node(rank as u32, d as u32)
                    && self.count(rank, d) > 0
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conservation_and_bounds() {
        let cfg = MoeConfig::decode(16, 128);
        let plan = RoutingPlan::generate(&cfg, 1);
        // Every token lands on between 1 and top_k ranks.
        for src in 0..16 {
            let copies: u32 = plan.tokens_to[src].iter().sum();
            assert!(copies >= cfg.tokens, "at least one dst per token");
            assert!(copies <= cfg.tokens * cfg.top_k);
        }
        // recv totals consistent with the matrix.
        for d in 0..16 {
            let col: u64 = (0..16).map(|s| plan.count(s, d) as u64).sum();
            assert_eq!(col, plan.recv_totals[d]);
        }
        // Receive bound from §6.1 holds.
        for d in 0..16 {
            assert!(plan.recv_totals[d] <= cfg.recv_buffer_tokens());
        }
    }

    #[test]
    fn roughly_uniform_across_ranks() {
        let cfg = MoeConfig::decode(64, 128);
        let plan = RoutingPlan::generate(&cfg, 7);
        let mean = plan.recv_totals.iter().sum::<u64>() as f64 / 64.0;
        for &r in &plan.recv_totals {
            assert!(
                (r as f64) > mean * 0.6 && (r as f64) < mean * 1.4,
                "recv load {r} vs mean {mean}"
            );
        }
    }

    #[test]
    fn deterministic_per_seed_and_iter() {
        let cfg = MoeConfig::decode(8, 32);
        let a = RoutingPlan::generate(&cfg, 3);
        let b = RoutingPlan::generate(&cfg, 3);
        assert_eq!(a.tokens_to, b.tokens_to);
        let c = RoutingPlan::generate(&cfg, 4);
        assert_ne!(a.tokens_to, c.tokens_to);
    }

    #[test]
    fn peer_classification() {
        let cfg = MoeConfig::decode(16, 64);
        let plan = RoutingPlan::generate(&cfg, 0);
        let inter = plan.inter_peers_with_tokens(&cfg, 0);
        let intra = plan.intra_peers_with_tokens(&cfg, 0);
        assert!(inter.iter().all(|&d| d >= 8));
        assert!(intra.iter().all(|&d| d < 8 && d != 0));
    }
}
