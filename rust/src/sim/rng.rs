//! Seeded RNG + latency jitter distributions.
//!
//! xoshiro256++ (public-domain reference algorithm) — fast, decent
//! quality, and fully deterministic across platforms, which matters for
//! regenerating the paper's percentile tables.

/// xoshiro256++ pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator. A splitmix64 pass whitens the raw seed so
    /// consecutive seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style multiply-shift; bias is negligible for sim use.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with mean `mean`.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices out of `n` (k <= n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: first k entries become the sample.
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// A latency jitter model: `base + lognormal-ish tail`, with a rare
/// spike component to produce the long tails seen in the paper's p99.9
/// columns (e.g. UvmWatcher callback latency, EFA post times).
#[derive(Clone, Debug)]
pub struct Jitter {
    /// Median extra latency in ns (0 disables the component).
    pub median_ns: f64,
    /// Log-sigma of the lognormal body. ~0.3 = tight, ~1.0 = heavy.
    pub sigma: f64,
    /// Probability of a spike event per sample.
    pub spike_p: f64,
    /// Mean spike magnitude in ns (exponential).
    pub spike_mean_ns: f64,
}

impl Jitter {
    /// No jitter at all.
    pub const NONE: Jitter = Jitter {
        median_ns: 0.0,
        sigma: 0.0,
        spike_p: 0.0,
        spike_mean_ns: 0.0,
    };

    /// A tight jitter with the given median and mild tail.
    pub fn tight(median_ns: f64) -> Jitter {
        Jitter {
            median_ns,
            sigma: 0.25,
            spike_p: 0.001,
            spike_mean_ns: median_ns * 4.0,
        }
    }

    /// Sample one jitter value in ns.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.median_ns <= 0.0 {
            return 0;
        }
        let body = self.median_ns * (self.sigma * rng.normal()).exp();
        let spike = if self.spike_p > 0.0 && rng.f64() < self.spike_p {
            rng.exp(self.spike_mean_ns)
        } else {
            0.0
        };
        (body + spike).max(0.0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            let sample = r.choose_distinct(64, 8);
            let mut s = sample.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
            assert!(sample.iter().all(|&i| i < 64));
        }
    }

    #[test]
    fn jitter_median_roughly_right() {
        let j = Jitter::tight(1000.0);
        let mut r = Rng::new(5);
        let mut xs: Vec<u64> = (0..10_000).map(|_| j.sample(&mut r)).collect();
        xs.sort_unstable();
        let med = xs[xs.len() / 2] as f64;
        assert!((700.0..1400.0).contains(&med), "median {med}");
    }

    #[test]
    fn jitter_none_is_zero() {
        let mut r = Rng::new(6);
        assert_eq!(Jitter::NONE.sample(&mut r), 0);
    }
}
