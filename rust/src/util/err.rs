//! Minimal error plumbing standing in for `anyhow` (unavailable
//! offline — the crate builds with zero external dependencies).
//!
//! Provides the subset the codebase uses: a string-backed [`Error`],
//! a [`Result`] alias with a defaulted error type, the [`crate::bail!`]
//! macro, and the [`Context`] extension trait for `Result`/`Option`.
//! `?` works on any `std::error::Error` source via a blanket `From`.

use std::fmt;

/// String-backed error with optional context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug prints the message itself so `fn main() -> Result<()>` exits
// read like anyhow's.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` on io/parse/utf8/... errors. (No conflict with the reflexive
// `From<T> for T`: `Error` itself does not implement
// `std::error::Error`.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result`-shaped alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err(
            $crate::util::err::Error::msg(::std::format!($($arg)*)),
        )
    };
}

/// Attach context to the error side of a `Result` or to a `None`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke at {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "broke at 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting:"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }
}
