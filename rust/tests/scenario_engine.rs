//! Committed-corpus scenario tests: every spec under `scenarios/` at
//! the repo root parses, is stored in exact canonical form, runs
//! green on the DES runtime, and reproduces the outcome of the
//! hand-written chaos harness it was ported from on a same-seed
//! cluster — the spec file and the Rust harness are two spellings of
//! the same experiment.

use fabric_lib::apps::kvcache::{run_kv_failover, run_kv_link_partition, run_kv_nic_failover_on};
use fabric_lib::engine::traits::{new_flag, Cluster, Notify, OnRecv, RuntimeKind};
use fabric_lib::fabric::chaos::ChaosProfile;
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};
use fabric_lib::scenario::{run_scenario, RunOptions, ScenarioReport, ScenarioSpec};

const CORPUS: [&str; 4] = [
    "gossip_second_sender.json",
    "kv_fleet_failover.json",
    "kv_link_partition.json",
    "kv_nic_failover.json",
];

fn corpus_path(name: &str) -> String {
    format!("{}/../scenarios/{name}", env!("CARGO_MANIFEST_DIR"))
}

fn load(name: &str) -> ScenarioSpec {
    ScenarioSpec::load(&corpus_path(name))
        .unwrap_or_else(|e| panic!("corpus spec {name} must load: {e}"))
}

/// Run one corpus spec on the DES runtime; every committed spec must
/// pass its own declared assertions.
fn run(name: &str) -> ScenarioReport {
    let report = run_scenario(&load(name), &RunOptions::default())
        .unwrap_or_else(|e| panic!("corpus spec {name} must run: {e}"));
    assert!(report.passed(), "{name} failed: {:?}", report.failures);
    report
}

#[test]
fn corpus_specs_are_canonical_and_carry_assertions() {
    for name in CORPUS {
        let text = std::fs::read_to_string(corpus_path(name))
            .unwrap_or_else(|e| panic!("reading corpus spec {name}: {e}"));
        let spec = ScenarioSpec::parse(&text)
            .unwrap_or_else(|e| panic!("corpus spec {name} must parse: {e}"));
        assert!(
            !spec.assertions.is_empty(),
            "{name}: committed specs must assert something (fabric-lint R9)"
        );
        // The committed bytes are the canonical serialization — a
        // spec edited by hand into a non-canonical shape fails here.
        assert_eq!(
            spec.to_pretty_string(),
            text,
            "{name} is not stored in canonical to_pretty(2) form"
        );
    }
}

#[test]
fn corpus_runs_are_deterministic_on_des() {
    for name in CORPUS {
        let a = run(name);
        let b = run(name);
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "{name}: same-seed DES runs must agree exactly"
        );
        assert_eq!(a.end_ns, b.end_ns, "{name}");
    }
}

/// Ported from `chaos_kv_single_nic_failover_completes_without_redispatch`:
/// the spec run and the hand harness on a same-seed cluster agree on
/// the NIC mask and the transport-error count.
#[test]
fn spec_kv_nic_failover_matches_hand_harness() {
    let report = run("kv_nic_failover.json");
    let mut cluster = Cluster::new_with(
        RuntimeKind::Des,
        2,
        1,
        2,
        0xFA2,
        NicProfile::efa(),
        GpuProfile::h100(),
    );
    let engines = cluster.engines_rc();
    let (errors, mask) = {
        let (mut cx, _) = cluster.parts();
        run_kv_nic_failover_on(
            &mut cx,
            engines[0].clone(),
            engines[1].clone(),
            GpuProfile::h100(),
            128,
            15_000,
        )
    };
    cluster.shutdown();
    assert_eq!(mask, 0b01, "NIC 1 masked out of the prefiller's group");
    assert_eq!(report.nic_masks[0], mask, "spec and harness masks agree");
    assert_eq!(
        report.transport_errors[0], errors,
        "spec and harness transport errors agree"
    );
    assert!(report.no_lost_pages);
}

/// Ported from `chaos_kv_link_partition_completes_without_redispatch`:
/// a directed-link cut is not a local NIC failure — the mask stays
/// full on both spellings, and error counts agree.
#[test]
fn spec_kv_link_partition_matches_hand_harness() {
    let report = run("kv_link_partition.json");
    let (errors, mask, link_mask) = run_kv_link_partition(128, 15_000);
    assert_eq!(mask, 0b11, "a path failure is not a local NIC failure");
    assert_eq!(report.nic_masks[0], mask, "spec and harness masks agree");
    assert_eq!(
        report.transport_errors[0], errors,
        "spec and harness transport errors agree"
    );
    if errors > 0 {
        assert_eq!(link_mask, 0b01, "only the cut link's lane is masked");
    }
    assert!(report.no_lost_pages);
}

/// Ported from `chaos_kv_failover_redispatches_and_completes_every_request`:
/// the fleet spec reproduces served / redispatched / live-prefiller
/// counts of the hand harness on a same-seed cluster.
#[test]
fn spec_kv_fleet_failover_matches_hand_harness() {
    let report = run("kv_fleet_failover.json");
    let out = run_kv_failover(6, 10_000);
    assert_eq!(out.served, 6, "{out:?}");
    assert_eq!(report.served, out.served as u64);
    assert_eq!(report.redispatched, out.redispatched as u64);
    assert_eq!(report.live_prefillers, out.live_prefillers as u64);
    assert_eq!(report.transport_errors[0], out.transport_errors);
    assert!(report.no_lost_pages && out.no_lost_pages, "{out:?}");
}

/// Ported from `chaos_gossip_second_sender_completes_clean`: sender A
/// pays the error round-trips for the partitioned destination NIC and
/// gossips; sender B completes with zero transport errors. The inline
/// hand run below issues the exact call sequence the executor issues,
/// so the counters must agree number-for-number.
#[test]
fn spec_gossip_second_sender_matches_hand_harness() {
    let report = run("gossip_second_sender.json");
    let mut cluster = Cluster::new(RuntimeKind::Des, 3, 1, 2, 0x6055);
    let (a_errors, b_errors) = {
        let (mut cx, engines) = cluster.parts();
        let (a, b, d) = (engines[0], engines[1], engines[2]);
        let d0 = NicAddr {
            node: 2,
            gpu: 0,
            nic: 0,
        };
        a.set_gossip_peers(0, vec![b.group_address(0)]);
        let mut profile = ChaosProfile::new(0x605E);
        for node in [0u16, 1] {
            for nic in 0..2u8 {
                profile = profile.link_down(50_000, (NicAddr { node, gpu: 0, nic }, d0));
            }
        }
        a.inject_chaos(&mut cx, &profile);
        b.submit_recvs(&mut cx, 0, 64, 4, OnRecv::handler(|_m| {}));
        let len: usize = 8 << 20;
        let pat: Vec<u8> = (0..len).map(|i| (i * 3 % 251) as u8).collect();
        for sender in [a, b] {
            let (src, _) = sender.alloc_mr(0, len);
            let (dst_h, dst_d) = d.alloc_mr(0, len);
            src.buf.write(0, &pat);
            let done = new_flag();
            sender
                .submit_single_write(
                    &mut cx,
                    (&src, 0),
                    len as u64,
                    (&dst_d, 0),
                    None,
                    Notify::Flag(done.clone()),
                )
                .unwrap();
            cx.wait(&done);
            cx.settle();
            assert_eq!(dst_h.buf.to_vec(), pat, "zero lost payload");
        }
        cx.settle();
        (a.transport_errors(), b.transport_errors())
    };
    cluster.shutdown();
    assert!(a_errors >= 2, "A paid the error round-trips");
    assert_eq!(b_errors, 0, "B never increments transport_errors");
    assert_eq!(report.transport_errors[0], a_errors, "spec matches A's count");
    assert_eq!(report.transport_errors[1], b_errors, "spec matches B's count");
}
