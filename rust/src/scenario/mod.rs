//! Declarative scenario engine: topology × traffic × chaos from
//! config.
//!
//! The test matrix this repo cares about — cluster shape × NIC/GPU
//! profile × workload mix × chaos schedule — is data, not code:
//! [`spec`] defines the JSON `ScenarioSpec`, [`exec`] materializes
//! one into a live cluster on either runtime and checks its
//! declarative assertions, and [`fuzz`] samples random specs by seed
//! and shrinks any failure to a minimal replayable reproducer.
//! `fabricctl run scenario.json` is the CLI front door; committed
//! specs live under `scenarios/` at the repo root.

pub mod exec;
pub mod fuzz;
pub mod spec;

pub use exec::{clamp_quick, run_scenario, RunOptions, ScenarioReport};
pub use fuzz::{check_spec, fuzz_sweep, gen_spec, shrink, SweepFailure};
pub use spec::{
    AssertionSpec, ChaosSpec, GossipSpec, LinkEventSpec, NicEventSpec, ScenarioSpec, TopologySpec,
    WorkloadStep,
};
