//! Runtime-independent submission core shared by both TransferEngine
//! runtimes (paper §3.2–3.4).
//!
//! Before this module existed, `des_engine.rs` and `threaded.rs` each
//! carried a private copy of the same submission-path state machines.
//! Everything that does not depend on *how* work requests are driven
//! (virtual clock vs. pinned threads) lives here exactly once:
//!
//! * [`PeerGroups`] — registry behind `add_peer_group` handles;
//! * [`Rotation`] — per-group NIC rotation cursor for load balancing;
//! * [`TransferTable`] — transfer-id allocation plus WR→transfer
//!   completion accounting (generic over the runtime's `OnDone`);
//! * [`ImmTable`] — IMMCOUNTER state plus expectation waiters
//!   (generic over the runtime's callback type);
//! * [`RecvPool`] — rotating receive-buffer matching and re-post
//!   bookkeeping;
//! * [`route_single_write`] / [`route_paged_writes`] /
//!   [`route_scatter`] / [`route_barrier`] — the bridge from the Fig-2
//!   API calls to [`super::sharding`] plans, with each planned write
//!   paired to its destination `(NIC, rkey)`.
//!
//! The routing bridge also enforces the §3.2 equal-NIC-count
//! invariant: in debug builds, submitting a transfer whose remote
//! descriptor carries a different rkey count than the local domain
//! group's fanout panics instead of silently wrapping rkey selection
//! modulo the remote count (the `MrDesc::rkey_for` footgun).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::api::{MrDesc, NetAddr, Pages, PeerGroupHandle, ScatterDst};
use super::imm_counter::{ImmCounter, ImmEvent};
use super::sharding::{plan_paged_writes, plan_scatter, plan_single_write, PlannedWrite};
use crate::fabric::mem::DmaBuf;
use crate::fabric::nic::NicAddr;
use crate::util::fasthash::FastMap;

/// A planned write routed to its destination: the NIC-indexed plan
/// plus the remote `(NIC, rkey)` pair it must target. Runtimes only
/// have to wrap each entry in a `WorkRequest` and post it.
pub type RoutedWrite = (PlannedWrite, (NicAddr, u64));

// ---------------------------------------------------------------------
// Peer groups
// ---------------------------------------------------------------------

/// Registry behind `add_peer_group` handles (paper Fig 2): a group is
/// a pre-registered peer list that scatter/barrier may target without
/// re-validating addresses per call.
#[derive(Default)]
pub struct PeerGroups {
    next: u64,
    groups: HashMap<u64, Vec<NetAddr>>,
}

impl PeerGroups {
    /// Empty registry; handles start at 1.
    pub fn new() -> Self {
        PeerGroups {
            next: 1,
            groups: HashMap::new(),
        }
    }

    /// Register a peer list, returning its handle.
    pub fn add(&mut self, addrs: Vec<NetAddr>) -> PeerGroupHandle {
        let id = self.next;
        self.next += 1;
        self.groups.insert(id, addrs);
        PeerGroupHandle(id)
    }

    /// Look up a group's peer list.
    pub fn get(&self, h: PeerGroupHandle) -> Option<&[NetAddr]> {
        self.groups.get(&h.0).map(|v| v.as_slice())
    }

    /// Release a group's registry entry, returning its peer list.
    /// Handles are never reused, so a freed handle stays invalid.
    pub fn remove(&mut self, h: PeerGroupHandle) -> Option<Vec<NetAddr>> {
        self.groups.remove(&h.0)
    }

    /// Registered group count (leak checks in tests).
    pub fn len(&self) -> usize {
        self.groups.len()
    }

    /// True when no groups are registered.
    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Debug-check a scatter/barrier submission against its group: the
    /// handle must be registered and the destination count must not
    /// exceed the group size. The body is all `debug_assert!`s —
    /// runtimes gate the call (and any lock it needs) behind
    /// `cfg!(debug_assertions)` to keep it off the release hot path.
    pub fn check(&self, group: Option<PeerGroupHandle>, n_dsts: usize) {
        if let Some(h) = group {
            let peers = self.get(h);
            debug_assert!(peers.is_some(), "submission against unknown {h:?}");
            if let Some(peers) = peers {
                debug_assert!(
                    n_dsts <= peers.len(),
                    "{n_dsts} destinations exceed the {} peers of {h:?}",
                    peers.len()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// NIC rotation
// ---------------------------------------------------------------------

/// Per-group rotation cursor: successive transfers start on successive
/// NICs so single-NIC-sized transfers still load-balance over time
/// (§3.4). Atomic so the threaded runtime can bump it lock-free; the
/// DES runtime uses it single-threaded.
#[derive(Default)]
pub struct Rotation(AtomicUsize);

impl Rotation {
    /// Cursor starting at zero.
    pub fn new() -> Self {
        Rotation(AtomicUsize::new(0))
    }

    /// Advance and return the new cursor value.
    pub fn bump(&self) -> usize {
        self.0.fetch_add(1, Ordering::Relaxed).wrapping_add(1)
    }
}

// ---------------------------------------------------------------------
// Transfer accounting
// ---------------------------------------------------------------------

struct Inflight<D> {
    remaining: usize,
    on_done: D,
}

/// Transfer-id allocation plus WR→transfer completion accounting,
/// generic over the runtime's completion payload (`OnDone` for the DES
/// engine, `OnDoneT` for the threaded one).
pub struct TransferTable<D> {
    next: u64,
    transfers: FastMap<u64, Inflight<D>>,
    wr_transfer: FastMap<u64, u64>,
}

impl<D> Default for TransferTable<D> {
    fn default() -> Self {
        Self::new()
    }
}

impl<D> TransferTable<D> {
    /// Empty table; transfer ids start at 1.
    pub fn new() -> Self {
        TransferTable {
            next: 1,
            transfers: FastMap::default(),
            wr_transfer: FastMap::default(),
        }
    }

    /// Open a transfer expecting `remaining` WR completions.
    pub fn begin(&mut self, remaining: usize, on_done: D) -> u64 {
        debug_assert!(remaining > 0, "empty transfer");
        let id = self.next;
        self.next += 1;
        self.transfers.insert(
            id,
            Inflight {
                remaining,
                on_done,
            },
        );
        id
    }

    /// Attribute a posted WR to a transfer.
    pub fn bind_wr(&mut self, wr_id: u64, transfer: u64) {
        self.wr_transfer.insert(wr_id, transfer);
    }

    /// Record a WR completion; returns the transfer's completion
    /// payload when its last WR finished, `None` otherwise (including
    /// for WRs the table never saw, e.g. receive reposts).
    pub fn complete_wr(&mut self, wr_id: u64) -> Option<D> {
        let tid = self.wr_transfer.remove(&wr_id)?;
        let t = self.transfers.get_mut(&tid).expect("transfer state");
        t.remaining -= 1;
        if t.remaining == 0 {
            Some(self.transfers.remove(&tid).unwrap().on_done)
        } else {
            None
        }
    }

    /// Open transfers (leak check in tests).
    pub fn in_flight(&self) -> usize {
        self.transfers.len()
    }
}

// ---------------------------------------------------------------------
// IMMCOUNTER + waiters
// ---------------------------------------------------------------------

/// IMMCOUNTER slots plus the expectation waiters both runtimes kept
/// separately, generic over the runtime's callback type.
pub struct ImmTable<CB> {
    counter: ImmCounter,
    waiters: HashMap<u32, CB>,
}

impl<CB> Default for ImmTable<CB> {
    fn default() -> Self {
        Self::new()
    }
}

impl<CB> ImmTable<CB> {
    /// Empty table.
    pub fn new() -> Self {
        ImmTable {
            counter: ImmCounter::new(),
            waiters: HashMap::new(),
        }
    }

    /// Register `expect_imm_count(imm, count)`: returns `Some(cb)`
    /// when the expectation is already satisfied (the caller must
    /// dispatch it), or parks the callback and returns `None`.
    pub fn expect(&mut self, imm: u32, count: u32, cb: CB) -> Option<CB> {
        match self.counter.expect(imm, count) {
            ImmEvent::Satisfied => Some(cb),
            ImmEvent::Pending => {
                self.waiters.insert(imm, cb);
                None
            }
        }
    }

    /// Record one received immediate; returns the waiter to dispatch
    /// when this increment satisfied its expectation.
    pub fn on_imm(&mut self, imm: u32) -> Option<CB> {
        match self.counter.increment(imm) {
            ImmEvent::Satisfied => self.waiters.remove(&imm),
            ImmEvent::Pending => None,
        }
    }

    /// Current count for `imm`.
    pub fn value(&self, imm: u32) -> u32 {
        self.counter.value(imm)
    }

    /// Release all state for `imm`, including any parked waiter.
    pub fn free(&mut self, imm: u32) {
        self.counter.free(imm);
        self.waiters.remove(&imm);
    }
}

// ---------------------------------------------------------------------
// Receive matching
// ---------------------------------------------------------------------

struct RecvSlot {
    buf: DmaBuf,
    len: usize,
}

/// Rotating receive-buffer pool: posted buffers keyed by wr_id, with
/// the payload-extraction + re-post bookkeeping both runtimes
/// duplicated.
#[derive(Default)]
pub struct RecvPool {
    slots: FastMap<u64, RecvSlot>,
}

impl RecvPool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Track a posted receive buffer of capacity `len`.
    pub fn post(&mut self, wr_id: u64, buf: DmaBuf, len: usize) {
        self.slots.insert(wr_id, RecvSlot { buf, len });
    }

    /// Complete a receive of `len` bytes on `wr_id`: extracts the
    /// payload (truncated to the buffer's capacity), re-tracks the
    /// buffer under `repost_id` (rotating-pool semantics) and returns
    /// `(payload, buffer, overflowed)` so the runtime can re-post the
    /// buffer and decide how to surface an oversized SEND. The pool
    /// itself must not panic here: the threaded runtime calls this on
    /// a worker thread, where a panic would poison the group lock and
    /// hang waiters instead of diagnosing anything.
    pub fn complete(&mut self, wr_id: u64, len: u32, repost_id: u64) -> (Vec<u8>, DmaBuf, bool) {
        let slot = self
            .slots
            .remove(&wr_id)
            .expect("RecvDone for unknown buffer");
        let overflowed = len as usize > slot.len;
        let mut data = vec![0u8; (len as usize).min(slot.len)];
        slot.buf.read(0, &mut data);
        let buf = slot.buf.clone();
        self.slots.insert(repost_id, slot);
        (data, buf, overflowed)
    }

    /// The message a runtime should raise when [`RecvPool::complete`]
    /// reports an overflow.
    pub fn overflow_msg(len: u32, capacity: usize) -> String {
        format!(
            "SEND of {len} B overflows the {capacity} B recv buffer \
             (size the submit_recvs pool for the largest message)"
        )
    }
}

// ---------------------------------------------------------------------
// API → plan → rkey routing bridge
// ---------------------------------------------------------------------

/// Effective fanout for a transfer against `desc`, enforcing the §3.2
/// invariant that local and remote domain groups run the same NIC
/// count. Debug builds panic on a mismatch; release builds fall back
/// to the defensive minimum so rkey selection never wraps.
fn checked_fanout(local_fanout: usize, desc: &MrDesc) -> usize {
    debug_assert_eq!(
        desc.rkeys.len(),
        local_fanout,
        "§3.2 equal-NIC-count invariant: remote descriptor has {} rkeys \
         but the local domain group has {local_fanout} NICs",
        desc.rkeys.len()
    );
    local_fanout.min(desc.rkeys.len()).max(1)
}

/// Route a contiguous one-sided write (paper `submit_single_write`):
/// plan sharding across NICs, then pair each shard with the remote
/// rkey of its paired NIC.
pub fn route_single_write(
    local_fanout: usize,
    rotation: usize,
    src_off: u64,
    len: u64,
    dst: (&MrDesc, u64),
    imm: Option<u32>,
) -> Vec<RoutedWrite> {
    let (desc, dst_off) = dst;
    let fanout = checked_fanout(local_fanout, desc);
    let plans = plan_single_write(len, src_off, desc.ptr + dst_off, imm, fanout, rotation);
    pair_with_rkeys(plans, desc)
}

/// Route paged writes (paper `submit_paged_writes`): source page `i`
/// lands at destination page `i`, one WR per page, round-robin across
/// NICs.
pub fn route_paged_writes(
    local_fanout: usize,
    rotation: usize,
    page_len: u64,
    src_pages: &Pages,
    dst: (&MrDesc, &Pages),
    imm: Option<u32>,
) -> Vec<RoutedWrite> {
    let (desc, dst_pages) = dst;
    let fanout = checked_fanout(local_fanout, desc);
    let src_offs: Vec<u64> = (0..src_pages.len()).map(|i| src_pages.at(i)).collect();
    let dst_vas: Vec<u64> = (0..dst_pages.len())
        .map(|i| desc.ptr + dst_pages.at(i))
        .collect();
    let plans = plan_paged_writes(page_len, &src_offs, &dst_vas, imm, fanout, rotation);
    pair_with_rkeys(plans, desc)
}

/// Route a scatter (paper `submit_scatter`): one WR per destination,
/// NIC-rotated per entry, each paired with its *own* destination's
/// rkey (destinations live on different peers).
pub fn route_scatter(
    local_fanout: usize,
    rotation: usize,
    dsts: &[ScatterDst],
    imm: Option<u32>,
) -> Vec<RoutedWrite> {
    let entries: Vec<(u64, u64, u64)> = dsts
        .iter()
        .map(|d| (d.len, d.src, d.dst.0.ptr + d.dst.1))
        .collect();
    let plans = plan_scatter(&entries, imm, local_fanout.max(1), rotation);
    plans
        .into_iter()
        .zip(dsts.iter())
        .map(|(p, d)| {
            let fanout = checked_fanout(local_fanout, &d.dst.0);
            let rk = d.dst.0.rkey_for(p.nic % fanout.max(1));
            (p, rk)
        })
        .collect()
}

/// Route a barrier (paper `submit_barrier`): a zero-length
/// immediate-only write per destination descriptor.
pub fn route_barrier(
    local_fanout: usize,
    rotation: usize,
    dsts: &[MrDesc],
    imm: u32,
) -> Vec<RoutedWrite> {
    let entries: Vec<(u64, u64, u64)> = dsts.iter().map(|d| (0u64, 0u64, d.ptr)).collect();
    let plans = plan_scatter(&entries, Some(imm), local_fanout.max(1), rotation);
    plans
        .into_iter()
        .zip(dsts.iter())
        .map(|(p, d)| {
            let fanout = checked_fanout(local_fanout, d);
            let rk = d.rkey_for(p.nic % fanout.max(1));
            (p, rk)
        })
        .collect()
}

fn pair_with_rkeys(plans: Vec<PlannedWrite>, desc: &MrDesc) -> Vec<RoutedWrite> {
    plans
        .into_iter()
        .map(|p| {
            let rk = desc.rkey_for(p.nic);
            (p, rk)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::api::SPLIT_THRESHOLD;

    fn nic(node: u16, x: u8) -> NicAddr {
        NicAddr { node, gpu: 0, nic: x }
    }

    fn desc(node: u16, nics: u8) -> MrDesc {
        MrDesc {
            ptr: 0x10_0000,
            len: 1 << 30,
            rkeys: (0..nics).map(|i| (nic(node, i), 100 + i as u64)).collect(),
        }
    }

    #[test]
    fn peer_groups_register_and_lookup() {
        let mut pg = PeerGroups::new();
        let addrs = vec![NetAddr { nics: vec![nic(1, 0)] }, NetAddr { nics: vec![nic(2, 0)] }];
        let h = pg.add(addrs.clone());
        assert_eq!(pg.get(h).unwrap(), addrs.as_slice());
        let h2 = pg.add(vec![]);
        assert_ne!(h, h2);
        pg.check(Some(h), 2);
        pg.check(None, 99);
        // Remove frees the entry exactly once; handles never recycle.
        assert_eq!(pg.len(), 2);
        assert_eq!(pg.remove(h).unwrap(), addrs);
        assert!(pg.remove(h).is_none());
        assert!(pg.get(h).is_none());
        let h3 = pg.add(vec![]);
        assert_ne!(h3, h, "freed handles are not reused");
        assert_eq!(pg.len(), 2);
    }

    #[test]
    fn rotation_advances_monotonically() {
        let r = Rotation::new();
        assert_eq!(r.bump(), 1);
        assert_eq!(r.bump(), 2);
        assert_eq!(r.bump(), 3);
    }

    #[test]
    fn transfer_table_completes_on_last_wr() {
        let mut t: TransferTable<&'static str> = TransferTable::new();
        let tid = t.begin(2, "done");
        t.bind_wr(10, tid);
        t.bind_wr(11, tid);
        assert!(t.complete_wr(99).is_none(), "unknown WR ignored");
        assert!(t.complete_wr(10).is_none());
        assert_eq!(t.in_flight(), 1);
        assert_eq!(t.complete_wr(11), Some("done"));
        assert_eq!(t.in_flight(), 0);
    }

    #[test]
    fn imm_table_parks_and_releases_waiters() {
        let mut t: ImmTable<u32> = ImmTable::new();
        assert!(t.expect(7, 2, 42).is_none());
        assert!(t.on_imm(7).is_none());
        assert_eq!(t.on_imm(7), Some(42));
        // Early arrivals satisfy a late expectation immediately.
        t.on_imm(9);
        assert_eq!(t.expect(9, 1, 5), Some(5));
        // free() drops parked waiters.
        t.expect(3, 1, 8);
        t.free(3);
        assert!(t.on_imm(3).is_none());
    }

    #[test]
    fn recv_pool_rotates_buffers() {
        let mut pool = RecvPool::new();
        let buf = DmaBuf::new(0x4000, 64);
        buf.write(0, b"payload!");
        pool.post(1, buf, 64);
        let (data, rebuf, overflowed) = pool.complete(1, 8, 2);
        assert_eq!(&data, b"payload!");
        assert!(!overflowed);
        // The buffer is re-tracked under the repost id.
        rebuf.write(0, b"again");
        let (data2, _, _) = pool.complete(2, 5, 3);
        assert_eq!(&data2, b"again");
    }

    #[test]
    fn recv_pool_reports_overflow_without_panicking() {
        // No panic here: the threaded runtime completes receives on a
        // worker thread, where a panic would poison the group lock.
        let mut pool = RecvPool::new();
        let buf = DmaBuf::new(0x4000, 8);
        buf.write(0, b"12345678");
        pool.post(1, buf, 8);
        let (data, _, overflowed) = pool.complete(1, 9, 2);
        assert!(overflowed);
        assert_eq!(&data, b"12345678", "payload truncated to capacity");
        assert!(RecvPool::overflow_msg(9, data.len()).contains("overflows"));
    }

    #[test]
    fn single_write_routes_to_paired_rkeys() {
        let d = desc(2, 2);
        let routed = route_single_write(2, 0, 0, 4 * SPLIT_THRESHOLD, (&d, 0), None);
        assert_eq!(routed.len(), 2, "large imm-less write shards");
        for (p, (dst_nic, rkey)) in &routed {
            assert_eq!(*dst_nic, nic(2, p.nic as u8), "NIC i pairs with remote NIC i");
            assert_eq!(*rkey, 100 + p.nic as u64);
        }
    }

    #[test]
    fn paged_writes_route_one_wr_per_page() {
        let d = desc(3, 2);
        let pages = Pages::contiguous(0, 6, 4096);
        let routed = route_paged_writes(2, 1, 4096, &pages, (&d, &pages), Some(9));
        assert_eq!(routed.len(), 6, "imm count preserved: one WR per page");
        assert!(routed.iter().all(|(p, _)| p.imm == Some(9)));
    }

    #[test]
    fn scatter_and_barrier_use_each_peers_rkey() {
        let peers: Vec<MrDesc> = (1..4).map(|n| desc(n, 1)).collect();
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .map(|d| ScatterDst { len: 128, src: 0, dst: (d.clone(), 0) })
            .collect();
        let routed = route_scatter(1, 0, &dsts, Some(4));
        assert_eq!(routed.len(), 3);
        for (i, (_, (dst_nic, _))) in routed.iter().enumerate() {
            assert_eq!(dst_nic.node, (i + 1) as u16);
        }
        let routed = route_barrier(1, 0, &peers, 5);
        assert_eq!(routed.len(), 3);
        assert!(routed.iter().all(|(p, _)| p.len == 0 && p.imm == Some(5)));
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "equal-NIC-count invariant")]
    fn fanout_mismatch_panics_in_debug() {
        // Local group has 2 NICs, remote descriptor only 1 rkey: the
        // old code silently wrapped `rkey_for` modulo 1; now the
        // submission asserts (§3.2).
        let d = desc(2, 1);
        route_single_write(2, 0, 0, 4096, (&d, 0), None);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "equal-NIC-count invariant")]
    fn scatter_fanout_mismatch_panics_in_debug() {
        let d = desc(2, 3);
        let dsts = vec![ScatterDst { len: 8, src: 0, dst: (d, 0) }];
        route_scatter(2, 0, &dsts, None);
    }
}
