//! Global scheduler (paper Fig 3): selects a prefiller and a decoder
//! for each incoming request and forwards it to the decoder.
//!
//! Placement policy is least-outstanding-work with capacity guards —
//! the disaggregated fleet scales elastically (no fixed membership:
//! prefillers/decoders join and leave between requests, which is the
//! point of P2P over collectives, §1). Runtime-neutral: dispatch goes
//! through the decoder's `Cx`-based entry point.

use std::cell::RefCell;
use std::rc::Rc;

use crate::engine::api::NetAddr;
use crate::engine::traits::Cx;

use super::decoder::Decoder;

/// A prefiller fleet entry.
#[derive(Clone)]
struct PrefillerSlot {
    addr: NetAddr,
    /// Outstanding prefill tokens assigned.
    load: u64,
    alive: bool,
}

struct SchedState {
    prefillers: Vec<PrefillerSlot>,
    decoders: Vec<(Decoder, u64)>,
    dispatched: u64,
}

/// The global scheduler.
#[derive(Clone)]
pub struct Scheduler {
    s: Rc<RefCell<SchedState>>,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    /// Empty fleet.
    pub fn new() -> Self {
        Scheduler {
            s: Rc::new(RefCell::new(SchedState {
                prefillers: Vec::new(),
                decoders: Vec::new(),
                dispatched: 0,
            })),
        }
    }

    /// Register a prefiller (dynamic scaling: callable at any time).
    pub fn add_prefiller(&self, addr: NetAddr) {
        self.s.borrow_mut().prefillers.push(PrefillerSlot {
            addr,
            load: 0,
            alive: true,
        });
    }

    /// Register a decoder.
    pub fn add_decoder(&self, d: Decoder) {
        self.s.borrow_mut().decoders.push((d, 0));
    }

    /// Mark a prefiller dead (heartbeat loss escalated by a decoder);
    /// it receives no further requests until re-registered.
    pub fn mark_prefiller_dead(&self, addr: &NetAddr) {
        for p in &mut self.s.borrow_mut().prefillers {
            if p.addr == *addr {
                p.alive = false;
            }
        }
    }

    /// Live prefillers remaining in the fleet (the chaos failover
    /// scenario asserts the fleet shrank, then kept serving).
    pub fn live_prefillers(&self) -> usize {
        self.s.borrow().prefillers.iter().filter(|p| p.alive).count()
    }

    /// Requests dispatched so far.
    pub fn dispatched(&self) -> u64 {
        self.s.borrow().dispatched
    }

    /// Schedule one request: pick the least-loaded live prefiller and
    /// the least-loaded decoder, then dispatch (paper: "a global
    /// scheduler selects a prefiller node and a decoder node ... and
    /// forwards the request to the decoder"). Returns
    /// (request id, decoder index, prefiller address).
    pub fn submit(
        &self,
        cx: &mut Cx,
        input_ids: Vec<u32>,
        decode_tokens: u32,
    ) -> (u64, usize, NetAddr) {
        let (pi, di, prefiller, decoder) = {
            let mut s = self.s.borrow_mut();
            let pi = s
                .prefillers
                .iter()
                .enumerate()
                .filter(|(_, p)| p.alive)
                .min_by_key(|(_, p)| p.load)
                .map(|(i, _)| i)
                .expect("no live prefillers");
            let di = s
                .decoders
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, load))| *load)
                .map(|(i, _)| i)
                .expect("no decoders");
            s.prefillers[pi].load += input_ids.len() as u64;
            s.decoders[di].1 += decode_tokens as u64;
            s.dispatched += 1;
            (
                pi,
                di,
                s.prefillers[pi].addr.clone(),
                s.decoders[di].0.clone(),
            )
        };
        let _ = pi;
        let id = decoder.submit_request(cx, &prefiller, input_ids, decode_tokens);
        (id, di, prefiller)
    }
}

/// MLA replica matching (§4): under tensor parallelism MLA replicates
/// the compressed KvCache on every prefiller rank; to balance the
/// transfer load, prefiller TP ranks are matched with decoder TP ranks
/// by a seeded random permutation (fresh per request).
pub fn mla_replica_matching(
    tp: u32,
    seed: u64,
) -> Vec<(u32, u32)> {
    let mut rng = crate::sim::Rng::new(seed ^ 0x41A);
    let mut decoder_ranks: Vec<u32> = (0..tp).collect();
    rng.shuffle(&mut decoder_ranks);
    (0..tp).map(|p| (p, decoder_ranks[p as usize])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::kvcache::{Prefiller, ServingWorkload};
    use crate::engine::model::ComputeModel;
    use crate::engine::traits::{Cluster, RuntimeKind};
    use crate::fabric::profile::{GpuProfile, NicProfile};

    fn fleet(n_prefill: u16, n_decode: u16) -> (Cluster, Scheduler, Vec<Decoder>) {
        let total = n_prefill + n_decode;
        let mut cluster = Cluster::new_with(
            RuntimeKind::Des,
            total,
            1,
            1,
            8,
            NicProfile::connectx7(),
            GpuProfile::h100(),
        );
        let engines = cluster.engines_rc();
        let sched = Scheduler::new();
        let w = ServingWorkload::tiny();
        let mut decoders = Vec::new();
        {
            let (mut cx, _) = cluster.parts();
            for node in 0..n_prefill {
                let e = engines[node as usize].clone();
                let compute = ComputeModel::new(GpuProfile::h100());
                let _p = Prefiller::new(&mut cx, e.clone(), 0, &compute, w.clone(), node);
                sched.add_prefiller(e.group_address(0));
            }
            for i in 0..n_decode {
                let node = n_prefill + i;
                let d = Decoder::new(&mut cx, engines[node as usize].clone(), 0, w.clone());
                sched.add_decoder(d.clone());
                decoders.push(d);
            }
        }
        (cluster, sched, decoders)
    }

    #[test]
    fn balances_across_fleet_and_completes() {
        let (mut cluster, sched, decoders) = fleet(2, 2);
        let mut prefiller_hits = std::collections::HashMap::new();
        {
            let (mut cx, _) = cluster.parts();
            for i in 0..8 {
                let input: Vec<u32> = (0..32 + i).collect();
                let (_, _, p) = sched.submit(&mut cx, input, 1);
                *prefiller_hits.entry(p.primary().node).or_insert(0u32) += 1;
            }
            cx.settle();
        }
        let total: usize = decoders.iter().map(|d| d.reports().borrow().len()).sum();
        assert_eq!(total, 8, "all requests served");
        // Both prefillers used (least-load balancing).
        assert_eq!(prefiller_hits.len(), 2);
        assert!(prefiller_hits.values().all(|&v| v >= 3));
        assert_eq!(sched.dispatched(), 8);
    }

    #[test]
    fn dead_prefiller_excluded_elastically() {
        let (mut cluster, sched, decoders) = fleet(2, 1);
        {
            let (mut cx, _) = cluster.parts();
            let (_, _, first) = sched.submit(&mut cx, (0..16).collect(), 1);
            sched.mark_prefiller_dead(&first);
            for _ in 0..4 {
                let (_, _, p) = sched.submit(&mut cx, (0..16).collect(), 1);
                assert_ne!(p, first, "dead prefiller must not be selected");
            }
            cx.settle();
        }
        assert_eq!(decoders[0].reports().borrow().len(), 5);
    }

    #[test]
    fn mla_matching_is_a_balanced_bijection() {
        for seed in 0..32 {
            let m = mla_replica_matching(4, seed);
            let mut dsts: Vec<u32> = m.iter().map(|&(_, d)| d).collect();
            dsts.sort_unstable();
            assert_eq!(dsts, vec![0, 1, 2, 3], "each decoder rank used once");
        }
        // Randomized across requests: not always identity.
        let distinct: std::collections::HashSet<Vec<u32>> = (0..32)
            .map(|s| mla_replica_matching(4, s).iter().map(|&(_, d)| d).collect())
            .collect();
        assert!(distinct.len() > 1, "matching must vary to balance load");
    }

    #[test]
    #[should_panic(expected = "no live prefillers")]
    fn empty_fleet_rejects() {
        let (mut cluster, sched, _d) = fleet(1, 1);
        sched.mark_prefiller_dead(&{
            let s = sched.s.borrow();
            s.prefillers[0].addr.clone()
        });
        let (mut cx, _) = cluster.parts();
        sched.submit(&mut cx, vec![1], 1);
    }
}
