//! The TransferEngine over the discrete-event fabric.
//!
//! This is the timing-faithful runtime behind the shared
//! [`super::traits::TransferEngine`] trait, used by every benchmark
//! and most integration tests; `engine::threaded` implements the same
//! trait over real threads. All runtime-independent submission logic
//! (peer groups, imm accounting, recv matching, NIC rotation, the
//! plan→rkey routing bridge) lives in [`super::core`]; this file adds
//! only what is DES-specific. Architecture mirrors the paper
//! (§3.2–3.4):
//!
//! * one engine instance per node, managing all of its GPUs;
//! * a **DomainGroup** per GPU with a pinned worker, coordinating 1–4
//!   **Domains** (one per NIC);
//! * submissions flow app-thread → lock-free queue → worker, with
//!   calibrated CPU costs charged along the way (Table 8);
//! * writes are sharded/rotated across the group's NICs
//!   ([`super::sharding`] via [`super::core`]);
//! * completions feed per-group imm counters and transfer-level
//!   `OnDone` notifications;
//! * no ordering is assumed anywhere — only counters.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::collections::VecDeque;

use std::rc::Rc;

use super::api::{
    EngineCosts, MrDesc, MrHandle, NetAddr, Pages, PeerGroupHandle, ScatterDst, TemplatedDst,
};
use super::core::{
    remap_routed, retarget, route_barrier, route_barrier_templated, route_batch_templated,
    route_paged_writes, route_paged_writes_templated, route_scatter, route_scatter_templated,
    route_single_write, route_single_write_templated, route_write_batch, FailoverPolicy, ImmTable,
    NicHealth, PeerGroups, RecvPool, Rotation, RouteSet, RoutedVec, RoutedWrite, TransferTable,
};
use super::model::Fired;
use super::wire;
use super::traits::{Cx, Notify, OnRecv, OnWatch, RuntimeKind, TransferEngine, UvmWatcher};
use crate::fabric::chaos::ChaosProfile;
use crate::fabric::mem::{DmaBuf, DmaSlice, RKey};
use crate::fabric::nic::{Cqe, CqeKind, NicAddr, QpId, WorkRequest, WrOp};
use crate::fabric::profile::GpuProfile;
use crate::fabric::simnet::SimNet;
use crate::fabric::topology::DeviceId;
use crate::sim::time::Instant;
use crate::sim::{Rng, Sim};
use crate::util::err::Result;
use crate::util::fasthash::FastMap;
use crate::util::smallvec::SmallVec;
use crate::util::telemetry::{
    Cell64, EngineMetrics, EngineSnapshot, PlainCell, SubmitKind, TraceEvent, TraceOutcome,
    TraceRing, DEFAULT_TRACE_CAP, NO_TRACE,
};

/// Sender-side completion notification (paper Fig 2 `OnDone`).
pub enum OnDone {
    /// Run on the engine's callback thread.
    Callback(Box<dyn FnOnce(&mut Sim)>),
    /// Set an atomic flag (polled by the app / GPU via GDRCopy).
    Flag(Rc<Cell<bool>>),
    /// Fire-and-forget.
    Noop,
}

/// Per-GPU domain group state.
struct Group {
    nics: Vec<NicAddr>,
    /// Worker-thread CPU availability (one pinned worker per group).
    worker_free: Instant,
    /// NIC rotation cursor for load balancing.
    rotation: Rotation,
    /// Link-state table: downed NICs are excluded from new submissions
    /// (kept in sync with the fabric through its health hooks).
    health: NicHealth,
    /// Back-pressured WRs per NIC index.
    pending: Vec<VecDeque<WorkRequest>>,
    /// Posted receive buffers by wr_id.
    recvs: RecvPool,
    /// Receive callback (rotating pool semantics); takes the message
    /// as an owned [`Fired`] so the Cont flavor pays no extra copy.
    recv_cb: Option<Rc<dyn Fn(&mut Sim, Fired)>>,
    /// IMMCOUNTER slots + expectation waiters.
    imm: ImmTable<Box<dyn FnOnce(&mut Sim)>>,
    /// Health-gossip neighborhood: peers told when this group's
    /// `WrError` attribution concludes a remote NIC is dead.
    gossip: Vec<NetAddr>,
}

struct State {
    net: SimNet,
    node: u16,
    nics_per_gpu: u8,
    costs: EngineCosts,
    gpu_profile: GpuProfile,
    rng: Rng,
    groups: Vec<Group>,
    /// Transfer-id allocation + WR→transfer completion accounting.
    transfers: TransferTable<OnDone>,
    next_wr: u64,
    peer_groups: PeerGroups,
    next_watcher: u64,
    watchers: HashMap<u64, Watcher>,
    /// Engine-wide counter registry ([`PlainCell`]s — the DES runtime
    /// is single-threaded behind the `RefCell`).
    metrics: EngineMetrics<PlainCell>,
    /// Bounded ring of submission trace spans (replaces the old
    /// unbounded `SubmitTrace` sink; Table 8/9 benches drain it).
    trace: TraceRing,
    /// True once chaos was injected or a NIC health override landed:
    /// from then on posted WRs are recorded in `retry` so a fabric
    /// `WrError` can resubmit them (the happy path records nothing).
    armed: bool,
    failover: FailoverPolicy,
    /// In-flight WRs by id, kept only while `armed` (see above).
    retry: FastMap<u64, RetryEntry>,
}

/// Everything needed to repost a failed WR on a surviving path.
struct RetryEntry {
    gpu: usize,
    /// Local NIC index of the ORIGINAL egress (the projection base, so
    /// successive attempts walk every survivor under a stable mask).
    lane: usize,
    /// Local NIC index the WR last actually went out on (what a
    /// `WrError` is attributed to, together with the WR's destination).
    cur_lane: usize,
    /// The destination region's full route set: failover may retarget
    /// the WR onto a surviving REMOTE NIC of the same region (empty
    /// for SENDs, which have a single fixed destination).
    routes: RouteSet,
    wr: WorkRequest,
    /// Failures so far; capped at fanout + route count before
    /// degrading to error-out.
    attempts: u8,
}

struct Watcher {
    value: u64,
    cb: Rc<dyn Fn(&mut Sim, u64, u64)>,
}

/// The DES TransferEngine. Clone handles freely.
#[derive(Clone)]
pub struct Engine {
    state: Rc<RefCell<State>>,
}

impl Engine {
    /// Create an engine for `node`, managing `gpus` GPUs with
    /// `nics_per_gpu` NICs each (which must already exist in `net`).
    pub fn new(
        net: &SimNet,
        node: u16,
        gpus: u8,
        nics_per_gpu: u8,
        gpu_profile: GpuProfile,
        costs: EngineCosts,
        seed: u64,
    ) -> Self {
        let groups = (0..gpus)
            .map(|gpu| {
                let nics: Vec<NicAddr> = (0..nics_per_gpu)
                    .map(|nic| NicAddr { node, gpu, nic })
                    .collect();
                Group {
                    pending: nics.iter().map(|_| VecDeque::new()).collect(),
                    health: NicHealth::new(nics.len()),
                    nics,
                    worker_free: 0,
                    rotation: Rotation::new(),
                    recvs: RecvPool::new(),
                    recv_cb: None,
                    imm: ImmTable::new(),
                    gossip: Vec::new(),
                }
            })
            .collect();
        let engine = Engine {
            state: Rc::new(RefCell::new(State {
                net: net.clone(),
                node,
                nics_per_gpu,
                costs,
                gpu_profile,
                rng: Rng::new(seed ^ 0x5EED_ECAF),
                groups,
                transfers: TransferTable::new(),
                next_wr: 1,
                peer_groups: PeerGroups::new(),
                next_watcher: 1,
                watchers: HashMap::new(),
                metrics: EngineMetrics::new(),
                trace: TraceRing::new(DEFAULT_TRACE_CAP),
                armed: false,
                failover: FailoverPolicy::default(),
                retry: FastMap::default(),
            })),
        };
        // Hook every NIC's completion queue to the owning group's
        // progress function, and its link state to the group's health
        // table (chaos NicDown/NicUp events flow in through this).
        for gpu in 0..gpus {
            for nic in 0..nics_per_gpu {
                let addr = NicAddr { node, gpu, nic };
                let e = engine.clone();
                net.set_cq_hook(
                    addr,
                    Rc::new(move |sim: &mut Sim| e.progress(sim, gpu as usize, addr)),
                );
                let e2 = engine.clone();
                net.set_health_hook(
                    addr,
                    Rc::new(move |_sim: &mut Sim, up| e2.set_nic_health(gpu, nic, up)),
                );
            }
        }
        engine
    }

    // ------------------------------------------------------------------
    // Transport perturbation (chaos) + NIC health
    // ------------------------------------------------------------------

    /// Install a [`ChaosProfile`] on the shared fabric (fabric-wide)
    /// and arm the failover bookkeeping on this engine. NicDown/NicUp
    /// events reach every engine on the fabric through the link-state
    /// hooks registered at construction.
    pub fn inject_chaos(&self, sim: &mut Sim, profile: &ChaosProfile) {
        let net = {
            let mut s = self.state.borrow_mut();
            s.armed = true;
            s.net.clone()
        };
        net.inject_chaos(sim, profile);
    }

    /// Engine-level health override for one local NIC (also how the
    /// fabric's link-state hooks report chaos events). Downed NICs are
    /// excluded from all new submissions at patch time.
    pub fn set_nic_health(&self, gpu: u8, nic: u8, up: bool) {
        let mut s = self.state.borrow_mut();
        s.armed = true;
        s.groups[gpu as usize].health.set(nic as usize, up);
    }

    /// Health bitmask of `gpu`'s domain group.
    pub fn nic_health_mask(&self, gpu: u8) -> u64 {
        self.state.borrow().groups[gpu as usize].health.mask()
    }

    /// Effective egress-lane mask of `gpu`'s group toward `remote`
    /// (see the trait docs).
    pub fn link_health_mask(&self, gpu: u8, remote: NicAddr) -> u64 {
        self.state.borrow().groups[gpu as usize].health.link_mask(remote)
    }

    /// Record a belief about a REMOTE NIC's health (the operation a
    /// received gossip message applies; also an operator override).
    pub fn report_remote_health(&self, gpu: u8, remote: NicAddr, up: bool) {
        let mut s = self.state.borrow_mut();
        s.armed = true;
        s.groups[gpu as usize].health.set_remote(remote, up);
    }

    /// Configure the health-gossip neighborhood of `gpu`'s group.
    pub fn set_gossip_peers(&self, gpu: u8, peers: Vec<NetAddr>) {
        self.state.borrow_mut().groups[gpu as usize].gossip = peers;
    }

    /// Select the in-flight failure policy (see the trait docs).
    pub fn set_failover_policy(&self, policy: FailoverPolicy) {
        self.state.borrow_mut().failover = policy;
    }

    /// Transport-level failures observed so far. Derived from the
    /// telemetry error ledger:
    /// `wr_err_total + rejected_all_down` — one source of truth.
    pub fn transport_errors(&self) -> u64 {
        self.state.borrow().metrics.transport_errors()
    }

    /// Toggle hot-path telemetry (submission/wire counters, imm/recv
    /// accounting, trace capture). The error ledger always counts —
    /// see [`crate::util::telemetry`].
    pub fn set_telemetry(&self, on: bool) {
        self.state.borrow().metrics.set_enabled(on);
    }

    /// Point-in-time copy of the engine-wide counter registry.
    pub fn telemetry(&self) -> EngineSnapshot {
        let s = self.state.borrow();
        let mut snap = s.metrics.snapshot();
        snap.trace_dropped = s.trace.dropped();
        snap
    }

    /// Drain the bounded submission-trace ring, oldest span first
    /// (Table 8 / Table 9 benches, `--trace-out`).
    pub fn take_traces(&self) -> Vec<TraceEvent> {
        self.state.borrow_mut().trace.drain()
    }

    /// Resize the trace ring; shrinking recycles oldest spans into
    /// the drop counter.
    pub fn set_trace_capacity(&self, cap: usize) {
        self.state.borrow_mut().trace.set_capacity(cap);
    }

    /// The engine's main address (paper: single address for discovery;
    /// we expose per-GPU group addresses, `main_address` is group 0's).
    pub fn main_address(&self) -> NetAddr {
        self.group_address(0)
    }

    /// Address of GPU `gpu`'s domain group.
    pub fn group_address(&self, gpu: u8) -> NetAddr {
        let s = self.state.borrow();
        NetAddr {
            nics: s.groups[gpu as usize].nics.clone(),
        }
    }

    /// NICs per GPU on this engine.
    pub fn nics_per_gpu(&self) -> u8 {
        self.state.borrow().nics_per_gpu
    }

    /// Device id of GPU `gpu` on this engine's node.
    pub fn device(&self, gpu: u8) -> DeviceId {
        DeviceId {
            node: self.state.borrow().node,
            gpu,
        }
    }

    // ------------------------------------------------------------------
    // Memory region management
    // ------------------------------------------------------------------

    /// Allocate + register `len` bytes on `gpu`'s device memory.
    /// Returns the local handle and the serializable descriptor
    /// (paper Fig 2 `reg_mr`, allocation fused in for the simulator).
    pub fn alloc_mr(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        let s = self.state.borrow();
        let (buf, rkey0) = s.net.mem().alloc(len);
        // The allocation-time rkey is never exposed through this API
        // (remote access goes through reg_mr's per-NIC rkeys); drop it
        // so dereg_mr returns the registry to its pre-alloc size.
        s.net.mem().deregister(rkey0);
        drop(s);
        self.reg_mr(gpu, &buf)
    }

    /// Allocate + register an **unbacked** (timing-only) region; see
    /// [`crate::fabric::mem::DmaBuf::unbacked`].
    pub fn alloc_mr_unbacked(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        let s = self.state.borrow();
        let (buf, rkey0) = s.net.mem().alloc_unbacked(len);
        s.net.mem().deregister(rkey0);
        drop(s);
        self.reg_mr(gpu, &buf)
    }

    /// Register an existing buffer on `gpu`, producing one rkey per
    /// NIC of the domain group.
    pub fn reg_mr(&self, gpu: u8, buf: &DmaBuf) -> (MrHandle, MrDesc) {
        let s = self.state.borrow();
        let mem = s.net.mem();
        let rkeys: Vec<(NicAddr, u64)> = s.groups[gpu as usize]
            .nics
            .iter()
            .map(|&nic| (nic, mem.register(buf).0))
            .collect();
        s.metrics.mr_regs.add(rkeys.len() as u64);
        let desc = MrDesc {
            ptr: buf.base(),
            len: buf.len() as u64,
            rkeys,
        };
        let handle = MrHandle {
            buf: buf.clone(),
            device: DeviceId { node: s.node, gpu },
        };
        (handle, desc)
    }

    /// Deregister every rkey of `desc` from the fabric's memory
    /// registry (paper Fig 2 `dereg_mr`). Later remote writes through
    /// those rkeys fault; unknown (already-deregistered) rkeys are
    /// ignored, so double-dereg is safe. The backing [`DmaBuf`] is
    /// refcounted and lives as long as any handle does.
    pub fn dereg_mr(&self, desc: &MrDesc) {
        let s = self.state.borrow();
        s.metrics.mr_deregs.add(desc.rkeys.len() as u64);
        let mem = s.net.mem();
        for &(_, rkey) in &desc.rkeys {
            mem.deregister(RKey(rkey));
        }
    }

    // ------------------------------------------------------------------
    // Two-sided SEND / RECV
    // ------------------------------------------------------------------

    /// Send a small message to a peer's posted RECV pool
    /// (copy-on-submit: the caller may reuse `msg` immediately).
    /// Uses only the first NIC of the group (paper §3.3).
    pub fn submit_send(
        &self,
        sim: &mut Sim,
        gpu: u8,
        addr: &NetAddr,
        msg: &[u8],
        on_done: OnDone,
    ) {
        let payload = msg.to_vec();
        let dst = addr.primary();
        let (wr_id, post_at, local) = {
            let mut s = self.state.borrow_mut();
            let wr_id = s.alloc_wr();
            let tid = s.transfers.begin(1, on_done);
            s.transfers.bind_wr(wr_id, tid);
            let (_, _, t) = s.charge_submission(sim.now(), gpu as usize);
            let prof_post = s.net.profile(s.groups[gpu as usize].nics[0]).post_ns;
            s.groups[gpu as usize].worker_free = t + prof_post;
            let local = s.groups[gpu as usize].nics[0];
            (wr_id, t + prof_post, local)
        };
        let this = self.clone();
        sim.at(post_at, move |sim| {
            let wr = WorkRequest {
                id: wr_id,
                qp: QpId(0), // SEND/RECV QP class
                op: WrOp::Send { dst, payload },
                chained: false,
            };
            let net = {
                let mut s = this.state.borrow_mut();
                if s.armed {
                    s.retry.insert(
                        wr_id,
                        RetryEntry {
                            gpu: gpu as usize,
                            lane: 0,
                            cur_lane: 0,
                            routes: RouteSet::default(),
                            wr: wr.clone(),
                            attempts: 0,
                        },
                    );
                }
                s.net.clone()
            };
            let ok = net.post(sim, local, wr);
            assert!(ok, "send queue full on SEND path");
        });
    }

    /// Post a rotating pool of `cnt` receive buffers of `len` bytes on
    /// `gpu`'s first NIC; `cb` runs for each received message (owned
    /// [`Fired`], bytes in `data`) and the buffer is re-posted
    /// afterwards.
    pub fn submit_recvs(
        &self,
        sim: &mut Sim,
        gpu: u8,
        len: usize,
        cnt: usize,
        cb: impl Fn(&mut Sim, Fired) + 'static,
    ) {
        let (bufs, local) = {
            let mut s = self.state.borrow_mut();
            s.groups[gpu as usize].recv_cb = Some(Rc::new(cb));
            let mem = s.net.mem();
            let bufs: Vec<(u64, DmaBuf)> = (0..cnt)
                .map(|_| (s.alloc_wr(), mem.alloc(len).0))
                .collect();
            let local = s.groups[gpu as usize].nics[0];
            for (id, buf) in &bufs {
                s.groups[gpu as usize].recvs.post(*id, buf.clone(), len);
            }
            s.metrics.recv_posts(bufs.len() as u64);
            (bufs, local)
        };
        let net = self.state.borrow().net.clone();
        for (id, buf) in bufs {
            net.post(
                sim,
                local,
                WorkRequest {
                    id,
                    qp: QpId(0),
                    op: WrOp::Recv {
                        buf: DmaSlice::whole(&buf),
                    },
                    chained: false,
                },
            );
        }
    }

    // ------------------------------------------------------------------
    // One-sided WRITE family
    // ------------------------------------------------------------------

    /// Contiguous one-sided write (paper `submit_single_write`).
    /// Sharded across NICs when large and imm-less; see
    /// [`super::api::SPLIT_THRESHOLD`].
    pub fn submit_single_write(
        &self,
        sim: &mut Sim,
        src: (&MrHandle, u64),
        len: u64,
        dst: (&MrDesc, u64),
        imm: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        let (handle, src_off) = src;
        let gpu = handle.device.gpu;
        let routed = route_single_write(
            self.fanout(gpu),
            self.peek_rotation(gpu),
            src_off,
            len,
            dst,
            imm,
        )?;
        self.execute_routed(sim, handle, routed, on_done, SubmitKind::Single)?;
        self.bump_rotation(gpu);
        Ok(())
    }

    /// Paged writes: page `i` of `src_pages` (each `page_len` bytes)
    /// lands at page `i` of `dst_pages` (paper `submit_paged_writes`).
    pub fn submit_paged_writes(
        &self,
        sim: &mut Sim,
        page_len: u64,
        src: (&MrHandle, &Pages),
        dst: (&MrDesc, &Pages),
        imm: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        let (handle, src_pages) = src;
        let gpu = handle.device.gpu;
        let routed = route_paged_writes(
            self.fanout(gpu),
            self.peek_rotation(gpu),
            page_len,
            src_pages,
            dst,
            imm,
        )?;
        self.execute_routed(sim, handle, routed, on_done, SubmitKind::Paged)?;
        self.bump_rotation(gpu);
        Ok(())
    }

    /// Register a peer group for scatter/barrier fast paths.
    pub fn add_peer_group(&self, addrs: Vec<NetAddr>) -> PeerGroupHandle {
        self.state.borrow_mut().peer_groups.add(addrs)
    }

    /// The peer list behind a group handle.
    pub fn peer_group(&self, group: PeerGroupHandle) -> Option<Vec<NetAddr>> {
        self.state.borrow().peer_groups.get(group).map(|p| p.to_vec())
    }

    /// Release a peer group's registry entry (paper §3.5: long-lived
    /// engines must free request-scoped groups). Invalidates the
    /// group's template: later templated submissions error.
    pub fn remove_peer_group(&self, group: PeerGroupHandle) -> bool {
        self.state.borrow_mut().peer_groups.remove(group).is_some()
    }

    /// Pre-template the group's work requests on `gpu`'s domain group
    /// (§3.5): resolves rkeys/NIC pairing once and registers the
    /// barrier scratch region, so `submit_*_templated` calls patch
    /// per-call fields only.
    pub fn bind_peer_group_mrs(
        &self,
        gpu: u8,
        group: PeerGroupHandle,
        descs: &[MrDesc],
    ) -> Result<()> {
        // Validate + resolve routes BEFORE allocating the scratch
        // region: a failed bind (stale handle, bad descriptors) must
        // not leak a registered MR.
        let fanout = self.fanout(gpu);
        let peers = self.state.borrow().peer_groups.prepare_bind(group, fanout, descs)?;
        let (scratch, _) = self.alloc_mr(gpu, 1);
        self.state
            .borrow_mut()
            .peer_groups
            .install_template(group, fanout, peers, scratch)
    }

    /// Scatter slices of `src` to many peers (paper `submit_scatter`).
    /// One WR per destination; `imm` delivered to each peer. The
    /// untemplated (ad-hoc) path: descriptors resolved per call.
    pub fn submit_scatter(
        &self,
        sim: &mut Sim,
        group: Option<PeerGroupHandle>,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        // Scatter fans out to *different* peers: plan per peer, NIC
        // rotated per entry.
        let gpu = src.device.gpu;
        if cfg!(debug_assertions) {
            self.state.borrow().peer_groups.check(group, dsts.len());
        }
        let routed = route_scatter(self.fanout(gpu), self.peek_rotation(gpu), dsts, imm)?;
        self.execute_routed(sim, src, routed, on_done, SubmitKind::Scatter)?;
        self.bump_rotation(gpu);
        Ok(())
    }

    /// Immediate-only notification to every peer (paper
    /// `submit_barrier`). `dsts` supplies a valid descriptor per peer
    /// — required on EFA even for zero-sized writes (§3.5). The
    /// untemplated path allocates its scratch source per call.
    pub fn submit_barrier(
        &self,
        sim: &mut Sim,
        gpu: u8,
        group: Option<PeerGroupHandle>,
        dsts: &[MrDesc],
        imm: u32,
        on_done: OnDone,
    ) -> Result<()> {
        if cfg!(debug_assertions) {
            self.state.borrow().peer_groups.check(group, dsts.len());
        }
        // Route AND health-check BEFORE allocating the scratch source:
        // a rejected barrier (§3.2 mismatch, all NICs down) must not
        // register anything.
        let routed = route_barrier(self.fanout(gpu), self.peek_rotation(gpu), dsts, imm)?;
        self.ensure_group_up(gpu)?;
        // Zero-length writes need a 1-byte-capable source; use a tiny
        // scratch region (pre-registered once on the templated path).
        let (scratch, scratch_desc) = self.alloc_mr(gpu, 1);
        if let Err(e) = self.execute_routed(sim, &scratch, routed, on_done, SubmitKind::Barrier) {
            // Group went down between the check above and dispatch:
            // unwind the scratch registration so a rejected barrier
            // leaves no MR behind.
            self.dereg_mr(&scratch_desc);
            return Err(e);
        }
        self.bump_rotation(gpu);
        Ok(())
    }

    /// Bail (and count the outage) when every NIC of `gpu`'s group is
    /// down — called by paths that would otherwise allocate state
    /// before [`Engine::execute_routed`] could reject the submission.
    fn ensure_group_up(&self, gpu: u8) -> Result<()> {
        let mut s = self.state.borrow_mut();
        if s.groups[gpu as usize].health.up_count() == 0 {
            s.metrics.rejected_all_down.add(1);
            let fanout = s.groups[gpu as usize].nics.len();
            drop(s);
            crate::bail!(
                "all {fanout} NICs of the domain group are down; \
                 submission rejected (see FailoverPolicy docs)"
            );
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // §3.5 templated fast path
    // ------------------------------------------------------------------

    /// Templated contiguous write to `peer` of a bound group: per-call
    /// fields are patched into the pre-resolved routes; no descriptor
    /// traversal or rkey resolution happens here.
    pub fn submit_single_write_templated(
        &self,
        sim: &mut Sim,
        src: (&MrHandle, u64),
        len: u64,
        group: PeerGroupHandle,
        peer: usize,
        dst_off: u64,
        imm: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        let t = self.state.borrow().peer_groups.template(group)?;
        let (handle, src_off) = src;
        let routed =
            route_single_write_templated(&t, t.rotation.next(), peer, src_off, len, dst_off, imm)?;
        self.execute_routed(sim, handle, routed, on_done, SubmitKind::SingleTpl)?;
        t.rotation.bump();
        Ok(())
    }

    /// Templated paged writes to `peer` of a bound group.
    pub fn submit_paged_writes_templated(
        &self,
        sim: &mut Sim,
        page_len: u64,
        src: (&MrHandle, &Pages),
        group: PeerGroupHandle,
        peer: usize,
        dst_pages: &Pages,
        imm: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        let t = self.state.borrow().peer_groups.template(group)?;
        let (handle, src_pages) = src;
        let routed = route_paged_writes_templated(
            &t,
            t.rotation.next(),
            peer,
            page_len,
            src_pages,
            dst_pages,
            imm,
        )?;
        self.execute_routed(sim, handle, routed, on_done, SubmitKind::PagedTpl)?;
        t.rotation.bump();
        Ok(())
    }

    /// Templated scatter over a bound group: four integers per
    /// destination, patched into the template.
    pub fn submit_scatter_templated(
        &self,
        sim: &mut Sim,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        let t = self.state.borrow().peer_groups.template(group)?;
        let routed = route_scatter_templated(&t, t.rotation.next(), dsts, imm)?;
        self.execute_routed(sim, src, routed, on_done, SubmitKind::ScatterTpl)?;
        t.rotation.bump();
        Ok(())
    }

    /// Templated barrier over a bound group: destinations, routes and
    /// the scratch source all come from the template.
    pub fn submit_barrier_templated(
        &self,
        sim: &mut Sim,
        group: PeerGroupHandle,
        imm: u32,
        on_done: OnDone,
    ) -> Result<()> {
        let t = self.state.borrow().peer_groups.template(group)?;
        let routed = route_barrier_templated(&t, t.rotation.next(), imm);
        let scratch = t.scratch.clone();
        self.execute_routed(sim, &scratch, routed, on_done, SubmitKind::BarrierTpl)?;
        t.rotation.bump();
        Ok(())
    }

    // ------------------------------------------------------------------
    // Batched write family: one engine crossing per N writes
    // ------------------------------------------------------------------

    /// Batched ad-hoc writes: all of `dsts` routed in one pass against
    /// one rotation peek, submitted as ONE engine crossing (one
    /// transfer, one submit→handoff→prep charge), committed with a
    /// single `bump_n`. Entry `i` routes exactly as the `i`-th of N
    /// sequential [`Engine::submit_single_write`] calls would, so the
    /// per-NIC WR streams are byte-identical to the loop — only the
    /// per-call overhead collapses. All-or-nothing: a rejected batch
    /// routes nothing and never shifts later NIC assignment.
    pub fn submit_write_batch(
        &self,
        sim: &mut Sim,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm_base: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        if dsts.is_empty() {
            self.fire_on_done(sim, on_done);
            return Ok(());
        }
        let gpu = src.device.gpu;
        let routed = route_write_batch(self.fanout(gpu), self.peek_rotation(gpu), dsts, imm_base)?;
        self.execute_routed(sim, src, routed, on_done, SubmitKind::Batch)?;
        self.state.borrow().groups[gpu as usize].rotation.bump_n(dsts.len());
        Ok(())
    }

    /// Batched templated writes over a bound group (§3.5 + batch): the
    /// template is resolved once, every destination is patched against
    /// the same rotation peek, and the group's cursor commits once via
    /// `bump_n` — equivalent to N sequential
    /// [`Engine::submit_single_write_templated`] calls but with one
    /// engine crossing and one health-mask snapshot. `imm_base` (when
    /// set) is delivered unchanged with EVERY entry: the receiver sees
    /// one increment per destination, matching
    /// `expect_imm_count(imm, N)`.
    pub fn submit_batch_templated(
        &self,
        sim: &mut Sim,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm_base: Option<u32>,
        on_done: OnDone,
    ) -> Result<()> {
        let t = self.state.borrow().peer_groups.template(group)?;
        if dsts.is_empty() {
            self.fire_on_done(sim, on_done);
            return Ok(());
        }
        let routed = route_batch_templated(&t, t.rotation.next(), dsts, imm_base)?;
        self.execute_routed(sim, src, routed, on_done, SubmitKind::BatchTpl)?;
        t.rotation.bump_n(dsts.len());
        Ok(())
    }

    /// Configure the probation TTL for believed-dead remote NICs on
    /// `gpu`'s group: a gossiped/concluded death mark older than
    /// `ttl_ns` is dropped on the next degraded submission and the
    /// remote is optimistically re-probed. Zero disables (default).
    pub fn set_remote_probe_ttl(&self, gpu: u8, ttl_ns: u64) {
        self.state.borrow().groups[gpu as usize]
            .health
            .set_remote_probe_ttl(ttl_ns);
    }

    // ------------------------------------------------------------------
    // Completion notification
    // ------------------------------------------------------------------

    /// Notify `cb` when `imm` has been received `count` times on
    /// `gpu`'s domain group (paper `expect_imm_count`).
    pub fn expect_imm_count(
        &self,
        sim: &mut Sim,
        gpu: u8,
        imm: u32,
        count: u32,
        cb: impl FnOnce(&mut Sim) + 'static,
    ) {
        let ready = {
            let mut s = self.state.borrow_mut();
            let r = s.groups[gpu as usize].imm.expect(imm, count, Box::new(cb));
            if s.metrics.enabled() {
                s.metrics.imm_arms.add(1);
                if r.is_some() {
                    // Satisfied from the recorded count on the spot.
                    s.metrics.imm_retires.add(1);
                }
            }
            r
        };
        if let Some(cb) = ready {
            let dispatch = self.state.borrow().costs.callback_ns;
            sim.after(dispatch, cb);
        }
    }

    /// Poll the current counter value (CPU-side read; GPU-side reads
    /// add GDRCopy latency at the call site).
    pub fn imm_value(&self, gpu: u8, imm: u32) -> u32 {
        self.state.borrow().groups[gpu as usize].imm.value(imm)
    }

    /// Release counter state for `imm` (paper `free_imm`).
    pub fn free_imm(&self, gpu: u8, imm: u32) {
        self.state.borrow_mut().groups[gpu as usize].imm.free(imm);
    }

    // ------------------------------------------------------------------
    // UVM watcher
    // ------------------------------------------------------------------

    /// Allocate a UVM watcher: `cb(old, new)` fires when the engine's
    /// polling thread observes a changed value (paper
    /// `alloc_uvm_watcher`).
    ///
    /// The polling thread is modeled event-wise: each device-side
    /// write schedules the observation at
    /// `write + PCIe + U(0, poll) + dispatch jitter`, statistically
    /// identical to a GDRCopy poll loop at `uvm_poll_ns` without
    /// simulating idle iterations.
    pub fn alloc_uvm_watcher(
        &self,
        cb: impl Fn(&mut Sim, u64, u64) + 'static,
    ) -> UvmWatcherHandle {
        let mut s = self.state.borrow_mut();
        let id = s.next_watcher;
        s.next_watcher += 1;
        s.watchers.insert(
            id,
            Watcher {
                value: 0,
                cb: Rc::new(cb),
            },
        );
        UvmWatcherHandle {
            engine: self.clone(),
            id,
        }
    }

    fn uvm_device_write(&self, sim: &mut Sim, id: u64, value: u64) {
        let (cb, old, delay) = {
            let mut s = self.state.borrow_mut();
            // Freed watcher: drop the write. A cancelled request's
            // still-enqueued kernels may bump a watcher the scenario
            // already released; the threaded runtime tolerates the
            // same (a dead word is just never observed).
            if !s.watchers.contains_key(&id) {
                return;
            }
            let pcie = s.gpu_profile.pcie_ns;
            let poll = s.costs.uvm_poll_ns;
            let phase = s.rng.below(poll.max(1));
            let jit = s.costs.submit_jitter.clone();
            let extra = jit.sample(&mut s.rng); // dispatch tail
            let w = s.watchers.get_mut(&id).expect("checked above");
            let old = w.value;
            w.value = value;
            (w.cb.clone(), old, pcie + phase + 500 + extra)
        };
        // Watcher coalescing: the poll may miss intermediate values;
        // the callback receives (old, new) exactly as the paper
        // specifies so it can catch up.
        sim.after(delay, move |s| cb(s, old, value));
    }

    // ------------------------------------------------------------------
    // Internals
    // ------------------------------------------------------------------

    fn fanout(&self, gpu: u8) -> usize {
        self.state.borrow().groups[gpu as usize].nics.len()
    }

    fn bump_rotation(&self, gpu: u8) -> usize {
        self.state.borrow().groups[gpu as usize].rotation.bump()
    }

    /// The rotation value the next bump will commit (route with this,
    /// bump only once routing succeeded).
    fn peek_rotation(&self, gpu: u8) -> usize {
        self.state.borrow().groups[gpu as usize].rotation.next()
    }

    /// Execute routed writes (each already paired with its destination
    /// `(NIC, rkey)` by [`super::core`]); charges worker CPU and posts
    /// WRs at the modeled times (chained where the NIC supports it).
    /// Unhealthy paths are masked here — at patch time, after routing
    /// — so untemplated and templated submissions alike egress only on
    /// lanes believed to reach their destination (downed local NICs,
    /// observed link partitions and gossiped-dead remote NICs all
    /// steer the choice); errs when the whole group is down locally.
    fn execute_routed(
        &self,
        sim: &mut Sim,
        src: &MrHandle,
        mut routed: RoutedVec,
        on_done: OnDone,
        kind: SubmitKind,
    ) -> Result<()> {
        assert!(!routed.is_empty(), "empty transfer");
        let gpu = src.device.gpu as usize;
        let now = sim.now();
        {
            let mut s = self.state.borrow_mut();
            let res = {
                let health = &s.groups[gpu].health;
                if health.all_clear() {
                    Ok(())
                } else {
                    // Probation: lift expired remote death-marks
                    // before masking, so a believed-dead remote is
                    // optimistically re-probed once its TTL elapses.
                    health.expire_dead_remotes(now);
                    remap_routed(&mut routed, health)
                }
            };
            if let Err(e) = res {
                // An all-NICs-down rejection is a transport failure
                // too: count it so scenarios can observe the outage.
                s.metrics.rejected_all_down.add(1);
                return Err(e);
            }
        }
        let posts = {
            let mut s = self.state.borrow_mut();
            s.metrics.submission(kind);
            let tid = s.transfers.begin(routed.len(), on_done);
            // Worker-cost model: submit → handoff → prep → per-WR post.
            let (enqueued, worker_start, first_post_at) = s.charge_submission(now, gpu);
            let nic0 = s.groups[gpu].nics[0];
            let prof = s.net.profile(nic0);
            // Inline up to the common fanout: the hot path allocates
            // nothing between routing and the per-WR post schedule.
            let mut posts: SmallVec<(Instant, usize, WorkRequest), 4> = SmallVec::new();
            let mut t = first_post_at;
            let mut bytes = 0u64;
            let mut lane0 = 0u8;
            for (i, w) in routed.into_iter().enumerate() {
                let RoutedWrite { plan: p, route: (dst_nic, rkey), alts } = w;
                let wr_id = s.alloc_wr();
                s.transfers.bind_wr(wr_id, tid);
                // Chaining: on RC up to `max_chain` WRs share a
                // doorbell; the chained ones cost less CPU.
                let chained = prof.max_chain > 1 && i % prof.max_chain != 0;
                t += if chained {
                    prof.post_chained_ns
                } else {
                    prof.post_ns
                };
                let wr = WorkRequest {
                    id: wr_id,
                    qp: QpId(1), // WRITE QP class (two-QP split, §3.5)
                    op: WrOp::Write {
                        dst: dst_nic,
                        dst_rkey: RKey(rkey),
                        dst_va: p.dst_va,
                        src: DmaSlice::new(&src.buf, p.src_off as usize, p.len as usize),
                        imm: p.imm,
                    },
                    chained,
                };
                if s.armed {
                    s.retry.insert(
                        wr_id,
                        RetryEntry {
                            gpu,
                            lane: p.nic,
                            cur_lane: p.nic,
                            routes: alts,
                            wr: wr.clone(),
                            attempts: 0,
                        },
                    );
                }
                s.metrics.wire(p.nic, 1, p.len);
                bytes += p.len;
                if i == 0 {
                    lane0 = p.nic as u8;
                }
                posts.push((t, p.nic, wr));
            }
            let wrs = posts.len() as u32;
            let g = &mut s.groups[gpu];
            g.worker_free = t;
            let seq = if s.metrics.enabled() {
                let ev = TraceEvent {
                    kind,
                    lane: lane0,
                    wrs,
                    bytes,
                    submitted: now,
                    enqueued,
                    worker_start,
                    first_post: first_post_at,
                    last_post: t,
                    retired: 0,
                    outcome: TraceOutcome::Posted,
                };
                s.trace.push(ev)
            } else {
                NO_TRACE
            };
            s.transfers.set_trace(tid, seq);
            posts
        };
        // Post each WR at its worker-time; back-pressured WRs queue on
        // the group and retry on completion events.
        for (at, nic_idx, wr) in posts {
            let this = self.clone();
            sim.at(at, move |sim| {
                let (net, local) = {
                    let s = this.state.borrow();
                    (s.net.clone(), s.groups[gpu].nics[nic_idx])
                };
                if !net.post(sim, local, wr.clone()) {
                    this.state.borrow_mut().groups[gpu].pending[nic_idx].push_back(wr);
                }
            });
        }
        Ok(())
    }

    /// Domain progress: runs when a NIC signals completions (stands in
    /// for one worker poll iteration).
    fn progress(&self, sim: &mut Sim, gpu: usize, addr: NicAddr) {
        let mut cqes = Vec::with_capacity(16);
        let net = self.state.borrow().net.clone();
        loop {
            cqes.clear();
            net.poll_cq(addr, 64, &mut cqes);
            if cqes.is_empty() {
                break;
            }
            for cqe in cqes.drain(..) {
                self.handle_cqe(sim, gpu, addr, cqe);
            }
        }
        // Retry back-pressured WRs now that SQ slots may have freed.
        let nic_idx = addr.nic as usize;
        loop {
            let wr = {
                let mut s = self.state.borrow_mut();
                match s.groups[gpu].pending[nic_idx].pop_front() {
                    Some(wr) => wr,
                    None => break,
                }
            };
            if !net.post(sim, addr, wr.clone()) {
                self.state.borrow_mut().groups[gpu].pending[nic_idx].push_front(wr);
                break;
            }
        }
    }

    fn handle_cqe(&self, sim: &mut Sim, gpu: usize, addr: NicAddr, cqe: Cqe) {
        match cqe.kind {
            CqeKind::SendDone | CqeKind::WriteDone => {
                let now = sim.now();
                let done = {
                    let mut s = self.state.borrow_mut();
                    if s.armed {
                        s.retry.remove(&cqe.wr_id);
                    }
                    let done = s.transfers.complete_wr(cqe.wr_id);
                    if let Some((_, seq)) = &done {
                        // Last WR of the transfer: close the span and
                        // bucket its submit→retire latency.
                        if let Some(sub) = s.trace.close(*seq, now, TraceOutcome::Retired) {
                            s.metrics.observe_latency(now.saturating_sub(sub));
                        }
                    }
                    done
                };
                if let Some((on_done, _)) = done {
                    self.fire_on_done(sim, on_done);
                }
            }
            CqeKind::WrError => self.on_wr_error(sim, cqe.wr_id),
            CqeKind::ImmRecvd { imm, .. } => {
                let (waiter, dispatch) = {
                    let mut s = self.state.borrow_mut();
                    let w = s.groups[gpu].imm.on_imm(imm);
                    if s.metrics.enabled() {
                        s.metrics.imm_bumps.add(1);
                        if w.is_some() {
                            s.metrics.imm_retires.add(1);
                        }
                    }
                    (w, s.costs.callback_ns)
                };
                if let Some(cb) = waiter {
                    sim.after(dispatch, cb);
                }
            }
            CqeKind::RecvDone { len, src: _src } => {
                let (payload, cb, repost, dispatch) = {
                    let mut s = self.state.borrow_mut();
                    // Rotating pool: re-post the buffer with a fresh id.
                    let new_id = s.alloc_wr();
                    let dispatch = s.costs.callback_ns;
                    let g = &mut s.groups[gpu];
                    let (data, buf, overflowed) = g.recvs.complete(cqe.wr_id, len, new_id);
                    // Single-threaded runtime: loud failure is safe
                    // and points straight at the mis-sized pool (the
                    // threaded runtime poisons the delivery instead).
                    assert!(!overflowed, "{}", RecvPool::overflow_msg(len, data.len()));
                    let cb = g.recv_cb.clone();
                    if s.metrics.enabled() {
                        s.metrics.recv_completed.add(1);
                    }
                    // The rotating repost keeps the pool depth steady.
                    s.metrics.recv_posts(1);
                    (data, cb, (new_id, buf), dispatch)
                };
                let net = self.state.borrow().net.clone();
                net.post(
                    sim,
                    addr,
                    WorkRequest {
                        id: repost.0,
                        qp: QpId(0),
                        op: WrOp::Recv {
                            buf: DmaSlice::whole(&repost.1),
                        },
                        chained: false,
                    },
                );
                // Engine-level control plane: health gossip rides the
                // same recv pool as heartbeats but is consumed HERE —
                // applied to the group's link table, never delivered
                // to application callbacks.
                if wire::is_nic_health(&payload) {
                    let mut s = self.state.borrow_mut();
                    s.metrics.gossip_received.add(1);
                    if let Ok((nic, up)) = wire::decode_nic_health(&payload) {
                        // Stamp the gossiped death at receive time so
                        // the probation TTL counts from when THIS
                        // group started believing it.
                        s.armed = true;
                        s.groups[gpu].health.set_remote_at(nic, up, sim.now());
                        s.metrics.gossip_applied.add(1);
                    }
                    return;
                }
                if let Some(cb) = cb {
                    // Ownership handoff: the extracted payload moves
                    // into the callback's `Fired` — no per-message
                    // copy on the Cont path.
                    sim.after(dispatch, move |s| cb(s, Fired::bytes(payload)));
                }
            }
        }
    }

    /// A WR died on a downed NIC or a partitioned link (fabric
    /// `WrError`). The failure is first ATTRIBUTED: the directed link
    /// `(egress lane → destination NIC)` is marked suspect in the
    /// group's [`NicHealth`] table, and once every local lane toward
    /// that destination has failed the REMOTE NIC is concluded dead —
    /// which is what the group's gossip peers are told, so they mask
    /// it before paying their own error round-trip. Under
    /// [`FailoverPolicy::Resubmit`] the WR is then reposted on the
    /// next believed-healthy path — another lane toward the same
    /// destination NIC first, then a surviving remote NIC of the same
    /// region (the payload provably did not commit, so neither can
    /// duplicate); attempts cap at fanout + route count, then — or
    /// under [`FailoverPolicy::ErrorOut`] immediately — the error is
    /// counted and the transfer completes undelivered so waiters do
    /// not hang (the receiver's ImmCounter stays un-bumped; see the
    /// trait docs for the caller-visible contract).
    fn on_wr_error(&self, sim: &mut Sim, wr_id: u64) {
        enum Act {
            Retry { gpu: usize, nic_idx: usize, wr: WorkRequest },
            Fail(Option<(OnDone, u64)>),
        }
        let now = sim.now();
        let (act, gossip) = {
            let mut s = self.state.borrow_mut();
            s.metrics.wr_err_total.add(1);
            let entry = s.retry.remove(&wr_id);
            match entry {
                Some(mut e) => {
                    let remote = e.wr.op.dst();
                    let mut gossip = None;
                    match remote {
                        Some(r) => {
                            // Attribution: the directed link (egress
                            // lane → destination NIC) is the suspect.
                            s.metrics.wr_err_link.add(1);
                            let g = &s.groups[e.gpu];
                            g.health.set_link(e.cur_lane, r, false);
                            // Conclude remote death only from full link
                            // evidence: one attributed WrError per local
                            // lane (a locally-dead lane proves nothing
                            // about the destination and cannot satisfy
                            // the bar).
                            if g.health.up_count() > 0
                                && g.health.all_links_observed_down(r)
                                && g.health.remote_up(r)
                            {
                                g.health.set_remote_at(r, false, now);
                                s.metrics.wr_err_remote.add(1);
                                if !g.gossip.is_empty() {
                                    gossip = Some((e.gpu, r));
                                }
                            }
                        }
                        // SEND-path WR: a single fixed destination,
                        // nothing to attribute beyond the egress NIC.
                        None => s.metrics.wr_err_nic.add(1),
                    }
                    if s.failover == FailoverPolicy::Resubmit {
                        e.attempts += 1;
                        let target = {
                            let g = &s.groups[e.gpu];
                            let cap = (g.nics.len() + e.routes.len()) as u8;
                            match (e.attempts <= cap, remote) {
                                (true, Some(r)) => retarget(
                                    &g.health,
                                    e.lane,
                                    e.attempts as usize,
                                    r,
                                    &e.routes,
                                ),
                                _ => None,
                            }
                        };
                        match target {
                            Some((lane, new_route)) => {
                                if let Some((r, rkey)) = new_route {
                                    if let WrOp::Write { dst, dst_rkey, .. } = &mut e.wr.op {
                                        *dst = r;
                                        *dst_rkey = RKey(rkey);
                                    }
                                }
                                // e.lane stays the ORIGINAL lane: the
                                // projection base is stable while the
                                // per-link mask shrinks with each
                                // attributed failure, so the walk
                                // visits every surviving path.
                                e.cur_lane = lane;
                                let wr = e.wr.clone();
                                let gpu = e.gpu;
                                s.metrics.resubmits.add(1);
                                // The repost is real wire traffic.
                                if let WrOp::Write { src, .. } = &e.wr.op {
                                    s.metrics.wire(lane, 1, src.len as u64);
                                }
                                s.retry.insert(wr_id, e);
                                (Act::Retry { gpu, nic_idx: lane, wr }, gossip)
                            }
                            None => {
                                s.metrics.error_outs.add(1);
                                (Act::Fail(s.transfers.complete_wr(wr_id)), gossip)
                            }
                        }
                    } else {
                        s.metrics.error_outs.add(1);
                        (Act::Fail(s.transfers.complete_wr(wr_id)), gossip)
                    }
                }
                None => {
                    // Unarmed WR (or already-retired transfer): the
                    // egress NIC is all we can attribute to.
                    s.metrics.wr_err_nic.add(1);
                    s.metrics.error_outs.add(1);
                    (Act::Fail(s.transfers.complete_wr(wr_id)), None)
                }
            }
        };
        if let Some((gpu, remote)) = gossip {
            self.send_gossip(sim, gpu, remote);
        }
        match act {
            Act::Retry { gpu, nic_idx, wr } => {
                let this = self.clone();
                sim.defer(move |sim| {
                    let (net, local) = {
                        let s = this.state.borrow();
                        (s.net.clone(), s.groups[gpu].nics[nic_idx])
                    };
                    if !net.post(sim, local, wr.clone()) {
                        this.state.borrow_mut().groups[gpu].pending[nic_idx].push_back(wr);
                    }
                });
            }
            Act::Fail(done) => {
                if let Some((d, seq)) = done {
                    self.state
                        .borrow_mut()
                        .trace
                        .close(seq, now, TraceOutcome::Failed);
                    self.fire_on_done(sim, d);
                }
            }
        }
    }

    /// Tell the group's gossip peers that `remote` was observed dead:
    /// one small control SEND per peer over the ordinary recv pool
    /// (fire-and-forget; peers owning the dead NIC are skipped — they
    /// know their own link state from the fabric hooks).
    fn send_gossip(&self, sim: &mut Sim, gpu: usize, remote: NicAddr) {
        let peers = self.state.borrow().groups[gpu].gossip.clone();
        if peers.is_empty() {
            return;
        }
        let msg = wire::encode_nic_health(remote, false);
        for p in &peers {
            if p.nics.contains(&remote) {
                continue;
            }
            self.state.borrow().metrics.gossip_sent.add(1);
            self.submit_send(sim, gpu as u8, p, &msg, OnDone::Noop);
        }
    }

    fn fire_on_done(&self, sim: &mut Sim, on_done: OnDone) {
        match on_done {
            OnDone::Callback(cb) => {
                let dispatch = self.state.borrow().costs.callback_ns;
                sim.after(dispatch, cb);
            }
            OnDone::Flag(f) => f.set(true),
            OnDone::Noop => {}
        }
    }
}

impl State {
    fn alloc_wr(&mut self) -> u64 {
        let id = self.next_wr;
        self.next_wr += 1;
        id
    }

    /// Charge the submit → handoff → prep pipeline, returning the
    /// `(enqueued, worker_start, first_post)` stamps of the modeled
    /// stages (the caller builds its [`TraceEvent`] from them). The
    /// worker is a single pinned thread per group: a submission waits
    /// for it to drain earlier work (`worker_free`).
    fn charge_submission(&mut self, now: Instant, gpu: usize) -> (Instant, Instant, Instant) {
        let c = self.costs.clone();
        let enq = now + c.submit_ns + c.submit_jitter.sample(&mut self.rng);
        let handoff = enq + c.handoff_ns + c.handoff_jitter.sample(&mut self.rng);
        let worker_start = handoff.max(self.groups[gpu].worker_free);
        let first_post = worker_start + c.prep_ns + c.prep_jitter.sample(&mut self.rng);
        (enq, worker_start, first_post)
    }
}

/// Handle to a UVM watcher; device code calls
/// [`UvmWatcherHandle::device_write`] when a GPU kernel updates the
/// watched word.
#[derive(Clone)]
pub struct UvmWatcherHandle {
    engine: Engine,
    id: u64,
}

impl UvmWatcherHandle {
    /// Record a device-side write of `value` at the current sim time;
    /// the watcher callback fires after PCIe + poll-phase latency.
    pub fn device_write(&self, sim: &mut Sim, value: u64) {
        self.engine.uvm_device_write(sim, self.id, value);
    }

    /// Drop the watcher (later writes are ignored).
    pub fn free(&self) {
        self.engine.state.borrow_mut().watchers.remove(&self.id);
    }
}

// ---------------------------------------------------------------------
// The uniform TransferEngine interface over the DES runtime
// ---------------------------------------------------------------------

impl TransferEngine for Engine {
    fn runtime_kind(&self) -> RuntimeKind {
        RuntimeKind::Des
    }

    fn group_address(&self, gpu: u8) -> NetAddr {
        Engine::group_address(self, gpu)
    }

    fn nics_per_gpu(&self) -> u8 {
        Engine::nics_per_gpu(self)
    }

    fn alloc_mr(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        Engine::alloc_mr(self, gpu, len)
    }

    fn alloc_mr_unbacked(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        Engine::alloc_mr_unbacked(self, gpu, len)
    }

    fn reg_mr(&self, gpu: u8, buf: &DmaBuf) -> (MrHandle, MrDesc) {
        Engine::reg_mr(self, gpu, buf)
    }

    fn dereg_mr(&self, desc: &MrDesc) {
        Engine::dereg_mr(self, desc)
    }

    fn submit_send(&self, cx: &mut Cx, gpu: u8, addr: &NetAddr, msg: &[u8], on_done: Notify) {
        Engine::submit_send(self, cx.sim(), gpu, addr, msg, on_done.into_des());
    }

    fn submit_recvs(&self, cx: &mut Cx, gpu: u8, len: usize, cnt: usize, on_msg: OnRecv) {
        match on_msg {
            OnRecv::Handler(cb) => {
                Engine::submit_recvs(self, cx.sim(), gpu, len, cnt, move |_sim, m| cb(m))
            }
            OnRecv::Cont(c) => {
                // Ownership handoff: the pooled message's extracted
                // bytes flow into the continuation without a copy.
                Engine::submit_recvs(self, cx.sim(), gpu, len, cnt, move |sim, m| {
                    c.fire_des(sim, m)
                })
            }
        }
    }

    fn submit_single_write(
        &self,
        cx: &mut Cx,
        src: (&MrHandle, u64),
        len: u64,
        dst: (&MrDesc, u64),
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_single_write(self, cx.sim(), src, len, dst, imm, on_done.into_des())
    }

    fn submit_paged_writes(
        &self,
        cx: &mut Cx,
        page_len: u64,
        src: (&MrHandle, &Pages),
        dst: (&MrDesc, &Pages),
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_paged_writes(self, cx.sim(), page_len, src, dst, imm, on_done.into_des())
    }

    fn add_peer_group(&self, addrs: Vec<NetAddr>) -> PeerGroupHandle {
        Engine::add_peer_group(self, addrs)
    }

    fn peer_group(&self, group: PeerGroupHandle) -> Option<Vec<NetAddr>> {
        Engine::peer_group(self, group)
    }

    fn remove_peer_group(&self, group: PeerGroupHandle) -> bool {
        Engine::remove_peer_group(self, group)
    }

    fn bind_peer_group_mrs(
        &self,
        gpu: u8,
        group: PeerGroupHandle,
        descs: &[MrDesc],
    ) -> Result<()> {
        Engine::bind_peer_group_mrs(self, gpu, group, descs)
    }

    fn submit_scatter(
        &self,
        cx: &mut Cx,
        group: Option<PeerGroupHandle>,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_scatter(self, cx.sim(), group, src, dsts, imm, on_done.into_des())
    }

    fn submit_write_batch(
        &self,
        cx: &mut Cx,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm_base: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_write_batch(self, cx.sim(), src, dsts, imm_base, on_done.into_des())
    }

    fn submit_barrier(
        &self,
        cx: &mut Cx,
        gpu: u8,
        group: Option<PeerGroupHandle>,
        dsts: &[MrDesc],
        imm: u32,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_barrier(self, cx.sim(), gpu, group, dsts, imm, on_done.into_des())
    }

    fn submit_single_write_templated(
        &self,
        cx: &mut Cx,
        src: (&MrHandle, u64),
        len: u64,
        group: PeerGroupHandle,
        peer: usize,
        dst_off: u64,
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_single_write_templated(
            self,
            cx.sim(),
            src,
            len,
            group,
            peer,
            dst_off,
            imm,
            on_done.into_des(),
        )
    }

    fn submit_paged_writes_templated(
        &self,
        cx: &mut Cx,
        page_len: u64,
        src: (&MrHandle, &Pages),
        group: PeerGroupHandle,
        peer: usize,
        dst_pages: &Pages,
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_paged_writes_templated(
            self,
            cx.sim(),
            page_len,
            src,
            group,
            peer,
            dst_pages,
            imm,
            on_done.into_des(),
        )
    }

    fn submit_scatter_templated(
        &self,
        cx: &mut Cx,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_scatter_templated(self, cx.sim(), src, group, dsts, imm, on_done.into_des())
    }

    fn submit_batch_templated(
        &self,
        cx: &mut Cx,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm_base: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_batch_templated(
            self,
            cx.sim(),
            src,
            group,
            dsts,
            imm_base,
            on_done.into_des(),
        )
    }

    fn submit_barrier_templated(
        &self,
        cx: &mut Cx,
        group: PeerGroupHandle,
        imm: u32,
        on_done: Notify,
    ) -> Result<()> {
        Engine::submit_barrier_templated(self, cx.sim(), group, imm, on_done.into_des())
    }

    fn expect_imm_count(&self, cx: &mut Cx, gpu: u8, imm: u32, count: u32, on: Notify) {
        Engine::expect_imm_count(self, cx.sim(), gpu, imm, count, on.into_sim_cb());
    }

    fn imm_value(&self, gpu: u8, imm: u32) -> u32 {
        Engine::imm_value(self, gpu, imm)
    }

    fn free_imm(&self, gpu: u8, imm: u32) {
        Engine::free_imm(self, gpu, imm)
    }

    fn alloc_uvm_watcher(&self, on: OnWatch) -> UvmWatcher {
        match on {
            OnWatch::Handler(cb) => UvmWatcher::Des(Engine::alloc_uvm_watcher(
                self,
                move |_sim, old, new| cb(old, new),
            )),
            OnWatch::Cont(c) => UvmWatcher::Des(Engine::alloc_uvm_watcher(
                self,
                move |sim, old, new| c.fire_des(sim, Fired::pair(old, new)),
            )),
        }
    }

    fn inject_chaos(&self, cx: &mut Cx, profile: &ChaosProfile) {
        Engine::inject_chaos(self, cx.sim(), profile)
    }

    fn set_nic_health(&self, gpu: u8, nic: u8, up: bool) {
        Engine::set_nic_health(self, gpu, nic, up)
    }

    fn nic_health_mask(&self, gpu: u8) -> u64 {
        Engine::nic_health_mask(self, gpu)
    }

    fn set_failover_policy(&self, policy: FailoverPolicy) {
        Engine::set_failover_policy(self, policy)
    }

    fn transport_errors(&self) -> u64 {
        Engine::transport_errors(self)
    }

    fn telemetry(&self) -> EngineSnapshot {
        Engine::telemetry(self)
    }

    fn take_traces(&self) -> Vec<TraceEvent> {
        Engine::take_traces(self)
    }

    fn set_telemetry(&self, on: bool) {
        Engine::set_telemetry(self, on)
    }

    fn set_trace_capacity(&self, cap: usize) {
        Engine::set_trace_capacity(self, cap)
    }

    fn link_health_mask(&self, gpu: u8, remote: NicAddr) -> u64 {
        Engine::link_health_mask(self, gpu, remote)
    }

    fn report_remote_health(&self, gpu: u8, remote: NicAddr, up: bool) {
        Engine::report_remote_health(self, gpu, remote, up)
    }

    fn set_gossip_peers(&self, gpu: u8, peers: Vec<NetAddr>) {
        Engine::set_gossip_peers(self, gpu, peers)
    }

    fn set_remote_probe_ttl(&self, gpu: u8, ttl_ns: u64) {
        Engine::set_remote_probe_ttl(self, gpu, ttl_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::profile::NicProfile;
    use crate::fabric::topology::ClusterSpec;

    /// Two-node EFA-like setup: 1 GPU per node, 2 NICs per GPU.
    fn setup(profile: fn() -> NicProfile) -> (Sim, SimNet, Engine, Engine) {
        let net = SimNet::new(11);
        for node in 0..2u16 {
            for nic in 0..2u8 {
                net.add_nic(NicAddr { node, gpu: 0, nic }, profile());
            }
        }
        let gp = GpuProfile::h200();
        let a = Engine::new(&net, 0, 1, 2, gp.clone(), EngineCosts::default(), 1);
        let b = Engine::new(&net, 1, 1, 2, gp, EngineCosts::default(), 2);
        (Sim::new(), net, a, b)
    }

    #[test]
    fn single_write_with_imm_counter() {
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        let (src, _) = a.alloc_mr(0, 4096);
        let (_dst_h, dst_d) = b.alloc_mr(0, 4096);
        src.buf.write(0, b"engine write path");

        let got = Rc::new(Cell::new(false));
        let done = Rc::new(Cell::new(false));
        let g = got.clone();
        b.expect_imm_count(&mut sim, 0, 77, 1, move |_| g.set(true));
        a.submit_single_write(
            &mut sim,
            (&src, 0),
            17,
            (&dst_d, 100),
            Some(77),
            OnDone::Flag(done.clone()),
        )
        .unwrap();
        sim.run();
        assert!(got.get(), "receiver notified via ImmCounter");
        assert!(done.get(), "sender OnDone flag set");
        // Payload landed at the right offset. Reading through the
        // descriptor's region (dst handle buf is the same region).
        let (h, _) = (_dst_h, ());
        assert_eq!(&h.buf.to_vec()[100..117], b"engine write path");
    }

    #[test]
    fn engine_workload_tracks_scheduler_stats() {
        // The timer-wheel scheduler's counters must stay coherent
        // under a real engine workload (proxy hops, NIC serialization,
        // delivery events), and `Cx::stats` must read them through.
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        let (src, _) = a.alloc_mr(0, 4096);
        let (_dst_h, dst_d) = b.alloc_mr(0, 4096);
        let done = Rc::new(Cell::new(false));
        a.submit_single_write(
            &mut sim,
            (&src, 0),
            512,
            (&dst_d, 0),
            None,
            OnDone::Flag(done.clone()),
        )
        .unwrap();
        sim.run();
        assert!(done.get());
        let st = sim.stats();
        assert!(st.scheduled > 0, "engine work schedules events");
        assert_eq!(
            st.executed + st.cancelled,
            st.scheduled,
            "every event fired or was cancelled once the sim drained"
        );
        assert_eq!(st.executed, sim.executed());
        assert!(st.peak_pending > 0 && st.peak_pending <= st.scheduled);
        assert_eq!(sim.pending(), 0);
        assert_eq!(crate::engine::traits::Cx::Des(&mut sim).stats(), st);
    }

    #[test]
    fn large_write_shards_across_both_nics() {
        let (mut sim, net, a, b) = setup(NicProfile::efa);
        let len = 4 << 20;
        let (src, _) = a.alloc_mr(0, len);
        let (dst_h, dst_d) = b.alloc_mr(0, len);
        let pattern: Vec<u8> = (0..len).map(|i| (i * 31 % 251) as u8).collect();
        src.buf.write(0, &pattern);

        a.submit_single_write(&mut sim, (&src, 0), len as u64, (&dst_d, 0), None, OnDone::Noop)
            .unwrap();
        sim.run();
        assert_eq!(dst_h.buf.to_vec(), pattern, "payload integrity after sharding");
        // Both local NICs carried traffic.
        let (tx0, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 0 });
        let (tx1, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 1 });
        assert!(tx0 > 0 && tx1 > 0, "sharded across NICs: {tx0} {tx1}");
        assert_eq!(tx0 + tx1, len as u64);
    }

    #[test]
    fn paged_writes_land_at_indexed_pages() {
        let (mut sim, _net, a, b) = setup(NicProfile::connectx7);
        let page = 4096u64;
        let (src, _) = a.alloc_mr(0, (page * 8) as usize);
        let (dst_h, dst_d) = b.alloc_mr(0, (page * 16) as usize);
        for i in 0..8u8 {
            src.buf.write((i as u64 * page) as usize, &[i + 1; 16]);
        }
        // Scatter source pages 0..8 to destination pages [3,9,1,12,0,7,5,14].
        let dst_idx = vec![3u32, 9, 1, 12, 0, 7, 5, 14];
        let done = Rc::new(Cell::new(false));
        a.submit_paged_writes(
            &mut sim,
            page,
            (&src, &Pages::contiguous(0, 8, page)),
            (&dst_d, &Pages { indices: dst_idx.clone(), stride: page, offset: 0 }),
            Some(5),
            OnDone::Flag(done.clone()),
        )
        .unwrap();
        sim.run();
        assert!(done.get());
        let v = dst_h.buf.to_vec();
        for (i, &di) in dst_idx.iter().enumerate() {
            let off = (di as u64 * page) as usize;
            assert_eq!(v[off..off + 16], [(i as u8) + 1; 16], "page {i} -> slot {di}");
        }
        // One imm per page.
        assert_eq!(b.imm_value(0, 5), 8);
    }

    #[test]
    fn send_recv_rpc_roundtrip() {
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        let inbox: Rc<RefCell<Vec<Vec<u8>>>> = Rc::default();
        let sink = inbox.clone();
        b.submit_recvs(&mut sim, 0, 256, 4, move |_s, m| {
            assert!(m.poison.is_none());
            sink.borrow_mut().push(m.data);
        });
        // More messages than posted buffers: rotation must re-post.
        for i in 0..10u8 {
            a.submit_send(&mut sim, 0, &b.group_address(0), &[i; 5], OnDone::Noop);
        }
        sim.run();
        let got = inbox.borrow();
        assert_eq!(got.len(), 10, "rotating pool re-posts buffers");
        // SRD: arrival order may differ; check the set.
        let mut firsts: Vec<u8> = got.iter().map(|m| m[0]).collect();
        firsts.sort_unstable();
        assert_eq!(firsts, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_and_barrier_to_many_peers() {
        // 1 sender node + 4 peer nodes on CX-7.
        let net = SimNet::new(5);
        for node in 0..5u16 {
            net.add_nic(NicAddr { node, gpu: 0, nic: 0 }, NicProfile::connectx7());
        }
        let gp = GpuProfile::h100();
        let engines: Vec<Engine> = (0..5)
            .map(|n| Engine::new(&net, n, 1, 1, gp.clone(), EngineCosts::default(), n as u64))
            .collect();
        let mut sim = Sim::new();
        let (src, _) = engines[0].alloc_mr(0, 1024);
        src.buf.write(0, &[7u8; 1024]);
        let peers: Vec<(MrHandle, MrDesc)> =
            (1..5).map(|i| engines[i].alloc_mr(0, 1024)).collect();
        // Scatter/barrier through a registered peer group.
        let group = engines[0].add_peer_group(
            (1..5).map(|i| engines[i].group_address(0)).collect(),
        );
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .enumerate()
            .map(|(i, (_, d))| ScatterDst {
                len: 256,
                src: (i as u64) * 256,
                dst: (d.clone(), 64),
            })
            .collect();
        let done = Rc::new(Cell::new(false));
        engines[0].submit_scatter(
            &mut sim,
            Some(group),
            &src,
            &dsts,
            Some(9),
            OnDone::Flag(done.clone()),
        )
        .unwrap();
        sim.run();
        assert!(done.get());
        for (i, (h, _)) in peers.iter().enumerate() {
            assert_eq!(&h.buf.to_vec()[64..64 + 256], &[7u8; 256], "peer {i}");
            assert_eq!(engines[i + 1].imm_value(0, 9), 1);
        }
        // Barrier: imm-only writes.
        let descs: Vec<MrDesc> = peers.iter().map(|(_, d)| d.clone()).collect();
        engines[0]
            .submit_barrier(&mut sim, 0, Some(group), &descs, 33, OnDone::Noop)
            .unwrap();
        sim.run();
        for i in 1..5 {
            assert_eq!(engines[i].imm_value(0, 33), 1, "barrier imm at peer {i}");
        }
    }

    #[test]
    fn expect_after_arrival_fires_immediately() {
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        let (src, _) = a.alloc_mr(0, 64);
        let (_dh, dd) = b.alloc_mr(0, 64);
        a.submit_single_write(&mut sim, (&src, 0), 64, (&dd, 0), Some(4), OnDone::Noop)
            .unwrap();
        sim.run();
        assert_eq!(b.imm_value(0, 4), 1);
        // Register the expectation after the write landed.
        let fired = Rc::new(Cell::new(false));
        let f = fired.clone();
        b.expect_imm_count(&mut sim, 0, 4, 1, move |_| f.set(true));
        sim.run();
        assert!(fired.get(), "late expectation satisfied from recorded count");
    }

    #[test]
    fn uvm_watcher_sees_coalesced_progress() {
        let (mut sim, _net, a, _b) = setup(NicProfile::efa);
        let log: Rc<RefCell<Vec<(u64, u64)>>> = Rc::default();
        let l = log.clone();
        let w = a.alloc_uvm_watcher(move |_s, old, new| l.borrow_mut().push((old, new)));
        let w2 = w.clone();
        sim.at(10_000, move |s| w2.device_write(s, 3));
        let w3 = w.clone();
        sim.at(20_000_000, move |s| w3.device_write(s, 7));
        sim.run();
        let log = log.borrow();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0], (0, 3), "callback gets old and new value");
        assert_eq!(log[1], (3, 7));
    }

    #[test]
    fn submission_trace_orders_events() {
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        let (src, _) = a.alloc_mr(0, 1 << 16);
        let descs: Vec<(MrHandle, MrDesc)> = (0..4).map(|_| b.alloc_mr(0, 4096)).collect();
        let dsts: Vec<ScatterDst> = descs
            .iter()
            .map(|(_, d)| ScatterDst { len: 1024, src: 0, dst: (d.clone(), 0) })
            .collect();
        a.submit_scatter(&mut sim, None, &src, &dsts, Some(1), OnDone::Noop)
            .unwrap();
        sim.run();
        let traces = a.take_traces();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.kind, SubmitKind::Scatter);
        assert!(t.submitted < t.enqueued);
        assert!(t.enqueued < t.worker_start);
        assert!(t.worker_start < t.first_post);
        assert!(t.first_post < t.last_post, "4 posts take time");
        assert!(t.last_post < t.retired, "span closed at the last CQE");
        assert_eq!(t.outcome, TraceOutcome::Retired);
        assert_eq!(t.wrs, 4);
        assert_eq!(t.bytes, 4 * 1024);
        // Table 8 ballpark: submit->enqueue ~0.1 µs, ->first post
        // within a few µs.
        assert!(t.enqueued - t.submitted < 5_000);
        assert!(t.first_post - t.submitted < 20_000);
        // The counter registry agrees with the span.
        let snap = a.telemetry();
        assert_eq!(snap.sub_scatter, 1);
        assert_eq!(snap.total_submissions(), 1);
        assert_eq!(snap.total_wrs(), 4);
        assert_eq!(snap.total_bytes(), 4 * 1024);
        assert_eq!(snap.transport_errors(), 0);
        assert_eq!(snap.trace_dropped, 0);
        assert_eq!(snap.lat_us_pow2.iter().sum::<u64>(), 1, "one retired span bucketed");
        // Receiver side: 4 imms delivered on b.
        assert_eq!(b.telemetry().imm_bumps, 4);
        // Drained is drained.
        assert!(a.take_traces().is_empty());
    }

    #[test]
    fn telemetry_disable_suppresses_hot_path_counters() {
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        a.set_telemetry(false);
        let (src, _) = a.alloc_mr(0, 4096);
        let (_dh, dd) = b.alloc_mr(0, 4096);
        a.submit_single_write(&mut sim, (&src, 0), 256, (&dd, 0), None, OnDone::Noop)
            .unwrap();
        sim.run();
        let snap = a.telemetry();
        assert_eq!(snap.total_submissions(), 0);
        assert_eq!(snap.total_bytes(), 0);
        assert!(a.take_traces().is_empty(), "no spans captured while off");
        assert_eq!(snap.trace_dropped, 0, "disabled capture is not a drop");
        // Re-enable: counting resumes mid-run.
        a.set_telemetry(true);
        a.submit_single_write(&mut sim, (&src, 0), 256, (&dd, 64), None, OnDone::Noop)
            .unwrap();
        sim.run();
        let snap = a.telemetry();
        assert_eq!(snap.sub_single, 1);
        assert_eq!(snap.total_bytes(), 256);
        assert_eq!(a.take_traces().len(), 1);
    }

    #[test]
    fn trace_ring_overflow_counts_drops() {
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        a.set_trace_capacity(2);
        let (src, _) = a.alloc_mr(0, 4096);
        let (_dh, dd) = b.alloc_mr(0, 4096);
        for i in 0..5u64 {
            a.submit_single_write(&mut sim, (&src, 0), 64, (&dd, i * 64), None, OnDone::Noop)
                .unwrap();
            sim.run();
        }
        assert_eq!(a.telemetry().trace_dropped, 3);
        let spans = a.take_traces();
        assert_eq!(spans.len(), 2, "ring holds only the newest spans");
        assert!(
            spans.iter().all(|t| t.outcome == TraceOutcome::Retired),
            "surviving spans were still closed by their CQEs"
        );
        // Every transfer completed before the next submit pushed a
        // span, so all five were closed while still live and bucketed
        // — recycling later does not retract a recorded latency.
        assert_eq!(a.telemetry().lat_us_pow2.iter().sum::<u64>(), 5);
    }

    #[test]
    fn write_batch_delivers_payloads_and_imms() {
        let (mut sim, _net, a, b) = setup(NicProfile::efa);
        let (src, _) = a.alloc_mr(0, 8192);
        let pattern: Vec<u8> = (0..8192).map(|i| (i % 253) as u8).collect();
        src.buf.write(0, &pattern);
        let peers: Vec<(MrHandle, MrDesc)> = (0..3).map(|_| b.alloc_mr(0, 4096)).collect();
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .enumerate()
            .map(|(i, (_, d))| ScatterDst {
                len: 1000 + 500 * i as u64,
                src: 2048 * i as u64,
                dst: (d.clone(), 64),
            })
            .collect();
        let done = Rc::new(Cell::new(false));
        a.submit_write_batch(&mut sim, &src, &dsts, Some(21), OnDone::Flag(done.clone()))
            .unwrap();
        sim.run();
        assert!(done.get(), "one OnDone for the whole batch");
        for (i, (h, _)) in peers.iter().enumerate() {
            let len = 1000 + 500 * i;
            let off = 2048 * i;
            assert_eq!(
                &h.buf.to_vec()[64..64 + len],
                &pattern[off..off + len],
                "batch entry {i} payload"
            );
        }
        // imm_base delivered unchanged with EVERY entry: one receiver
        // increment per destination.
        assert_eq!(b.imm_value(0, 21), 3);
    }

    #[test]
    fn empty_batch_completes_without_posting() {
        let (mut sim, net, a, _b) = setup(NicProfile::efa);
        let (src, _) = a.alloc_mr(0, 64);
        let done = Rc::new(Cell::new(false));
        a.submit_write_batch(&mut sim, &src, &[], Some(3), OnDone::Flag(done.clone()))
            .unwrap();
        assert!(done.get(), "empty batch fires OnDone immediately");
        sim.run();
        let (tx, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 0 });
        assert_eq!(tx, 0, "nothing posted for an empty batch");
    }

    #[test]
    fn batch_advances_rotation_by_len_like_the_loop() {
        // After a 3-entry batch the next single write must egress on
        // the same NIC it would after 3 sequential singles.
        let (mut sim, net, a, b) = setup(NicProfile::efa);
        let (src, _) = a.alloc_mr(0, 4096);
        let peers: Vec<(MrHandle, MrDesc)> = (0..3).map(|_| b.alloc_mr(0, 512)).collect();
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .map(|(_, d)| ScatterDst { len: 64, src: 0, dst: (d.clone(), 0) })
            .collect();
        a.submit_write_batch(&mut sim, &src, &dsts, None, OnDone::Noop).unwrap();
        sim.run();
        let (tx0_before, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 0 });
        let (tx1_before, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 1 });
        // Cursor is now 3; the next single routes at rotation 4 →
        // NIC 0 on a fanout-2 group.
        let (_, d0) = &peers[0];
        a.submit_single_write(&mut sim, (&src, 0), 64, (d0, 128), None, OnDone::Noop)
            .unwrap();
        sim.run();
        let (tx0_after, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 0 });
        let (tx1_after, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 1 });
        assert_eq!(tx0_after - tx0_before, 64, "post-batch cursor continues the round-robin");
        assert_eq!(tx1_after, tx1_before);
    }

    #[test]
    fn dereg_mr_returns_registry_to_baseline() {
        let (_sim, net, a, _b) = setup(NicProfile::efa);
        let before = net.mem().len();
        let (_h, d) = a.alloc_mr(0, 4096);
        assert_eq!(net.mem().len(), before + 2, "one rkey per NIC of the group");
        a.dereg_mr(&d);
        assert_eq!(net.mem().len(), before, "dereg removes every rkey");
        a.dereg_mr(&d); // double-dereg is safe
        assert_eq!(net.mem().len(), before);
    }

    #[test]
    fn engine_cluster_builder_compatible() {
        // Engines attach onto topology-built fabrics.
        let spec = ClusterSpec::h100_cx7(1);
        let cluster = spec.build();
        let e = Engine::new(
            &cluster.net,
            0,
            spec.gpus_per_node,
            spec.nics_per_gpu,
            spec.gpu_profile.clone(),
            EngineCosts::default(),
            3,
        );
        assert_eq!(e.nics_per_gpu(), 1);
        assert_eq!(e.group_address(3).primary(), NicAddr { node: 0, gpu: 3, nic: 0 });
    }
}
