//! Cross-runtime integration: the SAME scenario code, written against
//! `&dyn TransferEngine`, exercising scatter + barrier +
//! `expect_imm_count` end-to-end on each runtime (DES and threaded),
//! plus the generic app harness entry points on both.

use fabric_lib::apps::kvcache::harness::run_generic_kv_push;
use fabric_lib::apps::moe::harness::run_generic_dispatch_round;
use fabric_lib::apps::rlweights::{run_generic_rank0_fanout, run_generic_weight_sync};
use fabric_lib::engine::api::{MrDesc, MrHandle, ScatterDst};
use fabric_lib::engine::traits::{
    expect_flag, new_flag, Cluster, Cx, Notify, RuntimeKind, TransferEngine,
};

/// Scatter to every peer through a registered group, count the
/// per-peer immediates, then release everyone with a handle-based
/// barrier — the §6 dispatch skeleton, runtime-agnostic.
fn scatter_barrier_imm_scenario(cx: &mut Cx, engines: &[&dyn TransferEngine]) {
    let n = engines.len();
    let sender = engines[0];
    let (src, _) = sender.alloc_mr(0, 256 * (n - 1));
    src.buf.write(0, &vec![0xAB; 256 * (n - 1)]);
    let peers: Vec<(MrHandle, MrDesc)> =
        engines[1..].iter().map(|e| e.alloc_mr(0, 1024)).collect();
    let group =
        sender.add_peer_group(engines[1..].iter().map(|e| e.main_address()).collect());

    // Receiver-side expectations: one scatter imm + one barrier imm.
    let mut scattered = Vec::new();
    let mut released = Vec::new();
    for e in &engines[1..] {
        scattered.push(expect_flag(*e, cx, 0, 11, 1));
        released.push(expect_flag(*e, cx, 0, 12, 1));
    }

    let dsts: Vec<ScatterDst> = peers
        .iter()
        .enumerate()
        .map(|(i, (_, d))| ScatterDst {
            len: 256,
            src: (i as u64) * 256,
            dst: (d.clone(), 100),
        })
        .collect();
    let sent = new_flag();
    sender
        .submit_scatter(cx, Some(group), &src, &dsts, Some(11), Notify::Flag(sent.clone()))
        .unwrap();
    cx.wait(&sent);
    cx.wait_all(&scattered);
    for (i, (h, _)) in peers.iter().enumerate() {
        assert_eq!(&h.buf.to_vec()[100..356], &[0xAB; 256][..], "peer {i} payload");
    }

    // Barrier through the same handle.
    let descs: Vec<MrDesc> = peers.iter().map(|(_, d)| d.clone()).collect();
    sender
        .submit_barrier(cx, 0, Some(group), &descs, 12, Notify::Noop)
        .unwrap();
    cx.wait_all(&released);

    // Counters were retired by the satisfied expectations.
    for e in &engines[1..] {
        assert_eq!(e.imm_value(0, 11), 0);
        assert_eq!(e.imm_value(0, 12), 0);
    }
}

fn run_scenario_on(kind: RuntimeKind) {
    let mut cluster = Cluster::new(kind, 4, 1, 2, 0x1A7E);
    {
        let (mut cx, engines) = cluster.parts();
        assert!(engines.iter().all(|e| e.runtime_kind() == kind));
        scatter_barrier_imm_scenario(&mut cx, &engines);
        cx.settle();
    }
    cluster.shutdown();
}

#[test]
fn scatter_barrier_imm_end_to_end_des() {
    run_scenario_on(RuntimeKind::Des);
}

#[test]
fn scatter_barrier_imm_end_to_end_threaded() {
    run_scenario_on(RuntimeKind::Threaded);
}

/// All three app protocols, one runtime per test, from the same
/// generic entry points the app harnesses expose.
fn run_apps_on(kind: RuntimeKind) {
    let mut cluster = Cluster::new(kind, 6, 1, 2, 0xA995);
    {
        let (mut cx, engines) = cluster.parts();
        run_generic_kv_push(&mut cx, engines[0], engines[1], 8, 512);
        run_generic_dispatch_round(&mut cx, &engines[..4], 4, 64);
        run_generic_rank0_fanout(&mut cx, &engines[..4], 16 * 1024);
        let (trainers, replicas) = engines.split_at(4);
        run_generic_weight_sync(&mut cx, trainers, replicas, 2048);
        cx.settle();
    }
    cluster.shutdown();
}

#[test]
fn app_protocols_end_to_end_des() {
    run_apps_on(RuntimeKind::Des);
}

#[test]
fn app_protocols_end_to_end_threaded() {
    run_apps_on(RuntimeKind::Threaded);
}
