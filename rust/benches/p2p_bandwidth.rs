//! Paper Fig 8 + Table 2: point-to-point communication performance.
//!
//! Single and paged one-sided WRITE throughput across message sizes on
//! both NIC families, for the TransferEngine, a NIXL-like baseline
//! (UCX-style layer with heavier per-op bookkeeping) and the raw NIC
//! (ib_write_bw / fi_rma_bw stand-in). Prints the fraction-of-peak
//! series (Fig 8) and the absolute table (Table 2).
//!
//! Usage: cargo bench --bench p2p_bandwidth [-- --quick] [--json PATH]
//!
//! `--quick` (alias `--fast`) shrinks rep counts for CI smoke runs;
//! `--json PATH` merges the headline numbers into the report at PATH
//! under the `p2p_bandwidth` section (see BENCH_p2p.json).

use std::cell::Cell;
use std::collections::BTreeMap;
use std::rc::Rc;

use fabric_lib::engine::api::{EngineCosts, Pages};
use fabric_lib::engine::des_engine::{Engine, OnDone};
use fabric_lib::fabric::chaos::ChaosProfile;
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};
use fabric_lib::fabric::simnet::SimNet;
use fabric_lib::sim::time::gbps;
use fabric_lib::sim::Sim;
use fabric_lib::util::json::{update_report, Json};
use fabric_lib::util::table::{f, Table};

struct Bed {
    net: SimNet,
    sim: Sim,
    a: Engine,
    b: Engine,
    peak_gbps: f64,
}

fn bed(profile: NicProfile, nics: u8, extra_submit: u64) -> Bed {
    let net = SimNet::new(0xF18);
    for node in 0..2u16 {
        for x in 0..nics {
            net.add_nic(NicAddr { node, gpu: 0, nic: x }, profile.clone());
        }
    }
    let mut costs = EngineCosts::default();
    costs.submit_ns += extra_submit; // NIXL-like: heavier bookkeeping
    costs.prep_ns += extra_submit;
    let a = Engine::new(&net, 0, 1, nics, GpuProfile::h100(), costs.clone(), 1);
    let b = Engine::new(&net, 1, 1, nics, GpuProfile::h100(), costs, 2);
    Bed {
        net,
        sim: Sim::new(),
        a,
        b,
        peak_gbps: profile.rate_gbps * nics as f64,
    }
}

/// Serial single-write throughput (one outstanding transfer).
fn single_write_gbps(bed: &mut Bed, msg: u64, reps: u32) -> f64 {
    let (src, _) = bed.a.alloc_mr_unbacked(0, msg as usize);
    let (_h, dst) = bed.b.alloc_mr_unbacked(0, msg as usize);
    let t0 = bed.sim.now();
    for _ in 0..reps {
        let done = Rc::new(Cell::new(false));
        bed.a
            .submit_single_write(
                &mut bed.sim,
                (&src, 0),
                msg,
                (&dst, 0),
                None,
                OnDone::Flag(done.clone()),
            )
            .unwrap();
        bed.sim.run();
        assert!(done.get());
    }
    gbps(msg * reps as u64, bed.sim.now() - t0)
}

/// Pipelined paged-write throughput; returns (gbps, Mops).
fn paged_write_rate(bed: &mut Bed, page: u64, pages: u32) -> (f64, f64) {
    let region = (page * pages as u64) as usize;
    let (src, _) = bed.a.alloc_mr_unbacked(0, region);
    let (_h, dst) = bed.b.alloc_mr_unbacked(0, region);
    let idx: Vec<u32> = (0..pages).collect();
    let t0 = bed.sim.now();
    let done = Rc::new(Cell::new(false));
    bed.a
        .submit_paged_writes(
            &mut bed.sim,
            page,
            (&src, &Pages { indices: idx.clone(), stride: page, offset: 0 }),
            (&dst, &Pages { indices: idx, stride: page, offset: 0 }),
            None,
            OnDone::Flag(done.clone()),
        )
        .unwrap();
    bed.sim.run();
    assert!(done.get());
    let dt = bed.sim.now() - t0;
    (
        gbps(page * pages as u64, dt),
        pages as f64 / (dt as f64 / 1000.0), // ops per µs = Mop/s
    )
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast" || a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let reps = if fast { 4 } else { 16 };
    // Headline numbers for the BENCH_p2p.json trajectory, captured as
    // the tables are produced (keys must stay in sync with the
    // committed baseline and scripts/bench_diff.py).
    let mut headlines: BTreeMap<String, Json> = BTreeMap::new();

    let singles: &[u64] = &[64 << 10, 256 << 10, 1 << 20, 8 << 20, 16 << 20, 32 << 20];
    let pageds: &[u64] = &[1 << 10, 8 << 10, 16 << 10, 32 << 10, 64 << 10];

    // ---- Fig 8: fraction of peak vs message size ----
    let mut fig8 = Table::new(
        "Figure 8. P2P performance: fraction of peak bandwidth",
        &["op", "size", "EFA TE", "EFA NIXL", "CX7 TE", "CX7 NIXL"],
    );
    for &msg in singles {
        let mut row = vec!["single".to_string(), fmt_size(msg)];
        for (profile, nics) in [(NicProfile::efa(), 2u8), (NicProfile::connectx7(), 1u8)] {
            for extra in [0u64, 260] {
                let mut b = bed(profile.clone(), nics, extra);
                let g = single_write_gbps(&mut b, msg, reps);
                row.push(f(g / b.peak_gbps, 3));
            }
        }
        fig8.row(&row);
    }
    for &page in pageds {
        let pages = if fast { 512 } else { 2048 };
        let mut row = vec!["paged".to_string(), fmt_size(page)];
        for (profile, nics) in [(NicProfile::efa(), 2u8), (NicProfile::connectx7(), 1u8)] {
            for extra in [0u64, 260] {
                let mut b = bed(profile.clone(), nics, extra);
                let (g, _) = paged_write_rate(&mut b, page, pages);
                row.push(f(g / b.peak_gbps, 3));
            }
        }
        fig8.row(&row);
    }
    fig8.print();

    // ---- Table 2: absolute numbers (TransferEngine) ----
    let mut t2 = Table::new(
        "Table 2. EFA and ConnectX-7 performance (TransferEngine)",
        &["op", "size", "EFA Gbps", "EFA Mop/s", "CX7 Gbps", "CX7 Mop/s"],
    );
    for &msg in &[64 << 10, 256 << 10, 1 << 20, 32 << 20] {
        let mut row = vec!["single".to_string(), fmt_size(msg)];
        for (profile, nics, name) in [
            (NicProfile::efa(), 2u8, "efa"),
            (NicProfile::connectx7(), 1u8, "cx7"),
        ] {
            let mut b = bed(profile, nics, 0);
            let g = single_write_gbps(&mut b, msg, reps);
            if msg == 32 << 20 {
                headlines.insert(format!("{name}_single_32m_gbps"), Json::Num(g));
            }
            row.push(f(g, 0));
            row.push("-".into());
        }
        t2.row(&row);
    }
    for &page in &[1 << 10, 8 << 10, 16 << 10, 64 << 10] {
        let pages = if fast { 512 } else { 4096 };
        let mut row = vec!["paged".to_string(), fmt_size(page)];
        for (profile, nics, name) in [
            (NicProfile::efa(), 2u8, "efa"),
            (NicProfile::connectx7(), 1u8, "cx7"),
        ] {
            let mut b = bed(profile, nics, 0);
            let (g, mops) = paged_write_rate(&mut b, page, pages);
            if page == 64 << 10 {
                headlines.insert(format!("{name}_paged_64k_gbps"), Json::Num(g));
            }
            if page == 1 << 10 {
                headlines.insert(format!("{name}_paged_1k_mops"), Json::Num(mops));
            }
            row.push(f(g, 0));
            row.push(f(mops, 2));
        }
        t2.row(&row);
    }
    t2.print();
    println!(
        "\npaper targets — Table 2: EFA 64KiB single 16 Gbps / CX7 44 Gbps; \
         1KiB paged 2.11 / 11.10 Mop/s; 64KiB paged saturates both.\n"
    );

    // ---- Chaos overhead: throughput under extra wire jitter ----
    //
    // Tracks the cost of transport perturbation (and, transitively,
    // of the failover bookkeeping) so future PRs can watch failover
    // overhead: the same paged workload at 0% / 10% / 30% extra
    // jitter (median = pct × base wire latency, chaos RNG seeded).
    let mut cj = Table::new(
        "Chaos. Paged 64 KiB throughput under extra wire jitter",
        &["jitter", "EFA Gbps", "EFA frac", "CX7 Gbps", "CX7 frac"],
    );
    let pages = if fast { 512 } else { 2048 };
    for &pct in &[0u32, 10, 30] {
        let mut row = vec![format!("{pct}%")];
        for (profile, nics) in [(NicProfile::efa(), 2u8), (NicProfile::connectx7(), 1u8)] {
            let wire = profile.wire_ns;
            let mut b = bed(profile, nics, 0);
            if pct > 0 {
                let chaos = ChaosProfile::jitter_pct(0xC4A0 + pct as u64, wire, pct);
                let net = b.net.clone();
                net.inject_chaos(&mut b.sim, &chaos);
            }
            let (g, _) = paged_write_rate(&mut b, 64 << 10, pages);
            row.push(f(g, 0));
            row.push(f(g / b.peak_gbps, 3));
        }
        cj.row(&row);
    }
    cj.print();
    println!("\nchaos gate: jitter shifts latency, not delivered bytes — throughput should degrade gracefully, never lose pages.\n");

    if let Some(path) = json_path {
        headlines.insert(
            "provenance".to_string(),
            Json::from("measured by p2p_bandwidth (DES, deterministic)"),
        );
        update_report(&path, "p2p_bandwidth", Json::Obj(headlines)).expect("write bench report");
        println!("wrote p2p_bandwidth section to {path}");
    }
}

fn fmt_size(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{} MiB", b >> 20)
    } else {
        format!("{} KiB", b >> 10)
    }
}
