//! Transport perturbation ("chaos") profiles for the simulated fabric.
//!
//! The paper's transport contract is *reliable but unordered* delivery
//! with no NIC-count or liveness assumptions beyond §3.2 — yet a
//! happy-path simulation never actually delivers anything out of
//! order, never delays a completion adversarially, and never kills a
//! NIC. A [`ChaosProfile`] closes that gap: it is a **seeded,
//! deterministic** description of adversarial transport behavior that
//! either fabric backend can install:
//!
//! * **extra jitter** — an additional per-chunk wire delay drawn from
//!   its own [`Jitter`] distribution (the DES fabric applies it per
//!   packet/chunk on top of the NIC profile's calibrated jitter);
//! * **bounded reordering** — each message's commit is delayed by
//!   `U[0, reorder_ns)` from a dedicated chaos RNG, permuting
//!   completion order (and therefore ImmCounter bump order) within
//!   that window. Transport legality is preserved: the RC per-QP
//!   sequencer still commits in posting order, so only SRD-style
//!   traffic actually reorders — exactly the paper's "reliable but
//!   unordered" envelope, duplication-free by construction (a delayed
//!   message is still delivered exactly once, or dropped with an
//!   error CQE if a NIC died);
//! * **NIC lifecycle events** — scheduled [`NicEvent`]s take a NIC
//!   down (posts and in-flight traffic on it surface
//!   [`crate::fabric::nic::CqeKind::WrError`] completions to the
//!   sender; nothing is delivered through a dead NIC) and optionally
//!   bring it back up. The fabrics notify registered health hooks so
//!   the engine layer's `NicHealth` table tracks fabric truth.
//!
//! All randomness comes from the profile's own seeded [`Rng`] stream
//! — never from the fabric's base RNG — so (a) a quiet profile leaves
//! the base simulation bit-identical to a run without chaos, and (b)
//! the same seed + the same profile reproduce the exact same
//! perturbation schedule (the chaos determinism tests gate on this).
//!
//! * **per-link partitions** — scheduled [`LinkEvent`]s cut (or heal)
//!   one *directed* `(src NIC, dst NIC)` path while both endpoints
//!   stay up: WRs traversing a cut link fail with the same
//!   exactly-once [`crate::fabric::nic::CqeKind::WrError`] semantics
//!   as a dead NIC, but traffic on every other link — including the
//!   same NICs talking to other peers — is untouched. This is how real
//!   fabrics fail: a flapping switch port or routing black-hole takes
//!   out a path, not a whole NIC. Path failures are NOT locally
//!   observable at the sender's port, so (unlike whole-NIC events) the
//!   fabrics do not push them into the engines' health tables; senders
//!   learn from the `WrError` round-trip and share the observation via
//!   the engine-level health gossip
//!   (`TransferEngine::report_remote_health`).
//!
//! The threaded fabric ([`crate::fabric::local::LocalFabric`]) runs in
//! real time, so only the *semantic* knobs apply there: the reorder
//! window size and the NIC/link events (scheduled on the scenario's
//! Reactor). `extra_jitter`/`reorder_ns` shape DES timing only,
//! mirroring how NIC profiles already work across the two backends.
//!
//! # Example
//!
//! ```
//! use fabric_lib::fabric::chaos::ChaosProfile;
//! use fabric_lib::fabric::nic::NicAddr;
//!
//! let nic = |node, x| NicAddr { node, gpu: 0, nic: x };
//! // 30 µs of bounded commit reordering, NIC n0g0x1 flaps, and the
//! // directed path n1g0x0 → n2g0x0 is cut for 1 ms. Seed 7 pins the
//! // whole perturbation schedule.
//! let profile = ChaosProfile::new(7)
//!     .with_reorder(30_000, 16)
//!     .nic_down(50_000, nic(0, 1))
//!     .nic_up(400_000, nic(0, 1))
//!     .link_down(100_000, (nic(1, 0), nic(2, 0)))
//!     .link_up(1_100_000, (nic(1, 0), nic(2, 0)));
//! assert!(!profile.is_quiet());
//! assert_eq!(profile.link_events.len(), 2);
//! // Install with `TransferEngine::inject_chaos(cx, &profile)`.
//! ```

#![warn(missing_docs)]

use crate::fabric::nic::NicAddr;
use crate::sim::rng::{Jitter, Rng};

/// One scheduled NIC lifecycle event, in model time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NicEvent {
    /// Model-clock time (ns) at which the event fires.
    pub at: u64,
    /// The NIC whose link state flips.
    pub nic: NicAddr,
    /// `false` = NicDown, `true` = NicUp.
    pub up: bool,
}

/// One scheduled per-link partition event, in model time: the
/// *directed* path `src → dst` is cut (`up = false`) or healed while
/// both endpoint NICs stay up. WRs already in flight on a cut link —
/// and WRs posted onto it later — fail with
/// [`crate::fabric::nic::CqeKind::WrError`] at the sender, with the
/// exactly-once guarantee (nothing committed). The reverse direction
/// `dst → src` is a separate link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkEvent {
    /// Model-clock time (ns) at which the event fires.
    pub at: u64,
    /// Source (sender-side) NIC of the directed path.
    pub src: NicAddr,
    /// Destination (receiver-side) NIC of the directed path.
    pub dst: NicAddr,
    /// `false` = partition the link, `true` = heal it.
    pub up: bool,
}

/// A seeded, deterministic transport-perturbation profile. Build with
/// the fluent constructors, then install through
/// `TransferEngine::inject_chaos` (or directly on a fabric backend).
#[derive(Debug, Clone)]
pub struct ChaosProfile {
    /// Seed of the dedicated chaos RNG stream (independent of the
    /// fabric's base seed).
    pub seed: u64,
    /// Extra per-chunk wire delay distribution (DES fabric only).
    pub extra_jitter: Jitter,
    /// Bound of the uniform per-message commit delay that permutes
    /// delivery order (DES fabric only; 0 disables).
    pub reorder_ns: u64,
    /// Reorder window size for the threaded fabric's delivery thread
    /// (0 keeps the backend's default).
    pub reorder_window: usize,
    /// Scheduled NIC failures/recoveries.
    pub nic_events: Vec<NicEvent>,
    /// Scheduled per-link `(src, dst)` partitions/heals.
    pub link_events: Vec<LinkEvent>,
}

impl ChaosProfile {
    /// A quiet profile: no perturbation at all. The fluent builders
    /// below switch individual components on.
    pub fn new(seed: u64) -> Self {
        ChaosProfile {
            seed,
            extra_jitter: Jitter::NONE,
            reorder_ns: 0,
            reorder_window: 0,
            nic_events: Vec::new(),
            link_events: Vec::new(),
        }
    }

    /// Add an extra per-chunk wire-delay distribution.
    pub fn with_extra_jitter(mut self, jitter: Jitter) -> Self {
        self.extra_jitter = jitter;
        self
    }

    /// Convenience: extra jitter whose median is `pct`% of a base wire
    /// latency (how the bandwidth bench expresses "10% jitter").
    pub fn jitter_pct(seed: u64, base_wire_ns: u64, pct: u32) -> Self {
        let median = base_wire_ns as f64 * pct as f64 / 100.0;
        ChaosProfile::new(seed).with_extra_jitter(Jitter {
            median_ns: median,
            sigma: 0.4,
            spike_p: 0.001,
            spike_mean_ns: median * 6.0,
        })
    }

    /// Add bounded reordering: commits delayed by `U[0, bound_ns)` on
    /// the DES fabric; the threaded fabric buffers `window` messages
    /// and releases them in shuffled order.
    pub fn with_reorder(mut self, bound_ns: u64, window: usize) -> Self {
        self.reorder_ns = bound_ns;
        self.reorder_window = window;
        self
    }

    /// Schedule a NicDown at `at` ns.
    pub fn nic_down(mut self, at: u64, nic: NicAddr) -> Self {
        self.nic_events.push(NicEvent { at, nic, up: false });
        self
    }

    /// Schedule a NicUp at `at` ns.
    pub fn nic_up(mut self, at: u64, nic: NicAddr) -> Self {
        self.nic_events.push(NicEvent { at, nic, up: true });
        self
    }

    /// Schedule a partition of the directed link `src → dst` at `at`
    /// ns: WRs traversing that path fail with `WrError` (exactly-once
    /// — nothing committed) while both NICs, and every other link,
    /// keep working. The reverse direction is a separate link.
    pub fn link_down(mut self, at: u64, (src, dst): (NicAddr, NicAddr)) -> Self {
        self.link_events.push(LinkEvent { at, src, dst, up: false });
        self
    }

    /// Schedule a heal of the directed link `src → dst` at `at` ns.
    pub fn link_up(mut self, at: u64, (src, dst): (NicAddr, NicAddr)) -> Self {
        self.link_events.push(LinkEvent { at, src, dst, up: true });
        self
    }

    /// True when the profile perturbs nothing (installing it is a
    /// no-op beyond arming the failover bookkeeping).
    pub fn is_quiet(&self) -> bool {
        self.extra_jitter.median_ns <= 0.0
            && self.reorder_ns == 0
            && self.reorder_window == 0
            && self.nic_events.is_empty()
            && self.link_events.is_empty()
    }

    /// Materialize the seeded sampling state a fabric keeps while the
    /// profile is installed.
    pub fn state(&self) -> ChaosState {
        ChaosState {
            rng: Rng::new(self.seed ^ 0xC4A0_5EED),
            extra: self.extra_jitter.clone(),
            reorder_ns: self.reorder_ns,
        }
    }
}

/// The live sampling state behind an installed [`ChaosProfile`]: a
/// dedicated RNG stream plus the delay distributions. Draw order is
/// fixed by the (deterministic) DES event order, so the same seed and
/// profile always reproduce the same perturbations.
pub struct ChaosState {
    rng: Rng,
    extra: Jitter,
    reorder_ns: u64,
}

impl ChaosState {
    /// Extra wire delay for one chunk.
    pub fn sample_extra(&mut self) -> u64 {
        self.extra.sample(&mut self.rng)
    }

    /// Commit-permuting delay for one message: `U[0, reorder_ns)`.
    pub fn sample_reorder(&mut self) -> u64 {
        if self.reorder_ns == 0 {
            0
        } else {
            self.rng.below(self.reorder_ns)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nic(node: u16) -> NicAddr {
        NicAddr { node, gpu: 0, nic: 0 }
    }

    #[test]
    fn chaos_profile_builders_compose() {
        let p = ChaosProfile::new(7)
            .with_reorder(50_000, 16)
            .nic_down(1_000, nic(0))
            .nic_up(9_000, nic(0));
        assert!(!p.is_quiet());
        assert_eq!(p.reorder_ns, 50_000);
        assert_eq!(p.reorder_window, 16);
        assert_eq!(p.nic_events.len(), 2);
        assert!(!p.nic_events[0].up && p.nic_events[1].up);
        assert!(ChaosProfile::new(7).is_quiet());
    }

    #[test]
    fn chaos_link_event_builders_compose() {
        let p = ChaosProfile::new(3)
            .link_down(2_000, (nic(0), nic(1)))
            .link_up(8_000, (nic(0), nic(1)));
        assert!(!p.is_quiet(), "a link partition alone is perturbation");
        assert_eq!(p.nic_events.len(), 0);
        assert_eq!(p.link_events.len(), 2);
        assert_eq!(p.link_events[0].src, nic(0));
        assert_eq!(p.link_events[0].dst, nic(1));
        assert!(!p.link_events[0].up && p.link_events[1].up);
    }

    #[test]
    fn chaos_state_is_deterministic_per_profile() {
        let p = ChaosProfile::jitter_pct(42, 2600, 30).with_reorder(10_000, 8);
        let mut a = p.state();
        let mut b = p.state();
        for _ in 0..256 {
            assert_eq!(a.sample_extra(), b.sample_extra());
            assert_eq!(a.sample_reorder(), b.sample_reorder());
        }
        // A different seed gives a different stream.
        let mut c = ChaosProfile::jitter_pct(43, 2600, 30)
            .with_reorder(10_000, 8)
            .state();
        let same = (0..64).all(|_| a.sample_reorder() == c.sample_reorder());
        assert!(!same, "distinct seeds must decorrelate the chaos stream");
    }

    #[test]
    fn chaos_reorder_delay_is_bounded() {
        let mut s = ChaosProfile::new(3).with_reorder(4096, 8).state();
        for _ in 0..10_000 {
            assert!(s.sample_reorder() < 4096);
        }
        let mut quiet = ChaosProfile::new(3).state();
        assert_eq!(quiet.sample_reorder(), 0);
        assert_eq!(quiet.sample_extra(), 0);
    }

    #[test]
    fn chaos_jitter_pct_scales_with_base() {
        let p10 = ChaosProfile::jitter_pct(1, 1000, 10);
        let p30 = ChaosProfile::jitter_pct(1, 1000, 30);
        assert!(p30.extra_jitter.median_ns > p10.extra_jitter.median_ns);
        assert!((p10.extra_jitter.median_ns - 100.0).abs() < 1e-9);
    }
}
