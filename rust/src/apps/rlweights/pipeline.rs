//! Pipelined P2P weight-transfer execution (paper §5.2, Fig 5).
//!
//! Each parameter is a *task* with four overlapping stages:
//! (1) H2D memcpy (FSDP CPU offload), (2) preparation —
//! `full_tensor()` unshard, projection fusion, fp8 quantization,
//! (3) zero-copy RDMA WRITE to every inference replica, (4) global
//! barrier across mesh groups (GLOO over Ethernet). New tasks start
//! only while in-flight temporary GPU memory stays under a watermark.
//!
//! Stage costs are calibrated against paper Table 5 (per-call means:
//! H2D 378 µs, full_tensor 532 µs, fuse 37 µs, quantize 137 µs, RDMA
//! submit 23 µs) via byte-roofline + fixed-overhead terms.
//!
//! Runtime-neutral since the compute-model migration: the pipeline
//! holds `Rc<dyn TransferEngine>` per rank and charges its stage
//! costs on [`SerialResource`]s / the [`BarrierModel`], so the same
//! state machine runs on the DES virtual clock and on the threaded
//! runtime's reactor.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::engine::api::{MrDesc, MrHandle};
use crate::engine::model::{BarrierModel, Fired, SerialResource};
use crate::engine::traits::{
    expect_flag, new_flag, Cluster, Cx, Notify, RuntimeKind, SharedFlag, TransferEngine,
};
use crate::fabric::profile::{GpuProfile, NicProfile};
use crate::sim::time::{Duration, Instant, MS};

use super::spec::{compute_routing, RlModelSpec, TransferTask};

/// Calibrated per-stage cost model.
#[derive(Debug, Clone)]
pub struct RlCosts {
    /// H2D PCIe bandwidth (bytes/ns).
    pub h2d_bytes_per_ns: f64,
    /// Fixed overhead per full_tensor() call + NVLink allgather rate.
    pub full_tensor_fixed_ns: Duration,
    pub allgather_bytes_per_ns: f64,
    /// full_tensor calls per parameter (FSDP + optimizer state views).
    pub full_tensor_calls: u32,
    /// Projection fusion per parameter.
    pub fuse_ns: Duration,
    /// Quantize: fixed + HBM-roofline term; ~1.33 calls/param.
    pub quant_fixed_ns: Duration,
    pub hbm_bytes_per_ns: f64,
    /// Framework-side cost of one RDMA submit call (torch → engine).
    pub rdma_submit_ns: Duration,
    /// GLOO barrier latency over Ethernet.
    pub gloo_ns: Duration,
    /// Temporary-memory watermark (bytes).
    pub watermark: u64,
}

impl Default for RlCosts {
    fn default() -> Self {
        RlCosts {
            h2d_bytes_per_ns: 44.0,       // ~44 GB/s effective PCIe
            full_tensor_fixed_ns: 470_000, // torch/dtensor overhead
            allgather_bytes_per_ns: 250.0, // NVLink + network mix
            full_tensor_calls: 2,
            fuse_ns: 37_000,
            quant_fixed_ns: 120_000,
            hbm_bytes_per_ns: 4800.0,
            rdma_submit_ns: 21_000,
            gloo_ns: 2_500_000,
            watermark: 8 << 30,
        }
    }
}

/// Per-rank stage totals (paper Table 5 rows), ns.
#[derive(Debug, Default, Clone, Copy)]
pub struct StageTotals {
    pub h2d: u64,
    pub h2d_calls: u32,
    pub full_tensor: u64,
    pub full_tensor_calls: u32,
    pub fuse: u64,
    pub fuse_calls: u32,
    pub quantize: u64,
    pub quantize_calls: u32,
    pub rdma_submit: u64,
    pub rdma_calls: u32,
    pub wait_ranks: u64,
    pub total: u64,
}

/// Whole-run report.
#[derive(Debug, Clone)]
pub struct RlReport {
    pub model: &'static str,
    pub total_ms: f64,
    pub rank0: StageTotals,
    /// Total bytes written over the fabric.
    pub bytes: u64,
    /// Aggregate achieved bandwidth (Gbps).
    pub agg_gbps: f64,
}

struct RankState {
    rank: u32,
    engine: Rc<dyn TransferEngine>,
    /// Tasks grouped per mesh group; entry = (param tasks to all
    /// replicas).
    groups: Vec<Vec<Vec<TransferTask>>>,
    group: usize,
    next: usize,
    /// Serial engines: H2D copy engine, prep stream, submit thread.
    h2d: SerialResource,
    prep: SerialResource,
    submit: SerialResource,
    inflight: u64,
    /// Write completions still expected for the current mesh group
    /// (initialized to the group's total replica-write count so early
    /// completions can't trigger a premature barrier arrival).
    group_writes_left: usize,
    totals: StageTotals,
    costs: RlCosts,
    src: MrHandle,
    dst_regions: Rc<Vec<MrDesc>>,
    barriers: Rc<Vec<BarrierModel>>,
    started_at: Instant,
    done: Rc<RefCell<HashMap<u32, Instant>>>,
}

/// One training rank's pipeline driver.
#[derive(Clone)]
struct RankSim {
    s: Rc<RefCell<RankState>>,
}

impl RankSim {
    fn pump(&self, cx: &mut Cx) {
        loop {
            let plan = {
                let mut s = self.s.borrow_mut();
                if s.group >= s.groups.len() {
                    return;
                }
                if s.next >= s.groups[s.group].len() {
                    // All tasks of this group started; the barrier
                    // arrival happens when writes drain (see
                    // on_write_done).
                    return;
                }
                let tasks = s.groups[s.group][s.next].clone();
                let bytes = tasks[0].param.bf16_bytes() + tasks[0].param.fp8_bytes();
                if s.inflight + bytes > s.costs.watermark && s.inflight > 0 {
                    return; // watermark gate (§5.2)
                }
                s.inflight += bytes;
                s.next += 1;
                // Stage 1: H2D memcpy (serial copy engine).
                let h2d_cost =
                    (tasks[0].param.bf16_bytes() as f64 / s.costs.h2d_bytes_per_ns) as Duration;
                let (_, end) = s.h2d.reserve(cx, h2d_cost);
                s.totals.h2d += h2d_cost;
                s.totals.h2d_calls += 1;
                Some((tasks, end))
            };
            let Some((tasks, h2d_end)) = plan else { return };
            let this = self.clone();
            cx.at(h2d_end, move |cx: &mut Cx| this.on_h2d_done(cx, tasks));
        }
    }

    /// Stage 2: preparation on the GPU (serial prep stream).
    fn on_h2d_done(&self, cx: &mut Cx, tasks: Vec<TransferTask>) {
        let prep_end = {
            let mut s = self.s.borrow_mut();
            let p = &tasks[0].param;
            let c = &s.costs;
            let ft = c.full_tensor_fixed_ns
                + (p.bf16_bytes() as f64 / c.allgather_bytes_per_ns) as Duration;
            let ft_total = ft * c.full_tensor_calls as u64;
            let fuse = c.fuse_ns;
            // ~1.33 quantize calls per param: MoE params quantize both
            // halves of the fused projection.
            let q_calls = if p.moe && p.id % 3 != 0 { 2 } else { 1 };
            let quant = (c.quant_fixed_ns
                + (2 * p.bf16_bytes() as u64) / (c.hbm_bytes_per_ns as u64))
                * q_calls as u64;
            let total = ft_total + fuse + quant;
            let (_, end) = s.prep.reserve(cx, total);
            s.totals.full_tensor += ft_total;
            s.totals.full_tensor_calls += s.costs.full_tensor_calls;
            s.totals.fuse += fuse;
            s.totals.fuse_calls += 1;
            s.totals.quantize += quant;
            s.totals.quantize_calls += q_calls;
            end
        };
        let this = self.clone();
        cx.at(prep_end, move |cx: &mut Cx| this.on_prepared(cx, tasks));
    }

    /// Stage 3: RDMA WRITE to every replica (framework submit cost +
    /// engine).
    fn on_prepared(&self, cx: &mut Cx, tasks: Vec<TransferTask>) {
        let (engine, src, submits) = {
            let mut s = self.s.borrow_mut();
            let mut submits = Vec::with_capacity(tasks.len());
            for task in &tasks {
                let (_, t) = s.submit.reserve(cx, s.costs.rdma_submit_ns);
                s.totals.rdma_submit += s.costs.rdma_submit_ns;
                s.totals.rdma_calls += 1;
                let desc = s.dst_regions[task.dst as usize].clone();
                let len = task.param.fp8_bytes();
                let off = task.dst_offset % (desc.len - len).max(1);
                submits.push((t, desc, off, len));
            }
            (s.engine.clone(), s.src.clone(), submits)
        };
        let bytes_back = tasks[0].param.bf16_bytes() + tasks[0].param.fp8_bytes();
        let n = submits.len();
        for (i, (at, desc, off, len)) in submits.into_iter().enumerate() {
            let this = self.clone();
            let engine = engine.clone();
            let src = src.clone();
            // Memory released when the last replica write completes.
            let release = if i == n - 1 { bytes_back } else { 0 };
            cx.at(at, move |cx: &mut Cx| {
                let t2 = this.clone();
                let on_done = cx.cont(move |cx: &mut Cx, _f: Fired| {
                    t2.on_write_done(cx, release);
                });
                engine
                    .submit_single_write(
                        cx,
                        (&src, 0),
                        len,
                        (&desc, off),
                        None,
                        Notify::Cont(on_done),
                    )
                    .expect("replica weight write");
            });
        }
    }

    fn on_write_done(&self, cx: &mut Cx, release: u64) {
        let group_done = {
            let mut s = self.s.borrow_mut();
            s.inflight = s.inflight.saturating_sub(release);
            s.group_writes_left -= 1;
            s.group_writes_left == 0
        };
        self.pump(cx);
        if group_done {
            self.arrive_barrier(cx);
        }
    }

    /// Stage 4: global barrier across mesh groups.
    fn arrive_barrier(&self, cx: &mut Cx) {
        let (rank, group, barriers) = {
            let s = self.s.borrow();
            (s.rank, s.group, s.barriers.clone())
        };
        let this = self.clone();
        barriers[group].arrive(cx, rank, move |cx: &mut Cx, released_at| {
            this.on_barrier_release(cx, released_at);
        });
    }

    fn on_barrier_release(&self, cx: &mut Cx, _released_at: Instant) {
        {
            let mut s = self.s.borrow_mut();
            // wait time = release - own arrival.
            let own = s.barriers[s.group]
                .arrival_of(s.rank)
                .expect("released rank must have arrived");
            s.totals.wait_ranks += cx.now().saturating_sub(own);
            s.group += 1;
            s.next = 0;
            if s.group < s.groups.len() {
                let g = s.group;
                s.group_writes_left =
                    s.groups[g].iter().map(|v| v.len()).sum();
            }
        }
        let finished = {
            let s = self.s.borrow();
            s.group >= s.groups.len()
        };
        if finished {
            let mut s = self.s.borrow_mut();
            s.totals.total = cx.now().saturating_sub(s.started_at);
            let rank = s.rank;
            s.done.borrow_mut().insert(rank, cx.now());
        } else {
            self.pump(cx);
        }
    }
}

/// Run the full P2P transfer on whatever runtime backs `cx`:
/// `t_engines` holds one engine per training node, `r_engines` one
/// per inference node (8 GPUs per node, as in the paper deployment).
///
/// `scale` scales parameter bytes (1.0 = full model) to trade fidelity
/// for simulation time; counts and schedule stay identical.
pub fn run_p2p_transfer_on(
    cx: &mut Cx,
    t_engines: &[Rc<dyn TransferEngine>],
    r_engines: &[Rc<dyn TransferEngine>],
    spec: &RlModelSpec,
    scale: f64,
) -> RlReport {
    let gpus_per_node: u8 = 8;
    let t_nodes = spec.t_ranks.div_ceil(gpus_per_node as u32) as usize;
    let r_nodes = spec.r_ranks.div_ceil(gpus_per_node as u32) as usize;
    assert_eq!(t_engines.len(), t_nodes, "one engine per training node");
    assert_eq!(r_engines.len(), r_nodes, "one engine per inference node");
    let start_t = cx.now();

    // Inference weight regions (unbacked).
    let region_len: usize = 32 << 30;
    let mut dst_regions = Vec::with_capacity(spec.r_ranks as usize);
    for r in 0..spec.r_ranks {
        let node = (r / gpus_per_node as u32) as usize;
        let gpu = (r % gpus_per_node as u32) as u8;
        let (_h, d) = r_engines[node].alloc_mr_unbacked(gpu, region_len);
        dst_regions.push(d);
    }
    let dst_regions = Rc::new(dst_regions);

    let costs = RlCosts::default();
    let barriers = Rc::new(
        (0..spec.mesh_groups)
            .map(|_| BarrierModel::new(spec.t_ranks as usize, costs.gloo_ns))
            .collect::<Vec<_>>(),
    );
    let done: Rc<RefCell<HashMap<u32, Instant>>> = Rc::default();

    let mut ranks = Vec::new();
    let mut total_bytes = 0u64;
    for rank in 0..spec.t_ranks {
        let node = (rank / gpus_per_node as u32) as usize;
        let gpu = (rank % gpus_per_node as u32) as u8;
        let engine = t_engines[node].clone();
        let mut tasks = compute_routing(spec, rank);
        for t in &mut tasks {
            t.param.elems = ((t.param.elems as f64 * scale) as u64).max(1);
        }
        total_bytes += tasks.iter().map(|t| t.param.fp8_bytes()).sum::<u64>();
        // Group per mesh group, then per param (all replicas of a
        // param form one prep task).
        let mut groups: Vec<Vec<Vec<TransferTask>>> =
            (0..spec.mesh_groups).map(|_| Vec::new()).collect();
        let mut by_param: HashMap<u32, Vec<TransferTask>> = HashMap::new();
        for t in tasks {
            by_param.entry(t.param.id).or_default().push(t);
        }
        let mut params: Vec<_> = by_param.into_values().collect();
        params.sort_by_key(|v| v[0].param.id);
        for p in params {
            groups[p[0].param.mesh_group as usize].push(p);
        }
        let (src, _) = engine.alloc_mr_unbacked(gpu, 4 << 30);
        let first_group_writes: usize = groups
            .first()
            .map(|g| g.iter().map(|v| v.len()).sum())
            .unwrap_or(0);
        let rs = RankSim {
            s: Rc::new(RefCell::new(RankState {
                rank,
                engine,
                groups,
                group: 0,
                next: 0,
                h2d: SerialResource::new(),
                prep: SerialResource::new(),
                submit: SerialResource::new(),
                inflight: 0,
                group_writes_left: first_group_writes,
                totals: StageTotals::default(),
                costs: costs.clone(),
                src,
                dst_regions: dst_regions.clone(),
                barriers: barriers.clone(),
                started_at: start_t,
                done: done.clone(),
            })),
        };
        ranks.push(rs);
    }
    for r in &ranks {
        r.pump(cx);
    }
    {
        let done = done.clone();
        let t_ranks = spec.t_ranks as usize;
        cx.drive_until("all RL ranks finish", move || done.borrow().len() == t_ranks);
    }

    let done = done.borrow();
    assert_eq!(done.len(), spec.t_ranks as usize, "all ranks must finish");
    let total_ns = done.values().max().unwrap().saturating_sub(start_t);
    let rank0 = ranks[0].s.borrow().totals;
    RlReport {
        model: spec.name,
        total_ms: total_ns as f64 / MS as f64,
        rank0,
        bytes: total_bytes,
        agg_gbps: total_bytes as f64 * 8.0 / total_ns.max(1) as f64,
    }
}

/// Run the full P2P transfer for `spec` on a DES cluster with `nic`
/// NICs (one per GPU) and return the report — the timing-faithful
/// convenience wrapper around [`run_p2p_transfer_on`].
pub fn run_p2p_transfer(spec: &RlModelSpec, nic: NicProfile, scale: f64) -> RlReport {
    let gpus_per_node: u8 = 8;
    let t_nodes = spec.t_ranks.div_ceil(gpus_per_node as u32) as u16;
    let r_nodes = spec.r_ranks.div_ceil(gpus_per_node as u32) as u16;
    let mut cluster = Cluster::new_with(
        RuntimeKind::Des,
        t_nodes + r_nodes,
        gpus_per_node,
        1,
        0xA11,
        nic,
        GpuProfile::h200(),
    );
    let engines = cluster.engines_rc();
    let (t_engines, r_engines) = engines.split_at(t_nodes as usize);
    let report = {
        let (mut cx, _) = cluster.parts();
        run_p2p_transfer_on(&mut cx, t_engines, r_engines, spec, scale)
    };
    cluster.shutdown();
    report
}

/// Runtime-agnostic P2P weight sync (the §5.2 transfer protocol,
/// stripped of the prep-pipeline cost model): every trainer
/// zero-copy-writes its `shard_bytes` shard into a trainer-indexed
/// slot of every replica's weight region (WRITEIMM per write), waits
/// for its own write completions, then arrives at the engine-level
/// barrier; each replica gates on count-based expectations for both.
/// Runs on whichever runtime backs `cx`. Each trainer's replica set is
/// a peer group bound (templated, §3.5) once per sync: shard writes
/// and the barrier all patch the pre-resolved routes. Groups are
/// request-scoped and freed on exit (`remove_peer_group`), so repeated
/// syncs on a long-lived engine don't leak registry entries.
pub fn run_generic_weight_sync(
    cx: &mut Cx,
    trainers: &[&dyn TransferEngine],
    replicas: &[&dyn TransferEngine],
    shard_bytes: u64,
) {
    assert!(!trainers.is_empty() && !replicas.is_empty());
    const IMM_SHARD: u32 = 0x520;
    const IMM_BARRIER: u32 = 0x521;
    let t = trainers.len();

    // Replica weight regions: one shard slot per trainer, plus the
    // receive-side expectations (shards + barrier), registered first.
    let mut regions = Vec::new();
    let mut shard_flags: Vec<SharedFlag> = Vec::new();
    let mut barrier_flags: Vec<SharedFlag> = Vec::new();
    for r in replicas {
        let (h, d) = r.alloc_mr(0, (shard_bytes * t as u64) as usize);
        shard_flags.push(expect_flag(*r, cx, 0, IMM_SHARD, t as u32));
        barrier_flags.push(expect_flag(*r, cx, 0, IMM_BARRIER, t as u32));
        regions.push((h, d));
    }
    let replica_descs: Vec<MrDesc> = regions.iter().map(|(_, d)| d.clone()).collect();

    // Per-trainer peer group over the replica set, bound once: the
    // per-shard writes and the barrier below patch this template.
    let mut groups = Vec::with_capacity(t);
    for tr in trainers {
        let group = tr.add_peer_group(replicas.iter().map(|r| r.main_address()).collect());
        tr.bind_peer_group_mrs(0, group, &replica_descs)
            .expect("replica region bind");
        groups.push(group);
    }

    // Stage 3 (per trainer): one templated write per replica.
    let mut write_flags: Vec<SharedFlag> = Vec::new();
    let mut srcs = Vec::new();
    for (ti, tr) in trainers.iter().enumerate() {
        let (src, _) = tr.alloc_mr(0, shard_bytes as usize);
        src.buf
            .write(0, &vec![ti as u8 + 1; shard_bytes as usize]);
        for ri in 0..regions.len() {
            let f = new_flag();
            tr.submit_single_write_templated(
                cx,
                (&src, 0),
                shard_bytes,
                groups[ti],
                ri,
                ti as u64 * shard_bytes,
                Some(IMM_SHARD),
                Notify::Flag(f.clone()),
            )
            .expect("templated shard write");
            write_flags.push(f);
        }
        srcs.push(src);
    }
    // Stage 4: a trainer arrives at the barrier only once its own
    // writes completed (the engine guarantees no ordering, so the
    // barrier immediate must not overtake an unposted write).
    cx.wait_all(&write_flags);
    for (tr, group) in trainers.iter().zip(&groups) {
        tr.submit_barrier_templated(cx, *group, IMM_BARRIER, Notify::Noop)
            .expect("templated barrier");
    }
    cx.wait_all(&shard_flags);
    cx.wait_all(&barrier_flags);
    // Sync over: free the request-scoped groups (registry hygiene on
    // long-lived engines); the freed handles error on reuse.
    for (tr, group) in trainers.iter().zip(groups) {
        assert!(tr.remove_peer_group(group), "group registered above");
        assert!(
            tr.submit_barrier_templated(cx, group, IMM_BARRIER, Notify::Noop)
                .is_err(),
            "stale handle must error"
        );
    }

    // Every replica holds every trainer's shard in the right slot.
    for (ri, (h, _)) in regions.iter().enumerate() {
        let v = h.buf.to_vec();
        for ti in 0..t {
            let seg = &v[(ti as u64 * shard_bytes) as usize..((ti as u64 + 1) * shard_bytes) as usize];
            assert!(
                seg.iter().all(|&b| b == ti as u8 + 1),
                "replica {ri}: shard from trainer {ti} corrupted"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::traits::run_on_both;

    #[test]
    fn generic_weight_sync_runs_on_both_runtimes() {
        run_on_both(5, 1, 1, 0x51EE7, |cx, engines| {
            let (trainers, replicas) = engines.split_at(3);
            run_generic_weight_sync(cx, trainers, replicas, 4096);
        });
    }

    #[test]
    fn chaos_weight_sync_invariant_under_reordering() {
        // The RL weight-sync protocol (count-gated shard writes + an
        // engine barrier) assumes nothing about delivery order, so it
        // must pass its own payload/expectation asserts under
        // aggressive reordering chaos on both runtimes. (On the
        // threaded runtime the chaos knob is the fabric's shuffle
        // window; on DES it is a bounded commit delay.)
        use crate::engine::traits::{Cluster, RuntimeKind};
        use crate::fabric::chaos::ChaosProfile;
        let chaos = ChaosProfile::new(0x51EE9).with_reorder(200_000, 32);
        for kind in [RuntimeKind::Des, RuntimeKind::Threaded] {
            let mut cluster = Cluster::new(kind, 5, 1, 2, 0x51EE8);
            {
                let (mut cx, engines) = cluster.parts();
                engines[0].inject_chaos(&mut cx, &chaos);
                let (trainers, replicas) = engines.split_at(3);
                run_generic_weight_sync(&mut cx, trainers, replicas, 4096);
                cx.settle();
            }
            cluster.shutdown();
        }
    }

    #[test]
    fn tiny_pipeline_completes_with_overlap() {
        let spec = RlModelSpec::tiny();
        let report = run_p2p_transfer(&spec, NicProfile::connectx7(), 1.0);
        let t = report.rank0;
        assert_eq!(t.h2d_calls, spec.params_per_rank);
        assert_eq!(t.fuse_calls, spec.params_per_rank);
        assert_eq!(
            t.rdma_calls,
            spec.params_per_rank * spec.replicas.min(spec.r_ranks)
        );
        // Pipeline overlap: total wall time < sum of serial stages.
        let serial = t.h2d + t.full_tensor + t.fuse + t.quantize + t.rdma_submit;
        assert!(
            t.total < serial + t.wait_ranks,
            "stages must overlap: total {} vs serial {serial}",
            t.total
        );
        assert!(report.total_ms > 0.0);
    }

    #[test]
    fn kimi_scaled_matches_table5_shape() {
        // 1/8-scale bytes keep counts and pipeline structure; the
        // stage *ratios* should resemble Table 5: full_tensor dominates
        // prep, RDMA submit is small, H2D in between.
        let spec = RlModelSpec {
            t_ranks: 16,
            r_ranks: 8,
            total_params: 1_000_000_000_000 / 16,
            ..RlModelSpec::kimi_k2_1t()
        };
        let report = run_p2p_transfer(&spec, NicProfile::connectx7(), 1.0);
        let t = report.rank0;
        assert!(t.full_tensor > t.h2d, "{t:?}");
        assert!(t.h2d > t.quantize, "{t:?}");
        assert!(t.quantize > t.rdma_submit, "{t:?}");
        // Total in the ~1 s ballpark (paper: 1.233 s).
        assert!(
            report.total_ms > 400.0 && report.total_ms < 4000.0,
            "{}",
            report.total_ms
        );
    }
}
