//! PJRT-backed executable cache.
//!
//! Loads `artifacts/manifest.json`, compiles each HLO-text entry point
//! on the PJRT CPU client on first use, and exposes a typed execute
//! interface. Pattern follows /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`, with `to_tuple` unwrapping (aot.py
//! lowers with `return_tuple=True`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::err::{Context, Result};

use crate::util::Json;

/// Model hyper-parameters recorded in the manifest.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub max_seq: usize,
    pub param_count: usize,
}

impl ModelInfo {
    /// f32 KV-cache bytes for `tokens` positions across all layers
    /// (K and V), matching the cache shapes in `model.py`.
    pub fn kv_bytes(&self, tokens: usize) -> usize {
        2 * self.n_layers * self.n_heads * tokens * (self.d_model / self.n_heads) * 4
    }
}

/// One entry point's I/O signature.
#[derive(Debug, Clone)]
struct Signature {
    file: PathBuf,
    inputs: Vec<(Vec<usize>, String)>,
    outputs: Vec<(Vec<usize>, String)>,
}

/// Input argument for execution.
pub enum ArgValue<'a> {
    /// Scalar i32 (token ids, positions).
    I32(i32),
    /// f32 tensor with shape.
    F32(&'a [f32], &'a [usize]),
}

/// The executable cache. Lazily compiles entries on first use.
pub struct Runtime {
    client: xla::PjRtClient,
    sigs: HashMap<String, Signature>,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    pub model: ModelInfo,
}

impl Runtime {
    /// Load the manifest from `dir` (e.g. `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let m = j.get("model").context("manifest missing `model`")?;
        let get = |k: &str| -> Result<usize> {
            Ok(m.get(k)
                .and_then(|v| v.u64())
                .with_context(|| format!("manifest model missing {k}"))? as usize)
        };
        let model = ModelInfo {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            n_layers: get("n_layers")?,
            d_ff: get("d_ff")?,
            n_experts: get("n_experts")?,
            top_k: get("top_k")?,
            max_seq: get("max_seq")?,
            param_count: get("param_count")?,
        };
        let mut sigs = HashMap::new();
        let entries = j
            .get("entries")
            .and_then(|e| e.obj())
            .context("manifest missing `entries`")?;
        for (name, e) in entries {
            let parse_io = |key: &str| -> Result<Vec<(Vec<usize>, String)>> {
                e.get(key)
                    .context("missing io")?
                    .items()
                    .iter()
                    .map(|io| {
                        let shape: Vec<usize> = io
                            .get("shape")
                            .context("shape")?
                            .items()
                            .iter()
                            .map(|d| d.u64().unwrap() as usize)
                            .collect();
                        let dtype = io
                            .get("dtype")
                            .and_then(|d| d.str())
                            .context("dtype")?
                            .to_string();
                        Ok((shape, dtype))
                    })
                    .collect()
            };
            sigs.insert(
                name.clone(),
                Signature {
                    file: dir.join(
                        e.get("file")
                            .and_then(|f| f.str())
                            .context("entry missing file")?,
                    ),
                    inputs: parse_io("inputs")?,
                    outputs: parse_io("outputs")?,
                },
            );
        }
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            sigs,
            exes: RefCell::new(HashMap::new()),
            model,
        })
    }

    /// Entry names available.
    pub fn entries(&self) -> Vec<String> {
        let mut v: Vec<String> = self.sigs.keys().cloned().collect();
        v.sort();
        v
    }

    /// Number of outputs of an entry.
    pub fn output_count(&self, name: &str) -> Result<usize> {
        Ok(self
            .sigs
            .get(name)
            .with_context(|| format!("unknown entry {name}"))?
            .outputs
            .len())
    }

    /// Output shape of entry `name`, index `i`.
    pub fn output_shape(&self, name: &str, i: usize) -> Result<Vec<usize>> {
        Ok(self.sigs[name].outputs[i].0.clone())
    }

    fn compile(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let sig = self
            .sigs
            .get(name)
            .with_context(|| format!("unknown entry point {name}"))?;
        let path = sig
            .file
            .to_str()
            .context("non-utf8 artifact path")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {name}"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with typed args; returns each output flattened
    /// to f32 (i32 outputs are converted).
    pub fn execute(&self, name: &str, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        self.compile(name)?;
        let sig = &self.sigs[name];
        if args.len() != sig.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, (shape, dtype)) in args.iter().zip(&sig.inputs) {
            let lit = match arg {
                ArgValue::I32(v) => {
                    if dtype != "int32" {
                        bail!("{name}: scalar i32 arg for {dtype} input");
                    }
                    xla::Literal::scalar(*v)
                }
                ArgValue::F32(data, dims) => {
                    let expect: usize = shape.iter().product();
                    if data.len() != expect {
                        bail!(
                            "{name}: input size {} != manifest {expect} (shape {shape:?})",
                            data.len()
                        );
                    }
                    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims_i)?
                }
            };
            literals.push(lit);
        }
        let exes = self.exes.borrow();
        let exe = &exes[name];
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let mut flat = Vec::with_capacity(outs.len());
        for (o, (_, dtype)) in outs.into_iter().zip(&sig.outputs) {
            let v: Vec<f32> = match dtype.as_str() {
                "float32" => o.to_vec::<f32>()?,
                "int32" => o.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect(),
                other => bail!("unsupported output dtype {other}"),
            };
            flat.push(v);
        }
        Ok(flat)
    }

    /// Convenience: prefill at bucket length `s` (must exist as
    /// `prefill_{s}`); returns (logits, k_cache, v_cache) flattened.
    pub fn prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let name = format!("prefill_{}", tokens.len());
        if !self.sigs.contains_key(&name) {
            bail!(
                "no prefill bucket for length {} (available: {:?})",
                tokens.len(),
                self.entries()
                    .into_iter()
                    .filter(|e| e.starts_with("prefill"))
                    .collect::<Vec<_>>()
            );
        }
        let toks_f: Vec<f32> = Vec::new(); // placeholder to satisfy lifetimes
        let _ = toks_f;
        // tokens are an i32 vector: build literal directly.
        self.compile(&name)?;
        let lit = {
            let dims = [tokens.len() as i64];
            xla::Literal::vec1(tokens).reshape(&dims)?
        };
        let exes = self.exes.borrow();
        let exe = &exes[&name];
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let mut it = outs.into_iter();
        let logits = it.next().context("logits")?.to_vec::<f32>()?;
        let k = it.next().context("k cache")?.to_vec::<f32>()?;
        let v = it.next().context("v cache")?.to_vec::<f32>()?;
        Ok((logits, k, v))
    }

    /// Convenience: one decode step. Caches are padded to
    /// `[L, H, max_seq, Dh]` flattened; returns (logits, k, v).
    pub fn decode(
        &self,
        token: i32,
        k_cache: &[f32],
        v_cache: &[f32],
        pos: i32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = &self.model;
        let dims = [m.n_layers, m.n_heads, m.max_seq, m.d_model / m.n_heads];
        let outs = self.execute(
            "decode",
            &[
                ArgValue::I32(token),
                ArgValue::F32(k_cache, &dims),
                ArgValue::F32(v_cache, &dims),
                ArgValue::I32(pos),
            ],
        )?;
        let mut it = outs.into_iter();
        Ok((
            it.next().context("logits")?,
            it.next().context("k")?,
            it.next().context("v")?,
        ))
    }

    /// Argmax helper for greedy decoding.
    pub fn argmax(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}
