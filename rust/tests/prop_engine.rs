//! Property tests on the coordinator's pure logic: sharding coverage,
//! IMMCOUNTER order-independence, wire-format fuzz — plus end-to-end
//! properties of the sharding invariants (coverage, imm-count
//! preservation, NIC balance) exercised through the shared
//! `TransferEngine` trait on BOTH runtimes.
//!
//! Uses the in-repo seeded property harness (`util::prop`); replay a
//! failure with FABRIC_PROP_SEED=<seed> FABRIC_PROP_CASES=1.

use fabric_lib::engine::api::{MrDesc, NetAddr, Pages, SPLIT_THRESHOLD};
use fabric_lib::engine::imm_counter::{ImmCounter, ImmEvent};
use fabric_lib::engine::sharding::{plan_paged_writes, plan_single_write, PlannedWrite};
use fabric_lib::engine::traits::{expect_flag, new_flag, Cluster, Notify, RuntimeKind};
use fabric_lib::engine::wire;
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::sim::Rng;
use fabric_lib::util::prop::check;

fn tiles_exactly(plans: &[PlannedWrite], src_off: u64, len: u64) -> Result<(), String> {
    let mut ranges: Vec<(u64, u64)> = plans.iter().map(|p| (p.src_off, p.len)).collect();
    ranges.sort_unstable();
    let mut cursor = src_off;
    for (off, l) in &ranges {
        if *off != cursor {
            return Err(format!("gap/overlap at {off}, expected {cursor}"));
        }
        cursor += l;
    }
    if cursor != src_off + len {
        return Err(format!("covered {} of {len}", cursor - src_off));
    }
    Ok(())
}

#[test]
fn prop_single_write_tiles_and_balances() {
    check(
        "single-write coverage",
        |rng: &mut Rng| {
            let len = 1 + rng.below(64 << 20);
            let src_off = rng.below(1 << 20);
            let dst_va = 0x1000 + rng.below(1 << 30);
            let fanout = 1 + rng.below(4) as usize;
            let imm = if rng.f64() < 0.3 { Some(rng.next_u64() as u32) } else { None };
            let rotation = rng.below(100) as usize;
            (len, src_off, dst_va, imm, fanout, rotation)
        },
        |&(len, src_off, dst_va, imm, fanout, rotation)| {
            let plans = plan_single_write(len, src_off, dst_va, imm, fanout, rotation);
            tiles_exactly(&plans, src_off, len)?;
            // dst offsets mirror src offsets.
            for p in &plans {
                if p.dst_va - dst_va != p.src_off - src_off {
                    return Err("dst/src offset mismatch".into());
                }
                if p.nic >= fanout {
                    return Err(format!("nic {} out of fanout {fanout}", p.nic));
                }
            }
            // Imm preservation: exactly one imm-carrying write iff imm.
            let n_imm = plans.iter().filter(|p| p.imm.is_some()).count();
            if imm.is_some() && n_imm != 1 {
                return Err(format!("imm write count {n_imm} != 1"));
            }
            if imm.is_none() && n_imm != 0 {
                return Err("phantom imm".into());
            }
            // Balance: shards within 1 byte when split.
            if imm.is_none() && len > SPLIT_THRESHOLD && fanout > 1 {
                let max = plans.iter().map(|p| p.len).max().unwrap();
                let min = plans.iter().map(|p| p.len).min().unwrap();
                if max - min > 1 {
                    return Err(format!("imbalance {max}-{min}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_paged_writes_preserve_count_and_mapping() {
    check(
        "paged-write mapping",
        |rng: &mut Rng| {
            let pages = 1 + rng.below(200) as usize;
            let page_len = 1 + rng.below(64 << 10);
            let fanout = 1 + rng.below(4) as usize;
            let srcs: Vec<u64> = (0..pages).map(|_| rng.below(1 << 30)).collect();
            let dsts: Vec<u64> = (0..pages).map(|_| rng.below(1 << 30)).collect();
            let imm = if rng.f64() < 0.5 { Some(7u32) } else { None };
            (page_len, srcs, dsts, imm, fanout)
        },
        |(page_len, srcs, dsts, imm, fanout)| {
            let plans = plan_paged_writes(*page_len, srcs, dsts, *imm, *fanout, 3);
            if plans.len() != srcs.len() {
                return Err(format!("{} plans for {} pages", plans.len(), srcs.len()));
            }
            for (i, p) in plans.iter().enumerate() {
                if p.src_off != srcs[i] || p.dst_va != dsts[i] {
                    return Err(format!("page {i} mis-mapped"));
                }
                if p.imm != *imm {
                    return Err("imm not preserved per page".into());
                }
            }
            // NIC assignment is round-robin: consecutive pages differ
            // when fanout > 1.
            if *fanout > 1 && plans.len() > 1 && plans[0].nic == plans[1].nic {
                return Err("not round-robin".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_imm_counter_order_independent() {
    check(
        "imm-counter order independence",
        |rng: &mut Rng| {
            // Several imms with target counts; a shuffled arrival
            // order; a random point at which expectations register.
            let n_imms = 1 + rng.below(5) as u32;
            let targets: Vec<u32> = (0..n_imms).map(|_| 1 + rng.below(20) as u32).collect();
            let mut arrivals: Vec<u32> = targets
                .iter()
                .enumerate()
                .flat_map(|(i, &c)| std::iter::repeat(i as u32).take(c as usize))
                .collect();
            rng.shuffle(&mut arrivals);
            let register_at = rng.below(arrivals.len() as u64 + 1) as usize;
            (targets, arrivals, register_at)
        },
        |(targets, arrivals, register_at)| {
            let mut c = ImmCounter::new();
            let mut satisfied = vec![false; targets.len()];
            for (step, &imm) in arrivals.iter().enumerate() {
                if step == *register_at {
                    for (i, &t) in targets.iter().enumerate() {
                        if !satisfied[i] && c.expect(i as u32, t) == ImmEvent::Satisfied {
                            satisfied[i] = true;
                        }
                    }
                }
                if c.increment(imm) == ImmEvent::Satisfied {
                    satisfied[imm as usize] = true;
                }
            }
            if *register_at >= arrivals.len() {
                for (i, &t) in targets.iter().enumerate() {
                    if !satisfied[i] && c.expect(i as u32, t) == ImmEvent::Satisfied {
                        satisfied[i] = true;
                    }
                }
            }
            if satisfied.iter().all(|&s| s) {
                Ok(())
            } else {
                Err(format!("unsatisfied expectations: {satisfied:?}"))
            }
        },
    );
}

/// Coverage + imm-count preservation THROUGH the engines: a random
/// paged transfer submitted via the shared trait must land every page
/// byte-exactly and deliver exactly one immediate per page (the
/// `SPLIT_THRESHOLD` contract: imm writes are never split), on both
/// runtimes.
#[test]
fn prop_paged_transfer_through_both_runtimes() {
    for kind in [RuntimeKind::Des, RuntimeKind::Threaded] {
        check(
            &format!("paged transfer integrity ({kind:?})"),
            |rng: &mut Rng| {
                let pages = 1 + rng.below(12) as u32;
                let page_len = 64 * (1 + rng.below(8));
                // Random destination slot permutation.
                let mut slots: Vec<u32> = (0..pages).collect();
                rng.shuffle(&mut slots);
                let seed = rng.next_u64();
                (pages, page_len, slots, seed)
            },
            |(pages, page_len, slots, seed)| {
                let mut cluster = Cluster::new(kind, 2, 1, 2, *seed);
                let result = {
                    let (mut cx, engines) = cluster.parts();
                    let (a, b) = (engines[0], engines[1]);
                    let bytes = (*pages as u64 * page_len) as usize;
                    let (src, _) = a.alloc_mr(0, bytes);
                    let (dst_h, dst_d) = b.alloc_mr(0, bytes);
                    for p in 0..*pages {
                        let fill = (p % 251) as u8 + 1;
                        src.buf.write(
                            (p as u64 * page_len) as usize,
                            &vec![fill; *page_len as usize],
                        );
                    }
                    let done = new_flag();
                    let counted = expect_flag(b, &mut cx, 0, 9, *pages);
                    a.submit_paged_writes(
                        &mut cx,
                        *page_len,
                        (&src, &Pages::contiguous(0, *pages, *page_len)),
                        (&dst_d, &Pages { indices: slots.clone(), stride: *page_len, offset: 0 }),
                        Some(9),
                        Notify::Flag(done.clone()),
                    )
                    .unwrap();
                    cx.wait(&done);
                    cx.wait(&counted);
                    let v = dst_h.buf.to_vec();
                    let mut result = Ok(());
                    for (i, &slot) in slots.iter().enumerate() {
                        let off = (slot as u64 * page_len) as usize;
                        let fill = (i as u32 % 251) as u8 + 1;
                        if !v[off..off + *page_len as usize].iter().all(|&b| b == fill) {
                            result = Err(format!("page {i} corrupted in slot {slot}"));
                            break;
                        }
                    }
                    cx.settle();
                    result
                };
                cluster.shutdown();
                result
            },
        );
    }
}

/// Balance through the engine: a large imm-less write sharded by the
/// DES engine must put traffic on every NIC of the group within one
/// byte of even (the sharding header's balance promise, observed at
/// the fabric, not just in the plan).
#[test]
fn prop_sharded_write_balances_nic_bytes() {
    check(
        "sharded write NIC balance (Des)",
        |rng: &mut Rng| {
            let len = SPLIT_THRESHOLD + 1 + rng.below(4 << 20);
            (len, rng.next_u64())
        },
        |&(len, seed)| {
            let mut cluster = Cluster::new(RuntimeKind::Des, 2, 1, 2, seed);
            let net = cluster.des_net().expect("DES cluster");
            {
                let (mut cx, engines) = cluster.parts();
                let (src, _) = engines[0].alloc_mr(0, len as usize);
                let (_dh, dd) = engines[1].alloc_mr(0, len as usize);
                let done = new_flag();
                engines[0]
                    .submit_single_write(
                        &mut cx,
                        (&src, 0),
                        len,
                        (&dd, 0),
                        None,
                        Notify::Flag(done.clone()),
                    )
                    .unwrap();
                cx.wait(&done);
            }
            let (tx0, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 0 });
            let (tx1, _) = net.nic_bytes(NicAddr { node: 0, gpu: 0, nic: 1 });
            cluster.shutdown();
            if tx0 + tx1 != len {
                return Err(format!("coverage: {tx0}+{tx1} != {len}"));
            }
            if tx0.abs_diff(tx1) > 1 {
                return Err(format!("imbalance beyond one byte: {tx0} vs {tx1}"));
            }
            Ok(())
        },
    );
}

/// §3.5 parity THROUGH the engines, on both runtimes: a random
/// scatter submitted untemplated (per-call descriptor clones) and
/// templated (bound group, four integers per destination) must land
/// byte-identical payloads and the same per-peer immediate counts.
#[test]
fn prop_templated_scatter_matches_untemplated() {
    use fabric_lib::engine::api::{ScatterDst, TemplatedDst};
    use fabric_lib::engine::traits::Cx;

    for kind in [RuntimeKind::Des, RuntimeKind::Threaded] {
        check(
            &format!("templated/untemplated scatter parity ({kind:?})"),
            |rng: &mut Rng| {
                let peers = 2 + rng.below(3) as usize;
                let entries = 1 + rng.below(8) as usize;
                // Entry i writes inside its own disjoint 256 B slot:
                // the threaded fabric delivers unordered, so
                // overlapping ranges would make the byte comparison
                // order-sensitive rather than path-sensitive.
                let specs: Vec<(usize, u64, u64, u64)> = (0..entries)
                    .map(|i| {
                        let peer = rng.below(peers as u64) as usize;
                        let off = rng.below(64);
                        let len = 1 + rng.below(192);
                        let src = rng.below(1024 - 256);
                        (peer, len, src, i as u64 * 256 + off)
                    })
                    .collect();
                (peers, specs, rng.next_u64())
            },
            |(peers, specs, seed)| {
                let mut cluster = Cluster::new(kind, 1 + *peers as u16, 1, 2, *seed);
                let result = {
                    let (mut cx, engines) = cluster.parts();
                    let sender = engines[0];
                    let (src, _) = sender.alloc_mr(0, 1024);
                    let fill: Vec<u8> = (0..1024u32).map(|i| (i % 253) as u8 + 1).collect();
                    src.buf.write(0, &fill);
                    let addrs: Vec<_> =
                        engines[1..].iter().map(|e| e.main_address()).collect();

                    // Two destination-region sets, one per path.
                    let run = |cx: &mut Cx, templated: bool, imm: u32| {
                        let regions: Vec<_> = engines[1..]
                            .iter()
                            .map(|e| e.alloc_mr(0, 4096))
                            .collect();
                        let mut flags = Vec::new();
                        for (i, e) in engines[1..].iter().enumerate() {
                            let n = specs.iter().filter(|s| s.0 == i).count() as u32;
                            if n > 0 {
                                flags.push(expect_flag(*e, cx, 0, imm, n));
                            }
                        }
                        let group = sender.add_peer_group(addrs.clone());
                        if templated {
                            let descs: Vec<_> =
                                regions.iter().map(|(_, d)| d.clone()).collect();
                            sender.bind_peer_group_mrs(0, group, &descs).unwrap();
                            let dsts: Vec<TemplatedDst> = specs
                                .iter()
                                .map(|&(peer, len, src, dst)| TemplatedDst {
                                    peer,
                                    len,
                                    src,
                                    dst,
                                })
                                .collect();
                            sender
                                .submit_scatter_templated(
                                    cx,
                                    &src,
                                    group,
                                    &dsts,
                                    Some(imm),
                                    Notify::Noop,
                                )
                                .unwrap();
                        } else {
                            let dsts: Vec<ScatterDst> = specs
                                .iter()
                                .map(|&(peer, len, src, dst)| ScatterDst {
                                    len,
                                    src,
                                    dst: (regions[peer].1.clone(), dst),
                                })
                                .collect();
                            // group-less (ad-hoc) submission: entries
                            // may repeat a peer, and the debug-mode
                            // group check requires dsts <= peer count.
                            sender
                                .submit_scatter(cx, None, &src, &dsts, Some(imm), Notify::Noop)
                                .unwrap();
                        }
                        for f in &flags {
                            cx.wait(f);
                        }
                        assert!(sender.remove_peer_group(group));
                        regions
                            .iter()
                            .map(|(h, _)| h.buf.to_vec())
                            .collect::<Vec<_>>()
                    };
                    let plain = run(&mut cx, false, 0x71);
                    let tpl = run(&mut cx, true, 0x72);
                    cx.settle();
                    if plain == tpl {
                        Ok(())
                    } else {
                        Err("templated scatter landed different bytes".to_string())
                    }
                };
                cluster.shutdown();
                result
            },
        );
    }
}

#[test]
fn prop_wire_roundtrip_and_fuzz() {
    check(
        "wire roundtrip",
        |rng: &mut Rng| {
            let nics: Vec<NicAddr> = (0..1 + rng.below(4))
                .map(|i| NicAddr {
                    node: rng.below(1 << 16) as u16,
                    gpu: rng.below(8) as u8,
                    nic: i as u8,
                })
                .collect();
            let desc = MrDesc {
                ptr: rng.next_u64(),
                len: rng.next_u64(),
                rkeys: nics.iter().map(|&n| (n, rng.next_u64())).collect(),
            };
            let flip = rng.below(64);
            (NetAddr { nics }, desc, flip)
        },
        |(addr, desc, flip)| {
            let b = wire::encode_net_addr(addr);
            if wire::decode_net_addr(&b).map_err(|e| e.to_string())? != *addr {
                return Err("NetAddr roundtrip".into());
            }
            let b2 = wire::encode_mr_desc(desc);
            if wire::decode_mr_desc(&b2).map_err(|e| e.to_string())? != *desc {
                return Err("MrDesc roundtrip".into());
            }
            // Fuzz: flipping a header byte must never panic (errors ok).
            let mut mutated = b2.clone();
            let idx = (*flip as usize) % mutated.len().min(3);
            mutated[idx] ^= 0xFF;
            let _ = wire::decode_mr_desc(&mutated);
            // Truncations must error, not panic.
            for cut in 0..b2.len().min(16) {
                if wire::decode_mr_desc(&b2[..cut]).is_ok() {
                    return Err(format!("truncation at {cut} decoded"));
                }
            }
            Ok(())
        },
    );
}
