//! RL rollout weight transfer (paper §5).
//!
//! After each training step, new weights must reach the inference
//! cluster. Existing frameworks funnel everything through training
//! Rank0 and broadcast — bottlenecked by one NIC. fabric-lib's P2P
//! approach has every training GPU WRITE directly into inference GPU
//! memory using the full cluster bandwidth, with a 4-stage pipeline
//! (H2D memcpy → parameter preparation → RDMA → barrier) and a GPU
//! memory watermark (§5.2).

pub mod baseline;
pub mod pipeline;
pub mod spec;

pub use baseline::{run_generic_rank0_fanout, run_rank0_broadcast};
pub use pipeline::{
    run_generic_weight_sync, run_p2p_transfer, run_p2p_transfer_on, RlReport, StageTotals,
};
pub use spec::{compute_routing, ParamMeta, RlModelSpec, TransferTask};
