//! Integration: the full disaggregated KvCache flow (§4) on backed
//! buffers — data integrity, cancellation confirmation, heartbeat
//! failure handling, page-pool hygiene — with the scenario state
//! machines built through the runtime-neutral compute model (the same
//! code path the both-runtime parity tests drive).

use fabric_lib::apps::kvcache::{Decoder, Prefiller, ServingWorkload};
use fabric_lib::engine::api::NetAddr;
use fabric_lib::engine::model::ComputeModel;
use fabric_lib::engine::traits::{Cluster, Cx, RuntimeKind};
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};
use fabric_lib::sim::time::MS;

fn setup() -> (Cluster, Prefiller, Decoder, NetAddr) {
    let mut cluster = Cluster::new_with(
        RuntimeKind::Des,
        2,
        1,
        2,
        3,
        NicProfile::efa(),
        GpuProfile::h200(),
    );
    let engines = cluster.engines_rc();
    let w = ServingWorkload::tiny();
    let (p, d, prefiller_addr) = {
        let (mut cx, _) = cluster.parts();
        let compute = ComputeModel::new(GpuProfile::h200());
        let p = Prefiller::new(&mut cx, engines[0].clone(), 0, &compute, w.clone(), 0);
        let d = Decoder::new(&mut cx, engines[1].clone(), 0, w);
        (p, d, engines[0].group_address(0))
    };
    (cluster, p, d, prefiller_addr)
}

#[test]
fn end_to_end_request_completes_and_frees_pages() {
    let (mut cluster, _p, d, prefiller) = setup();
    let free0 = d.free_slot_count();
    let id = {
        let (mut cx, _) = cluster.parts();
        let input: Vec<u32> = (0..100).collect();
        let id = d.submit_request(&mut cx, &prefiller, input, 3);
        cx.settle();
        id
    };
    let reports = d.reports();
    let reports = reports.borrow();
    assert_eq!(reports.len(), 1);
    let r = reports[0];
    assert_eq!(r.req_id, id);
    assert!(r.transfer_done > r.submitted);
    assert!(r.ttft > r.transfer_done);
    assert!(r.finished > r.ttft);
    assert_eq!(d.free_slot_count(), free0, "pages returned to the pool");
}

#[test]
fn kv_payload_lands_at_allocated_slots() {
    let (mut cluster, p, d, prefiller) = setup();
    // Pattern the prefiller's KV source.
    let src = p.kv_src_handle();
    let pat: Vec<u8> = (0..src.buf.len()).map(|i| (i % 251) as u8).collect();
    src.buf.write(0, &pat);
    {
        let (mut cx, _) = cluster.parts();
        let input: Vec<u32> = (0..48).collect(); // 3 pages of 16 tokens
        d.submit_request(&mut cx, &prefiller, input, 1);
        cx.settle();
    }
    // Decoder KV region must contain nonzero data in exactly the
    // regions of 3 pages × 3 layers (tiny layout: 4096B pages).
    let kv = d.kv_handle();
    let v = kv.buf.to_vec();
    let nonzero_pages = v
        .chunks(4096)
        .filter(|c| c.iter().any(|&b| b != 0))
        .count();
    assert_eq!(nonzero_pages, 9, "3 pages × 3 layers transferred");
}

#[test]
fn cancellation_quarantines_pages_until_ack() {
    let (mut cluster, _p, d, prefiller) = setup();
    let free0 = d.free_slot_count();
    let id = {
        let (mut cx, _) = cluster.parts();
        let input: Vec<u32> = (0..64).collect();
        let id = d.submit_request(&mut cx, &prefiller, input, 5);
        // Cancel very early, while transfers are in flight.
        let d2 = d.clone();
        cx.after(10_000, move |cx: &mut Cx| d2.cancel(cx, id));
        cx.settle();
        id
    };
    use fabric_lib::apps::kvcache::decoder::ReqState;
    assert_eq!(d.req_state(id), Some(ReqState::Cancelled), "ack received");
    assert_eq!(d.free_slot_count(), free0, "pages freed only after ack");
}

#[test]
fn dead_prefiller_detected_by_heartbeat_timeout() {
    let (mut cluster, p, d, prefiller) = setup();
    let free0 = d.free_slot_count();
    let id = {
        let (mut cx, _) = cluster.parts();
        p.start_heartbeats(&mut cx, vec![d.address()], 2 * MS);
        d.start_monitor(&mut cx, 2 * MS);
        // Kill the prefiller immediately: the dispatch is never served.
        p.kill();
        let input: Vec<u32> = (0..64).collect();
        let id = d.submit_request(&mut cx, &prefiller, input, 1);
        // Run long enough for the 30 ms heartbeat timeout to fire (the
        // monitor re-arms forever, so bound the virtual clock).
        cx.sim().run_until(200 * MS);
        id
    };
    use fabric_lib::apps::kvcache::decoder::ReqState;
    assert_eq!(
        d.req_state(id),
        Some(ReqState::Cancelled),
        "request force-cancelled after heartbeat timeout"
    );
    assert_eq!(d.free_slot_count(), free0, "pages reclaimed");
}

#[test]
fn many_concurrent_requests() {
    let (mut cluster, _p, d, prefiller) = setup();
    {
        let (mut cx, _) = cluster.parts();
        for i in 0..6 {
            let input: Vec<u32> = (0..32 + i * 16).collect();
            d.submit_request(&mut cx, &prefiller, input, 2);
        }
        cx.settle();
    }
    assert_eq!(d.reports().borrow().len(), 6, "all requests served");
}
