//! Paper Table 7: end-to-end decode speed with and without dual-batch
//! overlap (DeepSeek-V3, EP=DP=64, EFA).
//!
//! Dual-batch overlap splits the batch into two microbatches and
//! pipelines one's computation against the other's communication: the
//! per-layer time becomes ~2 × max(compute, comm) at half batch
//! instead of compute + comm at full batch. Worth it only when comm
//! is slow relative to compute — which is why it *helps* our
//! low-latency kernels only at large batches and *hurts* pplx.
//!
//! Usage: cargo bench --bench moe_overlap [-- --fast]

use fabric_lib::apps::moe::{run_decode_epoch, MoeConfig, MoeImpl};
use fabric_lib::fabric::profile::NicProfile;
use fabric_lib::util::table::{f, Table};

const LAYERS: u64 = 61;
const MOE_LAYERS: u64 = 58;
const MTP_TOKENS_PER_STEP: f64 = 1.8;

fn compute_ns(batch: u32) -> u64 {
    260_000 + batch as u64 * 1_500
}

fn step_tokens_per_s(comm_us: f64, batch: u32, dual: bool) -> f64 {
    let step_ns = if dual {
        // Two half-batches; comm of one overlaps compute of the other.
        let c = compute_ns(batch / 2) as f64;
        let m = comm_us * 1000.0;
        LAYERS as f64 * 2.0 * c.max(m * MOE_LAYERS as f64 / LAYERS as f64)
    } else {
        LAYERS as f64 * compute_ns(batch) as f64 + MOE_LAYERS as f64 * comm_us * 1000.0
    };
    MTP_TOKENS_PER_STEP / (step_ns / 1e9)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let iters = if fast { 2 } else { 4 };
    let ranks = if fast { 16 } else { 64 };
    let batches: &[u32] = &[128, 96, 64, 48, 32];

    let mut t = Table::new(
        &format!("Table 7. Decode speed with/without dual-batch overlap (EP={ranks}, EFA)"),
        &["batch", "ours no-ovl", "ours dual", "pplx no-ovl", "pplx dual"],
    );
    for &b in batches {
        let mut row = vec![b.to_string()];
        for imp in [MoeImpl::Ours, MoeImpl::Pplx] {
            // Full batch for no-overlap.
            let cfg = MoeConfig::decode(ranks, b);
            let mut lat = run_decode_epoch(&cfg, imp, NicProfile::efa(), 2, iters);
            let comm_full =
                (lat.dispatch.percentile(50.0) + lat.combine.percentile(50.0)) as f64 / 1000.0;
            // Half batch for dual (per-microbatch comm).
            let cfg_h = MoeConfig::decode(ranks, (b / 2).max(1));
            let mut lat_h = run_decode_epoch(&cfg_h, imp, NicProfile::efa(), 2, iters);
            let comm_half =
                (lat_h.dispatch.percentile(50.0) + lat_h.combine.percentile(50.0)) as f64 / 1000.0;
            row.push(f(step_tokens_per_s(comm_full, b, false), 1));
            row.push(f(step_tokens_per_s(comm_half, b, true), 1));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "\npaper — ours: 11.8→13.9 at 128 (overlap helps), 32.0→30.2 at 32 \
         (hurts); pplx: overlap consistently degrades. Claim preserved: \
         low communication latency matters even in throughput regimes.\n"
    );
}
