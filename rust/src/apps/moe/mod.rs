//! MoE dispatch/combine kernels around the TransferEngine (paper §6).
//!
//! Split send/receive kernels coordinate with a host proxy thread via
//! UVM watchers and GDRCopy-polled IMMCOUNTERs. Dispatch first
//! exchanges routing information (per-expert token counts) so every
//! sender can compute its unique range in each receiver's contiguous
//! buffer; the latency of that exchange is hidden by speculatively
//! scattering the first tokens into private per-source buffers.
//! Combine reuses the routing and issues a single scatter. Intra-node
//! payloads ride NVLink. Up to 2 WRITEs per inter-node peer for
//! dispatch and 1 for combine.
//!
//! Baselines: [`deepep`] (GPU-initiated, RC-ordered, per-token — the
//! ConnectX-only comparator) and [`pplx`] (NVSHMEM-style generic
//! host proxy with per-token synchronization).

pub mod config;
pub mod deepep;
pub mod harness;
pub mod pplx;
pub mod rank;
pub mod routing;

pub use config::MoeConfig;
pub use harness::{
    run_decode_epoch, run_epoch_on, run_epoch_with_chaos, run_generic_dispatch_round, MoeImpl,
    MoeLatencies,
};
pub use routing::RoutingPlan;
