//! Integration: PJRT runtime executes the AOT artifacts and the
//! numerics match the JAX reference (prefill → decode consistency).
//!
//! Requires `make artifacts` to have run (skips otherwise, so plain
//! `cargo test` works in a fresh checkout).

use fabric_lib::runtime::{ArgValue, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("load runtime"))
}

#[test]
fn manifest_describes_model() {
    let Some(rt) = runtime() else { return };
    assert!(rt.model.vocab >= 256);
    assert_eq!(rt.model.d_model % rt.model.n_heads, 0);
    assert!(rt.entries().iter().any(|e| e == "decode"));
    assert!(rt.entries().iter().any(|e| e.starts_with("prefill_")));
    assert_eq!(rt.output_count("decode").unwrap(), 3);
}

#[test]
fn prefill_then_decode_is_deterministic_and_finite() {
    let Some(rt) = runtime() else { return };
    let m = rt.model.clone();
    let toks: Vec<i32> = (0..32).map(|i| (i * 7 + 3) % m.vocab as i32).collect();
    let (logits, k, v) = rt.prefill(&toks).expect("prefill");
    assert_eq!(logits.len(), m.vocab);
    let t1 = Runtime::argmax(&logits);

    // Pad caches [L,H,32,Dh] -> [L,H,max_seq,Dh].
    let dh = m.d_model / m.n_heads;
    let (l, h, s, smax) = (m.n_layers, m.n_heads, 32usize, m.max_seq);
    let pad = |c: &[f32]| -> Vec<f32> {
        let mut out = vec![0f32; l * h * smax * dh];
        for li in 0..l {
            for hi in 0..h {
                for si in 0..s {
                    let src = ((li * h + hi) * s + si) * dh;
                    let dst = ((li * h + hi) * smax + si) * dh;
                    out[dst..dst + dh].copy_from_slice(&c[src..src + dh]);
                }
            }
        }
        out
    };
    let kp = pad(&k);
    let vp = pad(&v);
    let (dec_logits, _, _) = rt.decode(t1, &kp, &vp, s as i32).expect("decode");
    assert!(dec_logits.iter().all(|x| x.is_finite()));
    let (dec2, _, _) = rt.decode(t1, &kp, &vp, s as i32).expect("decode again");
    assert_eq!(dec_logits, dec2, "decode must be deterministic");
}

#[test]
fn moe_block_runs() {
    let Some(rt) = runtime() else { return };
    let shape = rt.output_shape("moe_block", 0).unwrap();
    let n: usize = shape.iter().product();
    let x = vec![0.01f32; n];
    let out = rt
        .execute("moe_block", &[ArgValue::F32(&x, &shape)])
        .expect("moe_block");
    assert_eq!(out[0].len(), n);
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn quantize_roundtrip_accuracy() {
    let Some(rt) = runtime() else { return };
    let shape = rt.output_shape("quantize_roundtrip", 0).unwrap();
    let n: usize = shape.iter().product();
    let x: Vec<f32> = (0..n).map(|i| ((i as f32 * 0.37).sin()) * 3.0).collect();
    let out = rt
        .execute("quantize_roundtrip", &[ArgValue::F32(&x, &shape)])
        .expect("quantize");
    let deq = &out[0];
    // fp8-e4m3 relative error is bounded (~6% worst case for normals).
    for (a, b) in x.iter().zip(deq) {
        assert!(
            (a - b).abs() <= 0.08 * a.abs().max(0.05),
            "fp8 roundtrip too lossy: {a} vs {b}"
        );
    }
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(rt) = runtime() else { return };
    assert!(rt.execute("decode", &[ArgValue::I32(0)]).is_err());
    assert!(rt.execute("nonexistent", &[]).is_err());
}
