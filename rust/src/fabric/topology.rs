//! Cluster topology: nodes × GPUs × NICs, NUMA placement, and the
//! builders that instantiate a simulated cluster from a spec.
//!
//! Mirrors the paper's two evaluation clusters:
//! * `ClusterSpec::h200_efa()` — 8×H200 nodes, 2×200 Gbps EFA per GPU;
//! * `ClusterSpec::h100_cx7()` — 8×H100 nodes, 1×400 Gbps CX-7 per GPU.

use super::gpu::{GpuSim, NvlinkFabric};
use super::nic::NicAddr;
use super::profile::{GpuProfile, NicProfile};
use super::simnet::SimNet;

/// One GPU's identity within the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId {
    pub node: u16,
    pub gpu: u8,
}

impl DeviceId {
    /// Global linear rank given `gpus_per_node`.
    pub fn rank(&self, gpus_per_node: u8) -> usize {
        self.node as usize * gpus_per_node as usize + self.gpu as usize
    }

    /// Inverse of [`DeviceId::rank`].
    pub fn from_rank(rank: usize, gpus_per_node: u8) -> Self {
        DeviceId {
            node: (rank / gpus_per_node as usize) as u16,
            gpu: (rank % gpus_per_node as usize) as u8,
        }
    }
}

impl std::fmt::Display for DeviceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}g{}", self.node, self.gpu)
    }
}

/// NIC identity = device + NIC index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NicId {
    pub device: DeviceId,
    pub nic: u8,
}

impl NicId {
    /// The fabric-level address of this NIC.
    pub fn addr(&self) -> NicAddr {
        NicAddr {
            node: self.device.node,
            gpu: self.device.gpu,
            nic: self.nic,
        }
    }
}

/// Declarative description of a cluster.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub nodes: u16,
    pub gpus_per_node: u8,
    pub nics_per_gpu: u8,
    pub nic_profile: NicProfile,
    pub gpu_profile: GpuProfile,
    /// RNG seed for the fabric's jitter streams.
    pub seed: u64,
}

impl ClusterSpec {
    /// The paper's EFA cluster: H200, 2×200 Gbps EFA per GPU.
    pub fn h200_efa(nodes: u16) -> Self {
        ClusterSpec {
            name: "H200-EFA",
            nodes,
            gpus_per_node: 8,
            nics_per_gpu: 2,
            nic_profile: NicProfile::efa(),
            gpu_profile: GpuProfile::h200(),
            seed: 0xEFA,
        }
    }

    /// The paper's ConnectX cluster: H100, 1×400 Gbps CX-7 per GPU.
    pub fn h100_cx7(nodes: u16) -> Self {
        ClusterSpec {
            name: "H100-CX7",
            nodes,
            gpus_per_node: 8,
            nics_per_gpu: 1,
            nic_profile: NicProfile::connectx7(),
            gpu_profile: GpuProfile::h100(),
            seed: 0xC87,
        }
    }

    /// Total GPU count.
    pub fn total_gpus(&self) -> usize {
        self.nodes as usize * self.gpus_per_node as usize
    }

    /// Aggregate per-GPU network bandwidth in Gbps.
    pub fn gpu_net_gbps(&self) -> f64 {
        self.nics_per_gpu as f64 * self.nic_profile.rate_gbps
    }

    /// All device ids, rank order.
    pub fn devices(&self) -> Vec<DeviceId> {
        (0..self.total_gpus())
            .map(|r| DeviceId::from_rank(r, self.gpus_per_node))
            .collect()
    }

    /// NIC ids attached to `dev`.
    pub fn nics_of(&self, dev: DeviceId) -> Vec<NicId> {
        (0..self.nics_per_gpu)
            .map(|n| NicId { device: dev, nic: n })
            .collect()
    }

    /// Instantiate the simulated cluster.
    pub fn build(&self) -> Cluster {
        let net = SimNet::new(self.seed);
        let mut gpus = Vec::new();
        let mut nvlinks = Vec::new();
        for node in 0..self.nodes {
            nvlinks.push(NvlinkFabric::new());
            for gpu in 0..self.gpus_per_node {
                let dev = DeviceId { node, gpu };
                gpus.push(GpuSim::new(dev, self.gpu_profile.clone()));
                for nic in 0..self.nics_per_gpu {
                    net.add_nic(
                        NicAddr { node, gpu, nic },
                        self.nic_profile.clone(),
                    );
                }
            }
        }
        Cluster {
            spec: self.clone(),
            net,
            gpus,
            nvlinks,
        }
    }
}

/// An instantiated simulated cluster.
pub struct Cluster {
    pub spec: ClusterSpec,
    pub net: SimNet,
    gpus: Vec<GpuSim>,
    nvlinks: Vec<NvlinkFabric>,
}

impl Cluster {
    /// GPU simulator for a device.
    pub fn gpu(&self, dev: DeviceId) -> &GpuSim {
        &self.gpus[dev.rank(self.spec.gpus_per_node)]
    }

    /// NVLink fabric of `node`.
    pub fn nvlink(&self, node: u16) -> &NvlinkFabric {
        &self.nvlinks[node as usize]
    }

    /// Rank-ordered devices.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.spec.devices()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_roundtrip() {
        for rank in 0..64 {
            let d = DeviceId::from_rank(rank, 8);
            assert_eq!(d.rank(8), rank);
        }
        assert_eq!(
            DeviceId::from_rank(13, 8),
            DeviceId { node: 1, gpu: 5 }
        );
    }

    #[test]
    fn efa_cluster_shape() {
        let spec = ClusterSpec::h200_efa(8);
        assert_eq!(spec.total_gpus(), 64);
        assert_eq!(spec.nics_per_gpu, 2);
        assert!((spec.gpu_net_gbps() - 400.0).abs() < 1e-9);
        let cluster = spec.build();
        assert_eq!(cluster.devices().len(), 64);
        // every NIC exists in the fabric
        for dev in cluster.devices() {
            for nic in spec.nics_of(dev) {
                let _ = cluster.net.profile(nic.addr());
            }
        }
    }

    #[test]
    fn cx7_cluster_shape() {
        let spec = ClusterSpec::h100_cx7(2);
        assert_eq!(spec.total_gpus(), 16);
        assert_eq!(spec.nics_per_gpu, 1);
        assert!((spec.gpu_net_gbps() - 400.0).abs() < 1e-9);
    }
}
