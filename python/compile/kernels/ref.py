"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; the
pytest suite asserts allclose between kernel and oracle across shape /
dtype sweeps (hypothesis). These oracles are also used directly by
`model.py` when a dimension is too small to tile.
"""

import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


def moe_expert_ref(x, w1, w2):
    """Grouped expert FFN: per-expert 2-layer MLP with SiLU.

    Args:
      x:  [E, C, D]  tokens packed per expert (padded to capacity C).
      w1: [E, D, F]  up-projection per expert.
      w2: [E, F, D]  down-projection per expert.

    Returns:
      [E, C, D] expert outputs.
    """
    h = silu(jnp.einsum("ecd,edf->ecf", x, w1))
    return jnp.einsum("ecf,efd->ecd", h, w2)


def decode_attention_ref(q, k, v, n_valid=None):
    """Single-token decode attention.

    Args:
      q: [B, H, Dh]      query for the new token.
      k: [B, H, S, Dh]   key cache (padded).
      v: [B, H, S, Dh]   value cache.
      n_valid: scalar — number of valid cache rows (default S).

    Returns:
      [B, H, Dh] attention output.
    """
    s = k.shape[2]
    if n_valid is None:
        n_valid = s
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    mask = jnp.arange(s) < n_valid
    scores = jnp.where(mask[None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhs,bhsd->bhd", p, v)


FP8_MAX = 448.0  # float8_e4m3fn max normal


def quantize_fp8_ref(x):
    """Row-wise fp8-e4m3 quantization (RL weight transfer path).

    Args:
      x: [R, C] float32/bfloat16.

    Returns:
      (q, scale): q [R, C] float8_e4m3fn, scale [R, 1] float32 with
      x ≈ q.astype(f32) * scale.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / FP8_MAX
    q = (x.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    return q, scale


def dequantize_fp8_ref(q, scale):
    """Inverse of :func:`quantize_fp8_ref` (up to fp8 rounding)."""
    return q.astype(jnp.float32) * scale
