//! MoE scenario harness: builds a cluster, runs iterations, collects
//! the latency distributions the paper's Figures 9–12 and Tables 6–9
//! report.
//!
//! Entry points:
//!
//! * [`run_epoch_on`] — the full dispatch/combine epochs over any
//!   runtime: `&mut Cx` + one `Rc<dyn TransferEngine>` per node, with
//!   the GPU-kernel and NVLink side scheduled on the runtime-neutral
//!   [`crate::engine::model`] types. Timing-faithful on the DES
//!   runtime; structurally identical (same routing plan, same kernel
//!   durations) on the threaded runtime.
//! * [`run_decode_epoch`] / [`run_epoch_with`] — convenience wrappers
//!   reproducing the paper's testbeds on a DES [`Cluster`] (what the
//!   benches and the numeric tests use; `run_epoch_with` can collect
//!   node 0's Table-8/9 submission spans into a caller sink).
//! * [`run_generic_dispatch_round`] — the bare MoE *communication
//!   protocol* (peer-group scatter of token payloads, count-based
//!   completion, engine barrier for buffer reuse, §6.1–6.3) over
//!   `&dyn TransferEngine`, as a protocol smoke test.

use std::cell::RefCell;
use std::rc::Rc;

use crate::engine::api::{MrDesc, MrHandle, TemplatedDst};
use crate::engine::model::{ComputeModel, NvlinkModel};
use crate::engine::traits::{
    expect_flag, Cluster, Cx, Notify, RuntimeKind, SharedFlag, TransferEngine,
};
use crate::fabric::profile::{GpuProfile, NicProfile};
use crate::sim::stats::Histogram;
use crate::util::telemetry::TraceEvent;

use super::config::MoeConfig;
use super::rank::{IterSample, MoeRank, Strategy};
use super::routing::RoutingPlan;

/// Which implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoeImpl {
    Ours,
    DeepEp,
    Pplx,
}

impl MoeImpl {
    pub fn strategy(self) -> Strategy {
        match self {
            MoeImpl::Ours => Strategy::ours(),
            MoeImpl::DeepEp => Strategy::deepep(),
            MoeImpl::Pplx => Strategy::pplx(),
        }
    }

    pub fn name(self) -> &'static str {
        self.strategy().name
    }
}

/// Latency distributions across ranks × iterations (ns).
#[derive(Default)]
pub struct MoeLatencies {
    pub dispatch: Histogram,
    pub combine: Histogram,
    pub d_send_kernel: Histogram,
    pub d_recv_kernel: Histogram,
    pub c_send_kernel: Histogram,
    pub c_recv_kernel: Histogram,
}

/// Run `iters` decode iterations of `strat` on whatever runtime backs
/// `cx`, with one engine per node (rank r lives on node
/// `cfg.node_of(r)`, GPU `r % cfg.gpus_per_node`); `gpu_profile`
/// times the per-rank compute models (keep it consistent with the
/// cluster's).
pub fn run_epoch_on(
    cx: &mut Cx,
    engines: &[Rc<dyn TransferEngine>],
    cfg: &MoeConfig,
    strat: Strategy,
    gpu_profile: GpuProfile,
    iters: u64,
) -> MoeLatencies {
    let n = cfg.ranks as usize;
    let nodes = cfg.ranks.div_ceil(cfg.gpus_per_node) as usize;
    assert_eq!(engines.len(), nodes, "one engine per node");

    let nvlinks: Vec<NvlinkModel> = (0..nodes).map(|_| NvlinkModel::new()).collect();

    // Receive regions (contiguous buffer + private region + route
    // mailboxes), unbacked at production sizes.
    let region_len = ((cfg.recv_buffer_tokens() * cfg.dispatch_token_bytes as u64)
        .max(cfg.recv_buffer_tokens() * cfg.combine_token_bytes as u64)
        + (8 << 20)) as usize;
    let mut recv_descs = Vec::with_capacity(n);
    let mut computes: Vec<ComputeModel> = Vec::with_capacity(n);
    let mut send_bufs = Vec::with_capacity(n);
    for r in 0..n {
        let node = cfg.node_of(r as u32) as usize;
        let gpu = (r as u32 % cfg.gpus_per_node) as u8;
        let e = &engines[node];
        let (_h, d) = if region_len > (16 << 20) {
            e.alloc_mr_unbacked(gpu, region_len)
        } else {
            e.alloc_mr(gpu, region_len)
        };
        recv_descs.push(d);
        let (sb, _) = if region_len > (16 << 20) {
            e.alloc_mr_unbacked(gpu, region_len)
        } else {
            e.alloc_mr(gpu, region_len)
        };
        send_bufs.push(sb);
        computes.push(ComputeModel::new(gpu_profile.clone()));
    }
    let recv_descs = Rc::new(recv_descs);

    let ranks: Vec<MoeRank> = (0..n)
        .map(|r| {
            let node = cfg.node_of(r as u32) as usize;
            let gpu = (r as u32 % cfg.gpus_per_node) as u8;
            MoeRank::new(
                cfg,
                strat.clone(),
                r,
                engines[node].clone(),
                gpu,
                &computes[r],
                &nvlinks[node],
                recv_descs.clone(),
                send_bufs[r].clone(),
            )
        })
        .collect();
    let peer_registry = Rc::new(RefCell::new(ranks.clone()));
    for r in &ranks {
        r.set_peers(peer_registry.clone());
    }

    let mut out = MoeLatencies::default();
    for iter in 0..iters {
        let plan = Rc::new(RoutingPlan::generate(cfg, iter));
        let samples: Rc<RefCell<Vec<IterSample>>> = Rc::default();
        for rank in &ranks {
            let sink = samples.clone();
            rank.start_iteration(cx, iter, plan.clone(), move |_cx: &mut Cx, s| {
                sink.borrow_mut().push(s);
            });
        }
        {
            let what = format!("iteration {iter}: all ranks must finish (deadlock?)");
            let samples = samples.clone();
            cx.drive_until(&what, move || samples.borrow().len() == n);
        }
        let samples = samples.borrow();
        for s in samples.iter() {
            out.dispatch.record(s.dispatch_ns);
            out.combine.record(s.combine_ns);
            out.d_send_kernel.record(s.d_send_kernel_ns);
            out.d_recv_kernel.record(s.d_recv_kernel_ns);
            out.c_send_kernel.record(s.c_send_kernel_ns);
            out.c_recv_kernel.record(s.c_recv_kernel_ns);
        }
    }
    out
}

/// Run `iters` decode iterations of `imp` on a DES cluster with `nic`
/// NICs per GPU (×`nics_per_gpu`) and collect latency distributions.
pub fn run_decode_epoch(
    cfg: &MoeConfig,
    imp: MoeImpl,
    nic: NicProfile,
    nics_per_gpu: u8,
    iters: u64,
) -> MoeLatencies {
    run_epoch_with(cfg, imp.strategy(), nic, nics_per_gpu, iters, None)
}

/// Full-control variant: custom strategy + optional submission-span
/// sink (Table 8/9) — rank 0's engine records [`TraceEvent`] spans
/// during the run and they are drained into `trace_sink` afterwards —
/// on a DES cluster built through [`Cluster`].
pub fn run_epoch_with(
    cfg: &MoeConfig,
    strat: Strategy,
    nic: NicProfile,
    nics_per_gpu: u8,
    iters: u64,
    trace_sink: Option<Rc<RefCell<Vec<TraceEvent>>>>,
) -> MoeLatencies {
    run_epoch_inner(cfg, strat, nic, nics_per_gpu, iters, trace_sink, None)
}

/// [`run_epoch_with`] under transport perturbation: the chaos profile
/// is installed on the cluster's (shared) fabric before the first
/// iteration, so every dispatch/combine runs with the extra jitter /
/// bounded reordering / NIC events it describes. The MoE protocol is
/// count-gated (`expect_imm_count` per round) with no ordering
/// assumptions, so every clock-independent output — routing plan,
/// kernel durations, payloads — must be bit-identical to an
/// unperturbed run; only latencies move.
pub fn run_epoch_with_chaos(
    cfg: &MoeConfig,
    strat: Strategy,
    nic: NicProfile,
    nics_per_gpu: u8,
    iters: u64,
    chaos: &crate::fabric::chaos::ChaosProfile,
) -> MoeLatencies {
    run_epoch_inner(cfg, strat, nic, nics_per_gpu, iters, None, Some(chaos))
}

/// One DES epoch-cluster body behind both public variants, so the
/// chaos and non-chaos paths cannot drift.
fn run_epoch_inner(
    cfg: &MoeConfig,
    strat: Strategy,
    nic: NicProfile,
    nics_per_gpu: u8,
    iters: u64,
    trace_sink: Option<Rc<RefCell<Vec<TraceEvent>>>>,
    chaos: Option<&crate::fabric::chaos::ChaosProfile>,
) -> MoeLatencies {
    let nodes = cfg.ranks.div_ceil(cfg.gpus_per_node) as u16;
    let mut cluster = Cluster::new_with(
        RuntimeKind::Des,
        nodes,
        cfg.gpus_per_node as u8,
        nics_per_gpu,
        cfg.seed,
        nic,
        GpuProfile::h100(),
    );
    let engines = cluster.engines_rc();
    let out = {
        let (mut cx, _) = cluster.parts();
        if let Some(p) = chaos {
            engines[0].inject_chaos(&mut cx, p);
        }
        run_epoch_on(&mut cx, &engines, cfg, strat, GpuProfile::h100(), iters)
    };
    // Spans live in the engine's bounded trace ring during the run
    // (overflow counted in `telemetry().trace_dropped`, never
    // unbounded growth); hand rank 0's to the caller afterwards.
    if let Some(sink) = &trace_sink {
        if let Some(e) = cluster.des_engine(0) {
            sink.borrow_mut().extend(e.take_traces());
        }
    }
    cluster.shutdown();
    out
}

/// Runtime-agnostic MoE all-to-all round (§6.1–6.3 protocol): every
/// rank scatters `tokens_per_peer` tokens of `token_bytes` to each
/// peer through a registered peer group, receivers gate on one
/// `expect_imm_count` per round, and a handle-based engine barrier
/// confirms buffer reuse — scatter + barrier + imm counting end to
/// end on whichever runtime backs `cx`. The all-to-all runs on the
/// §3.5 templated path *batched*: each rank binds its peers' receive
/// regions once, and each round's whole fanout goes down in one
/// `submit_batch_templated` crossing patching offsets/lengths only. Peer
/// groups are request-scoped and freed on exit (`remove_peer_group`),
/// which also invalidates the templates, so repeated rounds on a
/// long-lived engine don't leak registry entries.
pub fn run_generic_dispatch_round(
    cx: &mut Cx,
    engines: &[&dyn TransferEngine],
    tokens_per_peer: u32,
    token_bytes: u64,
) {
    let n = engines.len();
    assert!(n >= 2, "all-to-all needs at least two ranks");
    let slot = tokens_per_peer as u64 * token_bytes;
    const IMM_TOKEN: u32 = 0x301;
    const IMM_BARRIER: u32 = 0x302;

    // Per-rank receive region: one slot per source rank.
    let regions: Vec<(MrHandle, MrDesc)> = engines
        .iter()
        .map(|e| e.alloc_mr(0, (slot * n as u64) as usize))
        .collect();

    // Receiver-side expectations, registered before any data moves.
    let mut token_flags: Vec<SharedFlag> = Vec::with_capacity(n);
    let mut barrier_flags: Vec<SharedFlag> = Vec::with_capacity(n);
    for e in engines {
        token_flags.push(expect_flag(*e, cx, 0, IMM_TOKEN, (n - 1) as u32));
        barrier_flags.push(expect_flag(*e, cx, 0, IMM_BARRIER, (n - 1) as u32));
    }

    // Dispatch: each rank scatters its token block into its own slot
    // of every peer's region, through a peer group bound (templated)
    // once per round. The fanout rides the batched fast path — one
    // engine crossing and one routing pass for all n-1 destinations,
    // each of which is four integers against the bound template.
    let mut groups = Vec::with_capacity(n);
    for (me, e) in engines.iter().enumerate() {
        let peers = engines
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != me)
            .map(|(_, p)| p.main_address())
            .collect();
        let group = e.add_peer_group(peers);
        let descs: Vec<MrDesc> = regions
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != me)
            .map(|(_, (_, desc))| desc.clone())
            .collect();
        e.bind_peer_group_mrs(0, group, &descs)
            .expect("peer region bind");
        groups.push(group);
        let (src, _) = e.alloc_mr(0, slot as usize);
        src.buf.write(0, &vec![me as u8 + 1; slot as usize]);
        let dsts: Vec<TemplatedDst> = (0..n - 1)
            .map(|peer| TemplatedDst {
                peer,
                len: slot,
                src: 0,
                dst: me as u64 * slot,
            })
            .collect();
        e.submit_batch_templated(cx, &src, group, &dsts, Some(IMM_TOKEN), Notify::Noop)
            .expect("batched dispatch scatter");
    }
    cx.wait_all(&token_flags);

    // Payload integrity: receiver `dst` sees `src + 1` bytes in slot
    // `src` for every source rank.
    for (dst, (h, _)) in regions.iter().enumerate() {
        let v = h.buf.to_vec();
        for src in 0..n {
            if src == dst {
                continue;
            }
            let seg = &v[(src as u64 * slot) as usize..((src as u64 + 1) * slot) as usize];
            assert!(
                seg.iter().all(|&b| b == src as u8 + 1),
                "rank {dst}: slot from rank {src} corrupted"
            );
        }
    }

    // Barrier through the same templated handles: destinations and
    // the scratch source live in the template, the call carries only
    // the immediate.
    for (me, e) in engines.iter().enumerate() {
        e.submit_barrier_templated(cx, groups[me], IMM_BARRIER, Notify::Noop)
            .expect("templated barrier");
    }
    cx.wait_all(&barrier_flags);

    // Round over: free the request-scoped groups (registry hygiene on
    // long-lived engines). Freeing invalidates the template — a stale
    // handle errors instead of touching freed state.
    for (me, e) in engines.iter().enumerate() {
        assert!(e.remove_peer_group(groups[me]), "group registered above");
        assert!(
            e.submit_barrier_templated(cx, groups[me], IMM_BARRIER, Notify::Noop)
                .is_err(),
            "stale handle must error"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::traits::run_on_both;
    use crate::sim::time::{MS, US};

    #[test]
    fn generic_dispatch_round_runs_on_both_runtimes() {
        run_on_both(4, 1, 1, 0x40E, |cx, engines| {
            run_generic_dispatch_round(cx, engines, 8, 64);
        });
    }

    #[test]
    fn generic_dispatch_round_multi_nic() {
        run_on_both(3, 1, 2, 0x40F, |cx, engines| {
            run_generic_dispatch_round(cx, engines, 4, 128);
        });
    }

    #[test]
    fn chaos_moe_epoch_reordering_leaves_results_bit_identical() {
        // The acceptance gate for the MoE protocol's
        // ordering-independence: a decode epoch under aggressive
        // reordering chaos must complete (no deadlock — the protocol
        // gates on counters, never on order) and produce the exact
        // kernel-duration distributions of the unperturbed run (those
        // are pure functions of the routing plan, i.e. the
        // dispatch/combine RESULTS; only latencies may move).
        let cfg = MoeConfig::tiny();
        let chaos = crate::fabric::chaos::ChaosProfile::new(0xC4A0)
            .with_reorder(150_000, 32)
            .with_extra_jitter(crate::sim::rng::Jitter::tight(2_000.0));
        let mut base = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::efa(), 2, 3);
        let mut perturbed = run_epoch_with_chaos(
            &cfg,
            MoeImpl::Ours.strategy(),
            NicProfile::efa(),
            2,
            3,
            &chaos,
        );
        assert_eq!(perturbed.dispatch.len(), base.dispatch.len(), "all ranks finished");
        for (name, b, p) in [
            ("d_send", &mut base.d_send_kernel, &mut perturbed.d_send_kernel),
            ("d_recv", &mut base.d_recv_kernel, &mut perturbed.d_recv_kernel),
            ("c_send", &mut base.c_send_kernel, &mut perturbed.c_send_kernel),
            ("c_recv", &mut base.c_recv_kernel, &mut perturbed.c_recv_kernel),
        ] {
            assert_eq!(
                b.sorted_samples(),
                p.sorted_samples(),
                "{name} kernel durations must be reorder-invariant"
            );
        }
    }

    #[test]
    fn tiny_epoch_completes_all_impls() {
        let cfg = MoeConfig::tiny();
        for imp in [MoeImpl::Ours, MoeImpl::DeepEp, MoeImpl::Pplx] {
            let lat = run_decode_epoch(&cfg, imp, NicProfile::connectx7(), 1, 3);
            assert_eq!(lat.dispatch.len(), 3 * 4, "{:?}", imp);
            let mut d = lat.dispatch;
            assert!(d.max() < MS, "{imp:?} dispatch too slow: {}", d.max());
        }
    }

    #[test]
    fn decode_ep16_ordering_matches_paper() {
        // Fig 9 inter-node shape on CX-7: ours ≲ DeepEP ≪ pplx.
        let cfg = MoeConfig::decode(16, 128);
        let ours = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::connectx7(), 1, 4);
        let deepep = run_decode_epoch(&cfg, MoeImpl::DeepEp, NicProfile::connectx7(), 1, 4);
        let pplx = run_decode_epoch(&cfg, MoeImpl::Pplx, NicProfile::connectx7(), 1, 4);
        let (mut o, mut d, mut p) = (ours.dispatch, deepep.dispatch, pplx.dispatch);
        let (om, dm, pm) = (o.percentile(50.0), d.percentile(50.0), p.percentile(50.0));
        assert!(om < 2 * dm, "ours {om} vs deepep {dm} must be comparable");
        assert!(pm > 3 * om, "pplx {pm} must be far slower than ours {om}");
        // Decode dispatch at EP16 lands in the tens-to-hundreds of µs.
        assert!(om > 20 * US && om < 800 * US, "{om}");
    }

    #[test]
    fn efa_trails_cx7_moderately() {
        // §7.4.3: EFA latencies trail CX-7 by ~30% (decode, ours).
        let cfg = MoeConfig::decode(16, 128);
        let cx7 = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::connectx7(), 1, 4);
        let efa = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::efa(), 2, 4);
        let (mut c, mut e) = (cx7.dispatch, efa.dispatch);
        let (cm, em) = (c.percentile(50.0) as f64, e.percentile(50.0) as f64);
        assert!(em > cm, "EFA should be slower ({em} vs {cm})");
        assert!(em < cm * 2.2, "but not catastrophically ({em} vs {cm})");
    }
}
