"""AOT pipeline: lower the L2/L1 graph to HLO **text** artifacts.

Interchange format is HLO text, NOT serialized protos: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the rust `xla` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Model weights are baked into the HLO as constants: the rust runtime
loads a self-contained executable per entry point and never imports
Python. A `manifest.json` describes every artifact's I/O signature.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.quantize import quantize_fp8

# Entry-point shape choices (static HLO per shape).
PREFILL_LENS = (32, 64, 128)
MOE_BLOCK_TOKENS = 128
QUANT_SHAPE = (64, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so
    the rust side unwraps a single tuple).

    `print_large_constants` is essential: the default printer elides
    big weight tensors as `constant({...})`, which the rust-side text
    parser would reject (or worse, mis-parse). Metadata is dropped to
    keep artifacts small.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _sig(args, outs):
    def one(x):
        return {"shape": list(x.shape), "dtype": str(x.dtype)}

    return {
        "inputs": [one(a) for a in args],
        "outputs": [one(o) for o in jax.tree.leaves(outs)],
    }


def build_artifacts(out_dir: str, cfg: M.ModelConfig, seed: int = 0):
    """Lower all entry points; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    params = M.init_params(cfg, seed)
    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "n_experts": cfg.n_experts,
            "top_k": cfg.top_k,
            "max_seq": cfg.max_seq,
            "param_count": cfg.param_count(),
            "seed": seed,
        },
        "entries": {},
    }

    def emit(name, fn, example_args):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *example_args)
        manifest["entries"][name] = {"file": fname, **_sig(example_args, outs)}
        print(f"  {name}: {len(text)} chars -> {fname}")

    # Prefill at several static lengths (chunked-prefill buckets).
    for s in PREFILL_LENS:
        if s > cfg.max_seq:
            continue
        emit(
            f"prefill_{s}",
            lambda toks: M.prefill(cfg, params, toks),
            (jax.ShapeDtypeStruct((s,), jnp.int32),),
        )

    # Single-token decode against the padded cache.
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head), jnp.float32
    )
    emit(
        "decode",
        lambda tok, kc, vc, pos: M.decode_step(cfg, params, tok, kc, vc, pos),
        (
            jax.ShapeDtypeStruct((), jnp.int32),
            cache,
            cache,
            jax.ShapeDtypeStruct((), jnp.int32),
        ),
    )

    # Standalone MoE block (expert compute for the MoE example).
    emit(
        "moe_block",
        lambda x: M.moe_block(cfg, params, x),
        (jax.ShapeDtypeStruct((MOE_BLOCK_TOKENS, cfg.d_model), jnp.float32),),
    )

    # fp8 quantize round-trip (RL weight-transfer stage 2); returns
    # dequantized f32 + scales so the PJRT I/O stays in f32.
    def quant_roundtrip(x):
        q, s = quantize_fp8(x)
        return q.astype(jnp.float32) * s, s

    emit(
        "quantize_roundtrip",
        quant_roundtrip,
        (jax.ShapeDtypeStruct(QUANT_SHAPE, jnp.float32),),
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.ModelConfig()
    print(f"AOT-lowering MoE transformer ({cfg.param_count()} params)")
    build_artifacts(args.out_dir, cfg, args.seed)
    print(f"manifest + artifacts in {os.path.abspath(args.out_dir)}")


if __name__ == "__main__":
    main()
