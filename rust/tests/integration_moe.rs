//! Integration: MoE dispatch/combine across implementations —
//! completion, determinism, buffer-bound respect, private-buffer
//! ablation monotonicity.

use fabric_lib::apps::moe::rank::Strategy;
use fabric_lib::apps::moe::routing::RoutingPlan;
use fabric_lib::apps::moe::{harness::run_epoch_with, run_decode_epoch, MoeConfig, MoeImpl};
use fabric_lib::fabric::profile::NicProfile;

#[test]
fn all_impls_complete_multiple_iterations() {
    let cfg = MoeConfig::tiny();
    for imp in [MoeImpl::Ours, MoeImpl::DeepEp, MoeImpl::Pplx] {
        for (nic, nics) in [(NicProfile::connectx7(), 1u8), (NicProfile::efa(), 2u8)] {
            let lat = run_decode_epoch(&cfg, imp, nic, nics, 4);
            assert_eq!(lat.dispatch.len(), 4 * cfg.ranks as usize, "{imp:?}");
            assert_eq!(lat.combine.len(), 4 * cfg.ranks as usize);
        }
    }
}

#[test]
fn epochs_are_deterministic() {
    let cfg = MoeConfig::decode(8, 32);
    let mut a = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::efa(), 2, 3).dispatch;
    let mut b = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::efa(), 2, 3).dispatch;
    assert_eq!(a.summary().p50, b.summary().p50);
    assert_eq!(a.max(), b.max());
}

#[test]
fn routing_respects_receive_buffer_bound() {
    // §6.1: receiver buffers sized to N·T·max(R, E/N) always suffice.
    for ranks in [8u32, 16, 64] {
        let cfg = MoeConfig::decode(ranks, 128);
        for it in 0..5 {
            let plan = RoutingPlan::generate(&cfg, it);
            for &recv in &plan.recv_totals {
                assert!(recv <= cfg.recv_buffer_tokens());
            }
        }
    }
}

#[test]
fn private_buffer_zero_is_slower_than_large() {
    // Fig 11: no speculation => route exchange on the critical path.
    let mut cfg0 = MoeConfig::decode(16, 128);
    cfg0.private_tokens = 0;
    let mut cfg_full = MoeConfig::decode(16, 128);
    cfg_full.private_tokens = 128;
    let mut none =
        run_epoch_with(&cfg0, Strategy::ours(), NicProfile::connectx7(), 1, 3, None).dispatch;
    let mut full =
        run_epoch_with(&cfg_full, Strategy::ours(), NicProfile::connectx7(), 1, 3, None).dispatch;
    assert!(
        none.percentile(50.0) > full.percentile(50.0),
        "no-speculation {} must exceed full-speculation {}",
        none.percentile(50.0),
        full.percentile(50.0)
    );
}

#[test]
fn kernel_times_are_small_fraction_of_dispatch() {
    // §7.4.5: total kernel execution ≲ a modest fraction of transfer
    // time at scale.
    let cfg = MoeConfig::decode(16, 128);
    let mut lat = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::connectx7(), 1, 3);
    let kernels = lat.d_send_kernel.percentile(50.0) + lat.d_recv_kernel.percentile(50.0);
    let dispatch = lat.dispatch.percentile(50.0);
    assert!(
        kernels < dispatch / 2,
        "kernels {kernels} should be well under dispatch {dispatch}"
    );
}

#[test]
fn prefill_scales_latency_with_tokens() {
    let d = {
        let cfg = MoeConfig::decode(8, 128);
        let mut l = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::connectx7(), 1, 2);
        l.dispatch.percentile(50.0)
    };
    let p = {
        let cfg = MoeConfig::prefill(8);
        let mut l = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::connectx7(), 1, 2);
        l.dispatch.percentile(50.0)
    };
    assert!(p > 4 * d, "prefill (4096 tok) {p} vs decode (128 tok) {d}");
}
