//! Legacy single-heap event scheduler, kept as a differential oracle.
//!
//! This is the pre-timer-wheel implementation of `sim/des.rs`: a
//! `BinaryHeap` of individually boxed `FnOnce` closures keyed by
//! `(time, seq)` with a `HashSet` tombstone set for cancellation. It is
//! compiled only for tests and under the `sim-oracle` feature, where
//! the differential property suite (`tests/sim_differential.rs` and
//! the tests below) drives it and the production wheel+arena scheduler
//! with identical operation streams and asserts the fired event
//! sequences match exactly — time order, same-timestamp scheduling
//! ties, cancellation, `run_until` deadline clamping, and
//! past-schedule clamping.
//!
//! Known wart preserved on purpose: cancelling an already-fired event
//! leaks a tombstone into `cancelled` forever. The production
//! scheduler's generation-tagged slots make that structurally
//! impossible; `des.rs::tests::cancel_fired_events_is_bounded` is the
//! regression test.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use super::time::{Duration, Instant};

/// Identifier of a scheduled event in the legacy scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LegacyEventId(u64);

type Thunk = Box<dyn FnOnce(&mut LegacySim)>;

struct Entry {
    at: Instant,
    seq: u64,
    thunk: Thunk,
}

// Order by (time, seq): earliest first via Reverse in the heap.
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// The legacy discrete-event simulator: boxed closures in one heap.
pub struct LegacySim {
    now: Instant,
    seq: u64,
    queue: BinaryHeap<Reverse<Entry>>,
    cancelled: HashSet<u64>,
    executed: u64,
    /// Hard cap on executed events.
    pub event_limit: u64,
}

impl Default for LegacySim {
    fn default() -> Self {
        Self::new()
    }
}

impl LegacySim {
    /// Create an empty simulator at t = 0.
    pub fn new() -> Self {
        LegacySim {
            now: 0,
            seq: 0,
            queue: BinaryHeap::new(),
            cancelled: HashSet::new(),
            executed: 0,
            event_limit: u64::MAX,
        }
    }

    /// Current virtual time.
    #[inline]
    pub fn now(&self) -> Instant {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Schedule `f` at absolute virtual time `at` (clamped to `now`).
    pub fn at(&mut self, at: Instant, f: impl FnOnce(&mut LegacySim) + 'static) -> LegacyEventId {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Reverse(Entry {
            at,
            seq,
            thunk: Box::new(f),
        }));
        LegacyEventId(seq)
    }

    /// Schedule `f` to run `delay` ns from now.
    pub fn after(
        &mut self,
        delay: Duration,
        f: impl FnOnce(&mut LegacySim) + 'static,
    ) -> LegacyEventId {
        let at = self.now.saturating_add(delay);
        self.at(at, f)
    }

    /// Cancel a pending event (tombstone insert).
    pub fn cancel(&mut self, id: LegacyEventId) {
        self.cancelled.insert(id.0);
    }

    /// Run until the event queue is empty. Returns the final time.
    pub fn run(&mut self) -> Instant {
        self.run_until(Instant::MAX)
    }

    /// Run events with `at <= deadline`.
    pub fn run_until(&mut self, deadline: Instant) -> Instant {
        while let Some(Reverse(entry)) = self.queue.peek() {
            if entry.at > deadline {
                self.now = self.now.max(deadline.min(entry.at));
                break;
            }
            let Reverse(entry) = self.queue.pop().unwrap();
            if self.cancelled.remove(&entry.seq) {
                continue;
            }
            self.now = entry.at;
            self.executed += 1;
            if self.executed > self.event_limit {
                panic!(
                    "sim event limit ({}) exceeded at t={} — runaway loop?",
                    self.event_limit, self.now
                );
            }
            (entry.thunk)(self);
        }
        self.now
    }

    /// True if no events remain.
    pub fn idle(&self) -> bool {
        self.queue.is_empty()
    }
}

/// Differential-oracle machinery shared by the in-crate tests and the
/// `tests/sim_differential.rs` integration suite: replay one seeded
/// operation stream on both schedulers and compare fired sequences.
pub mod differential {
    use super::super::des::Sim;
    use super::super::rng::Rng;
    use super::super::time::{Instant, MS, SEC, US};
    use super::LegacySim;
    use std::cell::RefCell;
    use std::rc::Rc;

    /// One scripted scheduler operation, derived deterministically
    /// from a seed so both sides replay the identical stream.
    #[derive(Debug, Clone, Copy)]
    pub enum Op {
        /// Schedule a no-payload event `delay` ns out; remember its id
        /// under `key` for later cancellation.
        After { delay: u64, key: usize },
        /// Schedule at an absolute time (possibly in the past — the
        /// clamping path).
        At { at: Instant, key: usize },
        /// Schedule an event that, when fired, schedules a follow-up
        /// (re-entrancy / cascade path).
        Chain { delay: u64, follow: u64 },
        /// Cancel the id stored under `key` (may already have fired).
        Cancel { key: usize },
        /// Drain events up to a deadline `ahead` ns past current time.
        RunUntil { ahead: u64 },
    }

    /// Generate a seeded random op stream mixing every API surface:
    /// near/far delays (heap and all wheel levels), absolute times in
    /// the past, same-timestamp ties, cancels of live and fired
    /// events, and partial drains.
    pub fn gen_ops(seed: u64, n: usize) -> Vec<Op> {
        let mut rng = Rng::new(seed);
        let mut ops = Vec::with_capacity(n);
        for i in 0..n {
            let roll = rng.below(100);
            let op = if roll < 40 {
                let delay = match rng.below(5) {
                    0 => rng.below(1000),            // same-bucket ties likely
                    1 => rng.below(2 * US),          // near
                    2 => rng.below(5 * MS),          // level 0/1
                    3 => rng.below(2 * SEC),         // mid levels
                    _ => rng.below(5000 * SEC),      // far future
                };
                Op::After { delay, key: i }
            } else if roll < 50 {
                Op::At {
                    at: rng.below(10 * SEC),
                    key: i,
                }
            } else if roll < 65 {
                Op::Chain {
                    delay: rng.below(3 * MS),
                    follow: rng.below(3 * MS),
                }
            } else if roll < 85 {
                Op::Cancel {
                    key: rng.below(n as u64) as usize,
                }
            } else {
                Op::RunUntil {
                    ahead: rng.below(20 * MS),
                }
            };
            ops.push(op);
        }
        ops
    }

    /// Fired-event log entry: (virtual time, label). Labels are the
    /// op index (or `usize::MAX - follow-up marker` for chains), so a
    /// mismatch pinpoints the diverging event.
    pub type FiredLog = Vec<(Instant, usize)>;

    /// Replay `ops` on the wheel+arena scheduler.
    pub fn replay_new(ops: &[Op]) -> (FiredLog, Instant, u64) {
        let mut sim = Sim::new();
        let log: Rc<RefCell<FiredLog>> = Rc::new(RefCell::new(Vec::new()));
        let mut ids = vec![None; ops.len()];
        for op in ops.iter() {
            match *op {
                Op::After { delay, key } => {
                    let log = log.clone();
                    ids[key] = Some(sim.after(delay, move |s| {
                        log.borrow_mut().push((s.now(), key));
                    }));
                }
                Op::At { at, key } => {
                    let log = log.clone();
                    ids[key] = Some(sim.at(at, move |s| {
                        log.borrow_mut().push((s.now(), key));
                    }));
                }
                Op::Chain { delay, follow } => {
                    let log = log.clone();
                    sim.after(delay, move |s| {
                        log.borrow_mut().push((s.now(), usize::MAX - 1));
                        let log = log.clone();
                        s.after(follow, move |s| {
                            log.borrow_mut().push((s.now(), usize::MAX - 2));
                        });
                    });
                }
                Op::Cancel { key } => {
                    if let Some(id) = ids[key] {
                        sim.cancel(id);
                    }
                }
                Op::RunUntil { ahead } => {
                    let deadline = sim.now().saturating_add(ahead);
                    sim.run_until(deadline);
                }
            }
        }
        sim.run();
        let fired = log.borrow().clone();
        (fired, sim.now(), sim.executed())
    }

    /// Replay `ops` on the legacy heap scheduler.
    pub fn replay_legacy(ops: &[Op]) -> (FiredLog, Instant, u64) {
        let mut sim = LegacySim::new();
        let log: Rc<RefCell<FiredLog>> = Rc::new(RefCell::new(Vec::new()));
        let mut ids = vec![None; ops.len()];
        for op in ops.iter() {
            match *op {
                Op::After { delay, key } => {
                    let log = log.clone();
                    ids[key] = Some(sim.after(delay, move |s| {
                        log.borrow_mut().push((s.now(), key));
                    }));
                }
                Op::At { at, key } => {
                    let log = log.clone();
                    ids[key] = Some(sim.at(at, move |s| {
                        log.borrow_mut().push((s.now(), key));
                    }));
                }
                Op::Chain { delay, follow } => {
                    let log = log.clone();
                    sim.after(delay, move |s| {
                        log.borrow_mut().push((s.now(), usize::MAX - 1));
                        let log = log.clone();
                        s.after(follow, move |s| {
                            log.borrow_mut().push((s.now(), usize::MAX - 2));
                        });
                    });
                }
                Op::Cancel { key } => {
                    if let Some(id) = ids[key] {
                        sim.cancel(id);
                    }
                }
                Op::RunUntil { ahead } => {
                    let deadline = sim.now().saturating_add(ahead);
                    sim.run_until(deadline);
                }
            }
        }
        sim.run();
        let fired = log.borrow().clone();
        (fired, sim.now(), sim.executed())
    }

    /// Assert both schedulers agree on one seeded workload.
    pub fn check_seed(seed: u64, n: usize) {
        let ops = gen_ops(seed, n);
        let (fired_new, now_new, exec_new) = replay_new(&ops);
        let (fired_old, now_old, exec_old) = replay_legacy(&ops);
        assert_eq!(
            exec_new, exec_old,
            "seed {seed}: executed-count divergence"
        );
        assert_eq!(now_new, now_old, "seed {seed}: final-clock divergence");
        assert_eq!(
            fired_new.len(),
            fired_old.len(),
            "seed {seed}: fired-count divergence"
        );
        for (i, (a, b)) in fired_new.iter().zip(fired_old.iter()).enumerate() {
            assert_eq!(a, b, "seed {seed}: divergence at fired event #{i}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::differential::check_seed;

    #[test]
    fn differential_small_seeds() {
        for seed in 0..8 {
            check_seed(seed, 200);
        }
    }

    #[test]
    fn differential_medium_seed() {
        check_seed(0xD15C0, 2_000);
    }

    #[test]
    fn legacy_tombstone_leak_is_real() {
        // Documents the bug the new scheduler fixes: cancelling fired
        // events grows the legacy tombstone set without bound.
        let mut sim = super::LegacySim::new();
        let mut ids = Vec::new();
        for i in 0..64u64 {
            ids.push(sim.at(i, |_| {}));
        }
        sim.run();
        for &id in &ids {
            sim.cancel(id);
        }
        assert_eq!(sim.cancelled.len(), 64, "legacy leak behavior changed");
    }
}
