//! Differential property suite: the timer-wheel + arena scheduler
//! must fire event sequences identical to the legacy single-heap
//! scheduler on seeded random workloads.
//!
//! Requires `--features sim-oracle` (the legacy scheduler is compiled
//! out of release builds otherwise):
//!
//! ```text
//! cargo test -q --features sim-oracle --test sim_differential
//! ```
//!
//! The oracle machinery lives in `fabric_lib::sim::legacy::differential`
//! so the in-crate unit tests share it; this suite runs bigger seeds
//! and targeted edge-case scripts (deadline clamping on cancelled
//! tails, same-timestamp ties across wheel levels, cancel-of-fired).

use fabric_lib::sim::legacy::differential::{check_seed, gen_ops, replay_legacy, replay_new, Op};
use fabric_lib::sim::time::{MS, SEC};

#[test]
fn differential_seed_sweep() {
    for seed in 0..32 {
        check_seed(seed, 500);
    }
}

#[test]
fn differential_long_runs() {
    for seed in [0xBEEF, 0xCAFE, 0xF00D] {
        check_seed(seed, 10_000);
    }
}

#[test]
fn differential_cancel_heavy() {
    // The generated streams cancel random keys, so many cancels land
    // on fired or not-yet-scheduled ids (the tombstone-leak path) and
    // the rest on pending ones; more seeds → more of both.
    for seed in 40u64..56 {
        check_seed(seed, 1_000);
    }
}

/// Hand-written edge scripts that the random generator hits only
/// rarely: both schedulers must agree exactly.
#[test]
fn differential_edge_scripts() {
    let scripts: &[Vec<Op>] = &[
        // Same-timestamp ties spanning schedule order.
        vec![
            Op::At { at: 5 * MS, key: 0 },
            Op::At { at: 5 * MS, key: 1 },
            Op::At { at: 5 * MS, key: 2 },
            Op::RunUntil { ahead: 4 * MS },
            Op::At { at: 5 * MS, key: 3 },
        ],
        // Deadline clamping with only a cancelled event beyond it.
        vec![
            Op::After { delay: 2 * SEC, key: 0 },
            Op::Cancel { key: 0 },
            Op::RunUntil { ahead: SEC },
            Op::After { delay: 10 * MS, key: 1 },
        ],
        // Past-schedule clamping after a drain.
        vec![
            Op::After { delay: 50 * MS, key: 0 },
            Op::RunUntil { ahead: 100 * MS },
            Op::At { at: 1, key: 1 },
            Op::Chain { delay: 0, follow: 0 },
        ],
        // Cancel of an already-fired key, then reuse-adjacent ids.
        vec![
            Op::After { delay: 1, key: 0 },
            Op::RunUntil { ahead: 10 },
            Op::Cancel { key: 0 },
            Op::After { delay: 1, key: 1 },
            Op::Cancel { key: 0 },
            Op::After { delay: 3 * SEC, key: 2 },
            Op::Cancel { key: 2 },
        ],
    ];
    for (i, ops) in scripts.iter().enumerate() {
        let new = replay_new(ops);
        let old = replay_legacy(ops);
        assert_eq!(new, old, "edge script #{i} diverged");
    }
}

#[test]
fn generator_covers_all_ops() {
    // Guard against the generator silently degenerating: a big sample
    // must exercise every op kind.
    let ops = gen_ops(123, 5_000);
    let (mut a, mut b, mut c, mut d, mut e) = (0, 0, 0, 0, 0);
    for op in &ops {
        match op {
            Op::After { .. } => a += 1,
            Op::At { .. } => b += 1,
            Op::Chain { .. } => c += 1,
            Op::Cancel { .. } => d += 1,
            Op::RunUntil { .. } => e += 1,
        }
    }
    assert!(a > 100 && b > 100 && c > 100 && d > 100 && e > 100);
}
