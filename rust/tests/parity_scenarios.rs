//! Both-runtime parity for the migrated full-model scenarios: the
//! KvCache Table-3 harness, a MoE decode epoch and the RL weight
//! pipeline each execute on the DES *and* the threaded runtime through
//! `TransferEngine` + the compute model, and the parts of their output
//! that do not depend on the clock — transfer schedules, page/write
//! counts, model-computed kernel durations, stage cost totals — must
//! agree exactly.
//!
//! (Clock-dependent readings — TTFT, dispatch latency, wall totals —
//! are virtual nanoseconds on DES and real nanoseconds on the threaded
//! runtime, so they are intentionally NOT compared.)

use std::rc::Rc;

use fabric_lib::apps::kvcache::{run_table3_row_on, Table3Row};
use fabric_lib::apps::moe::rank::Strategy;
use fabric_lib::apps::moe::{run_epoch_on, MoeConfig, MoeLatencies};
use fabric_lib::apps::rlweights::{run_p2p_transfer_on, RlModelSpec, RlReport};
use fabric_lib::engine::traits::{Cluster, Cx, RuntimeKind, TransferEngine};
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};

/// Build a cluster of `nodes`×`gpus`×`nics` on `kind`, hand the
/// scenario the context + owned engine handles, tear down, return.
fn on_cluster<T>(
    kind: RuntimeKind,
    nodes: u16,
    gpus: u8,
    nics: u8,
    nic: NicProfile,
    gpu_profile: GpuProfile,
    scenario: impl FnOnce(&mut Cx, Vec<Rc<dyn TransferEngine>>) -> T,
) -> T {
    let mut cluster = Cluster::new_with(kind, nodes, gpus, nics, 0x9A417, nic, gpu_profile);
    let engines = cluster.engines_rc();
    let out = {
        let (mut cx, _) = cluster.parts();
        let out = scenario(&mut cx, engines);
        cx.settle();
        out
    };
    cluster.shutdown();
    out
}

fn table3_on(kind: RuntimeKind) -> Table3Row {
    on_cluster(
        kind,
        2,
        1,
        2,
        NicProfile::efa(),
        GpuProfile::h200(),
        |cx, engines| {
            run_table3_row_on(
                cx,
                engines[0].clone(),
                engines[1].clone(),
                GpuProfile::h200(),
                4096,
            )
        },
    )
}

#[test]
fn table3_schedule_parity_des_vs_threaded() {
    let des = table3_on(RuntimeKind::Des);
    let thr = table3_on(RuntimeKind::Threaded);
    // The transfer schedule is clock-independent: same chunking, same
    // page counts, same number of paged WRITEs issued.
    assert_eq!(des.steps, thr.steps);
    assert_eq!(des.pages, thr.pages);
    assert_eq!(des.writes, thr.writes, "same WRITE schedule on both runtimes");
    // Kernel durations come from the workload model, not the clock.
    assert_eq!(des.per_layer_compute_ms, thr.per_layer_compute_ms);
    // Both runtimes actually ran the scenario to completion.
    assert!(des.ttft_disagg_ms > 0.0 && thr.ttft_disagg_ms > 0.0);
}

fn moe_epoch_on(kind: RuntimeKind) -> MoeLatencies {
    let cfg = MoeConfig::tiny();
    let nodes = cfg.ranks.div_ceil(cfg.gpus_per_node) as u16;
    on_cluster(
        kind,
        nodes,
        cfg.gpus_per_node as u8,
        1,
        NicProfile::connectx7(),
        GpuProfile::h100(),
        move |cx, engines| {
            run_epoch_on(cx, &engines, &cfg, Strategy::ours(), GpuProfile::h100(), 2)
        },
    )
}

#[test]
fn moe_epoch_parity_des_vs_threaded() {
    let mut des = moe_epoch_on(RuntimeKind::Des);
    let mut thr = moe_epoch_on(RuntimeKind::Threaded);
    // Every rank finished every iteration on both runtimes.
    assert_eq!(des.dispatch.len(), thr.dispatch.len());
    assert_eq!(des.combine.len(), thr.combine.len());
    // Kernel durations are computed from the (identical) routing plan
    // and the HBM roofline — clock-independent, so the distributions
    // must match exactly. Compare order-insensitive readings.
    for (a, b) in [
        (&mut des.d_send_kernel, &mut thr.d_send_kernel),
        (&mut des.d_recv_kernel, &mut thr.d_recv_kernel),
        (&mut des.c_send_kernel, &mut thr.c_send_kernel),
        (&mut des.c_recv_kernel, &mut thr.c_recv_kernel),
    ] {
        assert_eq!(a.len(), b.len());
        assert_eq!(a.min(), b.min());
        assert_eq!(a.max(), b.max());
        assert_eq!(a.percentile(50.0), b.percentile(50.0));
    }
}

fn rl_pipeline_on(kind: RuntimeKind) -> RlReport {
    let spec = RlModelSpec::tiny();
    on_cluster(
        kind,
        2, // 1 training node + 1 inference node (8 GPUs each)
        8,
        1,
        NicProfile::connectx7(),
        GpuProfile::h200(),
        move |cx, engines| {
            let (t_engines, r_engines) = engines.split_at(1);
            run_p2p_transfer_on(cx, t_engines, r_engines, &spec, 1.0)
        },
    )
}

#[test]
fn rl_pipeline_parity_des_vs_threaded() {
    let des = rl_pipeline_on(RuntimeKind::Des);
    let thr = rl_pipeline_on(RuntimeKind::Threaded);
    // Same routing → same bytes on the wire.
    assert_eq!(des.bytes, thr.bytes);
    // Stage cost totals are model-computed sums over the same static
    // schedule — clock-independent.
    let (a, b) = (des.rank0, thr.rank0);
    assert_eq!(a.h2d, b.h2d);
    assert_eq!(a.h2d_calls, b.h2d_calls);
    assert_eq!(a.full_tensor, b.full_tensor);
    assert_eq!(a.full_tensor_calls, b.full_tensor_calls);
    assert_eq!(a.fuse, b.fuse);
    assert_eq!(a.fuse_calls, b.fuse_calls);
    assert_eq!(a.quantize, b.quantize);
    assert_eq!(a.quantize_calls, b.quantize_calls);
    assert_eq!(a.rdma_submit, b.rdma_submit);
    assert_eq!(a.rdma_calls, b.rdma_calls);
    // Both runtimes completed the whole pipeline.
    assert!(des.total_ms > 0.0 && thr.total_ms > 0.0);
}
