//! Sharding planner: turns API-level transfers into per-NIC write
//! plans (paper §3.4: "requests are sharded and load-balanced across
//! the available DOMAINs").
//!
//! Pure functions — property-tested invariants:
//! * **coverage**: planned writes tile the requested byte range
//!   exactly, no gaps, no overlap;
//! * **imm-count preservation**: a transfer submitted with an
//!   immediate produces exactly the number of immediate-carrying
//!   writes the receiver's `expect_imm_count` was told about;
//! * **balance**: large imm-less transfers spread within one
//!   write-size of even across NICs.

use super::api::SPLIT_THRESHOLD;
use crate::util::smallvec::SmallVec;

/// Plan storage: inline up to the common 2–4 lane fanout so the
/// planner allocates nothing for small writes, single-NIC shards and
/// narrow scatters; wide paged/scatter plans spill to the heap.
pub type PlanVec = SmallVec<PlannedWrite, 4>;

/// One planned one-sided write on a specific NIC of the domain group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlannedWrite {
    /// NIC index within the domain group (pairs with the same index on
    /// the remote group).
    pub nic: usize,
    /// Source offset within the source region.
    pub src_off: u64,
    /// Destination virtual address.
    pub dst_va: u64,
    pub len: u64,
    pub imm: Option<u32>,
}

/// Plan a single contiguous write.
///
/// Imm-carrying writes are never split (the IMMCOUNTER count is part
/// of the application protocol); imm-less writes above
/// [`SPLIT_THRESHOLD`] are sharded evenly across all NICs.
/// `rotation` rotates NIC choice across successive transfers.
pub fn plan_single_write(
    len: u64,
    src_off: u64,
    dst_va: u64,
    imm: Option<u32>,
    fanout: usize,
    rotation: usize,
) -> PlanVec {
    assert!(fanout > 0);
    let mut plans = PlanVec::new();
    if imm.is_some() || len <= SPLIT_THRESHOLD || fanout == 1 {
        plans.push(PlannedWrite {
            nic: rotation % fanout,
            src_off,
            dst_va,
            len,
            imm,
        });
        return plans;
    }
    // Split evenly; remainder spread one byte at a time from the
    // front so shard sizes differ by at most 1.
    let n = fanout as u64;
    let base = len / n;
    let rem = len % n;
    let mut off = 0u64;
    for i in 0..fanout {
        let l = base + u64::from((i as u64) < rem);
        if l == 0 {
            continue;
        }
        plans.push(PlannedWrite {
            nic: (rotation + i) % fanout,
            src_off: src_off + off,
            dst_va: dst_va + off,
            len: l,
            imm: None,
        });
        off += l;
    }
    plans
}

/// Plan a paged write: one WR per page, source page `i` → destination
/// page `i`, round-robin across NICs starting at `rotation`.
///
/// Each page carries the immediate (the receiver expects one increment
/// per page — see the KvCache `imm_count` computation, Appendix A).
pub fn plan_paged_writes(
    page_len: u64,
    src_offsets: &[u64],
    dst_vas: &[u64],
    imm: Option<u32>,
    fanout: usize,
    rotation: usize,
) -> PlanVec {
    assert_eq!(
        src_offsets.len(),
        dst_vas.len(),
        "paged write: src/dst page counts differ"
    );
    assert!(fanout > 0);
    src_offsets
        .iter()
        .zip(dst_vas)
        .enumerate()
        .map(|(i, (&src_off, &dst_va))| PlannedWrite {
            nic: (rotation + i) % fanout,
            src_off,
            dst_va,
            len: page_len,
            imm,
        })
        .collect()
}

/// Plan a scatter: entry `i` (peer-specific length/offsets) goes out
/// on NIC `(rotation + i) % fanout`. Returns plans in submission
/// order; lengths may be zero only when the transport allows
/// immediate-only writes without descriptors (validated by the
/// domain).
pub fn plan_scatter(
    entries: &[(u64, u64, u64)], // (len, src_off, dst_va)
    imm: Option<u32>,
    fanout: usize,
    rotation: usize,
) -> PlanVec {
    assert!(fanout > 0);
    entries
        .iter()
        .enumerate()
        .map(|(i, &(len, src_off, dst_va))| PlannedWrite {
            nic: (rotation + i) % fanout,
            src_off,
            dst_va,
            len,
            imm,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_tiles(plans: &[PlannedWrite], src_off: u64, dst_va: u64, len: u64) {
        let mut ranges: Vec<(u64, u64)> = plans.iter().map(|p| (p.src_off, p.len)).collect();
        ranges.sort_unstable();
        let mut cursor = src_off;
        for (off, l) in &ranges {
            assert_eq!(*off, cursor, "gap or overlap at {off}");
            cursor += l;
        }
        assert_eq!(cursor, src_off + len, "total coverage");
        // dst mirrors src offsets
        for p in plans {
            assert_eq!(p.dst_va - dst_va, p.src_off - src_off);
        }
    }

    #[test]
    fn small_write_single_nic() {
        let plans = plan_single_write(4096, 0, 0x1000, None, 4, 2);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].nic, 2);
        assert_tiles(&plans, 0, 0x1000, 4096);
    }

    #[test]
    fn large_write_splits_across_nics() {
        let len = 1 << 20;
        let plans = plan_single_write(len, 100, 0x1000, None, 4, 0);
        assert_eq!(plans.len(), 4);
        assert_tiles(&plans, 100, 0x1000, len);
        // Balance within 1 byte.
        let lens: Vec<u64> = plans.iter().map(|p| p.len).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
        // All NICs used.
        let mut nics: Vec<usize> = plans.iter().map(|p| p.nic).collect();
        nics.sort_unstable();
        assert_eq!(nics, vec![0, 1, 2, 3]);
    }

    #[test]
    fn imm_write_never_splits() {
        let plans = plan_single_write(64 << 20, 0, 0, Some(9), 4, 1);
        assert_eq!(plans.len(), 1, "imm writes must not be split");
        assert_eq!(plans[0].imm, Some(9));
        assert_eq!(plans[0].nic, 1);
    }

    #[test]
    fn rotation_rotates() {
        for r in 0..8 {
            let p = plan_single_write(64, 0, 0, None, 2, r);
            assert_eq!(p[0].nic, r % 2);
        }
    }

    #[test]
    fn paged_round_robin_and_count() {
        let srcs: Vec<u64> = (0..10).map(|i| i * 32768).collect();
        let dsts: Vec<u64> = (0..10).map(|i| 0x100000 + i * 32768).collect();
        let plans = plan_paged_writes(32768, &srcs, &dsts, Some(5), 2, 1);
        assert_eq!(plans.len(), 10, "one WR per page: imm count preserved");
        assert!(plans.iter().all(|p| p.imm == Some(5)));
        // Round robin starting at 1.
        let nics: Vec<usize> = plans.iter().map(|p| p.nic).collect();
        assert_eq!(&nics[..4], &[1, 0, 1, 0]);
        // Page i maps to dst page i.
        for (i, p) in plans.iter().enumerate() {
            assert_eq!(p.src_off, srcs[i]);
            assert_eq!(p.dst_va, dsts[i]);
        }
    }

    #[test]
    #[should_panic(expected = "page counts differ")]
    fn paged_mismatch_panics() {
        plan_paged_writes(4096, &[0, 1], &[0], None, 1, 0);
    }

    #[test]
    fn scatter_one_wr_per_peer() {
        let entries: Vec<(u64, u64, u64)> =
            (0..7).map(|i| (256, i * 256, 0x9000 + i * 4096)).collect();
        let plans = plan_scatter(&entries, Some(3), 2, 0);
        assert_eq!(plans.len(), 7);
        assert!(plans.iter().all(|p| p.imm == Some(3)));
        let on0 = plans.iter().filter(|p| p.nic == 0).count();
        let on1 = plans.iter().filter(|p| p.nic == 1).count();
        assert!(on0.abs_diff(on1) <= 1, "balanced across NICs");
    }

    #[test]
    fn zero_fanout_guard() {
        let r = std::panic::catch_unwind(|| plan_single_write(10, 0, 0, None, 0, 0));
        assert!(r.is_err());
    }
}
