//! Quickstart: the TransferEngine public API in five minutes.
//!
//! Two engines ("nodes") exchange descriptors, then move data with
//! one-sided WRITEs, count completions with the IMMCOUNTER, and run an
//! RPC over SEND/RECV — the same primitives the KvCache / RL / MoE
//! systems are built from. The whole demo is written once against
//! `&dyn TransferEngine` and executed on BOTH runtimes.
//!
//! # Choosing a runtime
//!
//! fabric-lib ships one API (`engine::traits::TransferEngine`) with
//! two interchangeable runtimes:
//!
//! * **DES** (`engine::des_engine::Engine`) — single-threaded,
//!   deterministic, virtual-clock simulation of the multi-NIC fabric.
//!   Choose it for benchmarks, latency modeling and reproducible
//!   integration tests: a seed pins every byte and every nanosecond.
//! * **Threaded** (`engine::threaded::ThreadedEngine`) — real pinned
//!   worker threads over the in-process fabric, real memcpys, real
//!   wall-clock overheads. Choose it for runnable end-to-end examples
//!   and for *measuring* CPU costs (paper Table 8) rather than
//!   modeling them.
//!
//! Code written against the trait — like `demo()` below — does not
//! change between the two: `engine::traits::Cluster` builds either
//! flavor behind the same handle, the `Cx` context carries the
//! runtime-specific driving (event loop vs. thread waits), and
//! `Notify`/`SharedFlag` give runtime-neutral completion signaling.
//!
//! Run: cargo run --release --example quickstart

use std::sync::atomic::Ordering;

use fabric_lib::engine::traits::{
    expect_flag, new_flag, Cluster, Cx, Notify, OnRecv, RuntimeKind, TransferEngine,
};
use fabric_lib::engine::wire;
use fabric_lib::fabric::chaos::ChaosProfile;

/// The entire quickstart, written once against the trait.
fn demo(cx: &mut Cx, node_a: &dyn TransferEngine, node_b: &dyn TransferEngine) {
    println!("node A main address: {}", node_a.main_address());
    println!("node B main address: {}", node_b.main_address());

    // --- Memory registration + descriptor exchange ---------------------
    let (src, _src_desc) = node_a.alloc_mr(0, 4096);
    let (dst_handle, dst_desc) = node_b.alloc_mr(0, 4096);
    // MrDesc is serializable: peers exchange it out-of-band.
    let wire_bytes = wire::encode_mr_desc(&dst_desc);
    let dst_desc = wire::decode_mr_desc(&wire_bytes).unwrap();
    println!(
        "B's region: ptr={:#x}, {} rkeys (one per NIC), {} wire bytes",
        dst_desc.ptr,
        dst_desc.rkeys.len(),
        wire_bytes.len()
    );

    // --- One-sided WRITEIMM + IMMCOUNTER -------------------------------
    src.buf.write(0, b"hello, one-sided world");
    // B expects exactly one immediate 42 — no ordering assumptions,
    // just a count (paper §3.3).
    let received = expect_flag(node_b, cx, 0, 42, 1);
    let sent = new_flag();
    node_a
        .submit_single_write(
            cx,
            (&src, 0),
            22,
            (&dst_desc, 128),
            Some(42),
            Notify::Flag(sent.clone()),
        )
        .expect("§3.2-clean write");
    cx.wait(&sent);
    cx.wait(&received);
    let mut out = vec![0u8; 22];
    dst_handle.buf.read(128, &mut out);
    println!("B received via WRITEIMM: {:?}", String::from_utf8_lossy(&out));

    // --- Two-sided SEND/RECV RPC ----------------------------------------
    let replies = new_flag();
    let rp = replies.clone();
    let seen = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    let sn = seen.clone();
    node_b.submit_recvs(
        cx,
        0,
        256,
        8,
        // `OnRecv::checked` surfaces recv-pool truncation as an Err
        // instead of silently delivering a clipped payload.
        OnRecv::checked(move |msg| {
            let msg = msg.expect("recv pool sized for the largest RPC");
            println!("B got RPC: {:?}", String::from_utf8_lossy(msg));
            if sn.fetch_add(1, Ordering::Relaxed) + 1 == 3 {
                rp.store(true, Ordering::Release);
            }
        }),
    );
    for i in 0..3 {
        node_a.submit_send(
            cx,
            0,
            &node_b.group_address(0),
            format!("request #{i}").as_bytes(),
            Notify::Noop,
        );
    }
    cx.wait(&replies);

    // --- Sharded large write across both NICs --------------------------
    let len = 2 << 20;
    let (big_src, _) = node_a.alloc_mr(0, len);
    let (big_dst_h, big_dst_d) = node_b.alloc_mr(0, len);
    let pat: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
    big_src.buf.write(0, &pat);
    let done = new_flag();
    node_a
        .submit_single_write(
            cx,
            (&big_src, 0),
            len as u64,
            (&big_dst_d, 0),
            None,
            Notify::Flag(done.clone()),
        )
        .expect("§3.2-clean write");
    cx.wait(&done);
    assert_eq!(big_dst_h.buf.to_vec(), pat);
    println!(
        "2 MiB write sharded across {} NICs: payload verified",
        node_a.nics_per_gpu()
    );

    // --- Templated barrier through a bound peer group ------------------
    // Long-lived peer relationships pre-template their WRs (§3.5):
    // `bind_peer_group_mrs` resolves rkeys/routes once, and templated
    // submissions patch only per-call fields. A freed handle errors
    // instead of reusing stale state.
    let group = node_a.add_peer_group(vec![node_b.main_address()]);
    node_a
        .bind_peer_group_mrs(0, group, &[dst_desc])
        .expect("bind decoder region");
    let barried = expect_flag(node_b, cx, 0, 77, 1);
    node_a
        .submit_barrier_templated(cx, group, 77, Notify::Noop)
        .expect("templated barrier");
    cx.wait(&barried);
    println!("peer-group barrier delivered (templated imm-only write)");
    assert!(node_a.remove_peer_group(group));
    assert!(
        node_a
            .submit_barrier_templated(cx, group, 77, Notify::Noop)
            .is_err(),
        "stale handles fail loudly"
    );

    // --- Per-link health: partition → gossip-masked submit → recover ---
    // Real fabrics fail per directed (src, dst) PATH, not only per
    // NIC: a flapping switch port cuts one link while both NICs keep
    // serving every other peer. Cut A's lane-0 path to B's NIC 0; the
    // engine attributes the resulting WrErrors to exactly that link
    // and routes around it — no local NIC is ever masked.
    let a0 = node_a.group_address(0).nics[0];
    let b0 = node_b.group_address(0).nics[0];
    // Engines that share a destination register each other as gossip
    // peers: whoever concludes a remote NIC is dead tells the others
    // so they mask it without paying their own error round-trip.
    node_a.set_gossip_peers(0, vec![node_b.group_address(0)]);
    node_a.inject_chaos(cx, &ChaosProfile::new(11).link_down(0, (a0, b0)));
    // Fresh pattern + zeroed destination: the asserts below must prove
    // THIS section's writes landed, not inherit the earlier one's.
    let pat2: Vec<u8> = (0..len).map(|i| (i * 5 % 241) as u8).collect();
    big_src.buf.write(0, &pat2);
    big_dst_h.buf.write(0, &vec![0u8; len]);
    let done = new_flag();
    node_a
        .submit_single_write(
            cx,
            (&big_src, 0),
            len as u64,
            (&big_dst_d, 0),
            None,
            Notify::Flag(done.clone()),
        )
        .expect("§3.2-clean write");
    cx.wait(&done);
    assert_eq!(big_dst_h.buf.to_vec(), pat2, "failover across the partition loses nothing");
    println!(
        "write survived a cut {a0}→{b0} link: {} transport error(s), lane mask toward {b0}: {:#04b}",
        node_a.transport_errors(),
        node_a.link_health_mask(0, b0),
    );
    // A gossip-masked submit: `report_remote_health` is exactly what a
    // received gossip message applies — the remote NIC is masked out
    // of every route BEFORE any error round-trip is paid.
    node_a.report_remote_health(0, b0, false);
    assert_eq!(node_a.link_health_mask(0, b0), 0, "remote believed dead: no lane offered");
    big_dst_h.buf.write(0, &vec![0u8; len]);
    let done = new_flag();
    node_a
        .submit_single_write(
            cx,
            (&big_src, 0),
            len as u64,
            (&big_dst_d, 0),
            None,
            Notify::Flag(done.clone()),
        )
        .expect("gossip-masked write re-routes, it does not fail");
    cx.wait(&done);
    assert_eq!(big_dst_h.buf.to_vec(), pat2);
    println!("gossip-masked write delivered via B's surviving NIC(s)");
    // Recovery: heal the fabric link, then re-trust the path in the
    // engine table (clears the remote belief and its link marks).
    node_a.inject_chaos(cx, &ChaosProfile::new(12).link_up(cx.now(), (a0, b0)));
    cx.settle();
    node_a.report_remote_health(0, b0, true);
    assert_eq!(node_a.link_health_mask(0, b0), 0b11, "full fanout restored");
    println!("link healed and re-trusted: lane mask {:#04b}", node_a.link_health_mask(0, b0));

    // --- Telemetry: what did this demo actually put on the wire? -------
    // Every engine keeps an always-on counter registry plus a bounded
    // ring of submission spans — same shape on both runtimes, readable
    // at any point. `fabricctl kvcache --metrics-json / --trace-out`
    // exposes the same two calls from the command line.
    let snap = node_a.telemetry();
    let spans = node_a.take_traces();
    println!(
        "node A telemetry: {} submissions -> {} WRs / {} bytes on the wire, \
         {} transport error(s) ({} resubmitted, {} errored out), \
         {} spans buffered ({} dropped)",
        snap.total_submissions(),
        snap.total_wrs(),
        snap.total_bytes(),
        snap.transport_errors(),
        snap.resubmits,
        snap.error_outs,
        spans.len(),
        snap.trace_dropped,
    );
    println!("metrics JSON (the --metrics-json payload):\n{}", snap.to_json().to_pretty(2));
}

fn main() {
    for kind in [RuntimeKind::Des, RuntimeKind::Threaded] {
        println!("==== runtime: {kind:?} ====");
        // 2 nodes x 1 GPU x 2 NICs; SRD-style semantics: reliable,
        // connectionless, NO ordering — the common ground fabric-lib
        // standardizes on (paper Table 1).
        let mut cluster = Cluster::new(kind, 2, 1, 2, 7);
        {
            let (mut cx, engines) = cluster.parts();
            demo(&mut cx, engines[0], engines[1]);
            cx.settle();
        }
        cluster.shutdown();
        println!();
    }
    println!("quickstart OK on both runtimes");
}
