//! The [`TransferEngine`] trait: one Fig-2 API, two runtimes.
//!
//! The paper's central claim is a *uniform* interface over
//! heterogeneous transports (§3, Fig 2). This module is that
//! interface: a dyn-safe trait covering the full vocabulary —
//! `alloc_mr`/`reg_mr`, `submit_send`/`submit_recvs`,
//! `submit_single_write`/`submit_paged_writes`,
//! `add_peer_group`/`submit_scatter`/`submit_barrier`,
//! `expect_imm_count`/`imm_value`/`free_imm`, `alloc_uvm_watcher` —
//! implemented by both the deterministic DES engine
//! ([`super::des_engine::Engine`]) and the pinned-thread engine
//! ([`super::threaded::ThreadedEngine`]), so every workload runs on
//! either runtime from the same code path.
//!
//! The two runtimes drive progress differently (virtual event loop vs.
//! real threads), which the trait absorbs with two small types:
//!
//! * [`Cx`] — the execution context threaded through every
//!   submission: the DES variant carries `&mut Sim`, the threaded
//!   variant nothing. `Cx::wait` is the runtime-appropriate "block
//!   until this flag is set" (run the event loop to quiescence vs.
//!   spin with a deadline).
//! * [`Notify`] — runtime-neutral completion notification (atomic
//!   flag, `Send` callback, or nothing), converted to each runtime's
//!   native `OnDone` flavor at the boundary.
//!
//! [`Cluster`] builds an N-node cluster on either runtime behind the
//! same handle and is how harness tests and examples run one scenario
//! on both ([`run_on_both`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration as StdDuration;
use std::time::Instant as StdInstant;

use super::api::{EngineCosts, MrDesc, MrHandle, NetAddr, Pages, PeerGroupHandle, ScatterDst};
use super::des_engine::{Engine, OnDone, UvmWatcherHandle};
use super::threaded::{OnDoneT, ThreadedEngine};
use super::wire;
use crate::fabric::local::LocalFabric;
use crate::fabric::mem::DmaBuf;
use crate::fabric::nic::NicAddr;
use crate::fabric::profile::{GpuProfile, NicProfile, TransportKind};
use crate::fabric::simnet::SimNet;
use crate::sim::Sim;

/// Which runtime backs an engine or context.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// Deterministic discrete-event runtime (virtual clock).
    Des,
    /// Pinned-worker-thread runtime (wall clock).
    Threaded,
}

/// Completion flag shared between submitter and waiter; works on both
/// runtimes (the DES engine sets it from the event loop, the threaded
/// engine from a worker thread).
pub type SharedFlag = Arc<AtomicBool>;

/// Fresh unset [`SharedFlag`].
pub fn new_flag() -> SharedFlag {
    Arc::new(AtomicBool::new(false))
}

/// Register an `expect_imm_count(imm, count)` whose satisfaction sets
/// the returned flag — the standard receiver-side gate in scenario
/// code (pair with [`Cx::wait`]).
pub fn expect_flag(
    engine: &dyn TransferEngine,
    cx: &mut Cx,
    gpu: u8,
    imm: u32,
    count: u32,
) -> SharedFlag {
    let flag = new_flag();
    let f = flag.clone();
    engine.expect_imm_count(
        cx,
        gpu,
        imm,
        count,
        Box::new(move || f.store(true, Ordering::Release)),
    );
    flag
}

/// Runtime-neutral receive callback (`submit_recvs`).
pub type RecvHandler = Arc<dyn Fn(&[u8]) + Send + Sync>;

/// Runtime-neutral `expect_imm_count` callback.
pub type ImmHandler = Box<dyn FnOnce() + Send>;

/// Runtime-neutral UVM-watcher callback (`cb(old, new)`).
pub type WatchHandler = Box<dyn Fn(u64, u64) + Send + Sync>;

/// Runtime-neutral sender-side completion notification; converted to
/// the runtime's native flavor at the trait boundary.
pub enum Notify {
    /// Set an atomic flag (wait with [`Cx::wait`]).
    Flag(SharedFlag),
    /// Run a callback on the runtime's completion path.
    Callback(Box<dyn FnOnce() + Send>),
    /// Fire-and-forget.
    Noop,
}

impl Notify {
    /// Convert to the DES engine's native notification.
    pub fn into_des(self) -> OnDone {
        match self {
            Notify::Flag(f) => {
                OnDone::Callback(Box::new(move |_sim| f.store(true, Ordering::Release)))
            }
            Notify::Callback(cb) => OnDone::Callback(Box::new(move |_sim| cb())),
            Notify::Noop => OnDone::Noop,
        }
    }

    /// Convert to the threaded engine's native notification.
    pub fn into_threaded(self) -> OnDoneT {
        match self {
            Notify::Flag(f) => OnDoneT::Flag(f),
            Notify::Callback(cb) => OnDoneT::Callback(cb),
            Notify::Noop => OnDoneT::Noop,
        }
    }
}

/// Handle to a UVM watcher allocated through the trait; device-side
/// code reports progress with [`UvmWatcher::device_write`].
pub enum UvmWatcher {
    /// DES watcher (observation scheduled on the virtual clock).
    Des(UvmWatcherHandle),
    /// Threaded watcher word (polled by the engine's watcher thread).
    Threaded(Arc<AtomicU64>),
}

impl UvmWatcher {
    /// Record a device-side write of `value`.
    pub fn device_write(&self, cx: &mut Cx, value: u64) {
        match self {
            UvmWatcher::Des(h) => h.device_write(cx.sim(), value),
            UvmWatcher::Threaded(word) => word.store(value, Ordering::Release),
        }
    }
}

/// Execution context threaded through every submission call.
pub enum Cx<'a> {
    /// DES runtime: all progress happens inside this simulator.
    Des(&'a mut Sim),
    /// Threaded runtime: progress happens on background threads.
    Threaded,
}

impl Cx<'_> {
    /// Which runtime this context drives.
    pub fn kind(&self) -> RuntimeKind {
        match self {
            Cx::Des(_) => RuntimeKind::Des,
            Cx::Threaded => RuntimeKind::Threaded,
        }
    }

    /// The simulator (panics on the threaded runtime — only engine
    /// internals and DES-specific code paths may call this).
    pub fn sim(&mut self) -> &mut Sim {
        match self {
            Cx::Des(sim) => sim,
            Cx::Threaded => panic!("Cx::sim() on the threaded runtime"),
        }
    }

    /// Drive the runtime until `flag` is set: the DES variant runs the
    /// event loop to quiescence and asserts the flag (a clear signal
    /// of a lost completion), the threaded variant spins with a 10 s
    /// deadline.
    pub fn wait(&mut self, flag: &SharedFlag) {
        match self {
            Cx::Des(sim) => {
                sim.run();
                assert!(
                    flag.load(Ordering::Acquire),
                    "DES run quiesced without satisfying the awaited flag"
                );
            }
            Cx::Threaded => {
                let deadline = StdInstant::now() + StdDuration::from_secs(10);
                while !flag.load(Ordering::Acquire) {
                    assert!(StdInstant::now() < deadline, "timeout awaiting flag");
                    std::thread::yield_now();
                }
            }
        }
    }

    /// [`Cx::wait`] over several flags.
    pub fn wait_all(&mut self, flags: &[SharedFlag]) {
        for f in flags {
            self.wait(f);
        }
    }

    /// Let in-flight work finish without a flag to key on: run the DES
    /// event loop to quiescence; no-op on the threaded runtime (which
    /// has no global quiescence signal — key on flags instead).
    pub fn settle(&mut self) {
        if let Cx::Des(sim) = self {
            sim.run();
        }
    }
}

/// The uniform TransferEngine interface (paper Fig 2), dyn-safe so
/// scenario code can hold `&dyn TransferEngine` regardless of runtime.
pub trait TransferEngine {
    /// Which runtime backs this engine.
    fn runtime_kind(&self) -> RuntimeKind;

    /// The engine's main (discovery) address: group 0's.
    fn main_address(&self) -> NetAddr {
        self.group_address(0)
    }

    /// Address of GPU `gpu`'s domain group.
    fn group_address(&self, gpu: u8) -> NetAddr;

    /// NICs per GPU on this engine.
    fn nics_per_gpu(&self) -> u8;

    /// Allocate + register `len` bytes on `gpu` (paper `reg_mr` with
    /// allocation fused in).
    fn alloc_mr(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc);

    /// Register an existing buffer on `gpu`, one rkey per NIC.
    fn reg_mr(&self, gpu: u8, buf: &DmaBuf) -> (MrHandle, MrDesc);

    /// Two-sided send into the peer's posted RECV pool
    /// (copy-on-submit).
    fn submit_send(&self, cx: &mut Cx, gpu: u8, addr: &NetAddr, msg: &[u8], on_done: Notify);

    /// Post a rotating pool of `cnt` receive buffers of `len` bytes.
    fn submit_recvs(&self, cx: &mut Cx, gpu: u8, len: usize, cnt: usize, cb: RecvHandler);

    /// Contiguous one-sided write, sharded across NICs when large and
    /// imm-less.
    fn submit_single_write(
        &self,
        cx: &mut Cx,
        src: (&MrHandle, u64),
        len: u64,
        dst: (&MrDesc, u64),
        imm: Option<u32>,
        on_done: Notify,
    );

    /// Paged writes: source page `i` lands at destination page `i`.
    fn submit_paged_writes(
        &self,
        cx: &mut Cx,
        page_len: u64,
        src: (&MrHandle, &Pages),
        dst: (&MrDesc, &Pages),
        imm: Option<u32>,
        on_done: Notify,
    );

    /// Register a peer group for scatter/barrier fast paths.
    fn add_peer_group(&self, addrs: Vec<NetAddr>) -> PeerGroupHandle;

    /// The peer list behind a group handle.
    fn peer_group(&self, group: PeerGroupHandle) -> Option<Vec<NetAddr>>;

    /// Scatter slices of `src` to many peers; one WR per destination.
    fn submit_scatter(
        &self,
        cx: &mut Cx,
        group: Option<PeerGroupHandle>,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm: Option<u32>,
        on_done: Notify,
    );

    /// Immediate-only notification to every peer (zero-length writes;
    /// `dsts` supplies a valid descriptor per peer, required on EFA).
    fn submit_barrier(
        &self,
        cx: &mut Cx,
        gpu: u8,
        group: Option<PeerGroupHandle>,
        dsts: &[MrDesc],
        imm: u32,
        on_done: Notify,
    );

    /// Notify `cb` once `imm` has been received `count` times on
    /// `gpu`'s group.
    fn expect_imm_count(&self, cx: &mut Cx, gpu: u8, imm: u32, count: u32, cb: ImmHandler);

    /// Poll the current counter value for `imm`.
    fn imm_value(&self, gpu: u8, imm: u32) -> u32;

    /// Release counter state for `imm`.
    fn free_imm(&self, gpu: u8, imm: u32);

    /// Allocate a UVM watcher; `cb(old, new)` fires when the engine
    /// observes a changed value.
    fn alloc_uvm_watcher(&self, cb: WatchHandler) -> UvmWatcher;

    // -- wire bridge (descriptor exchange over SEND/RECV) -------------

    /// Send a wire-encoded [`MrDesc`] to a peer (out-of-band
    /// descriptor exchange, paper Fig 2 `#[serde]`).
    fn submit_send_mr_desc(&self, cx: &mut Cx, gpu: u8, addr: &NetAddr, desc: &MrDesc) {
        self.submit_send(cx, gpu, addr, &wire::encode_mr_desc(desc), Notify::Noop);
    }

    /// Send this engine's wire-encoded group address to a peer.
    fn submit_send_net_addr(&self, cx: &mut Cx, gpu: u8, addr: &NetAddr) {
        let own = self.group_address(gpu);
        self.submit_send(cx, gpu, addr, &wire::encode_net_addr(&own), Notify::Noop);
    }
}

// ---------------------------------------------------------------------
// Both-runtime cluster harness
// ---------------------------------------------------------------------

enum ClusterInner {
    Des {
        // Keeps the fabric alive for the engines; also exposed for
        // NIC-level assertions (e.g. per-NIC byte balance).
        net: SimNet,
        sim: Sim,
        engines: Vec<Engine>,
    },
    Threaded {
        fabric: LocalFabric,
        engines: Vec<ThreadedEngine>,
    },
}

/// An N-node × G-GPU × K-NIC cluster on either runtime behind one
/// handle: the uniform way for tests, harnesses and examples to run a
/// scenario on both runtimes.
pub struct Cluster {
    inner: ClusterInner,
}

impl Cluster {
    /// Build a cluster of `nodes` engines with `gpus` GPUs ×
    /// `nics_per_gpu` NICs each. The DES variant picks an EFA-like
    /// profile for multi-NIC groups and CX-7 for single-NIC ones; the
    /// threaded variant runs SRD semantics (reliable, unordered).
    pub fn new(kind: RuntimeKind, nodes: u16, gpus: u8, nics_per_gpu: u8, seed: u64) -> Self {
        let inner = match kind {
            RuntimeKind::Des => {
                let net = SimNet::new(seed);
                for node in 0..nodes {
                    for gpu in 0..gpus {
                        for nic in 0..nics_per_gpu {
                            let profile = if nics_per_gpu > 1 {
                                NicProfile::efa()
                            } else {
                                NicProfile::connectx7()
                            };
                            net.add_nic(NicAddr { node, gpu, nic }, profile);
                        }
                    }
                }
                let engines = (0..nodes)
                    .map(|node| {
                        Engine::new(
                            &net,
                            node,
                            gpus,
                            nics_per_gpu,
                            GpuProfile::h100(),
                            EngineCosts::default(),
                            seed ^ (node as u64),
                        )
                    })
                    .collect();
                ClusterInner::Des {
                    net,
                    sim: Sim::new(),
                    engines,
                }
            }
            RuntimeKind::Threaded => {
                let fabric = LocalFabric::new(TransportKind::Srd, seed);
                let engines = (0..nodes)
                    .map(|node| ThreadedEngine::new(&fabric, node, gpus, nics_per_gpu))
                    .collect();
                ClusterInner::Threaded { fabric, engines }
            }
        };
        Cluster { inner }
    }

    /// Which runtime this cluster runs.
    pub fn kind(&self) -> RuntimeKind {
        match &self.inner {
            ClusterInner::Des { .. } => RuntimeKind::Des,
            ClusterInner::Threaded { .. } => RuntimeKind::Threaded,
        }
    }

    /// The simulated fabric, when on the DES runtime (NIC-level
    /// assertions such as byte balance).
    pub fn des_net(&self) -> Option<SimNet> {
        match &self.inner {
            ClusterInner::Des { net, .. } => Some(net.clone()),
            ClusterInner::Threaded { .. } => None,
        }
    }

    /// Borrow the execution context plus the engines as trait objects.
    pub fn parts(&mut self) -> (Cx<'_>, Vec<&dyn TransferEngine>) {
        match &mut self.inner {
            ClusterInner::Des { sim, engines, .. } => (
                Cx::Des(sim),
                engines.iter().map(|e| e as &dyn TransferEngine).collect(),
            ),
            ClusterInner::Threaded { engines, .. } => (
                Cx::Threaded,
                engines.iter().map(|e| e as &dyn TransferEngine).collect(),
            ),
        }
    }

    /// Tear the cluster down (joins threads on the threaded runtime).
    pub fn shutdown(self) {
        if let ClusterInner::Threaded { fabric, engines } = self.inner {
            for e in &engines {
                e.shutdown();
            }
            fabric.shutdown();
        }
    }
}

/// Run `scenario` once per runtime on a fresh cluster each time — the
/// standard shape of a runtime-agnostic integration test.
pub fn run_on_both(
    nodes: u16,
    gpus: u8,
    nics_per_gpu: u8,
    seed: u64,
    scenario: impl Fn(&mut Cx, &[&dyn TransferEngine]),
) {
    for kind in [RuntimeKind::Des, RuntimeKind::Threaded] {
        let mut cluster = Cluster::new(kind, nodes, gpus, nics_per_gpu, seed);
        {
            let (mut cx, engines) = cluster.parts();
            scenario(&mut cx, &engines);
            cx.settle();
        }
        cluster.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The same scenario, byte-for-byte, on both runtimes: descriptor
    /// exchange-shaped write + imm counting through `&dyn
    /// TransferEngine`.
    #[test]
    fn both_runtimes_run_the_same_write_scenario() {
        run_on_both(2, 1, 2, 0xC0FFEE, |cx, engines| {
            let (a, b) = (engines[0], engines[1]);
            assert_eq!(a.nics_per_gpu(), 2);
            let (src, _) = a.alloc_mr(0, 4096);
            let (dst_h, dst_d) = b.alloc_mr(0, 4096);
            src.buf.write(0, b"one API, two runtimes");

            let got = expect_flag(b, cx, 0, 7, 1);
            let sent = new_flag();
            a.submit_single_write(
                cx,
                (&src, 0),
                21,
                (&dst_d, 64),
                Some(7),
                Notify::Flag(sent.clone()),
            );
            cx.wait(&sent);
            cx.wait(&got);
            assert_eq!(&dst_h.buf.to_vec()[64..85], b"one API, two runtimes");
        });
    }

    #[test]
    fn peer_groups_resolve_on_both_runtimes() {
        run_on_both(3, 1, 1, 9, |_cx, engines| {
            let peers: Vec<NetAddr> =
                engines[1..].iter().map(|e| e.main_address()).collect();
            let h = engines[0].add_peer_group(peers.clone());
            assert_eq!(engines[0].peer_group(h).unwrap(), peers);
            assert!(engines[0].peer_group(PeerGroupHandle(9999)).is_none());
        });
    }
}
