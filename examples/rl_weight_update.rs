//! RL rollout weight update (paper §5): P2P pipelined transfer vs the
//! rank0 gather+broadcast path existing frameworks use.
//!
//! Runs the simulated deployment at a 16-rank slice of the Kimi-K2-1T
//! shape and prints the per-rank stage breakdown + the baseline
//! comparison.
//!
//! Run: cargo run --release --example rl_weight_update [-- --full]

use fabric_lib::apps::rlweights::{run_p2p_transfer, run_rank0_broadcast, RlModelSpec};
use fabric_lib::fabric::profile::NicProfile;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let spec = if full {
        RlModelSpec::kimi_k2_1t()
    } else {
        RlModelSpec {
            t_ranks: 16,
            r_ranks: 8,
            total_params: 1_000_000_000_000 / 16,
            ..RlModelSpec::kimi_k2_1t()
        }
    };
    println!(
        "model {}: {} training ranks (bf16) -> {} inference ranks (fp8), \
         {} params/rank, {} mesh groups",
        spec.name, spec.t_ranks, spec.r_ranks, spec.params_per_rank, spec.mesh_groups
    );

    let p2p = run_p2p_transfer(&spec, NicProfile::connectx7(), 1.0);
    let t = p2p.rank0;
    println!("\nP2P pipelined transfer: total {:.0} ms", p2p.total_ms);
    println!("  rank0 stages (overlapped):");
    println!("    H2D memcpy      {:>6.0} ms  ({} calls)", t.h2d as f64 / 1e6, t.h2d_calls);
    println!("    full_tensor()   {:>6.0} ms  ({} calls)", t.full_tensor as f64 / 1e6, t.full_tensor_calls);
    println!("    fuse projections{:>6.0} ms", t.fuse as f64 / 1e6);
    println!("    quantize fp8    {:>6.0} ms  ({} calls)", t.quantize as f64 / 1e6, t.quantize_calls);
    println!("    RDMA submit     {:>6.0} ms  ({} writes)", t.rdma_submit as f64 / 1e6, t.rdma_calls);
    println!("    barrier wait    {:>6.0} ms", t.wait_ranks as f64 / 1e6);
    println!(
        "  fabric: {:.1} GiB written, aggregate {:.0} Gbps",
        p2p.bytes as f64 / (1u64 << 30) as f64,
        p2p.agg_gbps
    );

    let base = run_rank0_broadcast(&spec, NicProfile::connectx7(), 1);
    println!(
        "\nrank0 gather+broadcast baseline: gather {:.0} ms + broadcast {:.0} ms = {:.0} ms",
        base.gather_ms, base.broadcast_ms, base.total_ms
    );
    println!(
        "\nP2P speedup: {:.0}x  (paper: >100x at full 1T scale — every byte \
         of the baseline squeezes through one NIC)",
        base.total_ms / p2p.total_ms
    );
}
