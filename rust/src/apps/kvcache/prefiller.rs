//! Prefiller node: chunked prefill with layer-by-layer KV transfer
//! (paper §4 + Appendix A Fig 15).
//!
//! Runtime-neutral since the compute-model migration: the prefiller
//! holds `Rc<dyn TransferEngine>` and schedules its GPU kernels on a
//! [`ComputeModel`], so the same state machine runs on the DES virtual
//! clock and on the threaded runtime's reactor.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::engine::api::{MrDesc, MrHandle, NetAddr, Pages};
use crate::engine::model::{ComputeModel, Fired};
use crate::engine::traits::{Cx, Notify, OnRecv, OnWatch, TransferEngine, UvmWatcher};
use crate::sim::time::{Duration, Instant};

use super::proto::{self, CancelAck, CancelReq, DispatchReq, Heartbeat};
use super::workload::ServingWorkload;

/// Transfer timing stats collected for Table 3's per-layer columns.
#[derive(Debug, Default, Clone)]
pub struct TransferStats {
    /// (submit, done) per layer-transfer, model-clock ns.
    pub layer_transfers: Vec<(Instant, Instant)>,
    /// Per-layer compute kernel durations.
    pub layer_compute: Vec<Duration>,
    /// Total WRITEs issued.
    pub writes: u64,
}

struct ReqTask {
    req: DispatchReq,
    /// (chunk, layer) completions signalled so far via UVM.
    chunks: Vec<(u32, u32)>,
    watcher: UvmWatcher,
    outstanding_writes: usize,
    tail_sent: bool,
}

struct PState {
    engine: Rc<dyn TransferEngine>,
    gpu: u8,
    compute: ComputeModel,
    workload: ServingWorkload,
    kv_src: (MrHandle, MrDesc),
    tail_src: (MrHandle, MrDesc),
    active: HashMap<u64, ReqTask>,
    cancelled: HashSet<u64>,
    killed: bool,
    hb_seq: u64,
    hb_targets: Vec<NetAddr>,
    hb_interval: Duration,
    node: u16,
    pub stats: Rc<RefCell<TransferStats>>,
}

/// A prefiller node (one GPU's worth).
#[derive(Clone)]
pub struct Prefiller {
    state: Rc<RefCell<PState>>,
}

impl Prefiller {
    /// Create and start listening for dispatches.
    pub fn new(
        cx: &mut Cx,
        engine: Rc<dyn TransferEngine>,
        gpu: u8,
        compute: &ComputeModel,
        workload: ServingWorkload,
        node: u16,
    ) -> Self {
        // Source regions: the prefiller's own KV cache + tail staging.
        // Large configs use unbacked (timing-only) regions.
        let kv_len = workload.layout.region_bytes() as usize;
        let kv_src = if kv_len > (64 << 20) {
            engine.alloc_mr_unbacked(gpu, kv_len)
        } else {
            engine.alloc_mr(gpu, kv_len)
        };
        let tail_src = engine.alloc_mr(gpu, workload.tail_bytes as usize);
        let state = Rc::new(RefCell::new(PState {
            engine: engine.clone(),
            gpu,
            compute: compute.clone(),
            workload,
            kv_src,
            tail_src,
            active: HashMap::new(),
            cancelled: HashSet::new(),
            killed: false,
            hb_seq: 0,
            hb_targets: Vec::new(),
            hb_interval: 5_000_000, // 5 ms
            node,
            stats: Rc::default(),
        }));
        let p = Prefiller { state };
        let p2 = p.clone();
        let on_msg = OnRecv::Cont(cx.cont(move |cx: &mut Cx, fired: Fired| {
            p2.on_message(cx, &fired.data);
        }));
        engine.submit_recvs(cx, gpu, 1 << 20, 32, on_msg);
        p
    }

    /// Per-layer transfer/compute stats.
    pub fn stats(&self) -> Rc<RefCell<TransferStats>> {
        self.state.borrow().stats.clone()
    }

    /// Simulate node failure: stop heartbeats and all processing.
    pub fn kill(&self) {
        self.state.borrow_mut().killed = true;
    }

    /// Source KV descriptor (tests).
    pub fn kv_src_handle(&self) -> MrHandle {
        self.state.borrow().kv_src.0.clone()
    }

    /// Begin heartbeating to `decoders` every `interval`.
    pub fn start_heartbeats(&self, cx: &mut Cx, decoders: Vec<NetAddr>, interval: Duration) {
        {
            let mut s = self.state.borrow_mut();
            s.hb_targets = decoders;
            s.hb_interval = interval;
        }
        self.heartbeat_tick(cx);
    }

    fn heartbeat_tick(&self, cx: &mut Cx) {
        let (targets, interval, seq, engine, gpu, node, killed) = {
            let mut s = self.state.borrow_mut();
            s.hb_seq += 1;
            (
                s.hb_targets.clone(),
                s.hb_interval,
                s.hb_seq,
                s.engine.clone(),
                s.gpu,
                s.node,
                s.killed,
            )
        };
        if killed {
            return;
        }
        let msg = Heartbeat {
            sender_node: node,
            seq,
        }
        .encode();
        for t in &targets {
            engine.submit_send(cx, gpu, t, &msg, Notify::Noop);
        }
        let this = self.clone();
        cx.after(interval, move |cx: &mut Cx| this.heartbeat_tick(cx));
    }

    fn on_message(&self, cx: &mut Cx, msg: &[u8]) {
        if self.state.borrow().killed {
            return;
        }
        match proto::msg_tag(msg) {
            Ok(t) if t == crate::engine::wire::tag::KV_DISPATCH => {
                let req = DispatchReq::decode(msg).expect("bad DispatchReq");
                self.begin_prefill(cx, req);
            }
            Ok(t) if t == crate::engine::wire::tag::KV_CANCEL => {
                let c = CancelReq::decode(msg).expect("bad CancelReq");
                self.on_cancel(cx, c.req_id);
            }
            Ok(t) => panic!("prefiller: unexpected message tag {t}"),
            Err(e) => panic!("prefiller: undecodable message: {e}"),
        }
    }

    /// Start chunked prefill for a request (Appendix A Fig 15).
    fn begin_prefill(&self, cx: &mut Cx, req: DispatchReq) {
        let req_id = req.req_id;
        let chunks_layers: Vec<(u32, u32)> = {
            let s = self.state.borrow();
            let w = &s.workload;
            let seq = req.input_ids.len() as u32;
            w.chunks(seq)
                .iter()
                .enumerate()
                .flat_map(|(ci, _)| (0..w.layout.layers).map(move |l| (ci as u32, l)))
                .collect()
        };
        // UVM watcher: incremented after each layer's attention output
        // projection (CUDA-graph compatible). The continuation receives
        // (old, new) and may observe coalesced updates.
        let this = self.clone();
        let on_watch = OnWatch::Cont(cx.cont(move |cx: &mut Cx, f: Fired| {
            for v in f.a..f.b {
                this.on_layer_done(cx, req_id, v);
            }
        }));
        let watcher = {
            let s = self.state.borrow();
            s.engine.alloc_uvm_watcher(on_watch)
        };
        // Enqueue all layer kernels on the GPU stream now; they run
        // back-to-back (chunk-major), each bumping the watcher.
        {
            let (compute, kernel_plan) = {
                let s = self.state.borrow();
                let w = &s.workload;
                let seq = req.input_ids.len() as u32;
                let mut plan = Vec::new();
                for (start, len) in w.chunks(seq) {
                    for _l in 0..w.layout.layers {
                        let dur = w.compute.layer_ns(len, start);
                        s.stats.borrow_mut().layer_compute.push(dur);
                        plan.push(dur);
                    }
                }
                (s.compute.clone(), plan)
            };
            let mut counter = 0u64;
            for dur in kernel_plan {
                counter += 1;
                let wh = watcher.clone();
                let c = counter;
                compute.launch(cx, 0, dur, true, move |cx: &mut Cx, _end| {
                    wh.device_write(cx, c)
                });
            }
        }
        self.state.borrow_mut().active.insert(
            req_id,
            ReqTask {
                req,
                chunks: chunks_layers,
                watcher,
                outstanding_writes: 0,
                tail_sent: false,
            },
        );
    }

    /// One (chunk, layer) finished on the GPU: transfer its pages.
    fn on_layer_done(&self, cx: &mut Cx, req_id: u64, v: u64) {
        let submit_t = cx.now();
        let (engine, plan) = {
            let mut s = self.state.borrow_mut();
            if s.killed || s.cancelled.contains(&req_id) {
                return;
            }
            let w = s.workload.clone();
            let engine = s.engine.clone();
            let Some(task) = s.active.get_mut(&req_id) else {
                return;
            };
            let (chunk, layer) = task.chunks[v as usize];
            let seq = task.req.input_ids.len() as u32;
            let chunks = w.chunks(seq);
            let (start, len) = chunks[chunk as usize];
            // Pages of this chunk.
            let ppc = w.chunk_tokens / w.layout.tokens_per_page;
            let first_page = (start / w.layout.tokens_per_page) as usize;
            let n_pages = w.layout.pages_for(len).min(ppc) as usize;
            // Source: prefiller's own (layer, slot) pages; destination:
            // decoder's slots from the request, adjusted to `layer`.
            let src_idx: Vec<u32> = (0..n_pages)
                .map(|i| w.layout.page_index(layer, (first_page + i) as u32))
                .collect();
            let dst_idx: Vec<u32> = (0..n_pages)
                .map(|i| {
                    let slot = task.req.pages[first_page + i];
                    w.layout.page_index(layer, slot)
                })
                .collect();
            task.outstanding_writes += 1;
            let is_last = v as usize + 1 == task.chunks.len();
            (
                engine,
                Some((
                    w.layout.page_bytes,
                    src_idx,
                    dst_idx,
                    task.req.imm,
                    task.req.kv_desc.clone(),
                    is_last,
                )),
            )
        };
        let Some((page_bytes, src_idx, dst_idx, imm, kv_desc, is_last)) = plan else {
            return;
        };
        let (kv_src_handle, stats) = {
            let s = self.state.borrow();
            (s.kv_src.0.clone(), s.stats.clone())
        };
        stats.borrow_mut().writes += src_idx.len() as u64;
        let this = self.clone();
        let n_pages = src_idx.len();
        let on_done = cx.cont(move |cx: &mut Cx, _f: Fired| {
            stats
                .borrow_mut()
                .layer_transfers
                .push((submit_t, cx.now()));
            this.on_write_done(cx, req_id, n_pages);
        });
        if let Err(e) = engine.submit_paged_writes(
            cx,
            page_bytes,
            (
                &kv_src_handle,
                &Pages {
                    indices: src_idx,
                    stride: page_bytes,
                    offset: 0,
                },
            ),
            (
                &kv_desc,
                &Pages {
                    indices: dst_idx,
                    stride: page_bytes,
                    offset: 0,
                },
            ),
            Some(imm),
            Notify::Cont(on_done),
        ) {
            self.fence_on_dead_fabric(&e);
            return;
        }
        if is_last {
            self.send_tail(cx, req_id);
        }
    }

    /// A submission failed. When every NIC of this GPU is down (chaos
    /// NicDown took the whole group out), fence the node: stop
    /// heartbeats and all processing, exactly as a hardware fabric
    /// loss manifests — the decoder's heartbeat monitor reclaims the
    /// request's pages and the scheduler re-dispatches elsewhere. Any
    /// other submission error is a programming bug and still panics.
    fn fence_on_dead_fabric(&self, err: &crate::util::err::Error) {
        let mut s = self.state.borrow_mut();
        let mask = s.engine.nic_health_mask(s.gpu);
        assert_eq!(
            mask, 0,
            "prefiller submission failed with NICs still up: {err}"
        );
        s.killed = true;
    }

    /// Tail context: final single write carrying the +1 immediate.
    fn send_tail(&self, cx: &mut Cx, req_id: u64) {
        let (engine, tail_src, tail_bytes, desc, off, imm) = {
            let mut s = self.state.borrow_mut();
            if s.cancelled.contains(&req_id) {
                return;
            }
            let engine = s.engine.clone();
            let tail_buf = s.tail_src.0.clone();
            let tb = s.workload.tail_bytes;
            let task = s.active.get_mut(&req_id).expect("tail for unknown req");
            task.tail_sent = true;
            task.outstanding_writes += 1;
            (
                engine,
                tail_buf,
                tb,
                task.req.tail_desc.clone(),
                task.req.tail_idx as u64 * tb,
                task.req.imm,
            )
        };
        let this = self.clone();
        let on_done = cx.cont(move |cx: &mut Cx, _f: Fired| this.on_write_done(cx, req_id, 1));
        if let Err(e) = engine.submit_single_write(
            cx,
            (&tail_src, 0),
            tail_bytes,
            (&desc, off),
            Some(imm),
            Notify::Cont(on_done),
        ) {
            self.fence_on_dead_fabric(&e);
        }
    }

    fn on_write_done(&self, cx: &mut Cx, req_id: u64, _wrs: usize) {
        let ack = {
            let mut s = self.state.borrow_mut();
            let Some(task) = s.active.get_mut(&req_id) else {
                return;
            };
            task.outstanding_writes -= 1;
            let finished = task.outstanding_writes == 0 && task.tail_sent;
            let cancelled = s.cancelled.contains(&req_id);
            if finished || (cancelled && s.active[&req_id].outstanding_writes == 0) {
                let task = s.active.remove(&req_id).unwrap();
                // On cancellation, still-enqueued kernels may bump the
                // watcher after this free; both engines ignore writes
                // to a freed watcher.
                task.watcher.free();
                if cancelled {
                    s.cancelled.remove(&req_id);
                    Some(task.req.decoder_addr.clone())
                } else {
                    None
                }
            } else {
                None
            }
        };
        // Cancellation confirmed only after every pending WRITE has
        // completed — a stale write could otherwise clobber reused
        // pages (§4).
        if let Some(decoder) = ack {
            let (engine, gpu) = {
                let s = self.state.borrow();
                (s.engine.clone(), s.gpu)
            };
            engine.submit_send(
                cx,
                gpu,
                &decoder,
                &CancelAck { req_id }.encode(),
                Notify::Noop,
            );
        }
    }

    fn on_cancel(&self, cx: &mut Cx, req_id: u64) {
        let immediate_ack = {
            let mut s = self.state.borrow_mut();
            match s.active.get(&req_id) {
                Some(task) if task.outstanding_writes > 0 => {
                    // Writes in flight: ack once they drain.
                    s.cancelled.insert(req_id);
                    None
                }
                Some(task) => {
                    let addr = task.req.decoder_addr.clone();
                    s.cancelled.insert(req_id);
                    // No writes in flight but kernels may still bump
                    // the watcher; the cancelled set suppresses future
                    // transfers. Ack now.
                    Some(addr)
                }
                None => None, // already finished; ack anyway? No: done.
            }
        };
        if let Some(addr) = immediate_ack {
            let (engine, gpu) = {
                let s = self.state.borrow();
                (s.engine.clone(), s.gpu)
            };
            engine.submit_send(cx, gpu, &addr, &CancelAck { req_id }.encode(), Notify::Noop);
        }
    }
}
