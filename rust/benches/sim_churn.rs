//! Scheduler churn + scaled serving sweep for the timer-wheel DES core.
//!
//! Usage: cargo bench --bench sim_churn [-- --quick] [--json PATH]
//!
//! Two parts:
//!
//! 1. **Churn**: 10⁶ schedule/fire/cancel events (10⁷ without
//!    `--quick`) across delays spanning every wheel level, from 1024
//!    concurrent self-rearming timer chains plus a rolling ring of
//!    cancelled guard timers. After a warmup run brings the arena,
//!    heap and guard ring to steady state, the measured run must
//!    perform **zero heap allocations** — asserted via the
//!    [`CountingAlloc`] global allocator installed in this binary.
//! 2. **Serving sweep**: the ≥1000-node, 10⁶-request kvcache scenario
//!    (seeded open-loop Poisson arrivals, power-of-two-choices
//!    dispatch, per-request guard timers → 10⁶ cancellations). Asserts
//!    bounded peak-pending depth and the scheduler memory budget, and
//!    emits TTFT p50/p99/p99.9 headlines.
//!
//! `--json PATH` merges the headlines into the report at PATH under
//! the `sim_churn` section (pinned as BENCH_sim.json).

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;

use fabric_lib::apps::kvcache::{run_serving, Arrivals, PoissonArrivals, ServingConfig};
use fabric_lib::sim::time::{fmt_ns, MS, SEC, US};
use fabric_lib::sim::{EventId, Rng, Sim};
use fabric_lib::util::alloc_probe::{alloc_count, CountingAlloc};
use fabric_lib::util::json::{update_report, Json};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Concurrent self-rearming timer chains.
const CHAINS: usize = 1024;
/// Rolling window of live guard timers (cancel targets).
const GUARD_RING: usize = 512;

struct Churn {
    rng: Rng,
    fired: u64,
    target: u64,
    guards: VecDeque<EventId>,
}

fn tick(sim: &mut Sim, st: &Rc<RefCell<Churn>>) {
    let (delay, guard_roll) = {
        let mut b = st.borrow_mut();
        b.fired += 1;
        if b.fired >= b.target {
            return; // this chain ends
        }
        let delay = match b.rng.below(5) {
            0 => b.rng.below(1000),      // near: same level-0 bucket
            1 => b.rng.below(100 * US),  // level 0
            2 => b.rng.below(10 * MS),   // levels 0–1
            3 => b.rng.below(500 * MS),  // mid levels
            _ => b.rng.below(30 * SEC),  // far future
        };
        (delay, b.rng.below(4) == 0)
    };
    if guard_roll {
        // Schedule a far-out guard and cancel the oldest one — the
        // cancel-heavy pattern (heartbeats, request timeouts) that
        // leaked tombstones in the legacy scheduler.
        let id = sim.after(60 * SEC, |_| {});
        let victim = {
            let mut b = st.borrow_mut();
            b.guards.push_back(id);
            if b.guards.len() > GUARD_RING {
                b.guards.pop_front()
            } else {
                None
            }
        };
        if let Some(old) = victim {
            sim.cancel(old);
        }
    }
    let stc = st.clone();
    sim.after(delay, move |sim| tick(sim, &stc));
}

/// Seed `CHAINS` churn chains targeting `target` total fires. The
/// state allocation happens here, outside the measured window; the
/// caller runs `sim.run()` (allocation-free once warmed).
fn churn_seed(sim: &mut Sim, seed: u64, target: u64) {
    let st = Rc::new(RefCell::new(Churn {
        rng: Rng::new(seed),
        fired: 0,
        target,
        guards: VecDeque::with_capacity(GUARD_RING + 1),
    }));
    for _ in 0..CHAINS {
        let stc = st.clone();
        sim.after(1, move |sim| tick(sim, &stc));
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--fast" || a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut headlines: BTreeMap<String, Json> = BTreeMap::new();

    // ---- Part 1: schedule/fire/cancel churn --------------------------
    let churn_events: u64 = if quick { 1_000_000 } else { 10_000_000 };
    let mut sim = Sim::new();
    // Warmup run grows the arena/heap/ring to steady-state capacity.
    churn_seed(&mut sim, 7, 200_000);
    sim.run();
    let warm_slots = sim.arena_slots();
    churn_seed(&mut sim, 8, churn_events);
    let allocs_before = alloc_count();
    let wall = std::time::Instant::now();
    sim.run();
    let wall_ns = wall.elapsed().as_nanos() as u64;
    let steady_allocs = alloc_count() - allocs_before;
    let st = sim.stats();

    println!("sim_churn: {churn_events} events in {}", fmt_ns(wall_ns));
    println!(
        "  scheduled {} executed {} cancelled {} peak_pending {}",
        st.scheduled, st.executed, st.cancelled, st.peak_pending
    );
    println!(
        "  arena {} slots (warmup {}), ~{} KiB containers, steady-state allocs {}",
        sim.arena_slots(),
        warm_slots,
        sim.approx_mem_bytes() / 1024,
        steady_allocs
    );
    assert_eq!(
        steady_allocs, 0,
        "after warmup, the schedule/fire/cancel churn must not touch \
         the heap: the after() fast path is required to be allocation-free"
    );
    assert!(
        st.executed >= churn_events,
        "churn under-ran: {} events",
        st.executed
    );
    // Slot reuse: O(peak-pending), not O(total-events).
    assert!(
        (sim.arena_slots() as u64) < churn_events / 10,
        "arena grew with total events ({} slots)",
        sim.arena_slots()
    );
    headlines.insert("churn_events".into(), Json::from(churn_events));
    headlines.insert("churn_steady_allocs".into(), Json::from(steady_allocs));
    headlines.insert(
        "churn_arena_slots".into(),
        Json::from(sim.arena_slots() as u64),
    );

    // ---- Part 2: scaled kvcache serving sweep ------------------------
    let (prefillers, decoders, requests) = (640usize, 384usize, 1_000_000usize);
    let nodes = prefillers + decoders;
    let cfg = ServingConfig::scaled(prefillers, decoders, requests);
    let mem_budget = cfg.mem_budget_bytes;
    // ~0.7 utilization for the 2K/4K/8K mix on 640 prefillers.
    let arrivals = Arrivals::Poisson(PoissonArrivals::new(
        0xA11C,
        600 * US,
        vec![2048, 4096, 8192],
    ));
    let wall = std::time::Instant::now();
    let rep = run_serving(cfg, arrivals);
    let serve_wall_ns = wall.elapsed().as_nanos() as u64;

    println!(
        "\nserving sweep: {nodes} nodes, {} requests in {} wall ({} virtual)",
        rep.completed,
        fmt_ns(serve_wall_ns),
        fmt_ns(rep.end_ns)
    );
    println!(
        "  TTFT p50 {} p99 {} p999 {}  (timeouts {})",
        fmt_ns(rep.ttft.p50),
        fmt_ns(rep.ttft.p99),
        fmt_ns(rep.ttft.p999),
        rep.timeouts
    );
    println!(
        "  scheduler: peak_pending {} arena {} slots ~{} KiB (budget {} KiB), {} cancels",
        rep.sim.peak_pending,
        rep.arena_slots,
        rep.approx_mem_bytes / 1024,
        mem_budget / 1024,
        rep.sim.cancelled
    );
    assert_eq!(rep.completed as usize, requests, "sweep must complete");
    assert_eq!(rep.timeouts, 0, "guard timers must not fire at this load");
    assert!(
        rep.sim.peak_pending < 50_000,
        "peak pending {} not bounded — open-loop arrivals piled up",
        rep.sim.peak_pending
    );
    assert!(
        rep.sim.cancelled >= requests as u64,
        "every request cancels its guard timer"
    );

    headlines.insert("serving_nodes".into(), Json::from(nodes as u64));
    headlines.insert("serving_requests".into(), Json::from(rep.completed));
    headlines.insert("serving_ttft".into(), rep.ttft.headline_json());
    headlines.insert(
        "serving_peak_pending".into(),
        Json::from(rep.sim.peak_pending),
    );
    headlines.insert(
        "serving_sched_mem_bytes".into(),
        Json::from(rep.approx_mem_bytes as u64),
    );

    if let Some(path) = json_path {
        headlines.insert(
            "provenance".to_string(),
            Json::from("measured by sim_churn (DES, deterministic)"),
        );
        update_report(&path, "sim_churn", Json::Obj(headlines)).expect("write bench report");
        println!("wrote sim_churn section to {path}");
    }
}
