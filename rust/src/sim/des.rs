//! Single-threaded discrete-event executor.
//!
//! # Scheduler design (timer wheel + arena)
//!
//! Events live in a slab **arena** of fixed-size slots with
//! generation-tagged [`EventId`]s: cancelling is O(1) (flag the slot,
//! drop the closure), cancelling an already-fired event is a structural
//! no-op (the generation no longer matches), and fired slots go back on
//! a free list so a 10⁶-request run allocates O(peak-pending) slots,
//! not O(total-events).
//!
//! Scheduling is two-level:
//!
//! * a **near min-heap** ordered by `(time, seq)` holds events in the
//!   current level-0 wheel bucket (and any event scheduled "in the
//!   past" relative to the wheel reference clock);
//! * a **hierarchical timer wheel** — 8 levels × 64 buckets, level-k
//!   bucket span `2^(B0 + 6k)` ns (level 0 ≈ 1.05 ms)
//!   — holds everything further out as intrusive singly-linked slot
//!   chains (no per-bucket allocation), with one occupancy bitmap per
//!   level so finding the next non-empty bucket is a couple of
//!   `trailing_zeros` scans.
//!
//! Buckets cascade toward the heap as virtual time approaches them:
//! draining a level-k bucket re-bins its events at strictly lower
//! levels (or into the heap), so cascades terminate. Because a whole
//! level-0 bucket is poured into the heap *before* any event in it
//! fires, same-timestamp events always meet in the heap where the
//! `(time, seq)` order applies — the determinism contract (ties fire
//! in scheduling order) is identical to the legacy single-heap
//! scheduler, and a differential suite in `sim/legacy.rs`'s tests
//! proves it event-for-event.
//!
//! Closures are stored with **small-thunk inline storage** (same
//! `MaybeUninit` idiom as `util/smallvec.rs`): an `FnOnce` of up to 48
//! bytes is written directly into the slot, so the common
//! `after(delay, ..)` path performs no per-event heap allocation once
//! the arena and heap have warmed up. Bigger closures spill to a `Box`.
//!
//! Components live in `Rc<RefCell<..>>` cells captured by their event
//! closures — the `Sim` itself owns only the clock and the containers.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::mem::{self, MaybeUninit};

use super::time::{Duration, Instant};

/// Identifier of a scheduled event; used to cancel timers.
///
/// Generation-tagged: the id names an arena slot *and* the generation
/// the slot had when the event was scheduled. After the event fires
/// (or its cancellation is reaped) the slot's generation advances, so
/// a stale id can never alias a reused slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId {
    slot: u32,
    gen: u32,
}

/// Scheduler counters surfaced by [`Sim::stats`].
///
/// `peak_pending` is the high-water mark of simultaneously pending
/// (scheduled, not yet fired or cancelled) events — the quantity the
/// arena's memory footprint scales with.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Events ever scheduled (`at`/`after`/`defer`).
    pub scheduled: u64,
    /// Events executed (fired).
    pub executed: u64,
    /// Pending events cancelled. Cancelling an already-fired event is
    /// a no-op and is *not* counted.
    pub cancelled: u64,
    /// High-water mark of pending events.
    pub peak_pending: u64,
}

// ---------------------------------------------------------------------
// Thunk: FnOnce(&mut Sim) with inline storage for small closures.
// ---------------------------------------------------------------------

/// Words of inline closure storage (48 bytes on 64-bit): enough for
/// the typical captured `Rc` + a few scalars on the `after` fast path.
const INLINE_WORDS: usize = 6;

enum Thunk {
    /// No closure (slot free, cancelled, or already taken to fire).
    Empty,
    /// Closure stored inline in the slot; `call` reads it out of `buf`
    /// and invokes it, `drop_fn` drops it in place if never invoked.
    /// Both are monomorphized for the concrete closure type.
    Inline {
        buf: MaybeUninit<[usize; INLINE_WORDS]>,
        // SAFETY: only ever `call_inline::<F>` / `drop_inline::<F>`
        // for the same `F` that `Thunk::new` wrote into `buf`, so the
        // pointee type the callee assumes always matches the buffer.
        call: unsafe fn(*mut u8, &mut Sim),
        drop_fn: unsafe fn(*mut u8),
    },
    /// Closure too big (or over-aligned) for the inline buffer.
    Boxed(Box<dyn FnOnce(&mut Sim)>),
}

// SAFETY: caller must pass a pointer to an initialized `F` (written
// by `Thunk::new`) and must not touch the buffer again — the closure
// is moved out with `ptr::read`, so any later drop of the buffer
// contents would be a double-drop.
unsafe fn call_inline<F: FnOnce(&mut Sim)>(p: *mut u8, sim: &mut Sim) {
    // Moves the closure out of the buffer; the buffer must not be
    // dropped afterwards.
    let f = std::ptr::read(p as *const F);
    f(sim)
}

// SAFETY: caller must pass a pointer to an initialized `F` that was
// never moved out (the thunk was cancelled, not invoked); the value
// is dropped in place exactly once.
unsafe fn drop_inline<F>(p: *mut u8) {
    std::ptr::drop_in_place(p as *mut F)
}

impl Thunk {
    fn new<F: FnOnce(&mut Sim) + 'static>(f: F) -> Self {
        if mem::size_of::<F>() <= INLINE_WORDS * mem::size_of::<usize>()
            && mem::align_of::<F>() <= mem::align_of::<usize>()
        {
            let mut buf = MaybeUninit::<[usize; INLINE_WORDS]>::uninit();
            // SAFETY: the branch above checked size_of::<F>() fits
            // INLINE_WORDS usizes and align_of::<F>() <=
            // align_of::<usize>(), so the usize-aligned buffer can
            // hold `f`; `buf` is fresh, so nothing is overwritten.
            unsafe { std::ptr::write(buf.as_mut_ptr() as *mut F, f) };
            Thunk::Inline {
                buf,
                call: call_inline::<F>,
                drop_fn: drop_inline::<F>,
            }
        } else {
            Thunk::Boxed(Box::new(f))
        }
    }

    /// Invoke the stored closure. Consumes `self` without running the
    /// `Drop` impl (the closure is moved out, not dropped in place).
    fn invoke(self, sim: &mut Sim) {
        let this = mem::ManuallyDrop::new(self);
        // SAFETY: `self` is wrapped in ManuallyDrop, so no Drop glue
        // runs after the closure is moved out of its storage (`call`
        // does ptr::read for Inline, ptr::read(b) for Boxed): each
        // stored closure is read exactly once and never dropped in
        // place afterwards.
        unsafe {
            match &*this {
                Thunk::Empty => {}
                Thunk::Inline { buf, call, .. } => {
                    let call = *call;
                    call(buf.as_ptr() as *mut u8, sim);
                }
                Thunk::Boxed(b) => {
                    let b = std::ptr::read(b);
                    b(sim);
                }
            }
        }
    }
}

impl Drop for Thunk {
    fn drop(&mut self) {
        if let Thunk::Inline { buf, drop_fn, .. } = self {
            let drop_fn = *drop_fn;
            // SAFETY: Drop only runs if `invoke` never consumed the
            // thunk (invoke routes `self` through ManuallyDrop), so
            // `buf` still holds the initialized `F` that `drop_fn`
            // (monomorphized as drop_inline::<F>) expects.
            unsafe { drop_fn(buf.as_mut_ptr() as *mut u8) }
        }
        // Boxed drops its Box via the normal enum drop glue; Empty has
        // nothing to do.
    }
}

// ---------------------------------------------------------------------
// Arena slots and the wheel.
// ---------------------------------------------------------------------

/// Intrusive-list terminator / "no slot" marker.
const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    Free,
    Pending,
    Cancelled,
}

struct Slot {
    at: Instant,
    seq: u64,
    gen: u32,
    state: SlotState,
    /// Intrusive chain: wheel-bucket list while scheduled in the
    /// wheel, free list while free, `NIL` otherwise.
    next: u32,
    thunk: Thunk,
}

/// Wheel levels. Level k buckets span `2^(B0 + 6k)` ns; level 7 spans
/// `2^62` ns per bucket, so any `u64` timestamp fits some level.
const LEVELS: usize = 8;
/// Buckets per level (64 = one occupancy bitmap word).
const BUCKETS: usize = 64;
/// log2 of the level-0 bucket span in ns (2^20 ns ≈ 1.05 ms).
const B0: u32 = 20;

#[inline]
fn shift(level: usize) -> u32 {
    B0 + 6 * level as u32
}

/// The discrete-event simulator: a virtual clock plus an event queue.
pub struct Sim {
    now: Instant,
    seq: u64,
    /// Arena of event slots; never shrinks, grows to peak-pending.
    slots: Vec<Slot>,
    /// Head of the free-slot list (`NIL` when empty).
    free_head: u32,
    /// Near events, ordered by `(at, seq)`; `u32` is the slot index.
    near: BinaryHeap<Reverse<(Instant, u64, u32)>>,
    /// `wheel[k][b]` heads an intrusive chain of slots.
    wheel: Box<[[u32; BUCKETS]; LEVELS]>,
    /// Per-level bucket-occupancy bitmaps.
    occupied: [u64; LEVELS],
    /// Wheel reference clock: every wheel-resident event sits in a
    /// level-0 bucket strictly after `wheel_now`'s. Always <= `now`
    /// except transiently after a `run_until` deadline clamp.
    wheel_now: Instant,
    /// Entries currently chained in wheel buckets.
    wheel_len: usize,
    /// Pending (scheduled, not fired/cancelled) events.
    pending: usize,
    stats: SimStats,
    /// Hard cap on executed events; guards against runaway loops in
    /// misconfigured scenarios (poll loops that never quiesce).
    pub event_limit: u64,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    /// Create an empty simulator at t = 0.
    pub fn new() -> Self {
        Sim {
            now: 0,
            seq: 0,
            slots: Vec::new(),
            free_head: NIL,
            near: BinaryHeap::new(),
            wheel: Box::new([[NIL; BUCKETS]; LEVELS]),
            occupied: [0; LEVELS],
            wheel_now: 0,
            wheel_len: 0,
            pending: 0,
            stats: SimStats::default(),
            event_limit: u64::MAX,
        }
    }

    /// Current virtual time.
    #[inline]
    pub fn now(&self) -> Instant {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.stats.executed
    }

    /// Scheduler counters: scheduled/executed/cancelled and the
    /// pending-depth high-water mark.
    pub fn stats(&self) -> SimStats {
        self.stats
    }

    /// Events currently pending (scheduled, not yet fired or
    /// cancelled).
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Arena slots allocated so far. Grows to the peak number of
    /// simultaneously live (pending + not-yet-reaped cancelled)
    /// events and then stays flat — the memory-budget check in the
    /// `sim_churn` bench asserts against this.
    pub fn arena_slots(&self) -> usize {
        self.slots.len()
    }

    /// Rough resident footprint of the scheduler containers in bytes
    /// (arena + heap capacity; excludes spilled boxed closures).
    pub fn approx_mem_bytes(&self) -> usize {
        mem::size_of::<Self>()
            + mem::size_of::<[[u32; BUCKETS]; LEVELS]>()
            + self.slots.capacity() * mem::size_of::<Slot>()
            + self.near.capacity() * mem::size_of::<Reverse<(Instant, u64, u32)>>()
    }

    /// Schedule `f` to run at absolute virtual time `at`.
    ///
    /// Scheduling in the past is clamped to `now` (fires next).
    pub fn at(&mut self, at: Instant, f: impl FnOnce(&mut Sim) + 'static) -> EventId {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        let (idx, gen) = self.alloc_slot(at, seq, Thunk::new(f));
        self.insert(idx, at, seq);
        self.pending += 1;
        self.stats.scheduled += 1;
        if self.pending as u64 > self.stats.peak_pending {
            self.stats.peak_pending = self.pending as u64;
        }
        EventId { slot: idx, gen }
    }

    /// Schedule `f` to run `delay` ns from now.
    pub fn after(&mut self, delay: Duration, f: impl FnOnce(&mut Sim) + 'static) -> EventId {
        let at = self.now.saturating_add(delay);
        self.at(at, f)
    }

    /// Schedule `f` to run at the current instant, after already-queued
    /// same-time events.
    pub fn defer(&mut self, f: impl FnOnce(&mut Sim) + 'static) -> EventId {
        self.at(self.now, f)
    }

    /// Cancel a pending event in O(1). Cancelling an already-fired (or
    /// already-cancelled) event is a no-op — the slot generation no
    /// longer matches, so no state grows, no matter how often stale
    /// ids are re-cancelled.
    ///
    /// The closure is dropped immediately; the slot itself is reaped
    /// (and reused) when the scheduler next reaches its timestamp.
    pub fn cancel(&mut self, id: EventId) {
        if let Some(slot) = self.slots.get_mut(id.slot as usize) {
            if slot.gen == id.gen && slot.state == SlotState::Pending {
                slot.state = SlotState::Cancelled;
                slot.thunk = Thunk::Empty;
                self.pending -= 1;
                self.stats.cancelled += 1;
            }
        }
    }

    /// Run until the event queue is empty. Returns the final time.
    pub fn run(&mut self) -> Instant {
        self.run_until(Instant::MAX)
    }

    /// Run events with `at <= deadline`. The clock never advances past
    /// `deadline` even if later events remain queued.
    pub fn run_until(&mut self, deadline: Instant) -> Instant {
        while let Some((at, _seq, idx)) = self.settle_min() {
            if at > deadline {
                self.now = self.now.max(deadline.min(at));
                break;
            }
            self.near.pop();
            let slot = &mut self.slots[idx as usize];
            if slot.state == SlotState::Cancelled {
                self.free_slot(idx);
                continue;
            }
            let thunk = mem::replace(&mut slot.thunk, Thunk::Empty);
            self.free_slot(idx);
            self.pending -= 1;
            self.now = at;
            self.stats.executed += 1;
            if self.stats.executed > self.event_limit {
                panic!(
                    "sim event limit ({}) exceeded at t={} — runaway loop?",
                    self.event_limit, self.now
                );
            }
            thunk.invoke(self);
        }
        self.now
    }

    /// True if no events remain (pending or cancelled-but-unreaped).
    pub fn idle(&self) -> bool {
        self.near.is_empty() && self.wheel_len == 0
    }

    // -- internals ----------------------------------------------------

    /// Grab a slot from the free list (or grow the arena) and fill it.
    fn alloc_slot(&mut self, at: Instant, seq: u64, thunk: Thunk) -> (u32, u32) {
        if self.free_head != NIL {
            let idx = self.free_head;
            let slot = &mut self.slots[idx as usize];
            self.free_head = slot.next;
            slot.at = at;
            slot.seq = seq;
            slot.state = SlotState::Pending;
            slot.next = NIL;
            slot.thunk = thunk;
            (idx, slot.gen)
        } else {
            assert!(self.slots.len() < NIL as usize, "sim arena full");
            let idx = self.slots.len() as u32;
            self.slots.push(Slot {
                at,
                seq,
                gen: 0,
                state: SlotState::Pending,
                next: NIL,
                thunk,
            });
            (idx, 0)
        }
    }

    /// Bump the generation and return the slot to the free list. The
    /// caller must already have detached it from heap/bucket chains.
    fn free_slot(&mut self, idx: u32) {
        let head = self.free_head;
        let slot = &mut self.slots[idx as usize];
        slot.gen = slot.gen.wrapping_add(1);
        slot.state = SlotState::Free;
        slot.thunk = Thunk::Empty;
        slot.next = head;
        self.free_head = idx;
    }

    /// Place a slot into the near heap or the right wheel bucket.
    fn insert(&mut self, idx: u32, at: Instant, seq: u64) {
        // Same level-0 bucket as the wheel clock (or earlier): the
        // event is "near" — heap, where (at, seq) ordering applies.
        if at >> B0 <= self.wheel_now >> B0 {
            self.near.push(Reverse((at, seq, idx)));
            return;
        }
        // Lowest level where the event lands within the 64-bucket
        // window ahead of the wheel clock. Level 7 always fits
        // (bucket numbers there are at most 3 apart).
        let mut k = 0;
        while k + 1 < LEVELS && (at >> shift(k)) - (self.wheel_now >> shift(k)) >= BUCKETS as u64 {
            k += 1;
        }
        let b = ((at >> shift(k)) & (BUCKETS as u64 - 1)) as usize;
        let slot = &mut self.slots[idx as usize];
        slot.next = self.wheel[k][b];
        self.wheel[k][b] = idx;
        self.occupied[k] |= 1u64 << b;
        self.wheel_len += 1;
    }

    /// Earliest occupied wheel bucket as `(level, bucket, start_time)`.
    /// Caller must ensure `wheel_len > 0`.
    fn earliest_bucket(&self) -> (usize, usize, Instant) {
        let mut best: Option<(usize, usize, Instant)> = None;
        for k in 0..LEVELS {
            let occ = self.occupied[k];
            if occ == 0 {
                continue;
            }
            let sh = shift(k);
            let pos = (self.wheel_now >> sh) & (BUCKETS as u64 - 1);
            // Occupied buckets sit at true bucket-number distance
            // 0..=63 ahead of the wheel clock, so the wrapped distance
            // recovers the absolute bucket number exactly.
            let mut min_dist = u64::MAX;
            let mut min_b = 0usize;
            let mut bits = occ;
            while bits != 0 {
                let b = bits.trailing_zeros() as u64;
                bits &= bits - 1;
                let dist = b.wrapping_sub(pos) & (BUCKETS as u64 - 1);
                if dist < min_dist {
                    min_dist = dist;
                    min_b = b as usize;
                }
            }
            let start = ((self.wheel_now >> sh) + min_dist) << sh;
            match best {
                Some((_, _, s)) if s <= start => {}
                _ => best = Some((k, min_b, start)),
            }
        }
        best.expect("earliest_bucket called with empty wheel")
    }

    /// Drain bucket `(k, b)` and re-bin its events relative to the
    /// advanced wheel clock. Level-0 events pour into the near heap;
    /// higher-level events re-bin at strictly lower levels, so
    /// cascades terminate.
    fn cascade(&mut self, k: usize, b: usize, start: Instant) {
        if start > self.wheel_now {
            self.wheel_now = start;
        }
        let mut head = mem::replace(&mut self.wheel[k][b], NIL);
        self.occupied[k] &= !(1u64 << b);
        while head != NIL {
            let idx = head;
            let slot = &mut self.slots[idx as usize];
            head = slot.next;
            slot.next = NIL;
            let (at, seq) = (slot.at, slot.seq);
            self.wheel_len -= 1;
            // Cancelled slots keep flowing toward the heap so their
            // timestamps still participate in `run_until` deadline
            // checks (matching the legacy scheduler event-for-event);
            // they are reaped when popped.
            self.insert(idx, at, seq);
        }
    }

    /// Cascade until the globally-earliest entry (pending or
    /// cancelled) is at the top of the near heap, and return its key
    /// without popping. `None` when no entries remain.
    fn settle_min(&mut self) -> Option<(Instant, u64, u32)> {
        loop {
            let heap_min = self.near.peek().map(|&Reverse(e)| e);
            if self.wheel_len == 0 {
                return heap_min;
            }
            let (k, b, start) = self.earliest_bucket();
            if let Some(e) = heap_min {
                // Strict: on a tie the bucket cascades first so
                // same-timestamp events meet in the heap and fire in
                // seq order.
                if e.0 < start {
                    return Some(e);
                }
            }
            self.cascade(k, b, start);
        }
    }
}

/// Cloneable handle used by components to schedule follow-up events from
/// outside an event callback (e.g. API-facing wrappers in the DES
/// harness). It is a thin marker today; kept for API symmetry with the
/// threaded runtime.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimHandle;

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for &t in &[30u64, 10, 20] {
            let log = log.clone();
            sim.at(t, move |s| log.borrow_mut().push((s.now(), t)));
        }
        sim.run();
        assert_eq!(*log.borrow(), vec![(10, 10), (20, 20), (30, 30)]);
    }

    #[test]
    fn ties_fire_in_schedule_order() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..5 {
            let log = log.clone();
            sim.at(42, move |_| log.borrow_mut().push(i));
        }
        sim.run();
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn ties_fire_in_schedule_order_across_wheel() {
        // Same timestamp far enough out to land in the wheel; events
        // must still fire in scheduling order after cascading.
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        let t = 17 * super::super::time::MS + 123;
        for i in 0..16 {
            let log = log.clone();
            sim.at(t, move |_| log.borrow_mut().push(i));
        }
        sim.run();
        assert_eq!(*log.borrow(), (0..16).collect::<Vec<_>>());
        assert_eq!(sim.now(), t);
    }

    #[test]
    fn cascading_events() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        let h = hits.clone();
        sim.at(5, move |s| {
            *h.borrow_mut() += 1;
            let h2 = h.clone();
            s.after(10, move |_| *h2.borrow_mut() += 1);
        });
        let end = sim.run();
        assert_eq!(*hits.borrow(), 2);
        assert_eq!(end, 15);
    }

    #[test]
    fn cancel_pending() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        let h = hits.clone();
        let id = sim.at(5, move |_| *h.borrow_mut() += 1);
        sim.cancel(id);
        sim.run();
        assert_eq!(*hits.borrow(), 0);
    }

    #[test]
    fn run_until_stops_at_deadline() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        for t in [1u64, 2, 3, 100] {
            let h = hits.clone();
            sim.at(t, move |_| *h.borrow_mut() += 1);
        }
        sim.run_until(10);
        assert_eq!(*hits.borrow(), 3);
        assert!(!sim.idle());
        sim.run();
        assert_eq!(*hits.borrow(), 4);
        assert_eq!(sim.now(), 100);
    }

    #[test]
    fn past_schedule_clamps_to_now() {
        let mut sim = Sim::new();
        let t = Rc::new(RefCell::new(0u64));
        let tc = t.clone();
        sim.at(50, move |s| {
            let tc2 = tc.clone();
            s.at(1, move |s2| *tc2.borrow_mut() = s2.now());
        });
        sim.run();
        assert_eq!(*t.borrow(), 50);
    }

    #[test]
    #[should_panic(expected = "event limit")]
    fn event_limit_trips() {
        let mut sim = Sim::new();
        sim.event_limit = 10;
        fn rearm(s: &mut Sim) {
            s.after(1, rearm);
        }
        sim.after(1, rearm);
        sim.run();
    }

    #[test]
    fn far_future_events_fire_in_order() {
        // Spread across every wheel level: µs, ms, seconds, minutes,
        // hours of virtual time.
        use super::super::time::{MS, SEC, US};
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        let times = [
            3 * US,
            900 * US,
            2 * MS,
            70 * MS,
            900 * MS,
            3 * SEC,
            95 * SEC,
            3700 * SEC,
            90_000 * SEC,
        ];
        let mut shuffled = times;
        shuffled.reverse();
        for &t in &shuffled {
            let log = log.clone();
            sim.at(t, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run();
        assert_eq!(*log.borrow(), times.to_vec());
        assert_eq!(sim.now(), *times.last().unwrap());
    }

    #[test]
    fn cancel_far_future_event() {
        use super::super::time::SEC;
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        let h = hits.clone();
        let id = sim.at(100 * SEC, move |_| *h.borrow_mut() += 1);
        let h2 = hits.clone();
        sim.at(50 * SEC, move |_| *h2.borrow_mut() += 10);
        sim.cancel(id);
        sim.run();
        assert_eq!(*hits.borrow(), 10);
        assert_eq!(sim.stats().cancelled, 1);
    }

    #[test]
    fn cancel_fired_events_is_bounded() {
        // Regression for the legacy tombstone leak: cancelling
        // already-fired events over and over must not grow any state.
        let mut sim = Sim::new();
        let mut ids = Vec::new();
        for i in 0..100u64 {
            ids.push(sim.at(i, |_| {}));
        }
        sim.run();
        let base_mem = sim.approx_mem_bytes();
        for _ in 0..1000 {
            for &id in &ids {
                sim.cancel(id);
            }
        }
        assert_eq!(sim.stats().cancelled, 0, "fired-event cancel must be a no-op");
        assert_eq!(sim.approx_mem_bytes(), base_mem);
        assert!(sim.idle());
    }

    #[test]
    fn slots_are_reused_across_sequential_events() {
        // 10k fire-then-schedule cycles must not grow the arena past
        // the peak pending depth (here: a handful of slots).
        let mut sim = Sim::new();
        fn chain(s: &mut Sim, left: u32) {
            if left > 0 {
                s.after(10, move |s| chain(s, left - 1));
            }
        }
        chain(&mut sim, 10_000);
        sim.run();
        assert_eq!(sim.stats().executed, 10_000);
        assert!(
            sim.arena_slots() <= 4,
            "arena grew to {} slots for a 1-pending workload",
            sim.arena_slots()
        );
    }

    #[test]
    fn stale_id_does_not_cancel_reused_slot() {
        let mut sim = Sim::new();
        let fired = Rc::new(RefCell::new(Vec::new()));
        let f = fired.clone();
        let id_a = sim.at(1, move |_| f.borrow_mut().push('a'));
        sim.run();
        // Slot of `id_a` is free now; the next event reuses it.
        let f = fired.clone();
        let id_b = sim.at(2, move |_| f.borrow_mut().push('b'));
        assert_ne!(id_a, id_b);
        sim.cancel(id_a); // stale: must NOT cancel b
        sim.run();
        assert_eq!(*fired.borrow(), vec!['a', 'b']);
    }

    #[test]
    fn stats_counters_track() {
        let mut sim = Sim::new();
        let id = sim.at(5, |_| {});
        sim.at(6, |_| {});
        sim.at(7, |_| {});
        assert_eq!(sim.stats().scheduled, 3);
        assert_eq!(sim.pending(), 3);
        sim.cancel(id);
        assert_eq!(sim.pending(), 2);
        sim.run();
        let st = sim.stats();
        assert_eq!(st.scheduled, 3);
        assert_eq!(st.executed, 2);
        assert_eq!(st.cancelled, 1);
        assert_eq!(st.peak_pending, 3);
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn run_until_deadline_with_only_far_events() {
        use super::super::time::SEC;
        let mut sim = Sim::new();
        sim.at(10 * SEC, |_| {});
        let t = sim.run_until(3 * SEC);
        assert_eq!(t, 3 * SEC);
        assert!(!sim.idle());
        sim.run();
        assert_eq!(sim.now(), 10 * SEC);
    }

    #[test]
    fn large_closures_spill_to_box() {
        // A closure capturing > 48 bytes must still work (boxed path).
        let mut sim = Sim::new();
        let big = [7u64; 16];
        let sum = Rc::new(RefCell::new(0u64));
        let s2 = sum.clone();
        sim.at(1, move |_| *s2.borrow_mut() = big.iter().sum());
        sim.run();
        assert_eq!(*sum.borrow(), 112);
    }
}
