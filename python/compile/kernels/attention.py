"""Pallas kernel: single-token decode attention over the KV cache.

One grid step per (batch, head): the query row stays resident in VMEM
while K/V panels stream from HBM; softmax runs in f32 (numerically
safe for long prefixes). The cache is padded to `max_seq`; a validity
count masks padded rows to -inf inside the kernel, so the same static
HLO serves every sequence length — required for AOT export.

Grid/BlockSpec choices (TPU idiom, not a CUDA port): a warp-per-row
reduction in the paper's CUDA world becomes a lane-dimension reduction
over the VMEM tile here.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(n_valid_ref, q_ref, k_ref, v_ref, o_ref):
    # q_ref: [1, Dh]; k_ref/v_ref: [1, S, Dh]; n_valid_ref: [1].
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    n_valid = n_valid_ref[0]
    s = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [S]
    idx = jax.lax.broadcasted_iota(jnp.int32, (s,), 0)
    scores = jnp.where(idx < n_valid, scores, NEG_INF)
    m = jnp.max(scores)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p)
    o = jnp.dot(p, v, preferred_element_type=jnp.float32) / denom  # [Dh]
    o_ref[0] = o.astype(o_ref.dtype)


@jax.jit
def decode_attention(q, k, v, n_valid=None):
    """Decode attention via Pallas.

    Args:
      q: [B, H, Dh]; k, v: [B, H, S, Dh].
      n_valid: scalar i32 — number of valid cache rows (defaults to S).

    Returns:
      [B, H, Dh], dtype of ``q``.
    """
    b, h, dh = q.shape
    s = k.shape[2]
    if n_valid is None:
        n_valid = jnp.asarray(s, jnp.int32)
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b * h,))
    qf = q.reshape(b * h, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    out = pl.pallas_call(
        _kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, dh), q.dtype),
        interpret=True,
    )(nv, qf, kf, vf)
    return out.reshape(b, h, dh)
