//! Paper Fig 12: separate send/receive kernel latencies at EP=64.
//!
//! Measured by letting transfers settle in the gap between the send
//! and receive halves (a long artificial delay stands in for shared
//! experts / overlapped work), then reporting kernel execution times.
//!
//! Usage: cargo bench --bench moe_send_recv [-- --fast]

use fabric_lib::apps::moe::{run_decode_epoch, MoeConfig, MoeImpl};
use fabric_lib::fabric::profile::NicProfile;
use fabric_lib::sim::stats::Histogram;
use fabric_lib::sim::time::US;
use fabric_lib::util::table::{f, Table};

fn p50_us(h: &mut Histogram) -> String {
    f(h.percentile(50.0) as f64 / 1000.0, 1)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let iters = if fast { 2 } else { 6 };
    let ranks = if fast { 16 } else { 64 };

    let mut t = Table::new(
        &format!("Figure 12. Send and receive kernel latency, EP={ranks} (p50, us)"),
        &["impl", "disp send", "disp recv", "comb send", "comb recv"],
    );
    for (imp, nic, nics, name) in [
        (MoeImpl::Ours, NicProfile::connectx7(), 1u8, "ours CX7"),
        (MoeImpl::DeepEp, NicProfile::connectx7(), 1, "DeepEP CX7"),
        (MoeImpl::Ours, NicProfile::efa(), 2, "ours EFA"),
    ] {
        let mut cfg = MoeConfig::decode(ranks, 128);
        // Long artificial gap so transfers settle before receive.
        cfg.gemm_gap_ns = 400 * US;
        let mut lat = run_decode_epoch(&cfg, imp, nic, nics, iters);
        t.row(&[
            name.to_string(),
            p50_us(&mut lat.d_send_kernel),
            p50_us(&mut lat.d_recv_kernel),
            p50_us(&mut lat.c_send_kernel),
            p50_us(&mut lat.c_recv_kernel),
        ]);
    }
    t.print();
    println!(
        "\npaper — dispatch/combine send outperform DeepEP (memcpy only); \
         combine receive faster (pipelined accumulation); dispatch receive \
         is the outlier (NVLink loads). Kernel time ≲15% of transfer time.\n"
    );
}
