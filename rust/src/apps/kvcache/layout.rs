//! KvCache memory layout.
//!
//! The cache region on each rank is a dense array of pages:
//! `page(layer, slot)` at `(layer * n_slots + slot) * page_bytes`.
//! Within a page, heads precede tokens ("heads preceding the pages",
//! §4) so per-head slices of consecutive tokens stay contiguous and
//! GQA resharding can use page-wise offsets/strides with large
//! individual writes.

/// Layout parameters of a paged KvCache.
#[derive(Debug, Clone)]
pub struct KvLayout {
    /// Bytes of one KV page (one layer's K+V for `tokens_per_page`
    /// tokens), e.g. 32 KiB.
    pub page_bytes: u64,
    /// Tokens covered by one page (e.g. 128).
    pub tokens_per_page: u32,
    /// Transformer layers.
    pub layers: u32,
    /// Page slots available per layer on a rank.
    pub slots_per_layer: u32,
}

impl KvLayout {
    /// Total region size in bytes.
    pub fn region_bytes(&self) -> u64 {
        self.page_bytes * self.layers as u64 * self.slots_per_layer as u64
    }

    /// Pages needed for a sequence of `tokens`.
    pub fn pages_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.tokens_per_page)
    }

    /// Byte offset of `(layer, slot)`.
    pub fn page_offset(&self, layer: u32, slot: u32) -> u64 {
        debug_assert!(layer < self.layers);
        debug_assert!(slot < self.slots_per_layer);
        (layer as u64 * self.slots_per_layer as u64 + slot as u64) * self.page_bytes
    }

    /// Page index (for `Pages::indices`) of `(layer, slot)` given the
    /// uniform stride `page_bytes`.
    pub fn page_index(&self, layer: u32, slot: u32) -> u32 {
        layer * self.slots_per_layer + slot
    }

    /// Expected number of WRITEIMMs for a request: one per page per
    /// layer plus one tail write (Appendix A:
    /// `len(page_indices) * n_layers + 1`).
    pub fn expected_imms(&self, tokens: u32) -> u32 {
        self.pages_for(tokens) * self.layers + 1
    }

    /// GQA resharding (§4): select the slice of a page belonging to
    /// head group `group` of `groups` under a heads-before-tokens
    /// page layout. Because heads precede the pages, the slice of
    /// consecutive heads is contiguous — one (offset, len) per page,
    /// keeping individual writes large.
    ///
    /// Returns (byte offset within the page, byte length).
    pub fn gqa_slice(&self, group: u32, groups: u32) -> (u64, u64) {
        assert!(groups > 0 && group < groups);
        assert_eq!(
            self.page_bytes % groups as u64,
            0,
            "page must split evenly across head groups"
        );
        let len = self.page_bytes / groups as u64;
        (group as u64 * len, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout {
            page_bytes: 32 * 1024,
            tokens_per_page: 128,
            layers: 4,
            slots_per_layer: 64,
        }
    }

    #[test]
    fn page_math() {
        let l = layout();
        assert_eq!(l.pages_for(1), 1);
        assert_eq!(l.pages_for(128), 1);
        assert_eq!(l.pages_for(129), 2);
        assert_eq!(l.pages_for(4096), 32);
        assert_eq!(l.region_bytes(), 32 * 1024 * 4 * 64);
    }

    #[test]
    fn offsets_disjoint_across_layers() {
        let l = layout();
        let a = l.page_offset(0, 63);
        let b = l.page_offset(1, 0);
        assert_eq!(b - a, l.page_bytes);
        assert_eq!(l.page_index(2, 5), 2 * 64 + 5);
    }

    #[test]
    fn gqa_slices_tile_the_page_contiguously() {
        let l = layout();
        let groups = 4;
        let mut cursor = 0;
        for g in 0..groups {
            let (off, len) = l.gqa_slice(g, groups);
            assert_eq!(off, cursor, "heads-before-pages keeps slices contiguous");
            cursor += len;
        }
        assert_eq!(cursor, l.page_bytes);
    }

    #[test]
    fn imm_count_matches_appendix_a() {
        let l = layout();
        // 4096 tokens => 32 pages, 4 layers => 32*4 + 1.
        assert_eq!(l.expected_imms(4096), 129);
        assert_eq!(l.expected_imms(1), l.layers + 1);
    }
}
