//! Scenario harness for the KvCache app: builds a prefiller/decoder
//! pair and reproduces paper Table 3 rows.
//!
//! Entry points:
//!
//! * [`run_table3_row_on`] — the full Table-3 scenario over any
//!   runtime: `&mut Cx` + two `Rc<dyn TransferEngine>` peers, with the
//!   GPU side scheduled on the runtime-neutral
//!   [`crate::engine::model::ComputeModel`]. Timing-faithful on the
//!   DES runtime; structurally identical (same pages, steps, writes)
//!   on the threaded runtime.
//! * [`run_table3_row`] — convenience wrapper reproducing the paper's
//!   H200+2×EFA testbed on a DES [`Cluster`] (what the bench and the
//!   numeric tests use).
//! * [`run_generic_kv_push`] — the bare KvCache *transfer protocol*
//!   (paged WRITEIMMs + tail write counted by `expect_imm_count`,
//!   Appendix A) over `&dyn TransferEngine`, as a protocol smoke test.

use std::rc::Rc;

use crate::engine::api::Pages;
use crate::engine::model::ComputeModel;
use crate::engine::traits::{
    expect_flag, Cluster, Cx, Notify, RuntimeKind, TransferEngine,
};
use crate::fabric::profile::GpuProfile;
use crate::fabric::topology::ClusterSpec;
use crate::sim::time::{Instant, MS};

use super::decoder::Decoder;
use super::prefiller::Prefiller;
use super::workload::ServingWorkload;

/// One Table 3 row: TTFT and per-layer breakdown.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub seq: u32,
    /// Non-disaggregated TTFT (prefill + first decode pass), ms.
    pub ttft_non_ms: f64,
    /// Disaggregated TTFT, ms.
    pub ttft_disagg_ms: f64,
    /// Mean per-layer compute of the last chunk, ms.
    pub per_layer_compute_ms: f64,
    /// Mean per-layer transfer time, ms.
    pub per_layer_transfer_ms: f64,
    /// Chunked-prefill steps.
    pub steps: u32,
    /// Pages transferred per layer (capped at chunk size).
    pub pages: u32,
    /// Total WRITEs the prefiller issued (runtime-independent).
    pub writes: u64,
}

/// Run one disaggregated request of `seq` tokens on whatever runtime
/// backs `cx`: the prefiller on `eng_p`, the decoder on `eng_d`, GPU
/// kernels timed by `gpu_profile` through the compute model.
pub fn run_table3_row_on(
    cx: &mut Cx,
    eng_p: Rc<dyn TransferEngine>,
    eng_d: Rc<dyn TransferEngine>,
    gpu_profile: GpuProfile,
    seq: u32,
) -> Table3Row {
    let workload = ServingWorkload::qwen3_235b(seq);
    let compute = ComputeModel::new(gpu_profile);

    let prefiller = Prefiller::new(cx, eng_p.clone(), 0, &compute, workload.clone(), 0);
    let decoder = Decoder::new(cx, eng_d.clone(), 0, workload.clone());

    let input: Vec<u32> = (0..seq).map(|i| i % 1000).collect();
    decoder.submit_request(cx, &eng_p.group_address(0), input, 1);
    let reports = decoder.reports();
    {
        let reports = reports.clone();
        cx.drive_until("table3 request completion", move || {
            reports.borrow().len() == 1
        });
    }
    let reports = reports.borrow();
    let r = reports[0];

    // Non-disaggregated reference: same compute model, no transfer, no
    // extra decode pass for the final input token.
    let ttft_non: Instant = workload.total_prefill_ns(seq);

    let stats = prefiller.stats();
    let stats = stats.borrow();
    let mean_transfer = stats
        .layer_transfers
        .iter()
        .map(|&(s, e)| (e - s) as f64)
        .sum::<f64>()
        / stats.layer_transfers.len().max(1) as f64;
    // Last chunk's per-layer compute (the paper reports the steady
    // chunk).
    let last_layer_compute = *stats.layer_compute.last().unwrap() as f64;

    let chunks = workload.chunks(seq);
    let last_chunk_tokens = chunks.last().unwrap().1;
    Table3Row {
        seq,
        ttft_non_ms: ttft_non as f64 / MS as f64,
        // Relative to request submission: on DES the request starts at
        // t=0, on the threaded runtime the reactor epoch includes
        // cluster/scenario setup (and reuse on one cluster starts
        // mid-clock), so the absolute reading would be wrong there.
        ttft_disagg_ms: r.ttft.saturating_sub(r.submitted) as f64 / MS as f64,
        per_layer_compute_ms: last_layer_compute / MS as f64,
        per_layer_transfer_ms: mean_transfer / MS as f64,
        steps: chunks.len() as u32,
        pages: workload.layout.pages_for(last_chunk_tokens),
        writes: stats.writes,
    }
}

/// Simulate one disaggregated request of `seq` tokens on an
/// H200+2×EFA pair (paper Table 3 testbed) and report the row — the
/// timing-faithful DES convenience wrapper around
/// [`run_table3_row_on`].
pub fn run_table3_row(seq: u32) -> Table3Row {
    let spec = ClusterSpec::h200_efa(2);
    let mut cluster = Cluster::new_with(
        RuntimeKind::Des,
        2,
        1,
        spec.nics_per_gpu,
        spec.seed,
        spec.nic_profile.clone(),
        spec.gpu_profile.clone(),
    );
    let engines = cluster.engines_rc();
    let row = {
        let (mut cx, _) = cluster.parts();
        run_table3_row_on(
            &mut cx,
            engines[0].clone(),
            engines[1].clone(),
            spec.gpu_profile.clone(),
            seq,
        )
    };
    cluster.shutdown();
    row
}

/// Runtime-agnostic KV-cache page push (the §4 transfer protocol):
/// the prefiller writes `n_pages` KV pages into decoder-chosen page
/// slots with per-page WRITEIMMs plus one tail write, and the decoder
/// is notified by a single `expect_imm_count(imm, n_pages + 1)` — no
/// ordering assumptions anywhere. The prefiller↔decoder pair is a
/// long-lived peer relationship, so the transfer runs on the §3.5
/// templated path: the decoder's KV and tail regions are bound to a
/// peer group once, and each push patches only page indices/offsets.
/// Asserts payload placement and that the satisfied expectation
/// retired its counter slot.
pub fn run_generic_kv_push(
    cx: &mut Cx,
    prefiller: &dyn TransferEngine,
    decoder: &dyn TransferEngine,
    n_pages: u32,
    page_len: u64,
) {
    let kv_bytes = (n_pages as u64 * page_len) as usize;
    let (kv_src, _) = prefiller.alloc_mr(0, kv_bytes);
    let (kv_dst_h, kv_dst_d) = decoder.alloc_mr(0, kv_bytes);
    let (tail_src, _) = prefiller.alloc_mr(0, 256);
    let (tail_dst_h, tail_dst_d) = decoder.alloc_mr(0, 256);
    for p in 0..n_pages {
        let fill = (p % 249) as u8 + 1;
        kv_src
            .buf
            .write((p as u64 * page_len) as usize, &vec![fill; page_len as usize]);
    }
    tail_src.buf.write(0, b"tail context");

    // Session setup, once per prefiller↔decoder pair: register the
    // decoder twice (KV region, tail region) and pre-template both
    // destinations' routes. Templates bind one region per peer ENTRY,
    // so multi-region peers repeat; this group never runs a templated
    // barrier (which fans out per entry), the KV protocol gates on
    // write immediates instead.
    let group = prefiller.add_peer_group(vec![decoder.main_address(), decoder.main_address()]);
    prefiller
        .bind_peer_group_mrs(0, group, &[kv_dst_d.clone(), tail_dst_d.clone()])
        .expect("decoder regions bind");
    const KV: usize = 0; // peer index of the KV region
    const TAIL: usize = 1; // peer index of the tail region

    // Decoder side: allocate page slots (reversed here, as a stand-in
    // for scheduler-chosen placement) and register the expectation
    // BEFORE any data can arrive.
    let imm = 0x4B50; // request-scoped immediate ("KV push")
    let dst_slots: Vec<u32> = (0..n_pages).rev().collect();
    let transferred = expect_flag(decoder, cx, 0, imm, n_pages + 1);

    // Prefiller side: paged KV writes + the tail write, all carrying
    // the request's immediate, all patched into the bound template.
    prefiller
        .submit_paged_writes_templated(
            cx,
            page_len,
            (&kv_src, &Pages::contiguous(0, n_pages, page_len)),
            group,
            KV,
            &Pages { indices: dst_slots.clone(), stride: page_len, offset: 0 },
            Some(imm),
            Notify::Noop,
        )
        .expect("templated paged push");
    prefiller
        .submit_single_write_templated(cx, (&tail_src, 0), 12, group, TAIL, 0, Some(imm), Notify::Noop)
        .expect("templated tail write");
    cx.wait(&transferred);

    // Payload placement: source page i landed in slot dst_slots[i].
    let v = kv_dst_h.buf.to_vec();
    for (i, &slot) in dst_slots.iter().enumerate() {
        let off = (slot as u64 * page_len) as usize;
        let fill = (i as u32 % 249) as u8 + 1;
        assert!(
            v[off..off + page_len as usize].iter().all(|&b| b == fill),
            "page {i} corrupted in slot {slot}"
        );
    }
    assert_eq!(&tail_dst_h.buf.to_vec()[..12], b"tail context");
    // The satisfied expectation retired the counter slot (free_imm
    // semantics): a fresh request may reuse the immediate.
    assert_eq!(decoder.imm_value(0, imm), 0);
    // Session teardown frees the group; the stale handle then fails
    // loudly instead of reusing freed template state.
    assert!(prefiller.remove_peer_group(group));
    assert!(prefiller
        .submit_single_write_templated(cx, (&tail_src, 0), 1, group, TAIL, 0, None, Notify::Noop)
        .is_err());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::traits::run_on_both;

    #[test]
    fn generic_kv_push_runs_on_both_runtimes() {
        run_on_both(2, 1, 2, 0x4B5, |cx, engines| {
            run_generic_kv_push(cx, engines[0], engines[1], 16, 1024);
        });
    }

    #[test]
    fn table3_row_4k_shape() {
        let row = run_table3_row(4096);
        // Paper: non-disagg 214 ms, disagg 260 ms, per-layer compute
        // 2.267 ms, transfer 0.661 ms, 1 step, 32 pages (paper's 256
        // pages count is per 4 TP ranks at a finer page grain; ours is
        // seq/128). Check shape, not equality.
        assert_eq!(row.steps, 1);
        assert_eq!(row.pages, 32);
        assert!(row.ttft_non_ms > 100.0 && row.ttft_non_ms < 400.0, "{row:?}");
        assert!(row.ttft_disagg_ms > row.ttft_non_ms, "disagg pays an extra pass");
        let overhead = row.ttft_disagg_ms / row.ttft_non_ms;
        assert!(overhead < 1.4, "overhead must stay small: {row:?}");
        assert!(
            row.per_layer_transfer_ms < row.per_layer_compute_ms,
            "transfer hidden by compute: {row:?}"
        );
        // One paged write per (chunk, layer): 1 step × 94 layers × 32
        // pages each.
        assert_eq!(row.writes, 94 * 32);
    }

    #[test]
    fn table3_overhead_shrinks_with_seqlen() {
        let short = run_table3_row(4096);
        let long = run_table3_row(32768);
        let o_short = short.ttft_disagg_ms / short.ttft_non_ms;
        let o_long = long.ttft_disagg_ms / long.ttft_non_ms;
        assert!(
            o_long < o_short,
            "relative TTFT overhead must shrink with seqlen: {o_short} vs {o_long}"
        );
        assert_eq!(long.steps, 2);
    }
}
