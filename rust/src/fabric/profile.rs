//! Hardware profiles and the calibrated cost model.
//!
//! Every timing constant used by the simulated fabric lives here, with
//! its calibration source. Targets come from the paper's own
//! measurements (Table 2: op/s caps and saturation points; Table 8/9:
//! posting overheads; §6.2: ~15 µs launch-to-first-transfer) and
//! published hardware characteristics (sub-µs RDMA wire latency,
//! 2–5 µs PCIe/GDRCopy visibility).

use crate::sim::rng::Jitter;
use crate::sim::time::{Duration, US};

/// Which transport family a NIC speaks. This drives ordering semantics
/// throughout the fabric and the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TransportKind {
    /// Reliable Connection: connection-oriented, reliable, **in-order**
    /// delivery per queue pair (ConnectX-style, libibverbs).
    Rc,
    /// Scalable Reliable Datagram: connectionless, reliable,
    /// **out-of-order** delivery with packet spraying (EFA-style,
    /// libfabric).
    Srd,
}

impl TransportKind {
    /// Capability row, mirroring the paper's Table 1.
    pub fn capabilities(self) -> Capabilities {
        match self {
            TransportKind::Rc => Capabilities {
                reliable: true,
                ordered: true,
                connection_oriented: true,
                send_recv: true,
                write_imm: true,
                read: true,
                atomic: true,
            },
            TransportKind::Srd => Capabilities {
                reliable: true,
                ordered: false,
                connection_oriented: false,
                send_recv: true,
                write_imm: true,
                read: true,
                atomic: true,
            },
        }
    }
}

/// RDMA transport capability matrix (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    pub reliable: bool,
    pub ordered: bool,
    pub connection_oriented: bool,
    pub send_recv: bool,
    pub write_imm: bool,
    pub read: bool,
    pub atomic: bool,
}

/// The subset of capabilities fabric-lib itself relies on (Table 1,
/// last column): reliability without ordering or connections, with
/// SEND/RECV and WRITEIMM but *not* READ/atomics.
pub const FABRIC_LIB_CONTRACT: Capabilities = Capabilities {
    reliable: true,
    ordered: false,
    connection_oriented: false,
    send_recv: true,
    write_imm: true,
    read: false,
    atomic: false,
};

/// Calibrated per-NIC timing model.
#[derive(Debug, Clone)]
pub struct NicProfile {
    /// Human-readable name for tables.
    pub name: &'static str,
    pub transport: TransportKind,
    /// Line rate in Gbps.
    pub rate_gbps: f64,
    /// CPU-side cost of posting one work request (driver/libfabric
    /// overhead, charged to the posting worker thread).
    pub post_ns: Duration,
    /// CPU-side cost of each *chained* WR after the first in a doorbell
    /// batch (RC WR chaining amortizes the doorbell, §3.5).
    pub post_chained_ns: Duration,
    /// NIC-side processing time per work request (fetch WQE, DMA setup).
    pub wr_process_ns: Duration,
    /// One-way wire + switch latency.
    pub wire_ns: Duration,
    /// Jitter on wire latency (per packet for SRD, per message for RC).
    pub wire_jitter: Jitter,
    /// Path MTU for SRD packetization (RC streams at line rate).
    pub mtu: usize,
    /// Max WRs the NIC pipeline keeps in flight before back-pressure.
    pub sq_depth: usize,
    /// How many WRs one doorbell may chain (1 = no chaining support).
    pub max_chain: usize,
    /// SRD requires a valid target descriptor even for zero-sized
    /// immediate-only writes (§3.5, EFA divergence from the RDMA spec).
    pub imm_requires_desc: bool,
}

impl NicProfile {
    /// NVIDIA ConnectX-7, 400 Gbps, RC via libibverbs.
    ///
    /// Calibration: Table 2 reports 11.10 M op/s at 1 KiB paged writes
    /// → ~90 ns per WR end-to-end on the NIC pipeline; 44 Gbps at
    /// 64 KiB single writes → ~11 µs per isolated WR round including
    /// wire and DMA; saturation at 32 KiB paged / ≥16 MiB single.
    pub fn connectx7() -> Self {
        NicProfile {
            name: "CX-7",
            transport: TransportKind::Rc,
            rate_gbps: 400.0,
            post_ns: 60,
            post_chained_ns: 25,
            wr_process_ns: 90,
            wire_ns: 900,
            wire_jitter: Jitter {
                median_ns: 60.0,
                sigma: 0.3,
                spike_p: 0.0005,
                spike_mean_ns: 1500.0,
            },
            mtu: 4096,
            sq_depth: 1024,
            max_chain: 4,
            imm_requires_desc: false,
        }
    }

    /// AWS EFA (p5en-style), 200 Gbps per NIC, SRD via libfabric.
    ///
    /// Calibration: Table 2 reports a 2.1 M op/s cap (~470 ns/WR),
    /// 16 Gbps at 64 KiB single writes, and saturation only at large
    /// messages; Table 8 shows ~28 µs to post 56 scatter WRs (≈0.5 µs
    /// each inside libfabric); route exchange is slower than CX-7
    /// (Fig 11 knee at 32 vs 24 tokens).
    pub fn efa() -> Self {
        NicProfile {
            name: "EFA",
            transport: TransportKind::Srd,
            rate_gbps: 200.0,
            post_ns: 420,
            post_chained_ns: 420, // no chaining in libfabric
            wr_process_ns: 470,
            wire_ns: 2600,
            wire_jitter: Jitter {
                median_ns: 500.0,
                sigma: 0.55,
                spike_p: 0.002,
                spike_mean_ns: 6000.0,
            },
            mtu: 8192,
            sq_depth: 512,
            max_chain: 1,
            imm_requires_desc: true,
        }
    }

    /// Alibaba Cloud eRDMA-style adapter (paper §8: among rdma-core
    /// providers only EFA diverges from standard RC; RC-compatible
    /// NICs reuse the ConnectX code path with per-hardware tuning,
    /// not a redesign).
    pub fn erdma() -> Self {
        NicProfile {
            name: "eRDMA",
            transport: TransportKind::Rc,
            rate_gbps: 100.0,
            post_ns: 90,
            post_chained_ns: 40,
            wr_process_ns: 150,
            wire_ns: 1800,
            wire_jitter: Jitter {
                median_ns: 200.0,
                sigma: 0.4,
                spike_p: 0.001,
                spike_mean_ns: 3000.0,
            },
            mtu: 4096,
            sq_depth: 512,
            max_chain: 2,
            imm_requires_desc: false,
        }
    }

    /// Bytes per nanosecond at line rate.
    pub fn bytes_per_ns(&self) -> f64 {
        self.rate_gbps / 8.0
    }

    /// Time to serialize `bytes` at line rate.
    pub fn serialize_ns(&self, bytes: usize) -> Duration {
        ((bytes as f64 / self.bytes_per_ns()).ceil() as Duration).max(1)
    }
}

/// GPU timing model: enough to schedule kernels, PCIe transactions and
/// NVLink transfers with realistic latencies.
#[derive(Debug, Clone)]
pub struct GpuProfile {
    pub name: &'static str,
    /// HBM bandwidth in GB/s (kernel runtimes are HBM-roofline).
    pub hbm_gbps: f64,
    /// Achievable bf16 compute in TFLOP/s (dense, for compute-bound
    /// phases of the KvCache TTFT model).
    pub tflops_bf16: f64,
    /// NVLink per-direction bandwidth in GB/s.
    pub nvlink_gbps: f64,
    /// NVLink store visibility latency (one way).
    pub nvlink_ns: Duration,
    /// PCIe write visibility latency (NIC/CPU <-> GPU; GDRCopy reads
    /// land in the same band, §7.2: "2 µs to 5 µs PCIe latency").
    pub pcie_ns: Duration,
    /// Kernel launch overhead (host-initiated, outside CUDA graphs).
    pub launch_ns: Duration,
}

impl GpuProfile {
    /// NVIDIA H100 SXM.
    pub fn h100() -> Self {
        GpuProfile {
            name: "H100",
            hbm_gbps: 3350.0,
            tflops_bf16: 700.0,
            nvlink_gbps: 450.0,
            nvlink_ns: 550,
            pcie_ns: 2 * US,
            launch_ns: 3 * US,
        }
    }

    /// NVIDIA H200 (more/faster HBM, same interconnect generation).
    pub fn h200() -> Self {
        GpuProfile {
            name: "H200",
            hbm_gbps: 4800.0,
            tflops_bf16: 700.0,
            nvlink_gbps: 450.0,
            nvlink_ns: 550,
            pcie_ns: 2 * US,
            launch_ns: 3 * US,
        }
    }

    /// Time for an HBM-bound kernel moving `bytes` (read+write counted
    /// by caller).
    pub fn hbm_ns(&self, bytes: u64) -> Duration {
        ((bytes as f64 / self.hbm_gbps).ceil() as Duration).max(200)
    }

    /// Time for NVLink transfer of `bytes` (one direction).
    pub fn nvlink_transfer_ns(&self, bytes: u64) -> Duration {
        self.nvlink_ns + ((bytes as f64 / self.nvlink_gbps).ceil() as Duration).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 1: the capability matrix of the transports we model,
    /// and fabric-lib's common-ground contract.
    #[test]
    fn capability_matrix() {
        let rc = TransportKind::Rc.capabilities();
        assert!(rc.reliable && rc.ordered && rc.connection_oriented);
        assert!(rc.send_recv && rc.write_imm && rc.read && rc.atomic);

        let srd = TransportKind::Srd.capabilities();
        assert!(srd.reliable && !srd.ordered && !srd.connection_oriented);
        assert!(srd.send_recv && srd.write_imm);

        // fabric-lib's contract is the meet: reliable, unordered,
        // connectionless; no READ/atomics.
        let f = FABRIC_LIB_CONTRACT;
        assert!(f.reliable && !f.ordered && !f.connection_oriented);
        assert!(f.send_recv && f.write_imm && !f.read && !f.atomic);
        // Both transports satisfy everything the contract requires.
        for caps in [rc, srd] {
            assert!(caps.reliable >= f.reliable);
            assert!(caps.send_recv >= f.send_recv);
            assert!(caps.write_imm >= f.write_imm);
        }
    }

    #[test]
    fn op_rate_calibration() {
        // CX-7: 90 ns/WR → ~11.1 M op/s, matching Table 2 at 1 KiB.
        let cx7 = NicProfile::connectx7();
        let ops_per_sec = 1e9 / cx7.wr_process_ns as f64;
        assert!((10.0e6..12.5e6).contains(&ops_per_sec), "{ops_per_sec}");
        // EFA: 470 ns/WR → ~2.1 M op/s.
        let efa = NicProfile::efa();
        let ops_per_sec = 1e9 / efa.wr_process_ns as f64;
        assert!((1.9e6..2.3e6).contains(&ops_per_sec), "{ops_per_sec}");
    }

    /// Paper §8: porting to an RC-compatible NIC changes tuning, not
    /// the contract — application code on the engine is unchanged.
    #[test]
    fn erdma_is_rc_compatible() {
        let e = NicProfile::erdma();
        assert_eq!(e.transport, TransportKind::Rc);
        let caps = e.transport.capabilities();
        assert!(caps.reliable && caps.ordered && caps.write_imm);
        assert!(!e.imm_requires_desc, "standard RC immediate semantics");
    }

    #[test]
    fn serialization_rates() {
        let cx7 = NicProfile::connectx7();
        // 400 Gbps = 50 B/ns → 1 MiB in ~21 µs.
        let t = cx7.serialize_ns(1024 * 1024);
        assert!((20_000..22_000).contains(&t), "{t}");
        let efa = NicProfile::efa();
        assert!(efa.serialize_ns(1024) > cx7.serialize_ns(1024));
    }

    #[test]
    fn gpu_roofline() {
        let h200 = GpuProfile::h200();
        // Moving 4.8 GB at 4800 GB/s ≈ 1 ms.
        let t = h200.hbm_ns(4_800_000_000);
        assert!((900_000..1_100_000).contains(&t), "{t}");
        assert!(h200.hbm_ns(0) >= 200); // floor: kernel can't be free
        // NVLink: 450 KB at 450 GB/s ≈ 1 µs + latency.
        let t = h200.nvlink_transfer_ns(450_000);
        assert!((1_400..1_700).contains(&t), "{t}");
    }
}
