//! Open-loop arrival processes for the scaled serving scenario.
//!
//! Two generators produce the same `(arrival time, sequence length)`
//! stream shape:
//!
//! * [`PoissonArrivals`] — seeded exponential inter-arrival times at a
//!   configured offered rate, with sequence lengths drawn uniformly
//!   from a choice set. Deterministic per seed, infinite.
//! * [`TraceArrivals`] — replay of a trace file, one request per line
//!   (`<t_ns> <seq_tokens>`, `#` comments allowed), timestamps
//!   non-decreasing. Finite.
//!
//! Both are *open-loop*: arrivals do not wait for service, which is
//! what makes the 10⁶-request sweeps meaningful tail-latency
//! experiments rather than closed-loop echo tests. A Poisson stream
//! can be serialized with [`to_trace_text`] and replayed bit-for-bit
//! through [`TraceArrivals`] — the determinism test in this module
//! relies on that round trip.

use crate::bail;
use crate::sim::rng::Rng;
use crate::sim::time::Instant;
use crate::util::err::Result;

/// One open-loop request arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    /// Absolute virtual arrival time (ns).
    pub at: Instant,
    /// Prompt length in tokens.
    pub seq_tokens: u32,
}

/// Seeded Poisson arrival process (exponential inter-arrival times).
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    rng: Rng,
    mean_interarrival_ns: f64,
    seq_choices: Vec<u32>,
    next_at: f64,
}

impl PoissonArrivals {
    /// Offered load of one request per `mean_interarrival_ns` on
    /// average, prompt lengths drawn uniformly from `seq_choices`.
    pub fn new(seed: u64, mean_interarrival_ns: u64, seq_choices: Vec<u32>) -> Self {
        assert!(mean_interarrival_ns > 0, "rate must be finite");
        assert!(!seq_choices.is_empty(), "need at least one seq length");
        PoissonArrivals {
            rng: Rng::new(seed),
            mean_interarrival_ns: mean_interarrival_ns as f64,
            seq_choices,
            next_at: 0.0,
        }
    }
}

impl Iterator for PoissonArrivals {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        self.next_at += self.rng.exp(self.mean_interarrival_ns);
        let seq_tokens = self.seq_choices[self.rng.below(self.seq_choices.len() as u64) as usize];
        Some(Arrival {
            at: self.next_at as Instant,
            seq_tokens,
        })
    }
}

/// Trace-file replay arrivals.
#[derive(Debug, Clone)]
pub struct TraceArrivals {
    events: Vec<Arrival>,
    pos: usize,
}

impl TraceArrivals {
    /// Parse trace text: one `<t_ns> <seq_tokens>` pair per line,
    /// blank lines and `#` comments ignored, timestamps
    /// non-decreasing.
    pub fn parse(text: &str) -> Result<Self> {
        let mut events = Vec::new();
        let mut last_at = 0u64;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let (Some(t), Some(s)) = (it.next(), it.next()) else {
                bail!("trace line {}: expected '<t_ns> <seq_tokens>'", lineno + 1);
            };
            if it.next().is_some() {
                bail!("trace line {}: trailing fields", lineno + 1);
            }
            let at: u64 = match t.parse() {
                Ok(v) => v,
                Err(_) => bail!("trace line {}: bad timestamp {:?}", lineno + 1, t),
            };
            let seq_tokens: u32 = match s.parse() {
                Ok(v) if v > 0 => v,
                _ => bail!("trace line {}: bad seq_tokens {:?}", lineno + 1, s),
            };
            if at < last_at {
                bail!("trace line {}: timestamps must be non-decreasing", lineno + 1);
            }
            last_at = at;
            events.push(Arrival { at, seq_tokens });
        }
        Ok(TraceArrivals { events, pos: 0 })
    }

    /// Load and parse a trace file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Arrivals in the trace.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when the trace holds no arrivals.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl Iterator for TraceArrivals {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        let a = self.events.get(self.pos).copied();
        self.pos += a.is_some() as usize;
        a
    }
}

/// Either arrival process, as one iterator type the serving scenario
/// can hold without generics.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Seeded open-loop Poisson process (infinite).
    Poisson(PoissonArrivals),
    /// Trace replay (finite).
    Trace(TraceArrivals),
}

impl Iterator for Arrivals {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        match self {
            Arrivals::Poisson(p) => p.next(),
            Arrivals::Trace(t) => t.next(),
        }
    }
}

/// Serialize `n` arrivals from any process into trace text that
/// [`TraceArrivals::parse`] reads back identically.
pub fn to_trace_text(arrivals: &mut impl Iterator<Item = Arrival>, n: usize) -> String {
    let mut out = String::with_capacity(n * 16);
    out.push_str("# <t_ns> <seq_tokens>\n");
    for a in arrivals.take(n) {
        out.push_str(&format!("{} {}\n", a.at, a.seq_tokens));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_per_seed() {
        let a: Vec<_> = PoissonArrivals::new(7, 1000, vec![4096, 8192])
            .take(100)
            .collect();
        let b: Vec<_> = PoissonArrivals::new(7, 1000, vec![4096, 8192])
            .take(100)
            .collect();
        let c: Vec<_> = PoissonArrivals::new(8, 1000, vec![4096, 8192])
            .take(100)
            .collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at), "monotone times");
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let n = 10_000;
        let last = PoissonArrivals::new(1, 1000, vec![4096])
            .take(n)
            .last()
            .unwrap();
        let mean = last.at as f64 / n as f64;
        assert!(
            (mean - 1000.0).abs() < 100.0,
            "mean inter-arrival {mean} vs configured 1000"
        );
    }

    #[test]
    fn trace_round_trips_poisson() {
        let mut p = PoissonArrivals::new(42, 500, vec![2048, 4096, 16384]);
        let text = to_trace_text(&mut p, 500);
        let replay: Vec<_> = TraceArrivals::parse(&text).unwrap().collect();
        let direct: Vec<_> = PoissonArrivals::new(42, 500, vec![2048, 4096, 16384])
            .take(500)
            .collect();
        assert_eq!(replay, direct);
    }

    #[test]
    fn trace_parser_accepts_comments_rejects_garbage() {
        let t = TraceArrivals::parse("# hdr\n\n10 4096  # inline\n20 8192\n").unwrap();
        assert_eq!(t.len(), 2);
        assert!(TraceArrivals::parse("10\n").is_err(), "missing field");
        assert!(TraceArrivals::parse("10 0\n").is_err(), "zero seq");
        assert!(TraceArrivals::parse("20 1\n10 1\n").is_err(), "decreasing");
        assert!(TraceArrivals::parse("x 1\n").is_err(), "bad number");
        assert!(TraceArrivals::parse("1 2 3\n").is_err(), "trailing");
    }
}
