//! Paper Fig 11: impact of private buffer size on p50 decode dispatch
//! latency.
//!
//! The private per-source buffers hide the route-exchange latency by
//! speculatively shipping the first tokens before placements are
//! known. Too small → the second (placement-dependent) round sits on
//! the critical path; large enough → route exchange fully hidden.
//!
//! Usage: cargo bench --bench moe_private_buffer [-- --fast]

use fabric_lib::apps::moe::rank::Strategy;
use fabric_lib::apps::moe::{harness::run_epoch_with, MoeConfig};
use fabric_lib::fabric::profile::NicProfile;
use fabric_lib::util::table::{f, Table};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let iters = if fast { 3 } else { 6 };
    let sizes: &[u32] = &[0, 8, 16, 24, 32, 48, 64, 128];

    // Inter-node setups: 2 nodes (EP16) like the paper's inter-node
    // ablation, plus intra-node EP8.
    let setups: &[(&str, u32, NicProfile, u8)] = &[
        ("intra (EP8) CX7", 8, NicProfile::connectx7(), 1),
        ("inter (EP16) CX7", 16, NicProfile::connectx7(), 1),
        ("inter (EP16) EFA", 16, NicProfile::efa(), 2),
    ];
    let mut t = Table::new(
        "Figure 11. p50 decode dispatch latency (us) vs private buffer tokens",
        &["setup", "0", "8", "16", "24", "32", "48", "64", "128"],
    );
    for (name, ep, nic, nics) in setups {
        let mut row = vec![name.to_string()];
        for &p in sizes {
            let mut cfg = MoeConfig::decode(*ep, 128);
            cfg.private_tokens = p;
            let mut lat = run_epoch_with(&cfg, Strategy::ours(), nic.clone(), *nics, iters, None);
            row.push(f(lat.dispatch.percentile(50.0) as f64 / 1000.0, 0));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "\npaper — performance drops off as private buffers shrink; ~24 \
         tokens suffice on CX-7, EFA already degrades under 32 (slower \
         route exchange). Claim preserved: speculation hides route \
         latency.\n"
    );
}
