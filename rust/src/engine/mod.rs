//! The TransferEngine: fabric-lib's core component (paper §3).
//!
//! One uniform API, two runtimes, zero duplicated submission logic —
//! and, since the compute-model migration, zero duplicated *scenario*
//! logic either. The module is layered along that split:
//!
//! ```text
//!   apps/ scenarios (KvCache Table-3 + NIC-failover, MoE epochs
//!        │ under chaos, RL pipeline)
//!        │ written once against &mut Cx + dyn TransferEngine
//!        ▼
//!   [`model`] — runtime-neutral compute/clock model: delayed
//!        │ callbacks (`Cx::after`/`at`), continuations (`Cx::cont`),
//!        │ per-stream kernels (`ComputeModel`), NVLink pushes,
//!        │ serial H2D/prep/submit resources, barrier arrival
//!        ▼
//!   [`traits`] — the dyn-safe Fig-2 trait + `Cx`/`Notify`/`Cluster`,
//!        │ plus the chaos/health surface: `inject_chaos`,
//!        │ `set_nic_health`, `set_failover_policy`,
//!        │ `transport_errors`, the per-link layer:
//!        │ `link_health_mask`, `report_remote_health`,
//!        │ `set_gossip_peers`, and the observability surface:
//!        │ `telemetry` (counter snapshot) + `take_traces` (spans)
//!        │
//!        ├── [`des_engine::Engine`]      (virtual clock, deterministic)
//!        └── [`threaded::ThreadedEngine`] (pinned threads, wall clock)
//!        │
//!   [`core`] — shared submission core: peer-group registry (+ free),
//!        the §3.5 template cache (`GroupTemplate`: per-peer routes,
//!        rkeys and barrier scratch resolved once at
//!        `bind_peer_group_mrs`, invalidated on `remove_peer_group`),
//!        imm accounting, transfer/WR completion tables, recv
//!        matching, NIC rotation (mask-aware), plan→rkey routing
//!        (§3.2 equal-NIC invariant as a real error path), the
//!        templated route-patching fast path, and the chaos-layer
//!        `NicHealth` table (local NIC mask + per-link/remote
//!        observations) + `FailoverPolicy` + destination-aware
//!        `remap_routed` that keep downed NICs, partitioned links and
//!        gossiped-dead remote NICs out of every submission at patch
//!        time
//!        │
//!   [`api`], [`wire`], [`sharding`], [`imm_counter`] — vocabulary
//!        types, wire format (incl. the NIC-health gossip control
//!        message), pure sharding planner, counter logic
//!        │
//!   fabric chaos ([`crate::fabric::chaos`]) — seeded, deterministic
//!        transport perturbation UNDER the engine: per-chunk jitter,
//!        bounded commit reordering, scheduled NicDown/NicUp and
//!        per-link (src, dst) partitions with `WrError` completions;
//!        whole-NIC link-state hooks feed the engines' health tables
//!        (path failures deliberately don't — senders learn them from
//!        `WrError` attribution + gossip)
//! ```
//!
//! The full architecture — including the failover/gossip contract —
//! is documented in `docs/ARCHITECTURE.md` at the repository root.
//!
//! * [`traits`] — the [`traits::TransferEngine`] trait: the full
//!   Fig-2 vocabulary (`alloc_mr`/`reg_mr`, SEND/RECV, single/paged
//!   writes, peer groups with add/remove, scatter/barrier, IMMCOUNTER
//!   expectations, UVM watchers) as one dyn-safe interface, plus the
//!   [`traits::Cx`] execution context/clock and
//!   [`traits::Cluster`]/[`traits::run_on_both`] harness that runs any
//!   scenario on both runtimes;
//! * [`model`] — the runtime-neutral compute/clock model the full
//!   scenarios schedule their GPU/CPU side with, implemented once over
//!   the DES virtual clock and once over real threads/`std::time`
//!   (the [`model::Reactor`]);
//! * [`core`] — the shared submission core: peer-group registry and
//!   the §3.5 template cache ([`core::GroupTemplate`]) behind the
//!   `bind_peer_group_mrs`/`submit_*_templated` fast path, imm
//!   accounting, transfer/WR completion tables, recv matching, NIC
//!   rotation, and the bridge from API calls to [`sharding`] plans
//!   paired with destination rkeys (where the §3.2 equal-NIC-count
//!   invariant is enforced as a `Result` error, release builds
//!   included);
//! * [`des_engine::Engine`] — deterministic, timing-faithful runtime
//!   on the discrete-event fabric (benchmarks, integration tests);
//! * [`threaded::ThreadedEngine`] — real pinned threads over the
//!   in-process fabric (runnable examples, real CPU-overhead
//!   measurements);
//! * [`api`], [`wire`], [`sharding`], [`imm_counter`] — the shared
//!   vocabulary types, wire format, pure sharding planner and counter
//!   logic underneath all of it.
//!
//! Apps and examples written against `&dyn TransferEngine` (or
//! `impl TransferEngine`) run unchanged on either runtime; pick the
//! DES engine for reproducible timing, the threaded engine for real
//! wall-clock behavior. The full paper scenarios (`run_table3_row`,
//! MoE decode epochs, the RL weight pipeline) follow the same rule:
//! their `*_on` entry points take any `Cx` + engines, and the
//! convenience wrappers merely build a DES [`traits::Cluster`] first.

pub mod api;
pub mod core;
pub mod des_engine;
pub mod imm_counter;
pub mod model;
pub mod sharding;
pub mod threaded;
pub mod traits;
pub mod wire;

pub use api::{
    EngineCosts, MrDesc, MrHandle, NetAddr, Pages, PeerGroupHandle, ScatterDst, TemplatedDst,
};
pub use self::core::{FailoverPolicy, GroupTemplate, NicHealth, PeerTemplate, RouteSet, RoutedWrite};
pub use des_engine::{Engine, OnDone, UvmWatcherHandle};
pub use imm_counter::{ImmCounter, ImmEvent};
pub use model::{
    BarrierModel, ComputeModel, Cont, Fired, NvlinkModel, Reactor, SerialResource, WakeSender,
};
pub use threaded::{OnDoneT, ThreadedEngine};
pub use traits::{
    expect_flag, new_flag, run_on_both, Cluster, Cx, Notify, OnRecv, OnWatch, RuntimeKind,
    SharedFlag, TransferEngine, UvmWatcher,
};
