#!/usr/bin/env python3
"""fabric-lint: repo-specific invariant checker for the Rust sources.

Seven PRs of engine code have shipped desk-checked only (no Rust
toolchain exists in the build container), and review passes kept
re-finding the same invariant classes by hand. This linter is the
pre-toolchain verification gate: a lightweight token/brace-aware
scanner over `rust/src/**` that enforces the discipline rules the
failover/submit contract rests on (see docs/ARCHITECTURE.md,
"Enforced invariants").

Rules
-----
R1  bump-on-success: in every `submit_*` function under
    `rust/src/engine/`, rotation-cursor commits (`.bump()`,
    `.bump_n()`, `.bump_masked()`) must occur lexically AFTER the
    last fallible operation (`?`, `bail!`, `return Err`) of the
    function body. A cursor bumped before a fallible op can move on a
    failed submission, shifting NIC assignment for every later write.
R2  allocate-after-validate: in every `submit_*`/`bind_*` function
    under `rust/src/engine/`, no MR allocation (`alloc_mr`,
    `alloc_mr_unbacked`, `reg_mr`) may precede the first validation
    (`?` / `bail!` / health or group check). A failed bind or
    rejected barrier must not leak a registered scratch MR.
R3  SAFETY comments: every `unsafe` occurrence (block, fn, impl,
    fn-pointer type) is immediately preceded by a `//`/`///` comment
    block containing `SAFETY:`. A run of consecutive unsafe-bearing
    lines may share the comment block above the first line of the
    run (e.g. `unsafe impl Send` + `unsafe impl Sync`).
R4  trait parity: the method sets of `impl TransferEngine for
    Engine` (des_engine.rs) and `impl TransferEngine for
    ThreadedEngine` (threaded.rs) must be identical, cover every
    non-default trait method, and contain nothing the trait does not
    declare.
R5  wire tags: `wire.rs` message-tag values must be unique, and
    every tag constant must appear in at least one decode-side
    comparison (`== tag::X` / `!= tag::X` / match arm) somewhere in
    `rust/src/**` — an encoder-only tag is undecodable on the wire.
R6  lock order: `.lock()` acquisition sites in `threaded.rs`
    (non-test code) must use only lock classes declared in the
    allowlist's `[lock_order]` table, and nested acquisitions must
    follow the declared order (flagging inversions and same-class
    re-entry, the two deadlock shapes).
R7  no panics on the release submit surface: `submit_*`, `bind_*`,
    `route_*`, `dispatch_writes`, `execute_routed`, `remap_routed`
    and `retarget` in `rust/src/engine/` must not contain
    `.unwrap()`, `.expect(`, `panic!`, `unreachable!` or `assert!`
    (`debug_assert*` is fine). Documented loud-asserts go in the
    allowlist with a reason string.
R8  WrError attribution: every non-test `CqeKind::WrError` handling
    site in `rust/src/engine/` must reach the telemetry attribution
    ledger — the enclosing function's body mentions an attribution
    counter (`wr_err_link`/`wr_err_remote`/`wr_err_nic`, or a
    `record_wr_error` helper), or calls (one level, same file set) a
    function whose body does. An unattributed WrError path breaks the
    `wr_err_link + wr_err_nic == wr_err_total` accounting identity
    the chaos tests assert.
R9  scenario corpus: every committed spec under `scenarios/*.json`
    parses as a JSON object and carries a non-empty `assertions`
    array — a committed spec that asserts nothing reproduces
    nothing. (The executor in rust/src/scenario/ enforces the full
    schema; this gate keeps the corpus loadable with no toolchain.)

Findings print as `file:line RULE message`; exit code 1 when any
finding survives the allowlist, 0 otherwise. Intentional exceptions
live in scripts/fabric_lint_allow.toml (see that file for the entry
format); every entry needs a non-empty reason.

Usage: fabric_lint.py [--root DIR] [--allowlist FILE] [--no-allowlist] [-v]

Stdlib-only by design: this must run in CI and in containers with no
toolchain at all.
"""

import argparse
import json
import os
import re
import sys

# ---------------------------------------------------------------------
# Source model: raw text + a comment/string-masked shadow copy.
# ---------------------------------------------------------------------


def mask_source(text):
    """Return `text` with comments and string/char literals blanked.

    The masked copy has the same length and the same newline
    positions as the original, so byte offsets and line numbers
    computed on one apply to the other. Handles line comments, nested
    block comments, string literals with escapes, raw strings
    (r"..", r#".."# up to 4 hashes), byte strings, char literals, and
    leaves lifetimes (`'a`) alone.
    """
    out = list(text)
    i, n = 0, len(text)

    def blank(a, b):
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = text.find("\n", i)
            j = n if j == -1 else j
            blank(i, j)
            i = j
        elif c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if text.startswith("/*", j):
                    depth += 1
                    j += 2
                elif text.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == '"':
            j = i + 1
            while j < n:
                if text[j] == "\\":
                    j += 2
                elif text[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i + 1, j - 1 if j <= n and text[j - 1 : j] == '"' else j)
            i = j
        elif c == "r" and re.match(r'r#{0,4}"', text[i : i + 6]):
            m = re.match(r'r(#{0,4})"', text[i : i + 6])
            hashes = m.group(1)
            close = '"' + hashes
            j = text.find(close, i + len(m.group(0)))
            j = n if j == -1 else j + len(close)
            blank(i, j)
            i = j
        elif c == "b" and nxt == '"':
            j = i + 2
            while j < n:
                if text[j] == "\\":
                    j += 2
                elif text[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i + 1, j)
            i = j
        elif c == "'":
            # Char literal vs lifetime: a char literal closes within a
            # few chars ('x', '\n', '\u{1F600}').
            m = re.match(r"'(\\u\{[0-9a-fA-F]{1,6}\}|\\.|[^\\'])'", text[i:])
            if m:
                blank(i + 1, i + len(m.group(0)) - 1)
                i += len(m.group(0))
            else:
                i += 1
        else:
            i += 1
    return "".join(out)


class Source:
    """One parsed .rs file: raw text, masked text, line index."""

    def __init__(self, path, relpath):
        with open(path, encoding="utf-8") as fh:
            self.raw = fh.read()
        self.rel = relpath
        self.masked = mask_source(self.raw)
        self.raw_lines = self.raw.split("\n")
        # line_starts[k] = byte offset where line k+1 begins
        self.line_starts = [0]
        for m in re.finditer("\n", self.raw):
            self.line_starts.append(m.end())

    def line_of(self, idx):
        """1-based line number of byte offset `idx`."""
        lo, hi = 0, len(self.line_starts) - 1
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.line_starts[mid] <= idx:
                lo = mid
            else:
                hi = mid - 1
        return lo + 1

    def raw_line(self, lineno):
        return self.raw_lines[lineno - 1] if lineno - 1 < len(self.raw_lines) else ""


def match_brace(masked, open_idx):
    """Index of the `}` matching the `{` at open_idx (masked text)."""
    depth = 0
    for i in range(open_idx, len(masked)):
        c = masked[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return i
    return len(masked) - 1


FN_RE = re.compile(r"\bfn\s+([A-Za-z_][A-Za-z0-9_]*)")


def find_functions(src):
    """Yield (name, sig_idx, body_open, body_close) for every fn with a
    body. Trait methods without bodies (sig ends in `;` before any
    `{` at paren depth 0) yield body_open == body_close == -1."""
    out = []
    for m in FN_RE.finditer(src.masked):
        i = m.end()
        depth = 0
        body_open = -1
        while i < len(src.masked):
            c = src.masked[i]
            if c in "([":
                depth += 1
            elif c in ")]":
                depth -= 1
            elif c == "{" and depth == 0:
                body_open = i
                break
            elif c == ";" and depth == 0:
                break
            i += 1
        if body_open == -1:
            out.append((m.group(1), m.start(), -1, -1))
        else:
            out.append((m.group(1), m.start(), body_open, match_brace(src.masked, body_open)))
    return out


def test_mod_spans(src):
    """Byte spans of `#[cfg(test)] mod ... { }` regions."""
    spans = []
    for m in re.finditer(r"#\[cfg\(test\)\]\s*(?:pub\s+)?mod\s+\w+\s*\{", src.masked):
        open_idx = src.masked.index("{", m.start())
        spans.append((m.start(), match_brace(src.masked, open_idx)))
    return spans


def in_spans(idx, spans):
    return any(a <= idx <= b for a, b in spans)


# ---------------------------------------------------------------------
# Findings + allowlist
# ---------------------------------------------------------------------


class Finding:
    def __init__(self, rule, rel, line, message, src_line="", stmt=""):
        self.rule = rule
        self.rel = rel
        self.line = line
        self.message = message
        self.src_line = src_line  # raw source line, for allowlist matching
        self.stmt = stmt  # dot-joined full statement (multi-line chains)

    def __str__(self):
        return "%s:%d %s %s" % (self.rel, self.line, self.rule, self.message)


def stmt_text(src, idx):
    """The full statement containing byte offset `idx`, with method
    chains re-joined (`\\n    .lock()` -> `.lock()`) so allowlist
    `contains` patterns match chains that rustfmt split across lines."""
    a = idx
    while a > 0 and src.masked[a - 1] not in ";{}":
        a -= 1
    b = src.masked.find(";", idx)
    b = len(src.masked) if b == -1 else b + 1
    return re.sub(r"\s*\.\s*", ".", src.raw[a:b]).strip()


class AllowEntry:
    def __init__(self):
        self.rule = ""
        self.file = ""
        self.contains = ""
        self.reason = ""
        self.used = False

    def matches(self, f):
        if self.rule and self.rule != f.rule:
            return False
        if self.file and not f.rel.endswith(self.file):
            return False
        if self.contains and not any(
            self.contains in hay for hay in (f.src_line, f.stmt, f.message)
        ):
            return False
        return True


class Allowlist:
    """Minimal hand-parsed TOML subset: `[[allow]]` tables with
    string keys, plus a `[lock_order]` table with an `order` array."""

    def __init__(self):
        self.entries = []
        self.lock_order = []
        self.errors = []

    @classmethod
    def parse(cls, text, name="<allowlist>"):
        al = cls()
        cur = None
        section = None
        for lineno, line in enumerate(text.split("\n"), 1):
            stripped = line.strip()
            if not stripped or stripped.startswith("#"):
                continue
            if stripped == "[[allow]]":
                cur = AllowEntry()
                al.entries.append(cur)
                section = "allow"
                continue
            if stripped.startswith("["):
                section = stripped.strip("[]")
                cur = None
                continue
            m = re.match(r"(\w+)\s*=\s*(.*)$", stripped)
            if not m:
                al.errors.append("%s:%d unparseable line: %s" % (name, lineno, stripped))
                continue
            key, val = m.group(1), m.group(2).strip()
            if section == "allow" and cur is not None:
                if not (val.startswith('"') and val.endswith('"') and len(val) >= 2):
                    al.errors.append(
                        "%s:%d %s must be a quoted string" % (name, lineno, key)
                    )
                    continue
                setattr(cur, key, val[1:-1].replace('\\"', '"'))
            elif section == "lock_order" and key == "order":
                al.lock_order = re.findall(r'"([^"]+)"', val)
        for i, e in enumerate(al.entries):
            if not e.reason.strip():
                al.errors.append(
                    "%s: [[allow]] entry %d (%s %s) has no reason — every "
                    "exception must say why" % (name, i + 1, e.rule, e.contains or e.file)
                )
        return al

    def filter(self, findings):
        kept = []
        for f in findings:
            hit = next((e for e in self.entries if e.matches(f)), None)
            if hit:
                hit.used = True
            else:
                kept.append(f)
        return kept


DEFAULT_LOCK_ORDER = [
    "peer_groups",
    "shared",
    "gossip",
    "watchers",
    "worker",
    "watcher_thread",
]


# ---------------------------------------------------------------------
# R1: bump-on-success
# ---------------------------------------------------------------------

BUMP_RE = re.compile(r"\.bump(?:_n|_masked)?\s*\(")
QMARK_RE = re.compile(r"\?")
FALLIBLE_RE = re.compile(r"\bbail!\s*[(\[]|\breturn\s+Err\b")


def fallible_indices(masked, a, b):
    """Offsets of fallible ops (`?`, bail!, return Err) in masked[a:b],
    ignoring `?Sized` bounds."""
    idxs = []
    seg = masked[a:b]
    for m in QMARK_RE.finditer(seg):
        if seg[m.end() : m.end() + 5] == "Sized":
            continue
        idxs.append(a + m.start())
    for m in FALLIBLE_RE.finditer(seg):
        idxs.append(a + m.start())
    return idxs


def check_r1(src, findings):
    if "/engine/" not in "/" + src.rel.replace(os.sep, "/"):
        return
    tests = test_mod_spans(src)
    for name, sig, bo, bc in find_functions(src):
        if bo == -1 or not name.startswith("submit_") or in_spans(sig, tests):
            continue
        fallible = fallible_indices(src.masked, bo, bc)
        if not fallible:
            continue
        last_fallible = max(fallible)
        for m in BUMP_RE.finditer(src.masked, bo, bc):
            if m.start() < last_fallible:
                line = src.line_of(m.start())
                findings.append(
                    Finding(
                        "R1",
                        src.rel,
                        line,
                        "rotation commit in `%s` precedes a later fallible op "
                        "(line %d): cursors must bump only after the last "
                        "`?`/`bail!`/`return Err` so a failed submission "
                        "never advances the rotation" % (name, src.line_of(last_fallible)),
                        src.raw_line(line),
                    )
                )


# ---------------------------------------------------------------------
# R2: allocate-after-validate
# ---------------------------------------------------------------------

ALLOC_RE = re.compile(r"\b(?:alloc_mr|alloc_mr_unbacked|reg_mr)\s*\(")
VALIDATE_RE = re.compile(
    r"\bbail!\s*[(\[]|\breturn\s+Err\b|\bup_count\s*\(|\bprepare_bind\s*\(|"
    r"\bensure_group_up\s*\(|\broute_[a-z_]+\s*\(|\.check\s*\("
)


def check_r2(src, findings):
    if "/engine/" not in "/" + src.rel.replace(os.sep, "/"):
        return
    tests = test_mod_spans(src)
    for name, sig, bo, bc in find_functions(src):
        if bo == -1 or in_spans(sig, tests):
            continue
        if not (name.startswith("submit_") or name.startswith("bind_")):
            continue
        m = ALLOC_RE.search(src.masked, bo, bc)
        if not m:
            continue
        validated = VALIDATE_RE.search(src.masked, bo, m.start()) or [
            i for i in fallible_indices(src.masked, bo, m.start())
        ]
        if not validated:
            line = src.line_of(m.start())
            findings.append(
                Finding(
                    "R2",
                    src.rel,
                    line,
                    "`%s` allocates/registers an MR before any validation or "
                    "health check: a rejected submission would leak the "
                    "registration (validate, then allocate)" % name,
                    src.raw_line(line),
                )
            )


# ---------------------------------------------------------------------
# R3: SAFETY comments on every unsafe occurrence
# ---------------------------------------------------------------------

UNSAFE_RE = re.compile(r"\bunsafe\b")


def check_r3(src, findings):
    unsafe_lines = sorted({src.line_of(m.start()) for m in UNSAFE_RE.finditer(src.masked)})
    unsafe_set = set(unsafe_lines)
    for line in unsafe_lines:
        # Inline comment before the keyword on the same line counts.
        if "SAFETY:" in src.raw_line(line).split("unsafe")[0]:
            continue
        # Anchor: first line of a consecutive run of unsafe-bearing
        # lines — the run shares one comment block.
        anchor = line
        while anchor - 1 in unsafe_set:
            anchor -= 1
        ok = False
        p = anchor - 1
        # Skip attribute lines between the comment and the item.
        while p >= 1 and src.raw_line(p).strip().startswith("#["):
            p -= 1
        while p >= 1:
            stripped = src.raw_line(p).strip()
            if stripped.startswith("//"):
                if "SAFETY:" in stripped:
                    ok = True
                    break
                p -= 1
            else:
                break
        if not ok:
            findings.append(
                Finding(
                    "R3",
                    src.rel,
                    line,
                    "`unsafe` without an immediately preceding `// SAFETY:` "
                    "comment stating the invariant it relies on",
                    src.raw_line(line),
                )
            )


# ---------------------------------------------------------------------
# R4: TransferEngine trait/impl parity
# ---------------------------------------------------------------------


def block_methods(src, header_re):
    """(methods_with_body, methods_without_body) for fns declared at
    depth 1 of the first block whose header matches `header_re`."""
    m = header_re.search(src.masked)
    if not m:
        return None, None
    open_idx = src.masked.index("{", m.start())
    close_idx = match_brace(src.masked, open_idx)
    with_body, without_body = set(), set()
    for name, sig, bo, bc in find_functions(src):
        if not (open_idx < sig < close_idx):
            continue
        # depth-1 only: the fn must not be nested inside another fn of
        # the block (closures don't use `fn`, so nesting is rare;
        # check that no other fn body encloses this sig).
        if bo == -1:
            without_body.add(name)
        else:
            with_body.add(name)
    return with_body, without_body


def check_r4(root, sources, findings):
    by_rel = {s.rel.replace(os.sep, "/"): s for s in sources}
    traits = by_rel.get("rust/src/engine/traits.rs")
    des = by_rel.get("rust/src/engine/des_engine.rs")
    thr = by_rel.get("rust/src/engine/threaded.rs")
    if not (traits and des and thr):
        return  # fixture trees without the full engine are fine
    t_default, t_required = block_methods(
        traits, re.compile(r"\btrait\s+TransferEngine\b[^{]*")
    )
    if t_default is None:
        findings.append(
            Finding("R4", traits.rel, 1, "trait TransferEngine not found in traits.rs")
        )
        return
    trait_all = t_default | t_required
    impls = []
    for src, ty in ((des, "Engine"), (thr, "ThreadedEngine")):
        methods, _ = block_methods(
            src, re.compile(r"\bimpl\s+TransferEngine\s+for\s+%s\b" % ty)
        )
        if methods is None:
            findings.append(
                Finding(
                    "R4", src.rel, 1, "impl TransferEngine for %s not found" % ty
                )
            )
            return
        impls.append((src, ty, methods))
        for missing in sorted(t_required - methods):
            findings.append(
                Finding(
                    "R4",
                    src.rel,
                    1,
                    "impl TransferEngine for %s is missing required trait "
                    "method `%s`" % (ty, missing),
                )
            )
        for extra in sorted(methods - trait_all):
            findings.append(
                Finding(
                    "R4",
                    src.rel,
                    1,
                    "impl TransferEngine for %s defines `%s` which the trait "
                    "does not declare" % (ty, extra),
                )
            )
    (src_a, ty_a, a), (src_b, ty_b, b) = impls
    for name in sorted(a ^ b):
        present, absent, where = (ty_a, ty_b, src_b) if name in a else (ty_b, ty_a, src_a)
        findings.append(
            Finding(
                "R4",
                where.rel,
                1,
                "runtime parity break: `%s` overridden by %s but not by %s"
                % (name, present, absent),
            )
        )


# ---------------------------------------------------------------------
# R5: wire tag uniqueness + decode coverage
# ---------------------------------------------------------------------

TAG_CONST_RE = re.compile(r"pub\s+const\s+(\w+)\s*:\s*u8\s*=\s*(\d+)\s*;")


def check_r5(root, sources, findings):
    by_rel = {s.rel.replace(os.sep, "/"): s for s in sources}
    wire = by_rel.get("rust/src/engine/wire.rs")
    if not wire:
        return
    m = re.search(r"\bmod\s+tag\s*\{", wire.masked)
    if not m:
        findings.append(Finding("R5", wire.rel, 1, "no `mod tag` found in wire.rs"))
        return
    open_idx = wire.masked.index("{", m.start())
    close_idx = match_brace(wire.masked, open_idx)
    tags = {}
    for cm in TAG_CONST_RE.finditer(wire.raw[open_idx:close_idx]):
        name, val = cm.group(1), int(cm.group(2))
        at = open_idx + cm.start()
        if val in {v for v in tags.values()}:
            dup = next(k for k, v in tags.items() if v == val)
            findings.append(
                Finding(
                    "R5",
                    wire.rel,
                    wire.line_of(at),
                    "duplicate wire tag value %d: `%s` collides with `%s` — "
                    "decoders cannot distinguish the messages" % (val, name, dup),
                    wire.raw_line(wire.line_of(at)),
                )
            )
        tags[name] = val
    # Decode coverage: each tag needs >= 1 comparison site anywhere.
    for name in sorted(tags):
        covered = False
        use_re = re.compile(r"tag::" + name + r"\b")
        for s in sources:
            for um in use_re.finditer(s.masked):
                line = s.raw_line(s.line_of(um.start()))
                if "==" in line or "!=" in line or "=>" in line:
                    covered = True
                    break
            if covered:
                break
        if not covered:
            findings.append(
                Finding(
                    "R5",
                    wire.rel,
                    wire.line_of(open_idx),
                    "tag `%s` has no decode-side comparison anywhere in "
                    "rust/src — an encodable but undecodable message" % name,
                )
            )


# ---------------------------------------------------------------------
# R6: lock acquisition order in threaded.rs
# ---------------------------------------------------------------------

LOCK_RE = re.compile(r"\.lock\s*\(\s*\)")
IDENT_CHARS = re.compile(r"[A-Za-z0-9_]")


def lock_class(masked, idx):
    """Identifier immediately preceding the `.lock()` at `idx`
    (skipping whitespace/newlines), or '' if not an identifier."""
    j = idx - 1
    while j >= 0 and masked[j].isspace():
        j -= 1
    end = j + 1
    while j >= 0 and IDENT_CHARS.match(masked[j]):
        j -= 1
    return masked[j + 1 : end]


def enclosing_block_end(masked, idx):
    """Offset of the close brace of the innermost block containing
    idx (scan forward, balancing)."""
    depth = 0
    for i in range(idx, len(masked)):
        c = masked[i]
        if c == "{":
            depth += 1
        elif c == "}":
            if depth == 0:
                return i
            depth -= 1
    return len(masked) - 1


def stmt_head(masked, idx):
    a = idx
    while a > 0 and masked[a - 1] not in ";{}":
        a -= 1
    return masked[a:idx].strip()


def chain_after_lock(masked, end_idx):
    """Skip `.unwrap()` / `.expect(..)` / `?` adapters after a
    `.lock()` call and report whether the chain continues with a
    further projection (`.field` / `.method(`). A continued chain
    means the MutexGuard is a temporary — the binding holds the
    projected value, not the guard."""
    i = end_idx
    n = len(masked)
    while True:
        j = i
        while j < n and masked[j].isspace():
            j += 1
        if j < n and masked[j] == "?":
            i = j + 1
            continue
        if masked.startswith(".unwrap", j) or masked.startswith(".expect", j):
            k = masked.find("(", j)
            if k == -1:
                break
            depth, p = 0, k
            while p < n:
                if masked[p] == "(":
                    depth += 1
                elif masked[p] == ")":
                    depth -= 1
                    if depth == 0:
                        break
                p += 1
            i = p + 1
            continue
        break
    j = i
    while j < n and masked[j].isspace():
        j += 1
    return j < n and masked[j] == "."


def check_r6(root, sources, lock_order, findings):
    by_rel = {s.rel.replace(os.sep, "/"): s for s in sources}
    src = by_rel.get("rust/src/engine/threaded.rs")
    if not src:
        return
    order = lock_order or DEFAULT_LOCK_ORDER
    tests = test_mod_spans(src)
    sites = []  # (idx, class, holding, scope_end)
    for m in LOCK_RE.finditer(src.masked):
        if in_spans(m.start(), tests):
            continue
        cls = lock_class(src.masked, m.start())
        if not cls:
            continue
        head = stmt_head(src.masked, m.start())
        continues = chain_after_lock(src.masked, m.end())
        if not continues and re.match(r"(let|if\s+let|while\s+let)\b", head):
            # The guard itself is bound: live to end of the enclosing
            # block.
            holding = True
            scope_end = enclosing_block_end(src.masked, m.start())
        elif continues and re.search(r"\b(if|while|match)\b", head):
            # Scrutinee temporary (`if let Some(x) = m.lock()...take()`):
            # pre-2024 editions keep the guard alive through the body.
            holding = True
            bo = src.masked.find("{", m.end())
            scope_end = match_brace(src.masked, bo) if bo != -1 else m.end()
        else:
            # Plain temporary: guard dies at the end of the statement.
            holding = False
            semi = src.masked.find(";", m.end())
            scope_end = semi if semi != -1 else m.end()
        sites.append((m.start(), cls, holding, scope_end))
        if cls not in order:
            line = src.line_of(m.start())
            findings.append(
                Finding(
                    "R6",
                    src.rel,
                    line,
                    "lock class `%s` is not in the declared acquisition-order "
                    "table (add it to [lock_order] in the allowlist at the "
                    "right position)" % cls,
                    src.raw_line(line),
                )
            )
    for i, (idx_a, cls_a, holding, scope_end) in enumerate(sites):
        if not holding or cls_a not in order:
            continue
        for idx_b, cls_b, _, _ in sites[i + 1 :]:
            if idx_b > scope_end:
                break
            if cls_b not in order:
                continue
            if cls_a == cls_b:
                line = src.line_of(idx_b)
                findings.append(
                    Finding(
                        "R6",
                        src.rel,
                        line,
                        "`%s` re-locked while a `%s` guard from line %d is "
                        "still live: std::sync::Mutex self-deadlocks"
                        % (cls_b, cls_a, src.line_of(idx_a)),
                        src.raw_line(line),
                    )
                )
            elif order.index(cls_b) < order.index(cls_a):
                line = src.line_of(idx_b)
                findings.append(
                    Finding(
                        "R6",
                        src.rel,
                        line,
                        "lock-order inversion: `%s` acquired while holding "
                        "`%s` (line %d), but the declared order is %s"
                        % (cls_b, cls_a, src.line_of(idx_a), " < ".join(order)),
                        src.raw_line(line),
                    )
                )


# ---------------------------------------------------------------------
# R7: no unwrap/expect/panic on the release submit surface
# ---------------------------------------------------------------------

PANIC_RE = re.compile(
    r"\.unwrap\s*\(|\.expect\s*\(|\bpanic!\s*[(\[]|\bunreachable!\s*[(\[]|"
    r"(?<!debug_)\bassert(?:_eq|_ne)?!\s*[(\[]"
)
R7_FNS = re.compile(
    r"^(submit_|bind_|route_)|^(dispatch_writes|execute_routed|remap_routed|retarget)$"
)


def check_r7(src, findings):
    if "/engine/" not in "/" + src.rel.replace(os.sep, "/"):
        return
    tests = test_mod_spans(src)
    for name, sig, bo, bc in find_functions(src):
        if bo == -1 or in_spans(sig, tests) or not R7_FNS.search(name):
            continue
        for m in PANIC_RE.finditer(src.masked, bo, bc):
            line = src.line_of(m.start())
            token = m.group(0).strip(" (").lstrip(".")
            findings.append(
                Finding(
                    "R7",
                    src.rel,
                    line,
                    "`%s` on the release submit surface (`%s`): submit paths "
                    "return Result — propagate or allowlist with a reason"
                    % (token, name),
                    src.raw_line(line),
                    stmt_text(src, m.start()),
                )
            )


# ---------------------------------------------------------------------
# R8: every WrError handling path reaches the attribution ledger
# ---------------------------------------------------------------------

WR_ERROR_SITE_RE = re.compile(r"\bCqeKind\s*::\s*WrError\b")
ATTR_RE = re.compile(r"\bwr_err_(?:link|remote|nic)\b|\brecord_wr_error\b")
CALL_RE = re.compile(r"\b([a-z_][a-z0-9_]*)\s*\(")


def enclosing_fn(src, idx):
    """Innermost function whose body contains byte offset `idx`, as
    (name, bo, bc), or None when idx sits outside every fn body (enum
    declarations, use statements)."""
    best = None
    for name, _sig, bo, bc in find_functions(src):
        if bo != -1 and bo < idx < bc:
            if best is None or bo > best[1]:
                best = (name, bo, bc)
    return best


def check_r8(root, sources, findings):
    engine = [
        s for s in sources if "/engine/" in "/" + s.rel.replace(os.sep, "/")
    ]
    if not engine:
        return
    # Function table over the engine file set (non-test bodies only):
    # the one-level call-graph hop resolves callee names against it.
    fn_bodies = {}
    for s in engine:
        tests = test_mod_spans(s)
        for name, sig, bo, bc in find_functions(s):
            if bo == -1 or in_spans(sig, tests):
                continue
            fn_bodies.setdefault(name, []).append(s.masked[bo:bc])
    for s in engine:
        tests = test_mod_spans(s)
        for m in WR_ERROR_SITE_RE.finditer(s.masked):
            if in_spans(m.start(), tests):
                continue
            enc = enclosing_fn(s, m.start())
            if enc is None:
                continue  # type position outside any body
            name, bo, bc = enc
            body = s.masked[bo:bc]
            if ATTR_RE.search(body):
                continue
            attributed = False
            for cm in CALL_RE.finditer(body):
                for callee_body in fn_bodies.get(cm.group(1), []):
                    if ATTR_RE.search(callee_body):
                        attributed = True
                        break
                if attributed:
                    break
            if not attributed:
                line = s.line_of(m.start())
                findings.append(
                    Finding(
                        "R8",
                        s.rel,
                        line,
                        "`CqeKind::WrError` handled in `%s` without reaching "
                        "an attribution counter (wr_err_link/remote/nic) "
                        "directly or via a called helper: unattributed "
                        "errors break `wr_err_link + wr_err_nic == "
                        "wr_err_total`" % name,
                        s.raw_line(line),
                    )
                )


# ---------------------------------------------------------------------
# R9: committed scenario specs parse and assert something
# ---------------------------------------------------------------------


def check_r9(root, findings):
    scen_dir = os.path.join(root, "scenarios")
    if not os.path.isdir(scen_dir):
        return
    for fname in sorted(os.listdir(scen_dir)):
        if not fname.endswith(".json"):
            continue
        rel = os.path.join("scenarios", fname)
        with open(os.path.join(scen_dir, fname), encoding="utf-8") as fh:
            text = fh.read()
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            findings.append(
                Finding(
                    "R9",
                    rel,
                    e.lineno,
                    "committed scenario spec is not valid JSON: %s" % e.msg,
                )
            )
            continue
        if not isinstance(doc, dict):
            findings.append(
                Finding("R9", rel, 1, "a scenario spec must be a JSON object")
            )
            continue
        assertions = doc.get("assertions")
        if not isinstance(assertions, list) or not assertions:
            findings.append(
                Finding(
                    "R9",
                    rel,
                    1,
                    "scenario spec carries no assertions — a committed spec "
                    "must declare at least one postcondition",
                )
            )


# ---------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------


def collect_sources(root):
    src_root = os.path.join(root, "rust", "src")
    sources = []
    for dirpath, _dirnames, filenames in os.walk(src_root):
        for fname in sorted(filenames):
            if not fname.endswith(".rs"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root)
            sources.append(Source(path, rel))
    return sources


def run(root, allowlist):
    """Run every rule over `root`; returns (findings, notes)."""
    sources = collect_sources(root)
    findings = []
    for src in sources:
        check_r1(src, findings)
        check_r2(src, findings)
        check_r3(src, findings)
        check_r7(src, findings)
    check_r4(root, sources, findings)
    check_r5(root, sources, findings)
    check_r6(root, sources, allowlist.lock_order if allowlist else [], findings)
    check_r8(root, sources, findings)
    check_r9(root, findings)
    notes = []
    if allowlist:
        findings = allowlist.filter(findings)
        for e in allowlist.entries:
            if not e.used:
                notes.append(
                    "note: unused allowlist entry (%s %s %s) — remove it or "
                    "fix the pattern" % (e.rule, e.file, e.contains)
                )
    findings.sort(key=lambda f: (f.rel, f.line, f.rule))
    return findings, notes


def main(argv=None):
    default_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap = argparse.ArgumentParser(description="fabric-lint invariant checker")
    ap.add_argument("--root", default=default_root, help="repo root (contains rust/src)")
    ap.add_argument(
        "--allowlist",
        default=None,
        help="allowlist file (default: <root>/scripts/fabric_lint_allow.toml)",
    )
    ap.add_argument("--no-allowlist", action="store_true", help="ignore the allowlist")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    allowlist = None
    if not args.no_allowlist:
        path = args.allowlist or os.path.join(args.root, "scripts", "fabric_lint_allow.toml")
        if os.path.exists(path):
            with open(path, encoding="utf-8") as fh:
                allowlist = Allowlist.parse(fh.read(), path)
            if allowlist.errors:
                for e in allowlist.errors:
                    print(e, file=sys.stderr)
                return 2
        elif args.allowlist:
            print("fabric-lint: allowlist %s not found" % path, file=sys.stderr)
            return 2

    findings, notes = run(args.root, allowlist)
    for f in findings:
        print(f)
    if args.verbose:
        for n in notes:
            print(n)
    n_allowed = sum(1 for e in (allowlist.entries if allowlist else []) if e.used)
    if findings:
        print(
            "fabric-lint: %d finding(s) (%d allowlisted)" % (len(findings), n_allowed),
            file=sys.stderr,
        )
        return 1
    if args.verbose:
        print("fabric-lint: clean (%d allowlisted exception(s))" % n_allowed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
