#!/usr/bin/env python3
"""Compare a regenerated bench report against a committed baseline.

Usage: bench_diff.py BASELINE.json NEW.json [--tolerance PCT]
       bench_diff.py --self-test

Both files are the section/headline JSON the benches emit via
`--json` (see README "Benches"). Every numeric headline present in
BOTH files is compared; relative deviations beyond the tolerance
(default 30%) are printed as warnings. Non-numeric fields (e.g.
`provenance`) and headlines present on only one side are reported
informationally.

Warn-only by design: always exits 0. The perf gates that should FAIL
CI live inside the benches themselves (proxy_overhead asserts
batched < looped); this script is the trend report for the pinned
BENCH_*.json trajectory.
"""

import json
import sys
import tempfile


def flatten(doc, prefix=""):
    out = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(flatten(v, prefix + k + "."))
    else:
        out[prefix.rstrip(".")] = doc
    return out


def diff(base, new, tol):
    """Compare two flattened reports; returns (lines, warned)."""
    lines = []
    warned = 0
    for key in sorted(set(base) | set(new)):
        b, n = base.get(key), new.get(key)
        if key.endswith("provenance"):
            continue
        if b is None or n is None:
            side = "baseline" if n is None else "regenerated"
            lines.append(f"  note: {key} only in {side} report")
            continue
        if not isinstance(b, (int, float)) or not isinstance(n, (int, float)):
            continue
        if b == 0:
            if n != 0:
                lines.append(f"  WARN {key}: baseline 0, now {n}")
                warned += 1
            continue
        dev = (n - b) / abs(b)
        marker = "WARN" if abs(dev) > tol else "  ok"
        if abs(dev) > tol:
            warned += 1
        lines.append(f"  {marker} {key}: {b} -> {n} ({dev:+.1%})")
    return lines, warned


def self_test():
    """Fixture check of flatten/diff: nesting, tolerance boundary,
    provenance skip, one-sided keys, zero baselines, and the
    end-to-end file path. Exits 1 on any mismatch (unlike the diff
    itself, the self-test is a real gate)."""
    base = {
        "submit": {"p50_ns": 100, "p99_ns": 1000, "provenance": "desk"},
        "old_only": {"v": 1},
        "zero": 0,
        "label": "text",
    }
    new = {
        "submit": {"p50_ns": 120, "p99_ns": 1400, "provenance": "measured"},
        "new_only": {"v": 2},
        "zero": 5,
        "label": "other",
    }
    lines, warned = diff(flatten(base), flatten(new), 0.30)
    joined = "\n".join(lines)
    checks = [
        # 20% deviation within the 30% tolerance; 40% beyond it.
        ("  ok submit.p50_ns: 100 -> 120 (+20.0%)" in joined, "ok line"),
        ("WARN submit.p99_ns: 1000 -> 1400 (+40.0%)" in joined, "warn line"),
        ("provenance" not in joined, "provenance skipped"),
        ("old_only.v only in baseline" in joined, "baseline-only note"),
        ("new_only.v only in regenerated" in joined, "regenerated-only note"),
        ("WARN zero: baseline 0, now 5" in joined, "zero-baseline warn"),
        ("label" not in joined, "non-numeric skipped"),
        (warned == 2, f"warn count (got {warned})"),
    ]
    # Tolerance boundary: exactly-at-tolerance is ok, just-over warns.
    _, w_at = diff({"k": 100}, {"k": 130}, 0.30)
    _, w_over = diff({"k": 100}, {"k": 131}, 0.30)
    checks.append((w_at == 0, "at-tolerance is ok"))
    checks.append((w_over == 1, "over-tolerance warns"))
    # End-to-end through the file-reading main().
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as fb:
        json.dump(base, fb)
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as fn:
        json.dump(new, fn)
    main([fb.name, fn.name])

    failed = [name for ok, name in checks if not ok]
    if failed:
        print(f"bench_diff --self-test: FAILED: {', '.join(failed)}")
        return 1
    print(f"bench_diff --self-test: {len(checks)} checks passed")
    return 0


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if "--self-test" in argv:
        sys.exit(self_test())
    args = [a for a in argv if not a.startswith("--")]
    tol = 0.30
    if "--tolerance" in argv:
        tol = float(argv[argv.index("--tolerance") + 1]) / 100.0
    if len(args) != 2:
        print(__doc__)
        return
    try:
        with open(args[0]) as fh:
            base = flatten(json.load(fh))
        with open(args[1]) as fh:
            new = flatten(json.load(fh))
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read reports ({e}); skipping (warn-only)")
        return

    lines, warned = diff(base, new, tol)
    for line in lines:
        print(line)
    if warned:
        print(
            f"bench_diff: {warned} headline(s) deviate more than "
            f"{tol:.0%} from the committed baseline (warn-only; update "
            f"BENCH_*.json deliberately if the change is intended)"
        )
    else:
        print("bench_diff: all shared headlines within tolerance")


if __name__ == "__main__":
    main()
