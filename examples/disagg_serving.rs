//! End-to-end disaggregated serving (the paper's §4 system, live).
//!
//! A prefiller node and a decoder node each hold the AOT-compiled MoE
//! transformer (PJRT, from `artifacts/`). For every request:
//!
//! 1. the decoder allocates KV pages + registers an IMMCOUNTER
//!    expectation, then dispatches the request to the prefiller
//!    (SEND/RECV);
//! 2. the prefiller runs **real** prefill via PJRT, writes the KV
//!    cache layer-by-layer into the decoder's registered memory with
//!    paged WRITEIMMs, and the tail (logits) last;
//! 3. the decoder starts decoding from the transferred cache — and the
//!    result is verified **bit-for-bit** against a non-disaggregated
//!    reference run on the same weights.
//!
//! Requires artifacts: `make artifacts` first.
//! Run: cargo run --release --example disagg_serving

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fabric_lib::engine::api::Pages;
use fabric_lib::engine::threaded::{OnDoneT, ThreadedEngine};
use fabric_lib::fabric::local::LocalFabric;
use fabric_lib::fabric::profile::TransportKind;
use fabric_lib::runtime::Runtime;

fn main() -> fabric_lib::util::err::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    // Both nodes load the same AOT model (same baked weights).
    let prefill_rt = Runtime::load(&dir)?;
    let decode_rt = Runtime::load(&dir)?;
    let m = decode_rt.model.clone();
    println!(
        "model: {} params, {} layers, d_model {}, {} experts (AOT via PJRT)",
        m.param_count, m.n_layers, m.d_model, m.n_experts
    );

    let fabric = LocalFabric::new(TransportKind::Srd, 11);
    let prefiller = ThreadedEngine::new(&fabric, 0, 1, 2);
    let decoder = ThreadedEngine::new(&fabric, 1, 1, 2);

    // KV layout: one "page" = one layer's K or V for the whole
    // sequence bucket (simple paged layout for the example).
    let seq = 32usize;
    let dh = m.d_model / m.n_heads;
    let layer_kv_floats = m.n_heads * seq * dh;
    let page_bytes = (layer_kv_floats * 4) as u64;
    let n_pages = 2 * m.n_layers; // K and V per layer
    let (kv_dst_h, kv_dst_d) = decoder.alloc_mr(0, (page_bytes * n_pages as u64) as usize);
    let (kv_src_h, _) = prefiller.alloc_mr(0, (page_bytes * n_pages as u64) as usize);
    let (tail_dst_h, tail_dst_d) = decoder.alloc_mr(0, m.vocab * 4);
    let (tail_src_h, _) = prefiller.alloc_mr(0, m.vocab * 4);

    let reqs: Vec<Vec<i32>> = (0..4)
        .map(|r| (0..seq as i32).map(|i| (i * 7 + r * 13 + 3) % m.vocab as i32).collect())
        .collect();

    let mut ttfts = Vec::new();
    let t_all = Instant::now();
    for (rid, toks) in reqs.iter().enumerate() {
        let t0 = Instant::now();
        // --- prefiller: real PJRT prefill ---
        let (logits, k, v) = prefill_rt.prefill(toks)?;
        // Stage KV into the registered source region (layer-major,
        // K pages then V pages per layer).
        for l in 0..m.n_layers {
            let off = (l * seq * m.n_heads * dh * 4) as usize;
            let bytes_k: &[u8] = cast_f32(&k[l * layer_kv_floats..(l + 1) * layer_kv_floats]);
            let bytes_v: &[u8] = cast_f32(&v[l * layer_kv_floats..(l + 1) * layer_kv_floats]);
            let _ = off;
            kv_src_h.buf.write((2 * l) as usize * page_bytes as usize, bytes_k);
            kv_src_h.buf.write((2 * l + 1) as usize * page_bytes as usize, bytes_v);
        }
        tail_src_h.buf.write(0, cast_f32(&logits));

        // --- decoder: expect pages*1 + tail, then transfer ---
        let imm = 100 + rid as u32;
        let transferred = Arc::new(AtomicBool::new(false));
        let tr = transferred.clone();
        decoder.expect_imm_count(0, imm, n_pages as u32 + 1, move || {
            tr.store(true, Ordering::Release)
        });
        // Layer-by-layer paged writes (layer l's K+V as 2 pages).
        for l in 0..m.n_layers as u32 {
            prefiller
                .submit_paged_writes(
                    page_bytes,
                    (&kv_src_h, &Pages::contiguous(2 * l, 2, page_bytes)),
                    (&kv_dst_d, &Pages::contiguous(2 * l, 2, page_bytes)),
                    Some(imm),
                    OnDoneT::Noop,
                )
                .expect("KV paged write");
        }
        prefiller
            .submit_single_write(
                (&tail_src_h, 0),
                (m.vocab * 4) as u64,
                (&tail_dst_d, 0),
                Some(imm),
                OnDoneT::Noop,
            )
            .expect("tail write");
        let deadline = Instant::now() + Duration::from_secs(30);
        while !transferred.load(Ordering::Acquire) {
            assert!(Instant::now() < deadline, "transfer timeout");
            std::thread::yield_now();
        }

        // --- decoder: reconstruct caches, decode with real PJRT ---
        let mut kc = vec![0f32; m.n_layers * m.n_heads * m.max_seq * dh];
        let mut vc = kc.clone();
        let mut page = vec![0u8; page_bytes as usize];
        for l in 0..m.n_layers {
            for (which, cache) in [(0usize, &mut kc), (1usize, &mut vc)] {
                kv_dst_h.buf.read((2 * l + which) * page_bytes as usize, &mut page);
                let floats = cast_u8_to_f32(&page);
                for h in 0..m.n_heads {
                    for s in 0..seq {
                        let src_off = (h * seq + s) * dh;
                        let dst_off = ((l * m.n_heads + h) * m.max_seq + s) * dh;
                        cache[dst_off..dst_off + dh]
                            .copy_from_slice(&floats[src_off..src_off + dh]);
                    }
                }
            }
        }
        let mut tail = vec![0u8; m.vocab * 4];
        tail_dst_h.buf.read(0, &mut tail);
        let logits_rx = cast_u8_to_f32(&tail).to_vec();
        let mut tok = Runtime::argmax(&logits_rx);
        let ttft = t0.elapsed();
        ttfts.push(ttft);

        // Greedy-decode a few tokens from the transferred cache.
        let mut produced = vec![tok];
        let mut pos = seq as i32;
        for _ in 0..4 {
            let (lg, k2, v2) = decode_rt.decode(tok, &kc, &vc, pos)?;
            kc = k2;
            vc = v2;
            tok = Runtime::argmax(&lg);
            produced.push(tok);
            pos += 1;
        }

        // --- verification vs non-disaggregated reference ---
        let (ref_logits, rk, rv) = decode_rt.prefill(toks)?;
        assert_eq!(cast_f32(&ref_logits), cast_f32(&logits_rx), "logits must match bit-for-bit");
        let mut rkc = vec![0f32; m.n_layers * m.n_heads * m.max_seq * dh];
        let mut rvc = rkc.clone();
        for l in 0..m.n_layers {
            for h in 0..m.n_heads {
                for s in 0..seq {
                    let src = ((l * m.n_heads + h) * seq + s) * dh;
                    let dst = ((l * m.n_heads + h) * m.max_seq + s) * dh;
                    rkc[dst..dst + dh].copy_from_slice(&rk[src..src + dh]);
                    rvc[dst..dst + dh].copy_from_slice(&rv[src..src + dh]);
                }
            }
        }
        let mut rtok = Runtime::argmax(&ref_logits);
        let mut ref_produced = vec![rtok];
        let mut rpos = seq as i32;
        for _ in 0..4 {
            let (lg, k2, v2) = decode_rt.decode(rtok, &rkc, &rvc, rpos)?;
            rkc = k2;
            rvc = v2;
            rtok = Runtime::argmax(&lg);
            ref_produced.push(rtok);
            rpos += 1;
        }
        assert_eq!(produced, ref_produced, "disaggregated decode diverged!");
        println!(
            "req {rid}: TTFT {:.1} ms, tokens {:?} == reference ✓",
            ttft.as_secs_f64() * 1e3,
            produced
        );
    }
    let total = t_all.elapsed();
    println!(
        "\nserved {} requests in {:.2} s ({:.1} req/s); mean TTFT {:.1} ms; \
         KV bytes/request: {}",
        reqs.len(),
        total.as_secs_f64(),
        reqs.len() as f64 / total.as_secs_f64(),
        ttfts.iter().map(|t| t.as_secs_f64()).sum::<f64>() / ttfts.len() as f64 * 1e3,
        page_bytes * n_pages as u64,
    );
    prefiller.shutdown();
    decoder.shutdown();
    fabric.shutdown();
    println!("disagg_serving OK — disaggregated output verified against reference");
    Ok(())
}

fn cast_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn cast_u8_to_f32(v: &[u8]) -> &[f32] {
    assert_eq!(v.len() % 4, 0);
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const f32, v.len() / 4) }
}
