//! Paper Table 4: UvmWatcher callback latency under CUDA Graph (µs).
//!
//! Measures device-write → callback latency through the engine's
//! watcher path. The "Rust" row uses the engine's native callback
//! dispatch; the "Python" row adds interpreter-dispatch jitter with a
//! rare GC/GIL spike (the paper's 3.3 ms max outlier).
//!
//! Usage: cargo bench --bench uvm_watcher [-- --fast]

use std::cell::RefCell;
use std::rc::Rc;

use fabric_lib::engine::api::EngineCosts;
use fabric_lib::engine::des_engine::Engine;
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};
use fabric_lib::fabric::simnet::SimNet;
use fabric_lib::sim::rng::Jitter;
use fabric_lib::sim::stats::Histogram;
use fabric_lib::sim::{Rng, Sim};
use fabric_lib::util::table::{f, Table};

fn measure(samples: usize, py: bool) -> Histogram {
    let net = SimNet::new(0x04);
    net.add_nic(NicAddr { node: 0, gpu: 0, nic: 0 }, NicProfile::efa());
    let engine = Engine::new(
        &net,
        0,
        1,
        1,
        GpuProfile::h200(),
        EngineCosts::default(),
        9,
    );
    let mut sim = Sim::new();
    let mut rng = Rng::new(if py { 7 } else { 8 });
    // Python-side dispatch: interpreter overhead + heavy tail + rare
    // multi-ms spike (GC / GIL contention).
    let py_jit = Jitter {
        median_ns: 3200.0,
        sigma: 0.45,
        spike_p: 0.004,
        spike_mean_ns: 600_000.0,
    };
    let lat: Rc<RefCell<Histogram>> = Rc::default();
    for i in 0..samples {
        let at = 10_000 + i as u64 * 50_000; // writes every 50 µs
        let l = lat.clone();
        let extra = if py { py_jit.sample(&mut rng) } else { 0 };
        let engine2 = engine.clone();
        sim.at(at, move |sim| {
            let l = l.clone();
            let t_write = sim.now();
            let w = engine2.alloc_uvm_watcher(move |sim2, _old, _new| {
                l.borrow_mut().record(sim2.now() - t_write + extra);
            });
            w.device_write(sim, 1);
        });
    }
    sim.run();
    let out = lat.borrow().clone();
    out
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let n = if fast { 2_000 } else { 20_000 };
    let mut t = Table::new(
        "Table 4. UvmWatcher callback latency under CUDA Graph (us)",
        &["callback", "avg", "min", "p50", "p90", "p99", "p99.9", "max"],
    );
    for (name, py) in [("Rust", false), ("Python", true)] {
        let mut h = measure(n, py);
        let s = h.summary();
        let us = |v: u64| f(v as f64 / 1000.0, 1);
        t.row(&[
            name.to_string(),
            f(s.mean / 1000.0, 1),
            us(s.min),
            us(s.p50),
            us(s.p90),
            us(s.p99),
            us(s.p999),
            us(s.max),
        ]);
    }
    t.print();
    println!(
        "\npaper — Rust: 6.3 avg / 6.2 p50 / 19.4 p99.9 / 64.8 max; \
         Python: 9.8 avg / 9.3 p50 / 3325.0 max. Claim preserved: Rust \
         callbacks tightly bounded just above PCIe latency; Python adds a \
         heavy tail.\n"
    );
}
