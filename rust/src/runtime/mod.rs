//! PJRT runtime: load AOT-compiled HLO artifacts and execute them
//! from the Rust hot path — Python never runs at request time.

pub mod pjrt;

pub use pjrt::{ArgValue, ModelInfo, Runtime};
