//! API-compatible stub for the PJRT runtime, compiled when the `xla`
//! feature is off (the out-of-tree `xla` crate is not vendored, so the
//! default build must not reference it).
//!
//! Every constructor fails with a clear message; the type/function
//! surface matches `pjrt.rs` so callers compile unchanged and the
//! artifact-gated integration tests skip exactly as they do when
//! `artifacts/manifest.json` is absent.

use std::path::Path;

use crate::bail;
use crate::util::err::Result;

/// Model hyper-parameters recorded in the manifest.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub max_seq: usize,
    pub param_count: usize,
}

impl ModelInfo {
    /// f32 KV-cache bytes for `tokens` positions across all layers
    /// (K and V), matching the cache shapes in `model.py`.
    pub fn kv_bytes(&self, tokens: usize) -> usize {
        2 * self.n_layers * self.n_heads * tokens * (self.d_model / self.n_heads) * 4
    }
}

/// Input argument for execution.
pub enum ArgValue<'a> {
    /// Scalar i32 (token ids, positions).
    I32(i32),
    /// f32 tensor with shape.
    F32(&'a [f32], &'a [usize]),
}

/// Stub executable cache: construction always fails.
pub struct Runtime {
    pub model: ModelInfo,
}

impl Runtime {
    /// Always errors: the real backend needs `--features xla`.
    pub fn load(_dir: impl AsRef<Path>) -> Result<Runtime> {
        bail!(
            "PJRT runtime unavailable: fabric_lib was built without the \
             `xla` feature (the XLA/PJRT crate is not vendored offline)"
        )
    }

    /// Entry names available.
    pub fn entries(&self) -> Vec<String> {
        Vec::new()
    }

    /// Number of outputs of an entry.
    pub fn output_count(&self, name: &str) -> Result<usize> {
        bail!("stub runtime has no entry {name}")
    }

    /// Output shape of entry `name`, index `i`.
    pub fn output_shape(&self, name: &str, _i: usize) -> Result<Vec<usize>> {
        bail!("stub runtime has no entry {name}")
    }

    /// Execute `name` with typed args.
    pub fn execute(&self, name: &str, _args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        bail!("stub runtime cannot execute {name}")
    }

    /// Convenience: prefill at bucket length `tokens.len()`.
    pub fn prefill(&self, _tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        bail!("stub runtime cannot prefill")
    }

    /// Convenience: one decode step.
    pub fn decode(
        &self,
        _token: i32,
        _k_cache: &[f32],
        _v_cache: &[f32],
        _pos: i32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        bail!("stub runtime cannot decode")
    }

    /// Argmax helper for greedy decoding.
    pub fn argmax(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_clear_message() {
        let err = Runtime::load("artifacts").unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
    }

    #[test]
    fn argmax_matches_reference() {
        assert_eq!(Runtime::argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(Runtime::argmax(&[]), 0);
    }
}
