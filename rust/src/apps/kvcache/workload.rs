//! Serving workload description + prefill compute model.
//!
//! The timing model stands in for the Qwen3-235B / H200-TP4 testbed of
//! paper Table 3. Per-layer prefill time for a chunk of `C` tokens at
//! context depth `ctx` follows a linear+attention roofline
//! `C * (alpha + beta * (ctx + C/2))`, calibrated to the paper's
//! per-layer compute column (2.27 ms at 4K → 34.9 ms at 128K with
//! 16K chunks).

use crate::sim::time::Duration;

use super::layout::KvLayout;

/// Calibrated per-layer prefill compute model.
#[derive(Debug, Clone)]
pub struct PrefillComputeModel {
    /// ns per token (MLP + projections).
    pub alpha_ns: f64,
    /// ns per token per context token (attention).
    pub beta_ns: f64,
    /// Decode-pass time for one token (the extra final-token decode
    /// pass the paper attributes most TTFT overhead to).
    pub decode_pass_ns: Duration,
}

impl PrefillComputeModel {
    /// Qwen3-235B on H200 TP4 (paper Table 3 calibration).
    pub fn qwen3_235b_tp4() -> Self {
        PrefillComputeModel {
            alpha_ns: 540.0,
            beta_ns: 0.0145,
            decode_pass_ns: 40_000_000, // ~40 ms forward pass
        }
    }

    /// Per-layer time for a chunk of `chunk` tokens whose context
    /// (tokens before the chunk) is `ctx` tokens.
    pub fn layer_ns(&self, chunk: u32, ctx: u32) -> Duration {
        let c = chunk as f64;
        let t = c * (self.alpha_ns + self.beta_ns * (ctx as f64 + c / 2.0));
        t.ceil() as Duration
    }
}

/// A Table-3-style serving workload.
#[derive(Debug, Clone)]
pub struct ServingWorkload {
    pub layout: KvLayout,
    pub compute: PrefillComputeModel,
    /// Max chunk length for chunked prefill (paper: 16384).
    pub chunk_tokens: u32,
    /// Tail context bytes (last hidden state + logits).
    pub tail_bytes: u64,
}

impl ServingWorkload {
    /// The paper's Table 3 configuration: 32 KiB pages of 128 tokens,
    /// 94 layers (Qwen3-235B), chunked prefill at 16 K tokens.
    pub fn qwen3_235b(seq_tokens: u32) -> Self {
        let layout = KvLayout {
            page_bytes: 32 * 1024,
            tokens_per_page: 128,
            layers: 94,
            slots_per_layer: (seq_tokens / 128).max(16) * 2,
        };
        ServingWorkload {
            layout,
            compute: PrefillComputeModel::qwen3_235b_tp4(),
            chunk_tokens: 16384,
            tail_bytes: 256 * 1024,
        }
    }

    /// Tiny configuration for integration tests (backed buffers).
    pub fn tiny() -> Self {
        ServingWorkload {
            layout: KvLayout {
                page_bytes: 4096,
                tokens_per_page: 16,
                layers: 3,
                slots_per_layer: 32,
            },
            compute: PrefillComputeModel {
                alpha_ns: 100.0,
                beta_ns: 0.001,
                decode_pass_ns: 50_000,
            },
            chunk_tokens: 64,
            tail_bytes: 1024,
        }
    }

    /// Chunk boundaries for a sequence: (start, len) pairs.
    pub fn chunks(&self, seq: u32) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        let mut start = 0;
        while start < seq {
            let len = (seq - start).min(self.chunk_tokens);
            out.push((start, len));
            start += len;
        }
        out
    }

    /// Total prefill compute across layers and chunks.
    pub fn total_prefill_ns(&self, seq: u32) -> Duration {
        self.chunks(seq)
            .iter()
            .map(|&(start, len)| self.compute.layer_ns(len, start) * self.layout.layers as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::MS;

    #[test]
    fn chunking() {
        let w = ServingWorkload::qwen3_235b(40_000);
        let chunks = w.chunks(40_000);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], (0, 16384));
        assert_eq!(chunks[2], (32768, 40_000 - 32768));
        assert_eq!(w.chunks(100), vec![(0, 100)]);
    }

    #[test]
    fn compute_model_matches_paper_shape() {
        let m = PrefillComputeModel::qwen3_235b_tp4();
        // Table 3 per-layer compute: 4K → 2.267 ms, 16K → 9.860 ms,
        // 128K (last chunk at ctx 112K) → 34.895 ms. Allow 30%.
        let t4k = m.layer_ns(4096, 0);
        assert!((t4k as f64) > 1.5 * MS as f64 && (t4k as f64) < 3.2 * MS as f64, "{t4k}");
        let t16k = m.layer_ns(16384, 0);
        assert!((t16k as f64) > 7.0 * MS as f64 && (t16k as f64) < 13.0 * MS as f64, "{t16k}");
        let t128k_last = m.layer_ns(16384, 112 * 1024);
        assert!(
            (t128k_last as f64) > 25.0 * MS as f64 && (t128k_last as f64) < 45.0 * MS as f64,
            "{t128k_last}"
        );
        // Monotonic in context depth.
        assert!(m.layer_ns(16384, 65536) > m.layer_ns(16384, 16384));
    }

    #[test]
    fn total_prefill_grows_superlinearly() {
        let w = ServingWorkload::qwen3_235b(131072);
        let t32 = w.total_prefill_ns(32768);
        let t128 = w.total_prefill_ns(131072);
        assert!(t128 > 4 * t32, "attention term must bite: {t32} {t128}");
    }
}
