//! Control-plane messages between decoder and prefiller (Appendix A,
//! Fig 13), serialized with the engine wire format.

use crate::bail;
use crate::util::err::Result;

use crate::engine::api::{MrDesc, NetAddr};
use crate::engine::wire::{self, tag, Dec, Enc};

/// Decoder → prefiller: run prefill, WRITE results into my memory
/// (paper Fig 13 `DispatchReq`).
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchReq {
    /// Request id (for cancellation and bookkeeping).
    pub req_id: u64,
    /// Input token ids.
    pub input_ids: Vec<u32>,
    /// Decoder's domain-group address (for the prefiller's replies).
    pub decoder_addr: NetAddr,
    /// Immediate value the decoder's IMMCOUNTER expects.
    pub imm: u32,
    /// Descriptor of the decoder's KV region.
    pub kv_desc: MrDesc,
    /// Page slot indices (per layer addressing is derived from the
    /// layout; slot i holds tokens [i*tokens_per_page, ...)).
    pub pages: Vec<u32>,
    /// Descriptor of the decoder's tail-context region.
    pub tail_desc: MrDesc,
    /// Tail slot index.
    pub tail_idx: u32,
}

impl DispatchReq {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new(tag::KV_DISPATCH);
        e.u64(self.req_id);
        e.u32(self.input_ids.len() as u32);
        for &t in &self.input_ids {
            e.u32(t);
        }
        e.bytes(&wire::encode_net_addr(&self.decoder_addr));
        e.u32(self.imm);
        e.bytes(&wire::encode_mr_desc(&self.kv_desc));
        e.u32(self.pages.len() as u32);
        for &p in &self.pages {
            e.u32(p);
        }
        e.bytes(&wire::encode_mr_desc(&self.tail_desc));
        e.u32(self.tail_idx);
        e.finish()
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<DispatchReq> {
        let (t, mut d) = Dec::open(buf)?;
        if t != tag::KV_DISPATCH {
            bail!("expected KV_DISPATCH, got {t}");
        }
        let req_id = d.u64()?;
        let n = d.u32()? as usize;
        let mut input_ids = Vec::with_capacity(n);
        for _ in 0..n {
            input_ids.push(d.u32()?);
        }
        let decoder_addr = wire::decode_net_addr(&d.bytes()?)?;
        let imm = d.u32()?;
        let kv_desc = wire::decode_mr_desc(&d.bytes()?)?;
        let np = d.u32()? as usize;
        let mut pages = Vec::with_capacity(np);
        for _ in 0..np {
            pages.push(d.u32()?);
        }
        let tail_desc = wire::decode_mr_desc(&d.bytes()?)?;
        let tail_idx = d.u32()?;
        d.done()?;
        Ok(DispatchReq {
            req_id,
            input_ids,
            decoder_addr,
            imm,
            kv_desc,
            pages,
            tail_desc,
            tail_idx,
        })
    }
}

/// Decoder → prefiller: stop writing for `req_id` and confirm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CancelReq {
    pub req_id: u64,
}

impl CancelReq {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new(tag::KV_CANCEL);
        e.u64(self.req_id);
        e.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<CancelReq> {
        let (t, mut d) = Dec::open(buf)?;
        if t != tag::KV_CANCEL {
            bail!("expected KV_CANCEL");
        }
        let req_id = d.u64()?;
        d.done()?;
        Ok(CancelReq { req_id })
    }
}

/// Prefiller → decoder: no further WRITEs for `req_id` will be
/// issued; its pages may be reused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CancelAck {
    pub req_id: u64,
}

impl CancelAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new(tag::KV_CANCEL_ACK);
        e.u64(self.req_id);
        e.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<CancelAck> {
        let (t, mut d) = Dec::open(buf)?;
        if t != tag::KV_CANCEL_ACK {
            bail!("expected KV_CANCEL_ACK");
        }
        let req_id = d.u64()?;
        d.done()?;
        Ok(CancelAck { req_id })
    }
}

/// Liveness probe, both directions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Heartbeat {
    pub sender_node: u16,
    pub seq: u64,
}

impl Heartbeat {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new(tag::HEARTBEAT);
        e.u16(self.sender_node);
        e.u64(self.seq);
        e.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Heartbeat> {
        let (t, mut d) = Dec::open(buf)?;
        if t != tag::HEARTBEAT {
            bail!("expected HEARTBEAT");
        }
        let sender_node = d.u16()?;
        let seq = d.u64()?;
        d.done()?;
        Ok(Heartbeat { sender_node, seq })
    }
}

/// Peek the tag of an incoming control message.
pub fn msg_tag(buf: &[u8]) -> Result<u8> {
    Ok(Dec::open(buf)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::nic::NicAddr;

    fn addr() -> NetAddr {
        NetAddr {
            nics: vec![NicAddr { node: 1, gpu: 2, nic: 0 }],
        }
    }

    fn desc() -> MrDesc {
        MrDesc {
            ptr: 0xABCD,
            len: 1 << 20,
            rkeys: vec![(NicAddr { node: 1, gpu: 2, nic: 0 }, 7)],
        }
    }

    #[test]
    fn dispatch_roundtrip() {
        let req = DispatchReq {
            req_id: 42,
            input_ids: vec![1, 2, 3, 500],
            decoder_addr: addr(),
            imm: 99,
            kv_desc: desc(),
            pages: vec![5, 9, 0],
            tail_desc: desc(),
            tail_idx: 3,
        };
        let bytes = req.encode();
        assert_eq!(DispatchReq::decode(&bytes).unwrap(), req);
        assert_eq!(msg_tag(&bytes).unwrap(), tag::KV_DISPATCH);
    }

    #[test]
    fn cancel_roundtrip_and_tag_dispatch() {
        let c = CancelReq { req_id: 7 };
        assert_eq!(CancelReq::decode(&c.encode()).unwrap(), c);
        let a = CancelAck { req_id: 7 };
        assert_eq!(CancelAck::decode(&a.encode()).unwrap(), a);
        let h = Heartbeat { sender_node: 3, seq: 12 };
        assert_eq!(Heartbeat::decode(&h.encode()).unwrap(), h);
        // Cross-decoding fails loudly.
        assert!(DispatchReq::decode(&c.encode()).is_err());
        assert!(CancelReq::decode(&a.encode()).is_err());
    }

    #[test]
    fn truncated_dispatch_fails() {
        let req = DispatchReq {
            req_id: 1,
            input_ids: vec![1],
            decoder_addr: addr(),
            imm: 1,
            kv_desc: desc(),
            pages: vec![1],
            tail_desc: desc(),
            tail_idx: 0,
        };
        let bytes = req.encode();
        for cut in [3, 10, bytes.len() - 1] {
            assert!(DispatchReq::decode(&bytes[..cut]).is_err());
        }
    }
}
