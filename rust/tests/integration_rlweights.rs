//! Integration: RL weight transfer — P2P pipeline vs rank0 baseline,
//! schedule determinism, and the Fig-4 comparison claim.

use fabric_lib::apps::rlweights::{
    compute_routing, run_p2p_transfer, run_rank0_broadcast, RlModelSpec,
};
use fabric_lib::fabric::profile::NicProfile;

#[test]
fn p2p_beats_rank0_baseline_decisively() {
    // 8-rank slice, proportional bytes: the baseline still pushes all
    // bytes through one NIC while P2P uses every NIC.
    let spec = RlModelSpec {
        t_ranks: 8,
        r_ranks: 4,
        total_params: 40_000_000_000, // 40B params
        params_per_rank: 64,
        ..RlModelSpec::kimi_k2_1t()
    };
    let p2p = run_p2p_transfer(&spec, NicProfile::connectx7(), 1.0);
    let base = run_rank0_broadcast(&spec, NicProfile::connectx7(), 1);
    assert!(
        base.total_ms > 2.0 * p2p.total_ms,
        "P2P {} ms must beat rank0 {} ms",
        p2p.total_ms,
        base.total_ms
    );
}

#[test]
fn p2p_transfer_is_deterministic() {
    let spec = RlModelSpec::tiny();
    let a = run_p2p_transfer(&spec, NicProfile::efa(), 1.0);
    let b = run_p2p_transfer(&spec, NicProfile::efa(), 1.0);
    assert_eq!(a.total_ms, b.total_ms);
    assert_eq!(a.bytes, b.bytes);
    assert_eq!(a.rank0.rdma_calls, b.rank0.rdma_calls);
}

#[test]
fn routing_is_static_and_consistent_across_calls() {
    // Appendix B: the schedule is computed once and reused every step
    // without re-planning — recomputation must give the same result.
    let spec = RlModelSpec::kimi_k2_1t();
    for rank in [0u32, 17, 255] {
        let a = compute_routing(&spec, rank);
        let b = compute_routing(&spec, rank);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.dst, y.dst);
            assert_eq!(x.dst_offset, y.dst_offset);
            assert_eq!(x.param.elems, y.param.elems);
        }
    }
}

#[test]
fn transfer_scales_with_model_size() {
    let small = RlModelSpec {
        total_params: 1 << 30,
        ..RlModelSpec::tiny()
    };
    let large = RlModelSpec {
        total_params: 4 << 30,
        ..RlModelSpec::tiny()
    };
    let a = run_p2p_transfer(&small, NicProfile::connectx7(), 1.0);
    let b = run_p2p_transfer(&large, NicProfile::connectx7(), 1.0);
    assert!(b.total_ms > a.total_ms, "{} vs {}", a.total_ms, b.total_ms);
    assert_eq!(b.bytes, a.bytes * 4);
}

#[test]
fn stage_accounting_is_complete() {
    let spec = RlModelSpec::tiny();
    let r = run_p2p_transfer(&spec, NicProfile::connectx7(), 1.0);
    let t = r.rank0;
    // Every param accounted for in each stage.
    assert_eq!(t.h2d_calls, spec.params_per_rank);
    assert_eq!(t.full_tensor_calls, 2 * spec.params_per_rank);
    assert_eq!(t.fuse_calls, spec.params_per_rank);
    assert!(t.quantize_calls >= spec.params_per_rank);
    assert_eq!(t.rdma_calls, spec.params_per_rank * spec.replicas.min(spec.r_ranks));
    assert!(t.total > 0);
}
