//! PJRT runtime: load AOT-compiled HLO artifacts and execute them
//! from the Rust hot path — Python never runs at request time.
//!
//! The real backend (`pjrt.rs`) needs the out-of-tree `xla` crate and
//! is gated behind the `xla` feature; the default build compiles an
//! API-compatible stub whose `Runtime::load` errors, so artifact-gated
//! callers skip gracefully and the crate stays dependency-free.

#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use pjrt::{ArgValue, ModelInfo, Runtime};
