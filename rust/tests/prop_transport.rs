//! Property tests on the simulated transports: reliability (every
//! posted WR completes exactly once at both ends), RC in-order
//! delivery, payload integrity under random offsets/sizes, and the
//! payload-before-immediate invariant.

use fabric_lib::fabric::mem::DmaSlice;
use fabric_lib::fabric::nic::{CqeKind, NicAddr, QpId, WorkRequest, WrOp};
use fabric_lib::fabric::profile::NicProfile;
use fabric_lib::fabric::simnet::SimNet;
use fabric_lib::sim::{Rng, Sim};
use fabric_lib::util::prop::check;

struct Case {
    efa: bool,
    writes: Vec<(u64, u64)>, // (dst_off, len) disjoint
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Case(efa={}, {} writes)", self.efa, self.writes.len())
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let efa = rng.f64() < 0.5;
    // Disjoint destination ranges (slot i gets a random length).
    let n = 1 + rng.below(40) as usize;
    let slot = 64 * 1024 / n as u64;
    let writes = (0..n as u64)
        .map(|i| (i * slot, 1 + rng.below(slot.min(4096))))
        .collect();
    Case { efa, writes }
}

#[test]
fn prop_every_wr_completes_exactly_once_with_integrity() {
    check("transport reliability + integrity", gen_case, |case| {
        let net = SimNet::new(1234);
        let a = NicAddr { node: 0, gpu: 0, nic: 0 };
        let b = NicAddr { node: 1, gpu: 0, nic: 0 };
        let prof = if case.efa {
            NicProfile::efa()
        } else {
            NicProfile::connectx7()
        };
        net.add_nic(a, prof.clone());
        net.add_nic(b, prof);
        let mem = net.mem();
        let (sbuf, _) = mem.alloc(64 * 1024);
        let (dbuf, drkey) = mem.alloc(64 * 1024);
        let mut sim = Sim::new();
        for (i, &(off, len)) in case.writes.iter().enumerate() {
            let pat: Vec<u8> = (0..len).map(|j| ((i as u64 * 131 + j) % 251) as u8).collect();
            sbuf.write(off as usize, &pat);
            let ok = net.post(
                &mut sim,
                a,
                WorkRequest {
                    id: i as u64,
                    qp: QpId(1),
                    op: WrOp::Write {
                        dst: b,
                        dst_rkey: drkey,
                        dst_va: dbuf.base() + off,
                        src: DmaSlice::new(&sbuf, off as usize, len as usize),
                        imm: Some(i as u32),
                    },
                    chained: false,
                },
            );
            if !ok {
                return Err("unexpected backpressure".into());
            }
        }
        sim.run();
        // Sender completions: exactly one per WR.
        let mut cq = Vec::new();
        net.poll_cq(a, usize::MAX, &mut cq);
        let mut ids: Vec<u64> = cq
            .iter()
            .filter(|c| matches!(c.kind, CqeKind::WriteDone))
            .map(|c| c.wr_id)
            .collect();
        ids.sort_unstable();
        let want: Vec<u64> = (0..case.writes.len() as u64).collect();
        if ids != want {
            return Err(format!("sender completions {ids:?}"));
        }
        // Receiver imms: one per WR, any order.
        let mut rcq = Vec::new();
        net.poll_cq(b, usize::MAX, &mut rcq);
        let mut imms: Vec<u32> = rcq
            .iter()
            .filter_map(|c| match c.kind {
                CqeKind::ImmRecvd { imm, .. } => Some(imm),
                _ => None,
            })
            .collect();
        if imms.len() != case.writes.len() {
            return Err(format!("{} imms for {} writes", imms.len(), case.writes.len()));
        }
        imms.sort_unstable();
        if imms != (0..case.writes.len() as u32).collect::<Vec<_>>() {
            return Err("imm set mismatch".into());
        }
        // Payload integrity at every destination range.
        for (i, &(off, len)) in case.writes.iter().enumerate() {
            let mut got = vec![0u8; len as usize];
            dbuf.read(off as usize, &mut got);
            for (j, &g) in got.iter().enumerate() {
                let want = ((i as u64 * 131 + j as u64) % 251) as u8;
                if g != want {
                    return Err(format!("write {i} byte {j}: {g} != {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rc_delivers_in_posting_order_per_qp() {
    check(
        "RC in-order per QP",
        |rng: &mut Rng| {
            let n = 2 + rng.below(30) as usize;
            let sizes: Vec<u64> = (0..n).map(|_| 1 + rng.below(256 << 10)).collect();
            let qps: Vec<u32> = (0..n).map(|_| rng.below(3) as u32).collect();
            (sizes, qps)
        },
        |(sizes, qps)| {
            let net = SimNet::new(77);
            let a = NicAddr { node: 0, gpu: 0, nic: 0 };
            let b = NicAddr { node: 1, gpu: 0, nic: 0 };
            net.add_nic(a, NicProfile::connectx7());
            net.add_nic(b, NicProfile::connectx7());
            let mem = net.mem();
            let (sbuf, _) = mem.alloc(256 << 10);
            let (dbuf, drkey) = mem.alloc(256 << 10);
            let mut sim = Sim::new();
            for (i, (&len, &qp)) in sizes.iter().zip(qps.iter()).enumerate() {
                net.post(
                    &mut sim,
                    a,
                    WorkRequest {
                        id: i as u64,
                        qp: QpId(qp),
                        op: WrOp::Write {
                            dst: b,
                            dst_rkey: drkey,
                            dst_va: dbuf.base(),
                            src: DmaSlice::new(&sbuf, 0, len as usize),
                            imm: Some(i as u32),
                        },
                        chained: false,
                    },
                );
            }
            sim.run();
            let mut rcq = Vec::new();
            net.poll_cq(b, usize::MAX, &mut rcq);
            let arrival: Vec<u32> = rcq
                .iter()
                .filter_map(|c| match c.kind {
                    CqeKind::ImmRecvd { imm, .. } => Some(imm),
                    _ => None,
                })
                .collect();
            // Within each QP, arrival order must equal posting order.
            for q in 0..3u32 {
                let posted: Vec<u32> =
                    (0..sizes.len() as u32).filter(|&i| qps[i as usize] == q).collect();
                let arrived: Vec<u32> = arrival
                    .iter()
                    .copied()
                    .filter(|&i| qps[i as usize] == q)
                    .collect();
                if posted != arrived {
                    return Err(format!("QP{q}: posted {posted:?}, arrived {arrived:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_payload_visible_before_imm_under_srd() {
    // For every delivered immediate, the payload bytes must already be
    // readable — the IMMCOUNTER correctness keystone (§3.3).
    check(
        "payload-before-imm",
        |rng: &mut Rng| {
            let n = 1 + rng.below(64) as usize;
            (0..n).map(|_| 8 + rng.below(32 << 10)).collect::<Vec<u64>>()
        },
        |sizes| {
            let net = SimNet::new(55);
            let a = NicAddr { node: 0, gpu: 0, nic: 0 };
            let b = NicAddr { node: 1, gpu: 0, nic: 0 };
            net.add_nic(a, NicProfile::efa());
            net.add_nic(b, NicProfile::efa());
            let mem = net.mem();
            let total: u64 = sizes.iter().sum();
            let (sbuf, _) = mem.alloc(total as usize);
            let (dbuf, drkey) = mem.alloc(total as usize);
            let mut sim = Sim::new();
            let mut off = 0u64;
            let mut offs = Vec::new();
            for (i, &len) in sizes.iter().enumerate() {
                sbuf.write(off as usize, &vec![(i % 250 + 1) as u8; len as usize]);
                net.post(
                    &mut sim,
                    a,
                    WorkRequest {
                        id: i as u64,
                        qp: QpId(1),
                        op: WrOp::Write {
                            dst: b,
                            dst_rkey: drkey,
                            dst_va: dbuf.base() + off,
                            src: DmaSlice::new(&sbuf, off as usize, len as usize),
                            imm: Some(i as u32),
                        },
                        chained: false,
                    },
                );
                offs.push(off);
                off += len;
            }
            // Drain CQEs *during* the run (at 1 µs boundaries), not
            // only at the end.
            let mut seen = 0usize;
            while !sim.idle() {
                let t = sim.now();
                sim.run_until(t + 1_000);
                let mut rcq = Vec::new();
                net.poll_cq(b, usize::MAX, &mut rcq);
                for c in &rcq {
                    if let CqeKind::ImmRecvd { imm, .. } = c.kind {
                        let i = imm as usize;
                        let mut got = vec![0u8; sizes[i] as usize];
                        dbuf.read(offs[i] as usize, &mut got);
                        if got.iter().any(|&g| g != (i % 250 + 1) as u8) {
                            return Err(format!("imm {i} visible before payload"));
                        }
                        seen += 1;
                    }
                }
            }
            if seen != sizes.len() {
                return Err(format!("saw {seen} of {} imms", sizes.len()));
            }
            Ok(())
        },
    );
}
