//! MoE workload configuration (DeepSeek-V3/R1 microbenchmark shapes,
//! paper §7.4.3).

use crate::sim::time::{Duration, US};

/// Configuration of one MoE all-to-all scenario.
#[derive(Debug, Clone)]
pub struct MoeConfig {
    /// Expert-parallel world size.
    pub ranks: u32,
    /// GPUs per node (NVLink domain size).
    pub gpus_per_node: u32,
    /// Total experts.
    pub experts: u32,
    /// Experts each token routes to (R).
    pub top_k: u32,
    /// Tokens per rank per iteration (decode: ≤128; prefill: 4096).
    pub tokens: u32,
    /// Dispatch payload per token: 7168 fp8 + 56 f32 scales.
    pub dispatch_token_bytes: u32,
    /// Combine payload per token: 7168 bf16.
    pub combine_token_bytes: u32,
    /// Private per-source buffer capacity in tokens (Fig 11 ablation).
    pub private_tokens: u32,
    /// Simulated overlapped work between dispatch-recv and combine
    /// (grouped GEMM and shared experts).
    pub gemm_gap_ns: Duration,
    /// Seed for routing generation.
    pub seed: u64,
}

impl MoeConfig {
    /// Decode-shaped config (DeepSeek-V3: 7168 hidden, 56 scales,
    /// top-8, 256 experts).
    pub fn decode(ranks: u32, tokens: u32) -> Self {
        MoeConfig {
            ranks,
            gpus_per_node: 8,
            experts: 256,
            top_k: 8,
            tokens,
            dispatch_token_bytes: 7168 + 56 * 4,
            combine_token_bytes: 7168 * 2,
            private_tokens: 48,
            gemm_gap_ns: 30 * US,
            seed: 0x30E,
        }
    }

    /// Prefill-shaped config (4096-token chunks).
    pub fn prefill(ranks: u32) -> Self {
        MoeConfig {
            tokens: 4096,
            gemm_gap_ns: 300 * US,
            ..Self::decode(ranks, 4096)
        }
    }

    /// Tiny config for integration tests (backed buffers).
    pub fn tiny() -> Self {
        MoeConfig {
            ranks: 4,
            gpus_per_node: 2,
            experts: 8,
            top_k: 2,
            tokens: 8,
            dispatch_token_bytes: 64,
            combine_token_bytes: 128,
            private_tokens: 2,
            gemm_gap_ns: 5 * US,
            seed: 0x71,
        }
    }

    /// Local experts per rank.
    pub fn local_experts(&self) -> u32 {
        self.experts / self.ranks
    }

    /// Receive-buffer token bound: `N * T * max(R, E/N)` (paper §6.1).
    pub fn recv_buffer_tokens(&self) -> u64 {
        self.ranks as u64 * self.tokens as u64 * self.top_k.max(self.local_experts()) as u64
    }

    /// Node of a rank.
    pub fn node_of(&self, rank: u32) -> u32 {
        rank / self.gpus_per_node
    }

    /// True when two ranks share an NVLink domain.
    pub fn same_node(&self, a: u32, b: u32) -> bool {
        self.node_of(a) == self.node_of(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_shapes() {
        let c = MoeConfig::decode(64, 128);
        assert_eq!(c.local_experts(), 4);
        assert_eq!(c.dispatch_token_bytes, 7392);
        // Bound: 64 * 128 * max(8, 4).
        assert_eq!(c.recv_buffer_tokens(), 64 * 128 * 8);
        assert!(c.same_node(0, 7));
        assert!(!c.same_node(7, 8));
    }

    #[test]
    fn prefill_tokens() {
        let c = MoeConfig::prefill(32);
        assert_eq!(c.tokens, 4096);
        assert_eq!(c.local_experts(), 8);
        assert_eq!(c.recv_buffer_tokens(), 32 * 4096 * 8);
    }
}
