"""Pallas kernel: row-wise fp8-e4m3 quantization.

The RL weight-transfer pipeline (paper §5.2 stage 2) quantizes bf16
training weights to fp8 before the RDMA write. Each grid step owns a
row tile: amax reduction, scale computation, scaled cast — one HBM
read and one write per element, the roofline for this op.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FP8_MAX = 448.0
DEFAULT_TILE_R = 16


def _kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # [TILE_R, C]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / FP8_MAX
    q_ref[...] = (x / scale).astype(q_ref.dtype)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("tile_r",))
def quantize_fp8(x, tile_r: int = DEFAULT_TILE_R):
    """Quantize rows of ``x`` to fp8-e4m3 with per-row scales.

    Args:
      x: [R, C] float32/bfloat16; R must not be huge relative to tiles
        (padded internally otherwise).

    Returns:
      (q [R, C] float8_e4m3fn, scale [R, 1] float32).
    """
    r, c = x.shape
    tr = min(tile_r, r)
    if r % tr != 0:
        pad = tr - r % tr
        q, s = quantize_fp8(jnp.pad(x, ((0, pad), (0, 0))), tile_r=tr)
        return q[:r], s[:r]
    q, s = pl.pallas_call(
        _kernel,
        grid=(r // tr,),
        in_specs=[pl.BlockSpec((tr, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
            pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=True,
    )(x)
    return q, s
