//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over N seeded random cases; on failure it
//! reports the failing case number and seed so the case can be
//! replayed exactly (`FABRIC_PROP_SEED=<seed> FABRIC_PROP_CASES=1`).
//! Generators are plain closures over [`crate::sim::Rng`].

use crate::sim::Rng;

/// Number of cases per property (override with FABRIC_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("FABRIC_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Base seed (override with FABRIC_PROP_SEED to replay).
pub fn base_seed() -> u64 {
    std::env::var("FABRIC_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xF0F0_1234)
}

/// Run `prop` over seeded cases. `gen` builds a case from an RNG;
/// `prop` returns `Err(reason)` on violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(reason) = prop(&case) {
            panic!(
                "property '{name}' failed on case {i}/{cases} (seed {seed}):\n  \
                 reason: {reason}\n  case: {case:#?}\n  \
                 replay: FABRIC_PROP_SEED={seed} FABRIC_PROP_CASES=1"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check(
            "sum-commutes",
            |rng| (rng.below(100), rng.below(100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", |rng| rng.below(10), |_| Err("nope".into()));
    }
}
