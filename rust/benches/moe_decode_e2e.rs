//! Paper Table 6: end-to-end MoE decode speed (tokens/s, DeepSeek-V3,
//! MTP, EP=DP=64).
//!
//! One decode step = 61 layers × (attention + dense compute) + 58 MoE
//! layers × (dispatch + grouped GEMM + combine); MTP draft length 1
//! with 80% acceptance yields 1.8 tokens per step. Communication
//! latencies come from the fabric simulation; compute terms follow an
//! H100/H200 roofline calibrated so the CX-7 column lands near the
//! paper's.
//!
//! Usage: cargo bench --bench moe_decode_e2e [-- --fast]

use fabric_lib::apps::moe::{run_decode_epoch, MoeConfig, MoeImpl};
use fabric_lib::fabric::profile::NicProfile;
use fabric_lib::util::table::{f, Table};

const LAYERS: u64 = 61;
const MOE_LAYERS: u64 = 58;
const MTP_TOKENS_PER_STEP: f64 = 1.8; // draft 1, 80% acceptance

/// Non-communication per-layer time (attention + dense/shared parts +
/// grouped GEMM), ns, as a function of per-rank batch.
fn compute_ns(batch: u32) -> u64 {
    260_000 + batch as u64 * 1_500
}

fn tokens_per_s(dispatch_us: f64, combine_us: f64, batch: u32) -> f64 {
    let step_ns = LAYERS as f64 * compute_ns(batch) as f64
        + MOE_LAYERS as f64 * (dispatch_us + combine_us) * 1000.0;
    MTP_TOKENS_PER_STEP / (step_ns / 1e9)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let iters = if fast { 2 } else { 5 };
    let ranks = if fast { 16 } else { 64 };
    let batches: &[u32] = &[2, 8, 32];

    let mut t = Table::new(
        &format!("Table 6. End-to-end MoE decode speed (tokens/s, EP=DP={ranks}, MTP)"),
        &["cluster", "kernel", "batch=2", "batch=8", "batch=32"],
    );
    let combos: &[(&str, MoeImpl, NicProfile, u8)] = &[
        ("H200 EFA", MoeImpl::Ours, NicProfile::efa(), 2),
        ("H200 EFA", MoeImpl::Pplx, NicProfile::efa(), 2),
        ("H100 CX-7", MoeImpl::Ours, NicProfile::connectx7(), 1),
        ("H100 CX-7", MoeImpl::DeepEp, NicProfile::connectx7(), 1),
    ];
    for (cluster, imp, nic, nics) in combos {
        let mut row = vec![cluster.to_string(), imp.name().to_string()];
        for &b in batches {
            let cfg = MoeConfig::decode(ranks, b);
            let mut lat = run_decode_epoch(&cfg, *imp, nic.clone(), *nics, iters);
            let d = lat.dispatch.percentile(50.0) as f64 / 1000.0;
            let c = lat.combine.percentile(50.0) as f64 / 1000.0;
            row.push(f(tokens_per_s(d, c, b), 1));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "\npaper — EFA: ours 66.8/56.5/32.0 vs pplx 21.0/11.6/4.9; CX-7: ours \
         78.4/67.7/36.1 vs DeepEP 73.8/65.8/36.3. Claims preserved: ours \
         3-6x pplx on EFA; ours ≈ DeepEP on CX-7.\n"
    );
}
