//! Paper Table 8 (CPU overhead breakdown for MoE all-to-all) and
//! Table 9 (scatter post time vs EP), from the engine's submission
//! traces — plus a *real measured* threaded-engine trace for the
//! submit→post path (the only rows a simulator could fake), the
//! batched-vs-looped submission comparison that anchors the
//! `BENCH_submit.json` perf trajectory, and the telemetry on/off
//! sections that hold the instrumentation to its <5% budget on the
//! templated batch path (both runtimes).
//!
//! Usage: cargo bench --bench proxy_overhead [-- --quick] [--json PATH]
//!
//! `--quick` (alias `--fast`) shrinks iteration counts for CI smoke
//! runs; `--json PATH` merges the headline numbers into the report at
//! PATH under the `proxy_overhead` section (see BENCH_submit.json).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

use fabric_lib::apps::moe::rank::Strategy;
use fabric_lib::apps::moe::{harness::run_epoch_with, MoeConfig};
use fabric_lib::engine::api::{EngineCosts, ScatterDst, TemplatedDst};
use fabric_lib::engine::des_engine::{Engine as DesEngine, OnDone};
use fabric_lib::engine::model::Reactor;
use fabric_lib::engine::threaded::ThreadedEngine;
use fabric_lib::engine::traits::{new_flag, Cx, Notify, TransferEngine};
use fabric_lib::fabric::local::LocalFabric;
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::fabric::profile::{GpuProfile, NicProfile, TransportKind};
use fabric_lib::fabric::simnet::SimNet;
use fabric_lib::sim::stats::Histogram;
use fabric_lib::sim::Sim;
use fabric_lib::util::json::{update_report, Json};
use fabric_lib::util::table::{f, Table};

fn us(v: u64) -> String {
    f(v as f64 / 1000.0, 3)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast" || a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let iters = if fast { 2 } else { 5 };

    // ---- Table 8: virtual-time breakdown at EP64 (EFA + CX-7) ----
    for (nic, nics, name) in [
        (NicProfile::efa(), 2u8, "EFA"),
        (NicProfile::connectx7(), 1u8, "CX-7"),
    ] {
        let sink = Rc::new(RefCell::new(Vec::new()));
        let ep = if fast { 16 } else { 64 };
        let cfg = MoeConfig::decode(ep, 128);
        let _ = run_epoch_with(&cfg, Strategy::ours(), nic, nics, iters, Some(sink.clone()));
        let traces = sink.borrow();
        // Scatter submissions only (≥ half the peers).
        let mut enq = Histogram::new();
        let mut worker = Histogram::new();
        let mut first = Histogram::new();
        let mut last = Histogram::new();
        for t in traces.iter().filter(|t| t.wrs as usize >= (ep as usize / 2).max(2)) {
            enq.record(t.enqueued - t.submitted);
            worker.record(t.worker_start - t.enqueued);
            first.record(t.first_post - t.worker_start);
            last.record(t.last_post - t.first_post);
        }
        let mut t8 = Table::new(
            &format!("Table 8. CPU overhead breakdown, MoE all-to-all EP{ep} ({name}) (us)"),
            &["event (delta)", "thread", "p50", "p90", "p99", "p99.9"],
        );
        let mut row = |label: &str, thread: &str, h: &mut Histogram| {
            if h.is_empty() {
                return;
            }
            let s = h.summary();
            t8.row(&[
                label.to_string(),
                thread.to_string(),
                us(s.p50),
                us(s.p90),
                us(s.p99),
                us(s.p999),
            ]);
        };
        row("submit_scatter() -> enqueue done", "App", &mut enq);
        row("-> worker dequeue", "Worker", &mut worker);
        row("-> before posting first WRITE", "Worker", &mut first);
        row("-> after posting last WRITE", "Worker", &mut last);
        t8.print();
    }
    println!(
        "paper — Table 8 (EP64): enqueue 0.120, worker 0.855, first-post \
         0.441; post-all 27.9 us EFA / 8.5 us CX-7.\n"
    );

    // ---- Table 9: post time for all WRITEs of a scatter vs EP ----
    let mut t9 = Table::new(
        "Table 9. Post time for all WRITEs of scatter (us)",
        &["NIC", "EP", "p50", "p90", "p99", "p99.9"],
    );
    for (nic, nics, name) in [
        (NicProfile::efa(), 2u8, "EFA"),
        (NicProfile::connectx7(), 1u8, "CX-7"),
    ] {
        for ep in [8u32, 16, 32, 64] {
            if fast && ep > 16 {
                continue;
            }
            let sink = Rc::new(RefCell::new(Vec::new()));
            let cfg = MoeConfig::decode(ep, 128);
            let _ = run_epoch_with(&cfg, Strategy::ours(), nic.clone(), nics, iters, Some(sink.clone()));
            let traces = sink.borrow();
            let mut h = Histogram::new();
            for t in traces.iter().filter(|t| t.wrs as usize >= (ep as usize / 2).max(2)) {
                h.record(t.last_post - t.first_post);
            }
            if h.is_empty() {
                continue;
            }
            let s = h.summary();
            t9.row(&[
                name.to_string(),
                format!("EP{ep}"),
                us(s.p50),
                us(s.p90),
                us(s.p99),
                us(s.p999),
            ]);
        }
    }
    t9.print();
    println!(
        "paper — Table 9 p50: EFA 3.1/6.5/13.4/27.9, CX-7 0.8/1.9/4.1/8.5 \
         us for EP 8/16/32/64 (roughly linear in peers).\n"
    );

    // ---- Ablation: WR chaining (§3.5) ----
    // Posting cost of a 56-peer scatter with doorbell chaining (CX-7,
    // chain=4) vs without (chain=1): chaining amortizes the doorbell,
    // one of the hardware-specific optimizations DESIGN.md calls out.
    let mut ta = Table::new(
        "Ablation. WR chaining effect on scatter post time (CX-7, EP64) (us)",
        &["chaining", "p50", "p90"],
    );
    for (label, max_chain) in [("chain=4 (default)", 4usize), ("chain=1 (off)", 1usize)] {
        let mut nic = NicProfile::connectx7();
        nic.max_chain = max_chain;
        let sink = Rc::new(RefCell::new(Vec::new()));
        let ep = if fast { 16 } else { 64 };
        let cfg = MoeConfig::decode(ep, 128);
        let _ = run_epoch_with(&cfg, Strategy::ours(), nic, 1, iters, Some(sink.clone()));
        let traces = sink.borrow();
        let mut h = Histogram::new();
        for t in traces.iter().filter(|t| t.wrs as usize >= (ep as usize / 2).max(2)) {
            h.record(t.last_post - t.first_post);
        }
        let s = h.summary();
        ta.row(&[label.to_string(), us(s.p50), us(s.p90)]);
    }
    ta.print();
    println!("chaining must reduce CPU post time (fewer doorbells).\n");

    // ---- Real measurement: threaded engine submit→post (wall clock) ----
    // Driven through `&dyn TransferEngine` — the same trait the apps
    // use — so the measured path includes the uniform-API dispatch
    // (negligible against the µs-scale submit/post costs it verifies).
    let fabric = LocalFabric::new(TransportKind::Srd, 42);
    let a = ThreadedEngine::new(&fabric, 0, 1, 2);
    let b = ThreadedEngine::new(&fabric, 1, 1, 2);
    let eng: &dyn TransferEngine = &a;
    let mut cx = Cx::Threaded(Reactor::new());
    let (src, _) = eng.alloc_mr(0, 1 << 20);
    let peers: Vec<_> = (0..56).map(|_| b.alloc_mr(0, 1 << 20).1).collect();
    let group = eng.add_peer_group(vec![b.main_address(); 56]);
    let n_iters = if fast { 200 } else { 2000 };
    for _ in 0..n_iters {
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .map(|d| ScatterDst { len: 4096, src: 0, dst: (d.clone(), 0) })
            .collect();
        let done = new_flag();
        eng.submit_scatter(&mut cx, Some(group), &src, &dsts, None, Notify::Flag(done.clone()))
            .expect("untemplated scatter");
        cx.wait(&done);
    }
    let traces = a.take_traces();
    let mut enq = Histogram::new();
    let mut post = Histogram::new();
    for t in &traces {
        enq.record(t.worker_start.saturating_sub(t.submitted));
        post.record(t.last_post.saturating_sub(t.first_post));
    }
    let mut tr = Table::new(
        "Table 8b. REAL measured threaded-engine overhead (56-peer scatter) (us)",
        &["event", "p50", "p90", "p99"],
    );
    for (label, h) in [("submit -> worker dequeue", &mut enq), ("post all 56 WRITEs", &mut post)] {
        let s = h.summary();
        tr.row(&[label.to_string(), us(s.p50), us(s.p90), us(s.p99)]);
    }
    tr.print();

    // ---- §3.5 ablation: templated vs untemplated submission cost ------
    // Same 56-peer scatter, measured end-to-end on the calling thread:
    // the untemplated path clones one MrDesc (rkey vector included)
    // per destination per call and re-resolves every rkey; the
    // templated path was bound once and per call patches four
    // integers per destination into pre-resolved routes. The delta is
    // the pre-templating win the paper attributes much of its 400 Gbps
    // peak to.
    let tgroup = eng.add_peer_group(vec![b.main_address(); 56]);
    eng.bind_peer_group_mrs(0, tgroup, &peers)
        .expect("bind 56 peer regions");
    let mut submit_untpl = Histogram::new();
    let mut submit_tpl = Histogram::new();
    for _ in 0..n_iters {
        let t0 = std::time::Instant::now();
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .map(|d| ScatterDst { len: 4096, src: 0, dst: (d.clone(), 0) })
            .collect();
        let done = new_flag();
        eng.submit_scatter(&mut cx, Some(group), &src, &dsts, None, Notify::Flag(done.clone()))
            .expect("untemplated scatter");
        submit_untpl.record(t0.elapsed().as_nanos() as u64);
        cx.wait(&done);

        let t0 = std::time::Instant::now();
        let dsts: Vec<TemplatedDst> = (0..peers.len())
            .map(|peer| TemplatedDst { peer, len: 4096, src: 0, dst: 0 })
            .collect();
        let done = new_flag();
        eng.submit_scatter_templated(&mut cx, &src, tgroup, &dsts, None, Notify::Flag(done.clone()))
            .expect("templated scatter");
        submit_tpl.record(t0.elapsed().as_nanos() as u64);
        cx.wait(&done);
    }
    let mut ts = Table::new(
        "Ablation. §3.5 WR pre-templating, REAL app-thread submit cost \
         (56-peer scatter) (us)",
        &["path", "p50", "p90", "p99"],
    );
    for (label, h) in [
        ("untemplated (desc clones + rkey resolve)", &mut submit_untpl),
        ("templated (patch 4 ints/dst)", &mut submit_tpl),
    ] {
        let s = h.summary();
        ts.row(&[label.to_string(), us(s.p50), us(s.p90), us(s.p99)]);
    }
    ts.print();
    println!("templated submissions must not be slower than untemplated ones.\n");
    let untpl_p50 = submit_untpl.summary().p50;
    let tpl_p50 = submit_tpl.summary().p50;

    // ---- Tentpole: batched vs looped templated 56-peer scatter -------
    // The same 56 templated writes submitted two ways: a loop of 56
    // `submit_single_write_templated` calls (56 engine crossings, 56
    // rotation commits) vs ONE `submit_batch_templated` (one crossing,
    // one routing pass, one `bump_n`). Timed window = app-thread
    // submission cost only; completion is awaited outside it. These
    // are the headline numbers of BENCH_submit.json.
    let mut looped = Histogram::new();
    let mut batched = Histogram::new();
    for _ in 0..n_iters {
        let t0 = std::time::Instant::now();
        let mut flags = Vec::with_capacity(peers.len());
        for peer in 0..peers.len() {
            let done = new_flag();
            eng.submit_single_write_templated(
                &mut cx,
                (&src, 0),
                4096,
                tgroup,
                peer,
                0,
                None,
                Notify::Flag(done.clone()),
            )
            .expect("looped templated write");
            flags.push(done);
        }
        looped.record(t0.elapsed().as_nanos() as u64);
        cx.wait_all(&flags);

        let t0 = std::time::Instant::now();
        let dsts: Vec<TemplatedDst> = (0..peers.len())
            .map(|peer| TemplatedDst { peer, len: 4096, src: 0, dst: 0 })
            .collect();
        let done = new_flag();
        eng.submit_batch_templated(&mut cx, &src, tgroup, &dsts, None, Notify::Flag(done.clone()))
            .expect("batched templated scatter");
        batched.record(t0.elapsed().as_nanos() as u64);
        cx.wait(&done);
    }
    let looped_p50 = looped.summary().p50;
    let batched_p50 = batched.summary().p50;
    let mut tb = Table::new(
        "Tentpole. REAL app-thread submit cost, 56-peer templated scatter (us)",
        &["path", "p50", "p90", "p99"],
    );
    for (label, h) in [
        ("looped (56 x submit_single_write_templated)", &mut looped),
        ("batched (1 x submit_batch_templated)", &mut batched),
    ] {
        let s = h.summary();
        tb.row(&[label.to_string(), us(s.p50), us(s.p90), us(s.p99)]);
    }
    tb.print();
    assert!(
        batched_p50 < looped_p50,
        "batched 56-peer submission (p50 {batched_p50} ns) must cost strictly \
         less than the looped templated path (p50 {looped_p50} ns)"
    );
    println!("one engine crossing per N writes: batched < looped, as required.\n");

    // ---- Satellite: telemetry overhead on the templated batch path ---
    // The same batched templated submission measured twice in the same
    // standalone loop shape: hot-path counters + span capture ON (the
    // default every section above ran with) vs OFF. The budget for the
    // instrumentation is <5% of app-thread submit cost on the
    // templated batch path, plus a 500 ns grace for timer jitter at
    // single-digit-µs scale.
    let mut run_batched = |label: &str| {
        let mut h = Histogram::new();
        for _ in 0..n_iters {
            let t0 = std::time::Instant::now();
            let dsts: Vec<TemplatedDst> = (0..peers.len())
                .map(|peer| TemplatedDst { peer, len: 4096, src: 0, dst: 0 })
                .collect();
            let done = new_flag();
            eng.submit_batch_templated(&mut cx, &src, tgroup, &dsts, None, Notify::Flag(done.clone()))
                .unwrap_or_else(|e| panic!("batched templated scatter ({label}): {e}"));
            h.record(t0.elapsed().as_nanos() as u64);
            cx.wait(&done);
        }
        h
    };
    let mut batched_on = run_batched("telemetry on");
    a.set_telemetry(false);
    let mut batched_off = run_batched("telemetry off");
    a.set_telemetry(true);
    let on_p50 = batched_on.summary().p50;
    let off_p50 = batched_off.summary().p50;
    let overhead_pct = (on_p50 as f64 / off_p50.max(1) as f64 - 1.0) * 100.0;
    let mut tt = Table::new(
        "Satellite. Telemetry cost, batched templated 56-peer scatter (us)",
        &["telemetry", "p50", "p90", "p99"],
    );
    for (label, h) in [("on (default)", &mut batched_on), ("off", &mut batched_off)] {
        let s = h.summary();
        tt.row(&[label.to_string(), us(s.p50), us(s.p90), us(s.p99)]);
    }
    tt.print();
    assert!(
        on_p50 <= off_p50 + off_p50 / 20 + 500,
        "telemetry-on batched submission (p50 {on_p50} ns) must stay within 5% \
         (+500 ns jitter grace) of telemetry-off (p50 {off_p50} ns)"
    );
    println!("instrumentation overhead {overhead_pct:.1}% — under the 5% budget.\n");
    a.shutdown();
    b.shutdown();
    fabric.shutdown();

    // ---- DES: the same comparison in deterministic virtual time ------
    // The DES cost model charges submit→handoff→prep once per
    // submission (serialized on the group's worker), so the batched
    // round completes exactly 55 crossings earlier — a seed-stable
    // number that pins the trajectory independent of the host machine.
    let net = SimNet::new(0xBA7C);
    for node in 0..2u16 {
        for x in 0..2u8 {
            net.add_nic(NicAddr { node, gpu: 0, nic: x }, NicProfile::efa());
        }
    }
    let mut sim = Sim::new();
    let da = DesEngine::new(&net, 0, 1, 2, GpuProfile::h100(), EngineCosts::default(), 1);
    let db = DesEngine::new(&net, 1, 1, 2, GpuProfile::h100(), EngineCosts::default(), 2);
    let (dsrc, _) = da.alloc_mr_unbacked(0, 1 << 20);
    let dpeers: Vec<_> = (0..56).map(|_| db.alloc_mr_unbacked(0, 1 << 20).1).collect();
    let dg = da.add_peer_group(vec![db.main_address(); 56]);
    da.bind_peer_group_mrs(0, dg, &dpeers).expect("bind 56 DES peer regions");

    let t0 = sim.now();
    for peer in 0..dpeers.len() {
        da.submit_single_write_templated(&mut sim, (&dsrc, 0), 4096, dg, peer, 0, None, OnDone::Noop)
            .expect("DES looped templated write");
    }
    sim.run();
    let des_looped_ns = sim.now() - t0;

    let t0 = sim.now();
    let dsts: Vec<TemplatedDst> = (0..dpeers.len())
        .map(|peer| TemplatedDst { peer, len: 4096, src: 0, dst: 0 })
        .collect();
    let done = Rc::new(Cell::new(false));
    da.submit_batch_templated(&mut sim, &dsrc, dg, &dsts, None, OnDone::Flag(done.clone()))
        .expect("DES batched templated scatter");
    sim.run();
    assert!(done.get());
    let des_batched_ns = sim.now() - t0;

    let mut td = Table::new(
        "Tentpole. DES virtual-time 56-peer scatter, submit to last delivery (us)",
        &["path", "total"],
    );
    td.row(&["looped".to_string(), us(des_looped_ns)]);
    td.row(&["batched".to_string(), us(des_batched_ns)]);
    td.print();
    assert!(
        des_batched_ns < des_looped_ns,
        "DES batched round ({des_batched_ns} ns) must beat looped ({des_looped_ns} ns)"
    );
    println!("deterministic: same seed always reproduces these two numbers.\n");

    // DES flavor of the telemetry budget: the virtual-time cost model
    // charges the same nanoseconds whether or not counters/spans
    // record, so the batched round with telemetry off must land within
    // 5% of the telemetry-on round above (expected: exactly equal —
    // instrumentation never perturbs the deterministic timeline).
    da.set_telemetry(false);
    let t0 = sim.now();
    let dsts: Vec<TemplatedDst> = (0..dpeers.len())
        .map(|peer| TemplatedDst { peer, len: 4096, src: 0, dst: 0 })
        .collect();
    let done = Rc::new(Cell::new(false));
    da.submit_batch_templated(&mut sim, &dsrc, dg, &dsts, None, OnDone::Flag(done.clone()))
        .expect("DES batched templated scatter (telemetry off)");
    sim.run();
    assert!(done.get());
    let des_batched_off_ns = sim.now() - t0;
    da.set_telemetry(true);
    assert!(
        des_batched_ns * 100 <= des_batched_off_ns * 105
            && des_batched_off_ns * 100 <= des_batched_ns * 105,
        "DES batched round must be timing-neutral under telemetry \
         (on {des_batched_ns} ns vs off {des_batched_off_ns} ns)"
    );
    println!("DES: telemetry on/off virtual times agree ({des_batched_ns} ns).\n");

    if let Some(path) = json_path {
        let mut sec = BTreeMap::new();
        sec.insert("provenance".to_string(), Json::from("measured by proxy_overhead"));
        sec.insert("des_looped_56_ns".to_string(), Json::from(des_looped_ns));
        sec.insert("des_batched_56_ns".to_string(), Json::from(des_batched_ns));
        sec.insert("threaded_looped_56_p50_ns".to_string(), Json::from(looped_p50));
        sec.insert("threaded_batched_56_p50_ns".to_string(), Json::from(batched_p50));
        sec.insert("threaded_untemplated_56_p50_ns".to_string(), Json::from(untpl_p50));
        sec.insert("threaded_templated_56_p50_ns".to_string(), Json::from(tpl_p50));
        sec.insert("threaded_batched_telemoff_56_p50_ns".to_string(), Json::from(off_p50));
        sec.insert("telemetry_overhead_pct".to_string(), Json::from(overhead_pct));
        update_report(&path, "proxy_overhead", Json::Obj(sec)).expect("write bench report");
        println!("wrote proxy_overhead section to {path}");
    }
}
