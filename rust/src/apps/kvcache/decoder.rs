//! Decoder node: page allocation, dispatch, IMMCOUNTER-driven decode
//! start, cancellation and heartbeat monitoring (paper §4 + Appendix
//! A Fig 14).
//!
//! Runtime-neutral since the compute-model migration: the decoder
//! holds `Rc<dyn TransferEngine>` and schedules decode passes on the
//! shared clock (`Cx::after`), so the same state machine runs on the
//! DES virtual clock and on the threaded runtime's reactor.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::engine::api::{MrDesc, MrHandle, NetAddr};
use crate::engine::model::Fired;
use crate::engine::traits::{Cx, Notify, OnRecv, TransferEngine};
use crate::sim::time::{Duration, Instant};

use super::proto::{self, CancelAck, CancelReq, DispatchReq, Heartbeat};
use super::workload::ServingWorkload;

/// Lifecycle of one request on the decoder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqState {
    /// Dispatched; waiting for all WRITEIMMs.
    Transferring,
    /// KV received; decoding tokens.
    Decoding,
    /// Finished successfully.
    Done,
    /// Cancel sent; pages quarantined until the prefiller acks.
    Cancelling,
    /// Cancelled and confirmed (or force-freed after prefiller death).
    Cancelled,
}

/// Completion record for reporting.
#[derive(Debug, Clone, Copy)]
pub struct ReqReport {
    pub req_id: u64,
    pub seq_tokens: u32,
    pub submitted: Instant,
    pub transfer_done: Instant,
    /// Time to first token (includes the final-token decode pass).
    pub ttft: Instant,
    pub finished: Instant,
    pub decode_tokens: u32,
}

struct ReqInfo {
    state: ReqState,
    pages: Vec<u32>,
    tail: u32,
    imm: u32,
    prefiller: NetAddr,
    prefiller_node: u16,
    seq_tokens: u32,
    decode_tokens: u32,
    submitted: Instant,
    transfer_done: Instant,
    ttft: Instant,
}

struct DState {
    engine: Rc<dyn TransferEngine>,
    gpu: u8,
    workload: ServingWorkload,
    kv: (MrHandle, MrDesc),
    tails: (MrHandle, MrDesc),
    free_slots: Vec<u32>,
    free_tails: Vec<u32>,
    next_imm: u32,
    next_req: u64,
    requests: HashMap<u64, ReqInfo>,
    reports: Rc<RefCell<Vec<ReqReport>>>,
    last_seen: HashMap<u16, Instant>,
    hb_timeout: Duration,
    /// False once `stop_monitor` ran: the monitor tick stops
    /// re-arming (lets DES failover scenarios quiesce).
    monitor_on: bool,
}

/// A decoder node (one GPU's worth).
#[derive(Clone)]
pub struct Decoder {
    state: Rc<RefCell<DState>>,
}

impl Decoder {
    /// Create the decoder, allocating its KV + tail regions and
    /// starting its control-message listener.
    pub fn new(
        cx: &mut Cx,
        engine: Rc<dyn TransferEngine>,
        gpu: u8,
        workload: ServingWorkload,
    ) -> Self {
        let kv_len = workload.layout.region_bytes() as usize;
        let kv = if kv_len > (64 << 20) {
            engine.alloc_mr_unbacked(gpu, kv_len)
        } else {
            engine.alloc_mr(gpu, kv_len)
        };
        let n_tails = 64u32;
        let tails = engine.alloc_mr(gpu, (workload.tail_bytes * n_tails as u64) as usize);
        let slots = workload.layout.slots_per_layer;
        let state = Rc::new(RefCell::new(DState {
            engine: engine.clone(),
            gpu,
            workload,
            kv,
            tails,
            free_slots: (0..slots).rev().collect(),
            free_tails: (0..n_tails).rev().collect(),
            next_imm: 1,
            next_req: 1,
            requests: HashMap::new(),
            reports: Rc::default(),
            last_seen: HashMap::new(),
            hb_timeout: 30_000_000, // 30 ms
            monitor_on: true,
        }));
        let d = Decoder { state };
        let d2 = d.clone();
        let on_msg = OnRecv::Cont(cx.cont(move |cx: &mut Cx, fired: Fired| {
            d2.on_message(cx, &fired.data);
        }));
        engine.submit_recvs(cx, gpu, 1 << 12, 32, on_msg);
        d
    }

    /// Group address (for the scheduler / prefillers).
    pub fn address(&self) -> NetAddr {
        let s = self.state.borrow();
        s.engine.group_address(s.gpu)
    }

    /// Completed-request reports.
    pub fn reports(&self) -> Rc<RefCell<Vec<ReqReport>>> {
        self.state.borrow().reports.clone()
    }

    /// KV region handle (test inspection).
    pub fn kv_handle(&self) -> MrHandle {
        self.state.borrow().kv.0.clone()
    }

    /// Current state of a request.
    pub fn req_state(&self, req_id: u64) -> Option<ReqState> {
        self.state.borrow().requests.get(&req_id).map(|r| r.state)
    }

    /// Free page-slot count (leak detection in tests).
    pub fn free_slot_count(&self) -> usize {
        self.state.borrow().free_slots.len()
    }

    /// Submit a request: allocate pages + tail, register the
    /// IMMCOUNTER expectation, dispatch to `prefiller` (Fig 14).
    pub fn submit_request(
        &self,
        cx: &mut Cx,
        prefiller: &NetAddr,
        input_ids: Vec<u32>,
        decode_tokens: u32,
    ) -> u64 {
        let (req_id, msg, imm, expected, engine, gpu) = {
            let mut s = self.state.borrow_mut();
            let seq = input_ids.len() as u32;
            let n_pages = s.workload.layout.pages_for(seq) as usize;
            assert!(
                s.free_slots.len() >= n_pages,
                "KV pool exhausted: need {n_pages}, have {}",
                s.free_slots.len()
            );
            let pages: Vec<u32> = (0..n_pages).map(|_| s.free_slots.pop().unwrap()).collect();
            let tail = s.free_tails.pop().expect("tail pool exhausted");
            let imm = s.next_imm;
            s.next_imm += 1;
            let req_id = s.next_req;
            s.next_req += 1;
            let expected = s.workload.layout.expected_imms(seq);
            let req = DispatchReq {
                req_id,
                input_ids,
                decoder_addr: s.engine.group_address(s.gpu),
                imm,
                kv_desc: s.kv.1.clone(),
                pages: pages.clone(),
                tail_desc: s.tails.1.clone(),
                tail_idx: tail,
            };
            s.requests.insert(
                req_id,
                ReqInfo {
                    state: ReqState::Transferring,
                    pages,
                    tail,
                    imm,
                    prefiller: prefiller.clone(),
                    prefiller_node: prefiller.primary().node,
                    seq_tokens: seq,
                    decode_tokens,
                    submitted: cx.now(),
                    transfer_done: 0,
                    ttft: 0,
                },
            );
            (req_id, req.encode(), imm, expected, s.engine.clone(), s.gpu)
        };
        // Completion notification without any ordering assumption:
        // count WRITEIMMs.
        let this = self.clone();
        let on_complete = cx.cont(move |cx: &mut Cx, _f: Fired| {
            this.on_transfer_done(cx, req_id);
        });
        engine.expect_imm_count(cx, gpu, imm, expected, Notify::Cont(on_complete));
        engine.submit_send(cx, gpu, prefiller, &msg, Notify::Noop);
        req_id
    }

    fn on_transfer_done(&self, cx: &mut Cx, req_id: u64) {
        let (decode_pass, n_decode) = {
            let mut s = self.state.borrow_mut();
            let Some(r) = s.requests.get_mut(&req_id) else {
                return;
            };
            if r.state != ReqState::Transferring {
                return; // cancelled meanwhile
            }
            r.state = ReqState::Decoding;
            r.transfer_done = cx.now();
            let imm = r.imm;
            let n = r.decode_tokens;
            let dp = s.workload.compute.decode_pass_ns;
            s.engine.free_imm(s.gpu, imm);
            (dp, n)
        };
        // One extra decode pass for the final input token produces the
        // first output token (the paper's main TTFT overhead), then
        // autoregressive decoding.
        let this = self.clone();
        cx.after(decode_pass, move |cx: &mut Cx| {
            {
                let mut s = this.state.borrow_mut();
                if let Some(r) = s.requests.get_mut(&req_id) {
                    r.ttft = cx.now();
                }
            }
            let t2 = this.clone();
            cx.after(decode_pass * n_decode as u64, move |cx: &mut Cx| {
                t2.finish(cx, req_id);
            });
        });
    }

    fn finish(&self, cx: &mut Cx, req_id: u64) {
        let mut s = self.state.borrow_mut();
        let Some(r) = s.requests.get_mut(&req_id) else {
            return;
        };
        if r.state != ReqState::Decoding {
            return;
        }
        r.state = ReqState::Done;
        let report = ReqReport {
            req_id,
            seq_tokens: r.seq_tokens,
            submitted: r.submitted,
            transfer_done: r.transfer_done,
            ttft: r.ttft,
            finished: cx.now(),
            decode_tokens: r.decode_tokens,
        };
        let pages = r.pages.clone();
        let tail = r.tail;
        s.free_slots.extend(pages);
        s.free_tails.push(tail);
        s.reports.borrow_mut().push(report);
    }

    /// Cancel a request: pages stay quarantined until the prefiller
    /// confirms no further WRITEs are possible.
    pub fn cancel(&self, cx: &mut Cx, req_id: u64) {
        let (prefiller, engine, gpu) = {
            let mut s = self.state.borrow_mut();
            let Some(r) = s.requests.get_mut(&req_id) else {
                return;
            };
            if !matches!(r.state, ReqState::Transferring | ReqState::Decoding) {
                return;
            }
            r.state = ReqState::Cancelling;
            (r.prefiller.clone(), s.engine.clone(), s.gpu)
        };
        engine.submit_send(
            cx,
            gpu,
            &prefiller,
            &CancelReq { req_id }.encode(),
            Notify::Noop,
        );
    }

    fn on_message(&self, cx: &mut Cx, msg: &[u8]) {
        match proto::msg_tag(msg) {
            Ok(t) if t == crate::engine::wire::tag::KV_CANCEL_ACK => {
                let ack = CancelAck::decode(msg).expect("bad CancelAck");
                self.on_cancel_ack(ack.req_id);
            }
            Ok(t) if t == crate::engine::wire::tag::HEARTBEAT => {
                let hb = Heartbeat::decode(msg).expect("bad Heartbeat");
                let now = cx.now();
                self.state.borrow_mut().last_seen.insert(hb.sender_node, now);
            }
            Ok(t) => panic!("decoder: unexpected message tag {t}"),
            Err(e) => panic!("decoder: undecodable message: {e}"),
        }
    }

    fn on_cancel_ack(&self, req_id: u64) {
        let mut s = self.state.borrow_mut();
        let Some(r) = s.requests.get_mut(&req_id) else {
            return;
        };
        assert_eq!(
            r.state,
            ReqState::Cancelling,
            "CancelAck for request not being cancelled"
        );
        r.state = ReqState::Cancelled;
        let pages = r.pages.clone();
        let tail = r.tail;
        let imm = r.imm;
        // Only now are the pages safe to reuse.
        s.free_slots.extend(pages);
        s.free_tails.push(tail);
        let gpu = s.gpu;
        s.engine.free_imm(gpu, imm);
    }

    /// Start the heartbeat monitor: requests whose prefiller hasn't
    /// been seen within the timeout are cancelled after the timeout —
    /// stale transfers can no longer arrive from a dead transport
    /// (§4).
    pub fn start_monitor(&self, cx: &mut Cx, interval: Duration) {
        self.state.borrow_mut().monitor_on = true;
        self.monitor_tick(cx, interval);
    }

    /// Stop the heartbeat monitor at its next tick. Failover
    /// scenarios call this once every request has drained so the DES
    /// event queue can run to quiescence.
    pub fn stop_monitor(&self) {
        self.state.borrow_mut().monitor_on = false;
    }

    fn monitor_tick(&self, cx: &mut Cx, interval: Duration) {
        if !self.state.borrow().monitor_on {
            return;
        }
        let now = cx.now();
        let dead: Vec<u64> = {
            let mut s = self.state.borrow_mut();
            let timeout = s.hb_timeout;
            let last_seen = s.last_seen.clone();
            let mut dead = Vec::new();
            for (id, r) in s.requests.iter_mut() {
                if !matches!(r.state, ReqState::Transferring | ReqState::Cancelling) {
                    continue;
                }
                let seen = last_seen.get(&r.prefiller_node).copied().unwrap_or(0);
                if now.saturating_sub(seen) > timeout && now > timeout {
                    r.state = ReqState::Cancelled;
                    dead.push(*id);
                }
            }
            for id in &dead {
                let (pages, tail, imm) = {
                    let r = &s.requests[id];
                    (r.pages.clone(), r.tail, r.imm)
                };
                s.free_slots.extend(pages);
                s.free_tails.push(tail);
                let gpu = s.gpu;
                s.engine.free_imm(gpu, imm);
            }
            dead
        };
        let _ = dead;
        let this = self.clone();
        cx.after(interval, move |cx: &mut Cx| this.monitor_tick(cx, interval));
    }
}
