//! Public API vocabulary of the TransferEngine (paper Figure 2).
//!
//! These types are shared by both engine runtimes (the deterministic
//! DES engine used by benchmarks and the threaded engine used by the
//! examples): `NetAddr`, `MrDesc`, `Pages`, `ScatterDst`,
//! `PeerGroupHandle`, and the calibrated CPU-cost model for the hot
//! path.

use crate::fabric::mem::DmaBuf;
use crate::fabric::nic::NicAddr;
use crate::fabric::topology::DeviceId;
use crate::sim::rng::Jitter;
use crate::sim::time::Duration;

/// Serializable network address of a domain group (one GPU's NICs).
///
/// Exchanged between peers out-of-band (e.g. via an RPC layer or the
/// engine's own SEND/RECV) for identification and discovery. All peers
/// must use the same number of NICs per GPU (§3.2), which lets any
/// transfer pair local NIC *i* with remote NIC *i*.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct NetAddr {
    pub nics: Vec<NicAddr>,
}

impl NetAddr {
    /// The representative address (first NIC), used for SEND/RECV
    /// traffic and for display.
    pub fn primary(&self) -> NicAddr {
        self.nics[0]
    }

    /// Number of NICs backing this domain group.
    pub fn fanout(&self) -> usize {
        self.nics.len()
    }

    /// True when the peer shares a node with `other` (NVLink
    /// reachable).
    pub fn same_node(&self, other: &NetAddr) -> bool {
        self.primary().same_node(&other.primary())
    }
}

impl std::fmt::Display for NetAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.primary())
    }
}

/// Serializable descriptor of a registered memory region, exchangeable
/// with peers who may then WRITE through it (paper Fig 2: `MrDesc`).
#[derive(Debug, Clone, PartialEq)]
pub struct MrDesc {
    /// Virtual base address of the region on the owning device.
    pub ptr: u64,
    /// Region length in bytes.
    pub len: u64,
    /// Remote keys: one per NIC of the owning domain group, in NIC
    /// order.
    pub rkeys: Vec<(NicAddr, u64)>,
}

impl MrDesc {
    /// The rkey to use when targeting this region through remote NIC
    /// index `i`.
    ///
    /// Wraps modulo the rkey count as a defensive fallback only: §3.2
    /// requires local and remote domain groups to run the same NIC
    /// count, and every submission path rejects a mismatch with a real
    /// error — release builds included
    /// (`engine::core::checked_fanout`) — before indexes reach this
    /// method; a silent wrap here would otherwise misroute shards of a
    /// fanout-mismatched transfer.
    pub fn rkey_for(&self, i: usize) -> (NicAddr, u64) {
        self.rkeys[i % self.rkeys.len()]
    }

    /// The domain-group address owning this region.
    pub fn owner(&self) -> NetAddr {
        NetAddr {
            nics: self.rkeys.iter().map(|&(n, _)| n).collect(),
        }
    }
}

/// Local handle to a registered region: the source side of transfers.
#[derive(Clone, Debug)]
pub struct MrHandle {
    pub buf: DmaBuf,
    pub device: DeviceId,
}

/// Indirect paged addressing: `indices[i] * stride + offset` addresses
/// page `i` within a region (paper Fig 2: `Pages`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pages {
    pub indices: Vec<u32>,
    pub stride: u64,
    pub offset: u64,
}

impl Pages {
    /// Byte offset of the `i`-th page.
    pub fn at(&self, i: usize) -> u64 {
        self.indices[i] as u64 * self.stride + self.offset
    }

    /// Number of pages.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// True when no pages are addressed.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Contiguous run of `n` pages starting at page index `first`.
    pub fn contiguous(first: u32, n: u32, stride: u64) -> Self {
        Pages {
            indices: (first..first + n).collect(),
            stride,
            offset: 0,
        }
    }
}

/// One destination of a scatter: `len` bytes from source offset `src`
/// into `(desc, offset)` on a peer (paper Fig 2: `ScatterDst`).
#[derive(Debug, Clone)]
pub struct ScatterDst {
    pub len: u64,
    pub src: u64,
    pub dst: (MrDesc, u64),
}

/// Handle to a pre-registered peer group for scatter/barrier.
///
/// Handle ids are allocated monotonically and never recycled, so a
/// handle that survived `remove_peer_group` can never alias a newer
/// group (no ABA): templated submissions on a freed handle fail with a
/// deterministic error instead of reusing freed state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PeerGroupHandle(pub u64);

/// One destination of a *templated* scatter (paper §3.5): only the
/// per-call fields. The peer's descriptor, resolved rkeys and NIC
/// pairing were captured once at `bind_peer_group_mrs` time, so a
/// submission patches offsets/lengths into the pre-built template
/// instead of carrying (and re-resolving) a cloned [`MrDesc`] per
/// destination like [`ScatterDst`] does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TemplatedDst {
    /// Index into the group's peer list (bind order).
    pub peer: usize,
    /// Bytes to write.
    pub len: u64,
    /// Source offset within the source region.
    pub src: u64,
    /// Destination offset within the peer's bound region.
    pub dst: u64,
}

/// Calibrated CPU costs of the engine hot path, charged on the worker
/// in simulated time. Calibration targets: paper Table 8 (µs from
/// `submit_scatter()` to the last posted WRITE at EP64) and Table 9
/// (post-time scaling).
#[derive(Debug, Clone)]
pub struct EngineCosts {
    /// App-thread cost of `submit_*`: validate + enqueue onto the
    /// lock-free queue (Table 8 row 1: 0.120 µs p50).
    pub submit_ns: Duration,
    pub submit_jitter: Jitter,
    /// Cross-thread handoff until the worker dequeues (Table 8 row 2:
    /// 0.855 µs p50).
    pub handoff_ns: Duration,
    pub handoff_jitter: Jitter,
    /// Worker-side preparation before the first WRITE is posted
    /// (Table 8 row 3: 0.441 µs p50): WR templating fills in the
    /// per-transfer fields only.
    pub prep_ns: Duration,
    pub prep_jitter: Jitter,
    /// Dispatch latency of a completion callback onto the dedicated
    /// callback thread.
    pub callback_ns: Duration,
    /// Interval of the UVM-watcher polling thread (GDRCopy read per
    /// watcher per tick).
    pub uvm_poll_ns: Duration,
}

impl Default for EngineCosts {
    fn default() -> Self {
        EngineCosts {
            submit_ns: 110,
            submit_jitter: Jitter {
                median_ns: 15.0,
                sigma: 0.6,
                spike_p: 0.01,
                spike_mean_ns: 1800.0,
            },
            handoff_ns: 700,
            handoff_jitter: Jitter {
                median_ns: 160.0,
                sigma: 0.4,
                spike_p: 0.002,
                spike_mean_ns: 3000.0,
            },
            prep_ns: 380,
            prep_jitter: Jitter {
                median_ns: 60.0,
                sigma: 0.5,
                spike_p: 0.002,
                spike_mean_ns: 3000.0,
            },
            callback_ns: 250,
            uvm_poll_ns: 1000,
        }
    }
}

/// Writes carrying an immediate are never split across NICs: the
/// receiver's IMMCOUNTER expectation counts one increment per
/// submitted write (see `expect_imm_count` call sites in §4/§6), so
/// the engine must not change the count behind the caller's back.
/// Imm-less writes larger than this are sharded across all NICs of the
/// group.
pub const SPLIT_THRESHOLD: u64 = 128 * 1024;

#[cfg(test)]
mod tests {
    use super::*;

    fn nic(n: u16, x: u8) -> NicAddr {
        NicAddr { node: n, gpu: 0, nic: x }
    }

    #[test]
    fn pages_addressing() {
        let p = Pages {
            indices: vec![3, 0, 7],
            stride: 4096,
            offset: 128,
        };
        assert_eq!(p.at(0), 3 * 4096 + 128);
        assert_eq!(p.at(1), 128);
        assert_eq!(p.at(2), 7 * 4096 + 128);
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn pages_contiguous() {
        let p = Pages::contiguous(4, 3, 100);
        assert_eq!(p.indices, vec![4, 5, 6]);
        assert_eq!(p.at(2), 600);
    }

    #[test]
    fn mrdesc_rkey_pairing() {
        let d = MrDesc {
            ptr: 0x1000,
            len: 4096,
            rkeys: vec![(nic(2, 0), 11), (nic(2, 1), 22)],
        };
        assert_eq!(d.rkey_for(0), (nic(2, 0), 11));
        assert_eq!(d.rkey_for(1), (nic(2, 1), 22));
        // Defensive wrap only; submission paths error on the §3.2
        // equal-NIC-count violation first, in every build profile (see
        // engine::core tests for the mismatch path).
        assert_eq!(d.rkey_for(2), (nic(2, 0), 11));
        assert_eq!(d.owner().fanout(), 2);
    }

    #[test]
    fn netaddr_same_node() {
        let a = NetAddr { nics: vec![nic(1, 0), nic(1, 1)] };
        let b = NetAddr { nics: vec![nic(1, 0)] };
        let c = NetAddr { nics: vec![nic(2, 0)] };
        assert!(a.same_node(&b));
        assert!(!a.same_node(&c));
    }
}
