//! Scaled kvcache serving scenario: thousands of prefiller/decoder
//! nodes, millions of open-loop requests, played directly on the DES
//! scheduler.
//!
//! Unlike the Table-3 harness (which drives real `TransferEngine`
//! instances page-by-page), this is a *model-level* simulation sized
//! for 10⁶-request sweeps: per-prefiller compute and NIC-link
//! free-time cursors serialize the work, dispatch uses
//! power-of-two-choices on compute backlog, and each request costs a
//! constant handful of scheduler events —
//!
//! 1. its open-loop arrival (self-clocking: each arrival event
//!    schedules the next, so pending arrivals never pile up),
//! 2. prefill-compute completion on the chosen prefiller,
//! 3. KV-transfer completion over that prefiller's NIC
//!    ([`serialize_ns`] of the paged KV bytes + tail),
//! 4. first-token completion after the decoder's decode pass, which
//!    records TTFT and cancels
//! 5. a per-request guard timeout — 10⁶ cancellations exercising the
//!    scheduler's generation-tagged arena on every run.
//!
//! Every node also runs a self-rearming heartbeat timer (the pattern
//! that leaked tombstones in the legacy scheduler). The report carries
//! TTFT p50/p99/p99.9 plus the scheduler's own counters so callers
//! (the `sim_churn` bench, tests) can assert bounded peak-pending
//! depth and an explicit memory budget.

use std::cell::RefCell;
use std::rc::Rc;

use crate::sim::rng::Rng;
use crate::sim::stats::{Histogram, Summary};
use crate::sim::time::{serialize_ns, Duration, Instant, MS, SEC};
use crate::sim::{Sim, SimStats};

use super::arrivals::Arrivals;
use super::workload::ServingWorkload;

/// Configuration of a scaled serving sweep.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Prefiller nodes (each with one compute cursor and one NIC).
    pub prefillers: usize,
    /// Decoder nodes (heartbeat participants; decode passes are
    /// charged per request without cross-request contention).
    pub decoders: usize,
    /// Per-prefiller NIC rate for KV transfers, in Gbps.
    pub link_gbps: f64,
    /// Stop after this many requests (the trace may end earlier).
    pub requests: usize,
    /// Timing model + KV layout.
    pub workload: ServingWorkload,
    /// Per-request guard timer; cancelled on completion. Fires (and
    /// is counted) only if a request's TTFT exceeds it.
    pub timeout_ns: Duration,
    /// Heartbeat period for every node; 0 disables heartbeats.
    pub heartbeat_ns: Duration,
    /// When non-zero, `run_serving` asserts the scheduler's resident
    /// footprint ([`Sim::approx_mem_bytes`]) stays under this budget.
    pub mem_budget_bytes: usize,
}

impl ServingConfig {
    /// The acceptance-scale sweep: `prefillers + decoders` nodes,
    /// Qwen3-235B timing, 400 Gbps NICs, 60 s guard timers, 1 s
    /// heartbeats, and a 64 MiB scheduler memory budget.
    pub fn scaled(prefillers: usize, decoders: usize, requests: usize) -> Self {
        ServingConfig {
            prefillers,
            decoders,
            link_gbps: 400.0,
            requests,
            workload: ServingWorkload::qwen3_235b(8192),
            timeout_ns: 60 * SEC,
            heartbeat_ns: SEC,
            mem_budget_bytes: 64 << 20,
        }
    }

    /// Small configuration for unit tests.
    pub fn small(requests: usize) -> Self {
        ServingConfig {
            prefillers: 8,
            decoders: 8,
            link_gbps: 400.0,
            requests,
            workload: ServingWorkload::qwen3_235b(8192),
            timeout_ns: 60 * SEC,
            heartbeat_ns: 100 * MS,
            mem_budget_bytes: 16 << 20,
        }
    }
}

/// Outcome of one serving sweep.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Requests that reached first token.
    pub completed: u64,
    /// Guard timers that fired before completion.
    pub timeouts: u64,
    /// TTFT distribution (ns): p50/p99/p999 are the headline columns.
    pub ttft: Summary,
    /// Scheduler counters for the whole run.
    pub sim: SimStats,
    /// Arena slots the scheduler grew to (O(peak-pending), not
    /// O(total-events)).
    pub arena_slots: usize,
    /// Scheduler container footprint at end of run.
    pub approx_mem_bytes: usize,
    /// Virtual end-of-run time.
    pub end_ns: Instant,
}

struct State {
    cfg: ServingConfig,
    arrivals: Arrivals,
    /// Arrivals still allowed to launch.
    to_launch: usize,
    /// Per-prefiller compute free-time cursor.
    comp_free: Vec<Instant>,
    /// Per-prefiller NIC free-time cursor.
    link_free: Vec<Instant>,
    /// Dispatch randomness (power-of-two-choices probes).
    rng: Rng,
    ttft: Histogram,
    completed: u64,
    timeouts: u64,
    /// Set once every launched request completed; heartbeats stop
    /// rearming.
    done_target: u64,
    launched: u64,
    draining: bool,
}

/// KV bytes moved for a request: one page per layer per 128-token
/// page, plus the tail context write.
fn kv_bytes(w: &ServingWorkload, seq: u32) -> u64 {
    let pages = w.layout.pages_for(seq) as u64;
    pages * w.layout.page_bytes * w.layout.layers as u64 + w.tail_bytes
}

fn pump_arrival(sim: &mut Sim, st: &Rc<RefCell<State>>) {
    let next = {
        let mut b = st.borrow_mut();
        if b.to_launch == 0 {
            b.draining = true;
            None
        } else {
            b.to_launch -= 1;
            let a = b.arrivals.next();
            if a.is_none() {
                // Trace exhausted early: complete what was launched.
                b.draining = true;
                b.done_target = b.launched;
            }
            a
        }
    };
    let Some(a) = next else { return };
    let stc = st.clone();
    sim.at(a.at, move |sim| {
        on_arrival(sim, &stc, a.seq_tokens);
        pump_arrival(sim, &stc);
    });
}

fn on_arrival(sim: &mut Sim, st: &Rc<RefCell<State>>, seq: u32) {
    let now = sim.now();
    let (p, comp_done, timeout_ns) = {
        let mut b = st.borrow_mut();
        b.launched += 1;
        let n = b.comp_free.len() as u64;
        let (i, j) = (b.rng.below(n) as usize, b.rng.below(n) as usize);
        let p = if b.comp_free[i] <= b.comp_free[j] { i } else { j };
        let start = b.comp_free[p].max(now);
        let done = start.saturating_add(b.cfg.workload.total_prefill_ns(seq));
        b.comp_free[p] = done;
        (p, done, b.cfg.timeout_ns)
    };
    let stc = st.clone();
    let guard = sim.after(timeout_ns, move |_| {
        stc.borrow_mut().timeouts += 1;
    });
    let stc = st.clone();
    sim.at(comp_done, move |sim| {
        on_prefill_done(sim, &stc, p, now, seq, guard);
    });
}

fn on_prefill_done(
    sim: &mut Sim,
    st: &Rc<RefCell<State>>,
    p: usize,
    arrived: Instant,
    seq: u32,
    guard: crate::sim::EventId,
) {
    let now = sim.now();
    let xfer_done = {
        let mut b = st.borrow_mut();
        let bytes = kv_bytes(&b.cfg.workload, seq);
        let start = b.link_free[p].max(now);
        let done = start.saturating_add(serialize_ns(bytes, b.cfg.link_gbps));
        b.link_free[p] = done;
        done
    };
    let stc = st.clone();
    sim.at(xfer_done, move |sim| {
        let decode = stc.borrow().cfg.workload.compute.decode_pass_ns;
        let stc2 = stc.clone();
        sim.after(decode, move |sim| {
            sim.cancel(guard);
            let mut b = stc2.borrow_mut();
            b.ttft.record(sim.now() - arrived);
            b.completed += 1;
        });
    });
}

fn heartbeat(sim: &mut Sim, st: &Rc<RefCell<State>>, period: Duration) {
    let stop = {
        let b = st.borrow();
        b.draining && b.completed >= b.done_target
    };
    if stop {
        return;
    }
    let stc = st.clone();
    sim.after(period, move |sim| heartbeat(sim, &stc, period));
}

/// Play `arrivals` through the serving model and summarize TTFT and
/// scheduler behavior. Panics when a non-zero `mem_budget_bytes` is
/// exceeded or no request completes.
pub fn run_serving(cfg: ServingConfig, arrivals: Arrivals) -> ServingReport {
    assert!(cfg.prefillers > 0 && cfg.requests > 0);
    let mem_budget = cfg.mem_budget_bytes;
    let heartbeat_ns = cfg.heartbeat_ns;
    let nodes = cfg.prefillers + cfg.decoders;
    let st = Rc::new(RefCell::new(State {
        comp_free: vec![0; cfg.prefillers],
        link_free: vec![0; cfg.prefillers],
        to_launch: cfg.requests,
        done_target: cfg.requests as u64,
        arrivals,
        rng: Rng::new(0x5EE7),
        ttft: Histogram::new(),
        completed: 0,
        timeouts: 0,
        launched: 0,
        draining: false,
        cfg,
    }));

    let mut sim = Sim::new();
    if heartbeat_ns > 0 {
        for node in 0..nodes {
            // Stagger initial phases so heartbeats don't all tie.
            let phase = (node as u64 * heartbeat_ns) / nodes as u64;
            let stc = st.clone();
            sim.at(phase, move |sim| heartbeat(sim, &stc, heartbeat_ns));
        }
    }
    pump_arrival(&mut sim, &st);
    let end_ns = sim.run();

    if mem_budget > 0 {
        assert!(
            sim.approx_mem_bytes() <= mem_budget,
            "scheduler footprint {} exceeds budget {}",
            sim.approx_mem_bytes(),
            mem_budget
        );
    }
    let mut b = st.borrow_mut();
    assert!(b.completed > 0, "no request completed");
    let ttft = b.ttft.summary();
    ServingReport {
        completed: b.completed,
        timeouts: b.timeouts,
        ttft,
        sim: sim.stats(),
        arena_slots: sim.arena_slots(),
        approx_mem_bytes: sim.approx_mem_bytes(),
        end_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::super::arrivals::{to_trace_text, Arrivals, PoissonArrivals, TraceArrivals};
    use super::*;

    fn poisson(seed: u64) -> Arrivals {
        // ~8 prefillers at ~3.1 s prefill (8K tokens): stay below
        // saturation for the small config.
        Arrivals::Poisson(PoissonArrivals::new(
            seed,
            500 * MS,
            vec![2048, 4096, 8192],
        ))
    }

    #[test]
    fn serving_completes_and_reports_tail() {
        let rep = run_serving(ServingConfig::small(500), poisson(1));
        assert_eq!(rep.completed, 500);
        assert_eq!(rep.timeouts, 0);
        assert!(rep.ttft.p50 > 0);
        assert!(rep.ttft.p999 >= rep.ttft.p99 && rep.ttft.p99 >= rep.ttft.p50);
        // 500 requests x 5 events + heartbeats; every guard cancelled.
        assert_eq!(rep.sim.cancelled, 500);
    }

    #[test]
    fn serving_is_deterministic() {
        let a = run_serving(ServingConfig::small(300), poisson(9));
        let b = run_serving(ServingConfig::small(300), poisson(9));
        assert_eq!(a.ttft.p50, b.ttft.p50);
        assert_eq!(a.ttft.p999, b.ttft.p999);
        assert_eq!(a.end_ns, b.end_ns);
        assert_eq!(a.sim, b.sim);
    }

    #[test]
    fn trace_replay_matches_poisson_run() {
        let mut p = PoissonArrivals::new(77, 500 * MS, vec![2048, 4096, 8192]);
        let text = to_trace_text(&mut p, 300);
        let direct = run_serving(
            ServingConfig::small(300),
            Arrivals::Poisson(PoissonArrivals::new(77, 500 * MS, vec![2048, 4096, 8192])),
        );
        let replayed = run_serving(
            ServingConfig::small(300),
            Arrivals::Trace(TraceArrivals::parse(&text).unwrap()),
        );
        assert_eq!(direct.ttft.p50, replayed.ttft.p50);
        assert_eq!(direct.ttft.p999, replayed.ttft.p999);
        assert_eq!(direct.end_ns, replayed.end_ns);
        assert_eq!(direct.sim, replayed.sim);
    }

    #[test]
    fn pending_depth_stays_bounded() {
        let rep = run_serving(ServingConfig::small(1000), poisson(3));
        // Open-loop arrivals self-clock: peak pending is O(in-flight
        // + nodes), nowhere near O(total requests).
        assert!(
            rep.sim.peak_pending < 600,
            "peak pending {} suggests arrivals piled up",
            rep.sim.peak_pending
        );
        assert!(rep.arena_slots as u64 >= rep.sim.peak_pending / 2);
    }

    #[test]
    fn short_trace_ends_run_early() {
        let text = "0 2048\n1000000 2048\n";
        let rep = run_serving(
            ServingConfig::small(100),
            Arrivals::Trace(TraceArrivals::parse(text).unwrap()),
        );
        assert_eq!(rep.completed, 2);
    }
}
