//! §3.5 peer-group template coverage, cross-runtime:
//!
//! * stale-handle determinism — every templated entry point (and
//!   rebinding) errors on a handle that went through
//!   `remove_peer_group`, on BOTH runtimes, in release builds too
//!   (handles are never recycled, so there is no ABA window);
//! * templated vs untemplated equivalence at the ENGINE level — the
//!   same logical workload driven through both paths on two
//!   identically-seeded DES clusters produces identical per-NIC byte
//!   streams and payloads (the WR streams cannot differ if the bytes
//!   on every NIC agree under a deterministic fabric);
//! * the §3.2 equal-NIC-count violation is a real error on both
//!   runtimes (untemplated per call, templated once at bind).

use fabric_lib::engine::api::{MrDesc, MrHandle, PeerGroupHandle, ScatterDst, TemplatedDst};
use fabric_lib::engine::traits::{
    expect_flag, run_on_both, Cluster, Cx, Notify, RuntimeKind, TransferEngine,
};
use fabric_lib::fabric::nic::NicAddr;

/// All four templated entry points plus rebind against one handle;
/// returns the error strings for uniform assertions.
fn templated_errors(
    cx: &mut Cx,
    e: &dyn TransferEngine,
    src: &MrHandle,
    group: PeerGroupHandle,
    descs: &[MrDesc],
) -> Vec<String> {
    let pages = fabric_lib::engine::api::Pages::contiguous(0, 1, 64);
    vec![
        e.submit_single_write_templated(cx, (src, 0), 16, group, 0, 0, None, Notify::Noop)
            .map_err(|e| e.to_string())
            .unwrap_err(),
        e.submit_paged_writes_templated(cx, 64, (src, &pages), group, 0, &pages, None, Notify::Noop)
            .map_err(|e| e.to_string())
            .unwrap_err(),
        e.submit_scatter_templated(
            cx,
            src,
            group,
            &[TemplatedDst { peer: 0, len: 8, src: 0, dst: 0 }],
            None,
            Notify::Noop,
        )
        .map_err(|e| e.to_string())
        .unwrap_err(),
        e.submit_barrier_templated(cx, group, 9, Notify::Noop)
            .map_err(|e| e.to_string())
            .unwrap_err(),
        e.bind_peer_group_mrs(0, group, descs)
            .map_err(|e| e.to_string())
            .unwrap_err(),
    ]
}

#[test]
fn stale_handle_errors_deterministically_on_both_runtimes() {
    run_on_both(3, 1, 2, 0x57A1E, |cx, engines| {
        let e = engines[0];
        let (src, _) = e.alloc_mr(0, 1024);
        let descs: Vec<MrDesc> = engines[1..]
            .iter()
            .map(|p| p.alloc_mr(0, 4096).1)
            .collect();
        let addrs = engines[1..].iter().map(|p| p.main_address()).collect();
        let group = e.add_peer_group(addrs);

        // Before binding: templated submissions name the missing bind.
        let err = e
            .submit_barrier_templated(cx, group, 5, Notify::Noop)
            .unwrap_err();
        assert!(err.to_string().contains("no bound template"), "{err}");

        // Bound: the whole templated family works.
        e.bind_peer_group_mrs(0, group, &descs).unwrap();
        let done = expect_flag(engines[1], cx, 0, 5, 1);
        e.submit_barrier_templated(cx, group, 5, Notify::Noop).unwrap();
        cx.wait(&done);

        // Freed: every entry point errors, deterministically, with the
        // stale-handle diagnostic — no panic, no freed-state reuse.
        assert!(e.remove_peer_group(group));
        for err in templated_errors(cx, e, &src, group, &descs) {
            assert!(err.contains("stale or unknown"), "{err}");
        }
        // And again (double-free path): still the same deterministic
        // error, handles never recycle.
        assert!(!e.remove_peer_group(group));
        for err in templated_errors(cx, e, &src, group, &descs) {
            assert!(err.contains("stale or unknown"), "{err}");
        }
        // A never-registered handle behaves the same.
        for err in templated_errors(cx, e, &src, PeerGroupHandle(u64::MAX), &descs) {
            assert!(err.contains("stale or unknown"), "{err}");
        }
    });
}

#[test]
fn fanout_mismatch_errors_on_both_runtimes_in_every_build() {
    // Engines run 2 NICs per GPU; a descriptor advertising a single
    // rkey violates §3.2 and must be rejected as an Err — this is a
    // release-mode test on purpose (the old code only debug_asserted
    // and silently wrapped rkey selection in release builds).
    run_on_both(2, 1, 2, 0x32F, |cx, engines| {
        let (src, _) = engines[0].alloc_mr(0, 1024);
        let (_h, good) = engines[1].alloc_mr(0, 1024);
        let mut bad = good.clone();
        bad.rkeys.truncate(1);

        let err = engines[0]
            .submit_single_write(cx, (&src, 0), 64, (&bad, 0), None, Notify::Noop)
            .unwrap_err();
        assert!(err.to_string().contains("equal-NIC-count"), "{err}");
        let err = engines[0]
            .submit_scatter(
                cx,
                None,
                &src,
                &[ScatterDst { len: 8, src: 0, dst: (bad.clone(), 0) }],
                None,
                Notify::Noop,
            )
            .unwrap_err();
        assert!(err.to_string().contains("equal-NIC-count"), "{err}");
        let err = engines[0]
            .submit_barrier(cx, 0, None, &[bad.clone()], 3, Notify::Noop)
            .unwrap_err();
        assert!(err.to_string().contains("equal-NIC-count"), "{err}");

        // Templated path: the same violation is caught once, at bind.
        let group = engines[0].add_peer_group(vec![engines[1].main_address()]);
        let err = engines[0].bind_peer_group_mrs(0, group, &[bad]).unwrap_err();
        assert!(err.to_string().contains("equal-NIC-count"), "{err}");
        // The good descriptor binds fine afterwards.
        engines[0].bind_peer_group_mrs(0, group, &[good]).unwrap();
        assert!(engines[0].remove_peer_group(group));
    });
}

/// One logical workload — a scatter, a barrier, a single write and a
/// paged write to two peers — executed on a fresh DES cluster either
/// untemplated or templated.
fn run_workload(templated: bool) -> (Vec<Vec<u8>>, Vec<(u64, u64)>) {
    let mut cluster = Cluster::new(RuntimeKind::Des, 3, 1, 2, 0xD15C);
    let net = cluster.des_net().expect("DES cluster");
    let payloads = {
        let (mut cx, engines) = cluster.parts();
        let sender = engines[0];
        let (src, _) = sender.alloc_mr(0, 4096);
        let fill: Vec<u8> = (0..4096u32).map(|i| (i % 249) as u8 + 1).collect();
        src.buf.write(0, &fill);
        let regions: Vec<_> = engines[1..].iter().map(|e| e.alloc_mr(0, 8192)).collect();
        let descs: Vec<MrDesc> = regions.iter().map(|(_, d)| d.clone()).collect();
        let group =
            sender.add_peer_group(engines[1..].iter().map(|e| e.main_address()).collect());

        let scattered = expect_flag(engines[1], &mut cx, 0, 0x21, 2);
        let barried = expect_flag(engines[2], &mut cx, 0, 0x22, 1);
        let pages = fabric_lib::engine::api::Pages::contiguous(0, 4, 512);
        if templated {
            sender.bind_peer_group_mrs(0, group, &descs).unwrap();
            sender
                .submit_scatter_templated(
                    &mut cx,
                    &src,
                    group,
                    &[
                        TemplatedDst { peer: 0, len: 300, src: 0, dst: 100 },
                        TemplatedDst { peer: 0, len: 200, src: 512, dst: 3000 },
                    ],
                    Some(0x21),
                    Notify::Noop,
                )
                .unwrap();
            sender
                .submit_barrier_templated(&mut cx, group, 0x22, Notify::Noop)
                .unwrap();
            sender
                .submit_single_write_templated(
                    &mut cx,
                    (&src, 64),
                    1024,
                    group,
                    1,
                    4096,
                    None,
                    Notify::Noop,
                )
                .unwrap();
            sender
                .submit_paged_writes_templated(
                    &mut cx,
                    512,
                    (&src, &pages),
                    group,
                    1,
                    &pages,
                    None,
                    Notify::Noop,
                )
                .unwrap();
        } else {
            sender
                .submit_scatter(
                    &mut cx,
                    Some(group),
                    &src,
                    &[
                        ScatterDst { len: 300, src: 0, dst: (descs[0].clone(), 100) },
                        ScatterDst { len: 200, src: 512, dst: (descs[0].clone(), 3000) },
                    ],
                    Some(0x21),
                    Notify::Noop,
                )
                .unwrap();
            sender
                .submit_barrier(&mut cx, 0, Some(group), &descs, 0x22, Notify::Noop)
                .unwrap();
            sender
                .submit_single_write(
                    &mut cx,
                    (&src, 64),
                    1024,
                    (&descs[1], 4096),
                    None,
                    Notify::Noop,
                )
                .unwrap();
            sender
                .submit_paged_writes(
                    &mut cx,
                    512,
                    (&src, &pages),
                    (&descs[1], &pages),
                    None,
                    Notify::Noop,
                )
                .unwrap();
        }
        cx.wait(&scattered);
        cx.wait(&barried);
        cx.settle();
        regions.iter().map(|(h, _)| h.buf.to_vec()).collect::<Vec<_>>()
    };
    let mut nic_bytes = Vec::new();
    for node in 0..3u16 {
        for nic in 0..2u8 {
            nic_bytes.push(net.nic_bytes(NicAddr { node, gpu: 0, nic }));
        }
    }
    cluster.shutdown();
    (payloads, nic_bytes)
}

/// Acceptance gate: under a deterministic fabric with identical seeds,
/// the templated path must emit the SAME WR stream as the untemplated
/// one — observed as identical per-NIC byte counters (tx and rx, every
/// NIC of every node) and identical landed payloads.
#[test]
fn templated_workload_emits_identical_wr_stream() {
    let (plain_payloads, plain_nics) = run_workload(false);
    let (tpl_payloads, tpl_nics) = run_workload(true);
    assert_eq!(plain_payloads, tpl_payloads, "landed bytes diverged");
    assert_eq!(plain_nics, tpl_nics, "per-NIC byte streams diverged");
}
