//! Shared utilities: JSON parsing, CLI parsing, table rendering, and
//! the bench/property-test harnesses (criterion/proptest are not
//! available offline — see DESIGN.md §1).

pub mod alloc_probe;
pub mod cli;
pub mod err;
pub mod fasthash;
pub mod json;
pub mod prop;
pub mod smallvec;
pub mod table;
pub mod telemetry;

pub use json::Json;
