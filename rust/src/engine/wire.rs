//! Hand-rolled binary wire format for descriptors and control
//! messages.
//!
//! `NetAddr` and `MrDesc` must be serializable so peers can exchange
//! them out-of-band (paper Fig 2 marks both `#[serde]`); the KvCache
//! app also ships a `DispatchReq` over SEND/RECV. The format is a
//! compact little-endian TLV-free layout with explicit counts and a
//! magic/version prefix; golden tests pin the byte layout.

use crate::bail;
use crate::util::err::{Context, Result};

use super::api::{MrDesc, NetAddr};
use crate::fabric::nic::NicAddr;

const MAGIC: u8 = 0xFB; // "fabric"
const VERSION: u8 = 1;

/// Growable little-endian encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Start a message of the given type tag.
    pub fn new(tag: u8) -> Self {
        let mut e = Enc { buf: Vec::with_capacity(64) };
        e.buf.extend_from_slice(&[MAGIC, VERSION, tag]);
        e
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn nic(&mut self, a: NicAddr) -> &mut Self {
        self.buf.extend_from_slice(&a.pack());
        self
    }

    /// Finish, returning the encoded bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Checked little-endian decoder.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Open a message, validating magic/version and returning the tag.
    pub fn open(buf: &'a [u8]) -> Result<(u8, Dec<'a>)> {
        if buf.len() < 3 {
            bail!("message too short: {} bytes", buf.len());
        }
        if buf[0] != MAGIC {
            bail!("bad magic {:#x}", buf[0]);
        }
        if buf[1] != VERSION {
            bail!("unsupported wire version {}", buf[1]);
        }
        Ok((buf[2], Dec { buf, pos: 3 }))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated message: need {} bytes at {}, have {}",
                n,
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn nic(&mut self) -> Result<NicAddr> {
        Ok(NicAddr::unpack(self.take(4)?.try_into().unwrap()))
    }

    /// Assert the message is fully consumed.
    pub fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

/// Message tags.
pub mod tag {
    pub const NET_ADDR: u8 = 1;
    pub const MR_DESC: u8 = 2;
    pub const KV_DISPATCH: u8 = 3;
    pub const KV_CANCEL: u8 = 4;
    pub const KV_CANCEL_ACK: u8 = 5;
    pub const HEARTBEAT: u8 = 6;
    /// Engine-level NIC-health gossip: consumed by the receiving
    /// engine's recv path, never delivered to application callbacks.
    pub const NIC_HEALTH: u8 = 7;
}

/// Serialize a `NetAddr`.
pub fn encode_net_addr(a: &NetAddr) -> Vec<u8> {
    let mut e = Enc::new(tag::NET_ADDR);
    e.u8(a.nics.len() as u8);
    for &n in &a.nics {
        e.nic(n);
    }
    e.finish()
}

/// Deserialize a `NetAddr`.
pub fn decode_net_addr(buf: &[u8]) -> Result<NetAddr> {
    let (t, mut d) = Dec::open(buf)?;
    if t != tag::NET_ADDR {
        bail!("expected NET_ADDR, got tag {t}");
    }
    let n = d.u8()? as usize;
    let mut nics = Vec::with_capacity(n);
    for _ in 0..n {
        nics.push(d.nic()?);
    }
    d.done()?;
    if nics.is_empty() {
        bail!("NetAddr with zero NICs");
    }
    Ok(NetAddr { nics })
}

/// Serialize an `MrDesc`.
pub fn encode_mr_desc(m: &MrDesc) -> Vec<u8> {
    let mut e = Enc::new(tag::MR_DESC);
    e.u64(m.ptr).u64(m.len).u8(m.rkeys.len() as u8);
    for &(nic, rkey) in &m.rkeys {
        e.nic(nic).u64(rkey);
    }
    e.finish()
}

/// Deserialize an `MrDesc`.
pub fn decode_mr_desc(buf: &[u8]) -> Result<MrDesc> {
    let (t, mut d) = Dec::open(buf)?;
    if t != tag::MR_DESC {
        bail!("expected MR_DESC, got tag {t}");
    }
    let ptr = d.u64()?;
    let len = d.u64()?;
    let n = d.u8()? as usize;
    let mut rkeys = Vec::with_capacity(n);
    for _ in 0..n {
        let nic = d.nic()?;
        let rkey = d.u64().context("rkey")?;
        rkeys.push((nic, rkey));
    }
    d.done()?;
    Ok(MrDesc { ptr, len, rkeys })
}

/// Serialize a NIC-health gossip report: "I observed `nic` to be
/// `up`/down". Sent by an engine whose `WrError` attribution concluded
/// a REMOTE NIC is unreachable, over the ordinary SEND/RECV control
/// plane (the same recv pool heartbeats ride on), so other senders can
/// mask the dead destination before paying their own error round-trip.
pub fn encode_nic_health(nic: NicAddr, up: bool) -> Vec<u8> {
    let mut e = Enc::new(tag::NIC_HEALTH);
    e.nic(nic).u8(up as u8);
    e.finish()
}

/// Deserialize a NIC-health gossip report.
pub fn decode_nic_health(buf: &[u8]) -> Result<(NicAddr, bool)> {
    let (t, mut d) = Dec::open(buf)?;
    if t != tag::NIC_HEALTH {
        bail!("expected NIC_HEALTH, got tag {t}");
    }
    let nic = d.nic()?;
    let up = d.u8()? != 0;
    d.done()?;
    Ok((nic, up))
}

/// Cheap classifier for the engines' recv paths: is this message a
/// NIC-health gossip report? (Full validation still happens in
/// [`decode_nic_health`]; a truncated gossip message is dropped, not
/// delivered to the application.)
pub fn is_nic_health(buf: &[u8]) -> bool {
    buf.len() >= 3 && buf[0] == MAGIC && buf[1] == VERSION && buf[2] == tag::NIC_HEALTH
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nic(node: u16, gpu: u8, x: u8) -> NicAddr {
        NicAddr { node, gpu, nic: x }
    }

    #[test]
    fn net_addr_roundtrip() {
        let a = NetAddr {
            nics: vec![nic(3, 1, 0), nic(3, 1, 1)],
        };
        let bytes = encode_net_addr(&a);
        assert_eq!(decode_net_addr(&bytes).unwrap(), a);
    }

    #[test]
    fn net_addr_golden_bytes() {
        let a = NetAddr { nics: vec![nic(0x0102, 7, 1)] };
        assert_eq!(
            encode_net_addr(&a),
            vec![0xFB, 1, tag::NET_ADDR, 1, 0x02, 0x01, 7, 1]
        );
    }

    #[test]
    fn mr_desc_roundtrip() {
        let m = MrDesc {
            ptr: 0xDEAD_BEEF_0000,
            len: 1 << 30,
            rkeys: vec![(nic(1, 0, 0), 42), (nic(1, 0, 1), 43)],
        };
        let bytes = encode_mr_desc(&m);
        assert_eq!(decode_mr_desc(&bytes).unwrap(), m);
    }

    #[test]
    fn rejects_bad_magic_version_tag() {
        assert!(decode_net_addr(&[0x00, 1, 1, 0]).is_err());
        assert!(decode_net_addr(&[0xFB, 9, 1, 0]).is_err());
        // Valid NET_ADDR bytes presented as MR_DESC:
        let a = NetAddr { nics: vec![nic(0, 0, 0)] };
        assert!(decode_mr_desc(&encode_net_addr(&a)).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let a = NetAddr { nics: vec![nic(1, 2, 3)] };
        let bytes = encode_net_addr(&a);
        for cut in 0..bytes.len() {
            assert!(
                decode_net_addr(&bytes[..cut]).is_err(),
                "truncation at {cut} must fail"
            );
        }
        let mut extended = bytes.clone();
        extended.push(0);
        assert!(decode_net_addr(&extended).is_err(), "trailing bytes must fail");
    }

    #[test]
    fn nic_health_roundtrip_and_classifier() {
        let n = nic(9, 1, 3);
        for up in [false, true] {
            let bytes = encode_nic_health(n, up);
            assert!(is_nic_health(&bytes));
            assert_eq!(decode_nic_health(&bytes).unwrap(), (n, up));
        }
        // Other control messages never classify as gossip.
        let hb = encode_net_addr(&NetAddr { nics: vec![n] });
        assert!(!is_nic_health(&hb));
        assert!(!is_nic_health(b"app payload"));
        assert!(!is_nic_health(&[]));
        // A truncated gossip message classifies but fails validation —
        // the engine drops it rather than delivering it to the app.
        let bytes = encode_nic_health(n, true);
        assert!(is_nic_health(&bytes[..4]));
        assert!(decode_nic_health(&bytes[..4]).is_err());
    }

    #[test]
    fn rejects_zero_nic_netaddr() {
        let e = {
            let mut e = Enc::new(tag::NET_ADDR);
            e.u8(0);
            e.finish()
        };
        assert!(decode_net_addr(&e).is_err());
    }
}
