//! Single-threaded discrete-event executor.
//!
//! Events are boxed `FnOnce(&mut Sim)` closures keyed by `(time, seq)`;
//! `seq` breaks ties so same-timestamp events fire in scheduling order,
//! which keeps runs deterministic. Components live in `Rc<RefCell<..>>`
//! cells captured by their event closures — the `Sim` itself owns only
//! the clock and the queue.
//!
//! Events can be cancelled (timers, heartbeats) via their `EventId`.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use super::time::{Duration, Instant};

/// Identifier of a scheduled event; used to cancel timers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

type Thunk = Box<dyn FnOnce(&mut Sim)>;

struct Entry {
    at: Instant,
    seq: u64,
    thunk: Thunk,
}

// Order by (time, seq): earliest first via Reverse in the heap.
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// The discrete-event simulator: a virtual clock plus an event queue.
pub struct Sim {
    now: Instant,
    seq: u64,
    queue: BinaryHeap<Reverse<Entry>>,
    cancelled: HashSet<u64>,
    executed: u64,
    /// Hard cap on executed events; guards against runaway loops in
    /// misconfigured scenarios (poll loops that never quiesce).
    pub event_limit: u64,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    /// Create an empty simulator at t = 0.
    pub fn new() -> Self {
        Sim {
            now: 0,
            seq: 0,
            queue: BinaryHeap::new(),
            cancelled: HashSet::new(),
            executed: 0,
            event_limit: u64::MAX,
        }
    }

    /// Current virtual time.
    #[inline]
    pub fn now(&self) -> Instant {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Schedule `f` to run at absolute virtual time `at`.
    ///
    /// Scheduling in the past is clamped to `now` (fires next).
    pub fn at(&mut self, at: Instant, f: impl FnOnce(&mut Sim) + 'static) -> EventId {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Reverse(Entry {
            at,
            seq,
            thunk: Box::new(f),
        }));
        EventId(seq)
    }

    /// Schedule `f` to run `delay` ns from now.
    pub fn after(&mut self, delay: Duration, f: impl FnOnce(&mut Sim) + 'static) -> EventId {
        let at = self.now.saturating_add(delay);
        self.at(at, f)
    }

    /// Schedule `f` to run at the current instant, after already-queued
    /// same-time events.
    pub fn defer(&mut self, f: impl FnOnce(&mut Sim) + 'static) -> EventId {
        self.at(self.now, f)
    }

    /// Cancel a pending event. Cancelling an already-fired event is a
    /// no-op.
    pub fn cancel(&mut self, id: EventId) {
        self.cancelled.insert(id.0);
    }

    /// Run until the event queue is empty. Returns the final time.
    pub fn run(&mut self) -> Instant {
        self.run_until(Instant::MAX)
    }

    /// Run events with `at <= deadline`. The clock never advances past
    /// `deadline` even if later events remain queued.
    pub fn run_until(&mut self, deadline: Instant) -> Instant {
        while let Some(Reverse(entry)) = self.queue.peek() {
            if entry.at > deadline {
                self.now = self.now.max(deadline.min(entry.at));
                break;
            }
            let Reverse(entry) = self.queue.pop().unwrap();
            if self.cancelled.remove(&entry.seq) {
                continue;
            }
            self.now = entry.at;
            self.executed += 1;
            if self.executed > self.event_limit {
                panic!(
                    "sim event limit ({}) exceeded at t={} — runaway loop?",
                    self.event_limit, self.now
                );
            }
            (entry.thunk)(self);
        }
        self.now
    }

    /// True if no events remain.
    pub fn idle(&self) -> bool {
        self.queue.is_empty()
    }
}

/// Cloneable handle used by components to schedule follow-up events from
/// outside an event callback (e.g. API-facing wrappers in the DES
/// harness). It is a thin marker today; kept for API symmetry with the
/// threaded runtime.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimHandle;

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for &t in &[30u64, 10, 20] {
            let log = log.clone();
            sim.at(t, move |s| log.borrow_mut().push((s.now(), t)));
        }
        sim.run();
        assert_eq!(*log.borrow(), vec![(10, 10), (20, 20), (30, 30)]);
    }

    #[test]
    fn ties_fire_in_schedule_order() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..5 {
            let log = log.clone();
            sim.at(42, move |_| log.borrow_mut().push(i));
        }
        sim.run();
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cascading_events() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        let h = hits.clone();
        sim.at(5, move |s| {
            *h.borrow_mut() += 1;
            let h2 = h.clone();
            s.after(10, move |_| *h2.borrow_mut() += 1);
        });
        let end = sim.run();
        assert_eq!(*hits.borrow(), 2);
        assert_eq!(end, 15);
    }

    #[test]
    fn cancel_pending() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        let h = hits.clone();
        let id = sim.at(5, move |_| *h.borrow_mut() += 1);
        sim.cancel(id);
        sim.run();
        assert_eq!(*hits.borrow(), 0);
    }

    #[test]
    fn run_until_stops_at_deadline() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        for t in [1u64, 2, 3, 100] {
            let h = hits.clone();
            sim.at(t, move |_| *h.borrow_mut() += 1);
        }
        sim.run_until(10);
        assert_eq!(*hits.borrow(), 3);
        assert!(!sim.idle());
        sim.run();
        assert_eq!(*hits.borrow(), 4);
        assert_eq!(sim.now(), 100);
    }

    #[test]
    fn past_schedule_clamps_to_now() {
        let mut sim = Sim::new();
        let t = Rc::new(RefCell::new(0u64));
        let tc = t.clone();
        sim.at(50, move |s| {
            let tc2 = tc.clone();
            s.at(1, move |s2| *tc2.borrow_mut() = s2.now());
        });
        sim.run();
        assert_eq!(*t.borrow(), 50);
    }

    #[test]
    #[should_panic(expected = "event limit")]
    fn event_limit_trips() {
        let mut sim = Sim::new();
        sim.event_limit = 10;
        fn rearm(s: &mut Sim) {
            s.after(1, rearm);
        }
        sim.after(1, rearm);
        sim.run();
    }
}
