//! Virtual time: nanoseconds as plain `u64`s with readable constructors.
//!
//! The simulator's clock is `Instant` (ns since sim start); intervals are
//! `Duration` (ns). Plain integers keep the event loop allocation-free
//! and trivially comparable.

/// A point in virtual time, in nanoseconds since simulation start.
pub type Instant = u64;
/// A span of virtual time, in nanoseconds.
pub type Duration = u64;

/// One nanosecond.
pub const NS: Duration = 1;
/// One microsecond.
pub const US: Duration = 1_000;
/// One millisecond.
pub const MS: Duration = 1_000_000;
/// One second.
pub const SEC: Duration = 1_000_000_000;

/// One kibibyte.
pub const KIB: u64 = 1024;
/// One mebibyte.
pub const MIB: u64 = 1024 * 1024;
/// One gibibyte.
pub const GIB: u64 = 1024 * 1024 * 1024;

/// Gigabits per second expressed as bytes per nanosecond.
///
/// `rate_bytes_per_ns(400.0)` is the serialization rate of a 400 Gbps
/// link.
pub const GBPS: f64 = 1.0e9 / 8.0 / 1.0e9; // bytes per ns per Gbps = 0.125

/// Convert a link rate in Gbps into bytes/ns.
#[inline]
pub fn gbps_to_bytes_per_ns(gbps: f64) -> f64 {
    gbps * GBPS
}

/// Time to serialize `bytes` at `gbps`, in ns (rounded up, min 1 ns).
#[inline]
pub fn serialize_ns(bytes: u64, gbps: f64) -> Duration {
    let ns = (bytes as f64) / gbps_to_bytes_per_ns(gbps);
    ns.ceil().max(1.0) as Duration
}

/// Render a virtual duration as a human-readable string.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= SEC {
        format!("{:.3} s", ns as f64 / SEC as f64)
    } else if ns >= MS {
        format!("{:.3} ms", ns as f64 / MS as f64)
    } else if ns >= US {
        format!("{:.3} us", ns as f64 / US as f64)
    } else {
        format!("{} ns", ns)
    }
}

/// Render a throughput (bytes over a virtual duration) as Gbps.
pub fn gbps(bytes: u64, ns: Duration) -> f64 {
    if ns == 0 {
        return 0.0;
    }
    (bytes as f64 * 8.0) / ns as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialize_rate_roundtrip() {
        // 400 Gbps = 50 bytes/ns: 50_000 bytes take 1000 ns.
        assert_eq!(serialize_ns(50_000, 400.0), 1_000);
        // 100 Gbps = 12.5 bytes/ns.
        assert_eq!(serialize_ns(125, 100.0), 10);
    }

    #[test]
    fn gbps_of_transfer() {
        // 50 bytes in 1 ns = 400 Gbps.
        assert!((gbps(50, 1) - 400.0).abs() < 1e-9);
        // 1 MiB over 21 us ~= 399.5 Gbps.
        let g = gbps(MIB, 21 * US);
        assert!(g > 380.0 && g < 420.0, "{g}");
    }

    #[test]
    fn fmt_human() {
        assert_eq!(fmt_ns(12), "12 ns");
        assert_eq!(fmt_ns(1_500), "1.500 us");
        assert_eq!(fmt_ns(2 * MS), "2.000 ms");
        assert_eq!(fmt_ns(3 * SEC), "3.000 s");
    }

    #[test]
    fn serialize_minimum_one_ns() {
        assert_eq!(serialize_ns(0, 400.0), 1);
        assert_eq!(serialize_ns(1, 400.0), 1);
    }
}
