//! Production-shaped TransferEngine: real pinned worker threads over
//! the in-process fabric.
//!
//! Same architecture as the DES engine (§3.4): the app thread enqueues
//! commands onto a queue; one worker per domain group dequeues,
//! shards, posts WRs and polls completion queues in a tight loop,
//! prioritizing new submissions; completions feed ImmCounters and
//! OnDone notifications. A dedicated watcher thread polls UVM words.
//!
//! This runtime backs the runnable examples and the *measured* CPU
//! overhead numbers (Table 8): `TraceT` records real monotonic
//! timestamps from `submit_*()` to the last posted WRITE.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::api::{MrDesc, MrHandle, NetAddr, Pages, ScatterDst};
use super::imm_counter::{ImmCounter, ImmEvent};
use super::sharding::{plan_paged_writes, plan_scatter, plan_single_write, PlannedWrite};
use crate::fabric::local::LocalFabric;
use crate::fabric::mem::{DmaBuf, DmaSlice, RKey};
use crate::fabric::nic::{Cqe, CqeKind, NicAddr, QpId, WorkRequest, WrOp};
use crate::fabric::topology::DeviceId;

/// Sender-side completion notification (threaded flavor).
pub enum OnDoneT {
    /// Run on the worker's callback path.
    Callback(Box<dyn FnOnce() + Send>),
    /// Set an atomic flag.
    Flag(Arc<AtomicBool>),
    /// Fire-and-forget.
    Noop,
}

/// Real-time submission trace (ns since engine start).
#[derive(Debug, Clone, Copy)]
pub struct TraceT {
    pub submitted_ns: u64,
    pub worker_ns: u64,
    pub first_post_ns: u64,
    pub last_post_ns: u64,
    pub wrs: usize,
}

enum Cmd {
    Writes {
        plans: Vec<(PlannedWrite, MrDesc)>,
        src: DmaBuf,
        tid: u64,
        submitted_ns: u64,
    },
    Send {
        dst: NicAddr,
        payload: Vec<u8>,
        tid: u64,
    },
    Recvs {
        bufs: Vec<(u64, DmaBuf)>,
    },
    Shutdown,
}

struct GroupShared {
    imm: ImmCounter,
    imm_waiters: HashMap<u32, Box<dyn FnOnce() + Send>>,
    transfers: HashMap<u64, (usize, OnDoneT)>,
    wr_transfer: HashMap<u64, u64>,
    recv_slots: HashMap<u64, DmaBuf>,
    recv_cb: Option<Arc<dyn Fn(&[u8]) + Send + Sync>>,
    traces: Vec<TraceT>,
}

struct Group {
    nics: Vec<NicAddr>,
    tx: Sender<Cmd>,
    shared: Arc<Mutex<GroupShared>>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

struct Inner {
    fabric: LocalFabric,
    node: u16,
    groups: Vec<Group>,
    next_wr: AtomicU64,
    next_transfer: AtomicU64,
    epoch: Instant,
    watchers: Mutex<Vec<(Arc<AtomicU64>, u64, Arc<dyn Fn(u64, u64) + Send + Sync>)>>,
    watcher_stop: Arc<AtomicBool>,
    watcher_thread: Mutex<Option<JoinHandle<()>>>,
}

/// The threaded TransferEngine.
#[derive(Clone)]
pub struct ThreadedEngine {
    inner: Arc<Inner>,
}

impl ThreadedEngine {
    /// Create an engine for `node` with `gpus` × `nics_per_gpu` NICs,
    /// registering them in `fabric` and spawning one worker per group.
    pub fn new(fabric: &LocalFabric, node: u16, gpus: u8, nics_per_gpu: u8) -> Self {
        let epoch = Instant::now();
        let mut groups = Vec::new();
        for gpu in 0..gpus {
            let nics: Vec<NicAddr> = (0..nics_per_gpu)
                .map(|nic| {
                    let a = NicAddr { node, gpu, nic };
                    fabric.add_nic(a);
                    a
                })
                .collect();
            let shared = Arc::new(Mutex::new(GroupShared {
                imm: ImmCounter::new(),
                imm_waiters: HashMap::new(),
                transfers: HashMap::new(),
                wr_transfer: HashMap::new(),
                recv_slots: HashMap::new(),
                recv_cb: None,
                traces: Vec::new(),
            }));
            let (tx, rx) = mpsc::channel::<Cmd>();
            let f = fabric.clone();
            let sh = shared.clone();
            let nics2 = nics.clone();
            let worker = std::thread::Builder::new()
                .name(format!("te-worker-n{node}g{gpu}"))
                .spawn(move || worker_loop(f, nics2, sh, rx, epoch))
                .expect("spawn engine worker");
            groups.push(Group {
                nics,
                tx,
                shared,
                worker: Mutex::new(Some(worker)),
            });
        }
        let engine = ThreadedEngine {
            inner: Arc::new(Inner {
                fabric: fabric.clone(),
                node,
                groups,
                next_wr: AtomicU64::new(1),
                next_transfer: AtomicU64::new(1),
                epoch,
                watchers: Mutex::new(Vec::new()),
                watcher_stop: Arc::new(AtomicBool::new(false)),
                watcher_thread: Mutex::new(None),
            }),
        };
        engine.spawn_watcher_thread();
        engine
    }

    fn spawn_watcher_thread(&self) {
        let inner = self.inner.clone();
        let stop = self.inner.watcher_stop.clone();
        let h = std::thread::Builder::new()
            .name("te-uvm-watcher".into())
            .spawn(move || {
                // GDRCopy-style polling of all registered watch words.
                while !stop.load(Ordering::Relaxed) {
                    {
                        let mut ws = inner.watchers.lock().unwrap();
                        for (word, last, cb) in ws.iter_mut() {
                            let v = word.load(Ordering::Acquire);
                            if v != *last {
                                let old = *last;
                                *last = v;
                                cb(old, v);
                            }
                        }
                    }
                    std::thread::yield_now();
                }
            })
            .expect("spawn watcher thread");
        *self.inner.watcher_thread.lock().unwrap() = Some(h);
    }

    /// ns since engine start.
    pub fn now_ns(&self) -> u64 {
        self.inner.epoch.elapsed().as_nanos() as u64
    }

    /// The engine's main address.
    pub fn main_address(&self) -> NetAddr {
        self.group_address(0)
    }

    /// Address of GPU `gpu`'s domain group.
    pub fn group_address(&self, gpu: u8) -> NetAddr {
        NetAddr {
            nics: self.inner.groups[gpu as usize].nics.clone(),
        }
    }

    /// Allocate + register a region on `gpu`.
    pub fn alloc_mr(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        let (buf, _) = self.inner.fabric.mem().alloc(len);
        self.reg_mr(gpu, &buf)
    }

    /// Register an existing buffer on `gpu`.
    pub fn reg_mr(&self, gpu: u8, buf: &DmaBuf) -> (MrHandle, MrDesc) {
        let mem = self.inner.fabric.mem();
        let rkeys = self.inner.groups[gpu as usize]
            .nics
            .iter()
            .map(|&n| (n, mem.register(buf).0))
            .collect();
        (
            MrHandle {
                buf: buf.clone(),
                device: DeviceId {
                    node: self.inner.node,
                    gpu,
                },
            },
            MrDesc {
                ptr: buf.base(),
                len: buf.len() as u64,
                rkeys,
            },
        )
    }

    /// Two-sided send (copy-on-submit).
    pub fn submit_send(&self, gpu: u8, addr: &NetAddr, msg: &[u8], on_done: OnDoneT) {
        let tid = self.alloc_transfer(gpu, 1, on_done);
        self.inner.groups[gpu as usize]
            .tx
            .send(Cmd::Send {
                dst: addr.primary(),
                payload: msg.to_vec(),
                tid,
            })
            .expect("worker gone");
    }

    /// Post a rotating pool of `cnt` receive buffers with callback.
    pub fn submit_recvs(
        &self,
        gpu: u8,
        len: usize,
        cnt: usize,
        cb: impl Fn(&[u8]) + Send + Sync + 'static,
    ) {
        let g = &self.inner.groups[gpu as usize];
        let mem = self.inner.fabric.mem();
        let mut bufs = Vec::with_capacity(cnt);
        {
            let mut sh = g.shared.lock().unwrap();
            sh.recv_cb = Some(Arc::new(cb));
            for _ in 0..cnt {
                let id = self.inner.next_wr.fetch_add(1, Ordering::Relaxed);
                let (buf, _) = mem.alloc(len);
                sh.recv_slots.insert(id, buf.clone());
                bufs.push((id, buf));
            }
        }
        g.tx.send(Cmd::Recvs { bufs }).expect("worker gone");
    }

    /// Contiguous one-sided write.
    pub fn submit_single_write(
        &self,
        src: (&MrHandle, u64),
        len: u64,
        dst: (&MrDesc, u64),
        imm: Option<u32>,
        on_done: OnDoneT,
    ) {
        let submitted_ns = self.now_ns();
        let (h, src_off) = src;
        let (d, dst_off) = dst;
        let gpu = h.device.gpu;
        let fanout = self.inner.groups[gpu as usize].nics.len().min(d.rkeys.len());
        let plans = plan_single_write(len, src_off, d.ptr + dst_off, imm, fanout, 0);
        self.dispatch_writes(
            gpu,
            h,
            plans.into_iter().map(|p| (p, d.clone())).collect(),
            on_done,
            submitted_ns,
        );
    }

    /// Paged writes.
    pub fn submit_paged_writes(
        &self,
        page_len: u64,
        src: (&MrHandle, &Pages),
        dst: (&MrDesc, &Pages),
        imm: Option<u32>,
        on_done: OnDoneT,
    ) {
        let submitted_ns = self.now_ns();
        let (h, sp) = src;
        let (d, dp) = dst;
        let gpu = h.device.gpu;
        let src_offs: Vec<u64> = (0..sp.len()).map(|i| sp.at(i)).collect();
        let dst_vas: Vec<u64> = (0..dp.len()).map(|i| d.ptr + dp.at(i)).collect();
        let fanout = self.inner.groups[gpu as usize].nics.len().min(d.rkeys.len());
        let plans = plan_paged_writes(page_len, &src_offs, &dst_vas, imm, fanout, 0);
        self.dispatch_writes(
            gpu,
            h,
            plans.into_iter().map(|p| (p, d.clone())).collect(),
            on_done,
            submitted_ns,
        );
    }

    /// Scatter to many peers.
    pub fn submit_scatter(
        &self,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm: Option<u32>,
        on_done: OnDoneT,
    ) {
        let submitted_ns = self.now_ns();
        let gpu = src.device.gpu;
        let fanout = self.inner.groups[gpu as usize].nics.len();
        let entries: Vec<(u64, u64, u64)> = dsts
            .iter()
            .map(|s| (s.len, s.src, s.dst.0.ptr + s.dst.1))
            .collect();
        let plans = plan_scatter(&entries, imm, fanout, 0);
        let pairs = plans
            .into_iter()
            .zip(dsts.iter().map(|s| s.dst.0.clone()))
            .collect();
        self.dispatch_writes(gpu, src, pairs, on_done, submitted_ns);
    }

    /// Immediate-only barrier to every descriptor's owner.
    pub fn submit_barrier(&self, gpu: u8, dsts: &[MrDesc], imm: u32, on_done: OnDoneT) {
        let (scratch, _) = self.alloc_mr(gpu, 1);
        let submitted_ns = self.now_ns();
        let fanout = self.inner.groups[gpu as usize].nics.len();
        let entries: Vec<(u64, u64, u64)> = dsts.iter().map(|d| (0, 0, d.ptr)).collect();
        let plans = plan_scatter(&entries, Some(imm), fanout, 0);
        let pairs = plans.into_iter().zip(dsts.iter().cloned()).collect();
        self.dispatch_writes(gpu, &scratch, pairs, on_done, submitted_ns);
    }

    /// Register an expectation on `gpu`'s ImmCounter.
    pub fn expect_imm_count(
        &self,
        gpu: u8,
        imm: u32,
        count: u32,
        cb: impl FnOnce() + Send + 'static,
    ) {
        let g = &self.inner.groups[gpu as usize];
        let sat = {
            let mut sh = g.shared.lock().unwrap();
            match sh.imm.expect(imm, count) {
                ImmEvent::Satisfied => true,
                ImmEvent::Pending => {
                    sh.imm_waiters.insert(imm, Box::new(cb));
                    return;
                }
            }
        };
        if sat {
            cb();
        }
    }

    /// Poll an ImmCounter value.
    pub fn imm_value(&self, gpu: u8, imm: u32) -> u32 {
        self.inner.groups[gpu as usize]
            .shared
            .lock()
            .unwrap()
            .imm
            .value(imm)
    }

    /// Release counter state for `imm`.
    pub fn free_imm(&self, gpu: u8, imm: u32) {
        self.inner.groups[gpu as usize]
            .shared
            .lock()
            .unwrap()
            .imm
            .free(imm);
    }

    /// Allocate a UVM watcher word; device-side code stores to the
    /// returned atomic, `cb(old, new)` fires from the watcher thread.
    pub fn alloc_uvm_watcher(
        &self,
        cb: impl Fn(u64, u64) + Send + Sync + 'static,
    ) -> Arc<AtomicU64> {
        let word = Arc::new(AtomicU64::new(0));
        self.inner
            .watchers
            .lock()
            .unwrap()
            .push((word.clone(), 0, Arc::new(cb)));
        word
    }

    /// Stop workers and the watcher thread (fabric is left running;
    /// call `LocalFabric::shutdown` separately).
    pub fn shutdown(&self) {
        for g in &self.inner.groups {
            let _ = g.tx.send(Cmd::Shutdown);
            if let Some(h) = g.worker.lock().unwrap().take() {
                let _ = h.join();
            }
        }
        self.inner.watcher_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.inner.watcher_thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Collect submission traces from all groups (Table 8 real
    /// measurement).
    pub fn traces(&self) -> Vec<TraceT> {
        let mut out = Vec::new();
        for g in &self.inner.groups {
            out.extend(g.shared.lock().unwrap().traces.iter().copied());
        }
        out
    }

    fn alloc_transfer(&self, gpu: u8, remaining: usize, on_done: OnDoneT) -> u64 {
        let tid = self.inner.next_transfer.fetch_add(1, Ordering::Relaxed);
        self.inner.groups[gpu as usize]
            .shared
            .lock()
            .unwrap()
            .transfers
            .insert(tid, (remaining, on_done));
        tid
    }

    fn dispatch_writes(
        &self,
        gpu: u8,
        src: &MrHandle,
        plans: Vec<(PlannedWrite, MrDesc)>,
        on_done: OnDoneT,
        submitted_ns: u64,
    ) {
        assert!(!plans.is_empty(), "empty transfer");
        let tid = self.alloc_transfer(gpu, plans.len(), on_done);
        self.inner.groups[gpu as usize]
            .tx
            .send(Cmd::Writes {
                plans,
                src: src.buf.clone(),
                tid,
                submitted_ns,
            })
            .expect("worker gone");
    }
}

/// The pinned worker: drain submissions first (paper: "prioritizing
/// the submission of new requests"), then poll CQs.
fn worker_loop(
    fabric: LocalFabric,
    nics: Vec<NicAddr>,
    shared: Arc<Mutex<GroupShared>>,
    rx: mpsc::Receiver<Cmd>,
    epoch: Instant,
) {
    let mut next_wr: u64 = 1 << 48; // worker-allocated ids, disjoint from app ids
    let mut cqes: Vec<Cqe> = Vec::with_capacity(64);
    loop {
        // 1) submissions (block briefly when idle)
        match rx.recv_timeout(Duration::from_micros(50)) {
            Ok(Cmd::Shutdown) | Err(RecvTimeoutError::Disconnected) => break,
            Ok(Cmd::Writes {
                plans,
                src,
                tid,
                submitted_ns,
            }) => {
                let worker_ns = epoch.elapsed().as_nanos() as u64;
                let n = plans.len();
                let base_id = next_wr;
                {
                    let mut sh = shared.lock().unwrap();
                    for i in 0..n {
                        sh.wr_transfer.insert(base_id + i as u64, tid);
                    }
                }
                next_wr += n as u64;
                let mut first_post_ns = 0;
                for (i, (p, desc)) in plans.into_iter().enumerate() {
                    let (dst_nic, rkey) = desc.rkey_for(p.nic);
                    let wr = WorkRequest {
                        id: base_id + i as u64,
                        qp: QpId(1),
                        op: WrOp::Write {
                            dst: dst_nic,
                            dst_rkey: RKey(rkey),
                            dst_va: p.dst_va,
                            src: DmaSlice::new(&src, p.src_off as usize, p.len as usize),
                            imm: p.imm,
                        },
                        chained: false,
                    };
                    if i == 0 {
                        first_post_ns = epoch.elapsed().as_nanos() as u64;
                    }
                    fabric.post(nics[p.nic], wr);
                }
                let last_post_ns = epoch.elapsed().as_nanos() as u64;
                shared.lock().unwrap().traces.push(TraceT {
                    submitted_ns,
                    worker_ns,
                    first_post_ns,
                    last_post_ns,
                    wrs: n,
                });
            }
            Ok(Cmd::Send { dst, payload, tid }) => {
                let id = next_wr;
                next_wr += 1;
                shared.lock().unwrap().wr_transfer.insert(id, tid);
                fabric.post(
                    nics[0],
                    WorkRequest {
                        id,
                        qp: QpId(0),
                        op: WrOp::Send { dst, payload },
                        chained: false,
                    },
                );
            }
            Ok(Cmd::Recvs { bufs }) => {
                for (id, buf) in bufs {
                    fabric.post(
                        nics[0],
                        WorkRequest {
                            id,
                            qp: QpId(0),
                            op: WrOp::Recv {
                                buf: DmaSlice::whole(&buf),
                            },
                            chained: false,
                        },
                    );
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
        }

        // 2) completions on every NIC of the group
        for &nic in &nics {
            loop {
                cqes.clear();
                fabric.poll_cq(nic, 64, &mut cqes);
                if cqes.is_empty() {
                    break;
                }
                for cqe in cqes.drain(..) {
                    handle_cqe(&fabric, nic, &shared, cqe, &mut next_wr);
                }
            }
        }
    }
}

fn handle_cqe(
    fabric: &LocalFabric,
    nic: NicAddr,
    shared: &Arc<Mutex<GroupShared>>,
    cqe: Cqe,
    next_wr: &mut u64,
) {
    match cqe.kind {
        CqeKind::SendDone | CqeKind::WriteDone => {
            let done = {
                let mut sh = shared.lock().unwrap();
                let Some(tid) = sh.wr_transfer.remove(&cqe.wr_id) else {
                    return;
                };
                let (rem, _) = sh.transfers.get_mut(&tid).expect("transfer");
                *rem -= 1;
                if *rem == 0 {
                    Some(sh.transfers.remove(&tid).unwrap().1)
                } else {
                    None
                }
            };
            match done {
                Some(OnDoneT::Callback(cb)) => cb(),
                Some(OnDoneT::Flag(f)) => f.store(true, Ordering::Release),
                _ => {}
            }
        }
        CqeKind::ImmRecvd { imm, .. } => {
            let waiter = {
                let mut sh = shared.lock().unwrap();
                if sh.imm.increment(imm) == ImmEvent::Satisfied {
                    sh.imm_waiters.remove(&imm)
                } else {
                    None
                }
            };
            if let Some(cb) = waiter {
                cb();
            }
        }
        CqeKind::RecvDone { len, .. } => {
            let (payload, cb, repost) = {
                let mut sh = shared.lock().unwrap();
                let buf = sh
                    .recv_slots
                    .remove(&cqe.wr_id)
                    .expect("RecvDone for unknown buffer");
                let mut data = vec![0u8; (len as usize).min(buf.len())];
                buf.read(0, &mut data);
                let cb = sh.recv_cb.clone();
                let new_id = *next_wr;
                *next_wr += 1;
                sh.recv_slots.insert(new_id, buf.clone());
                (data, cb, (new_id, buf))
            };
            fabric.post(
                nic,
                WorkRequest {
                    id: repost.0,
                    qp: QpId(0),
                    op: WrOp::Recv {
                        buf: DmaSlice::whole(&repost.1),
                    },
                    chained: false,
                },
            );
            if let Some(cb) = cb {
                cb(&payload);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::profile::TransportKind;

    fn wait_flag(f: &AtomicBool) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !f.load(Ordering::Acquire) {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
    }

    #[test]
    fn threaded_single_write_and_imm() {
        let fabric = LocalFabric::new(TransportKind::Srd, 9);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        let (src, _) = a.alloc_mr(0, 1024);
        let (dst_h, dst_d) = b.alloc_mr(0, 1024);
        src.buf.write(0, b"threaded engine");

        let got = Arc::new(AtomicBool::new(false));
        let g = got.clone();
        b.expect_imm_count(0, 50, 1, move || g.store(true, Ordering::Release));
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 15, (&dst_d, 8), Some(50), OnDoneT::Flag(done.clone()));
        wait_flag(&done);
        wait_flag(&got);
        assert_eq!(&dst_h.buf.to_vec()[8..23], b"threaded engine");
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_sharded_large_write() {
        let fabric = LocalFabric::new(TransportKind::Srd, 10);
        let a = ThreadedEngine::new(&fabric, 0, 1, 4);
        let b = ThreadedEngine::new(&fabric, 1, 1, 4);
        let len = 1 << 20;
        let (src, _) = a.alloc_mr(0, len);
        let (dst_h, dst_d) = b.alloc_mr(0, len);
        let pat: Vec<u8> = (0..len).map(|i| (i % 253) as u8).collect();
        src.buf.write(0, &pat);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), len as u64, (&dst_d, 0), None, OnDoneT::Flag(done.clone()));
        wait_flag(&done);
        assert_eq!(dst_h.buf.to_vec(), pat);
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_send_recv_rpc() {
        let fabric = LocalFabric::new(TransportKind::Rc, 11);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let b = ThreadedEngine::new(&fabric, 1, 1, 1);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        b.submit_recvs(0, 128, 4, move |msg| {
            assert_eq!(msg, b"ping");
            h.fetch_add(1, Ordering::Relaxed);
        });
        for _ in 0..8 {
            a.submit_send(0, &b.group_address(0), b"ping", OnDoneT::Noop);
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while hits.load(Ordering::Relaxed) < 8 {
            assert!(
                Instant::now() < deadline,
                "timeout: {}",
                hits.load(Ordering::Relaxed)
            );
            std::thread::yield_now();
        }
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_uvm_watcher() {
        let fabric = LocalFabric::new(TransportKind::Rc, 12);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = seen.clone();
        let word = a.alloc_uvm_watcher(move |old, new| s2.lock().unwrap().push((old, new)));
        let deadline = Instant::now() + Duration::from_secs(5);
        word.store(5, Ordering::Release);
        while seen.lock().unwrap().is_empty() {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
        word.store(9, Ordering::Release);
        while seen.lock().unwrap().len() < 2 {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
        let v = seen.lock().unwrap().clone();
        assert_eq!(v[0], (0, 5));
        assert_eq!(v[1], (5, 9));
        a.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_traces_recorded() {
        let fabric = LocalFabric::new(TransportKind::Rc, 13);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let b = ThreadedEngine::new(&fabric, 1, 1, 1);
        let (src, _) = a.alloc_mr(0, 4096);
        let (_dh, dd) = b.alloc_mr(0, 4096);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 4096, (&dd, 0), None, OnDoneT::Flag(done.clone()));
        wait_flag(&done);
        let traces = a.traces();
        assert!(!traces.is_empty());
        let t = traces[0];
        assert!(t.submitted_ns <= t.worker_ns);
        assert!(t.worker_ns <= t.first_post_ns);
        assert!(t.first_post_ns <= t.last_post_ns);
        assert_eq!(t.wrs, 1);
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }
}
