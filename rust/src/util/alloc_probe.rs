//! Heap-allocation counting for zero-alloc assertions.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! `alloc`/`realloc` call in a process-global atomic. A bench binary
//! installs it as its `#[global_allocator]` and brackets a hot loop
//! with [`alloc_count`] reads to assert the loop allocates nothing —
//! the `sim_churn` bench uses this to prove the scheduler's `after`
//! fast path is allocation-free at steady state.
//!
//! Deliberately *not* installed for the library or test binaries:
//! a global allocator is a per-binary decision, and tests should not
//! pay the atomic on every allocation.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Heap allocations (alloc + realloc calls) since process start, as
/// counted by an installed [`CountingAlloc`]. Always 0 when no
/// `CountingAlloc` is installed.
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// A `#[global_allocator]` shim that counts allocations.
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: CountingAlloc = CountingAlloc;
/// ```
pub struct CountingAlloc;

// SAFETY: pure delegation — every method forwards the caller's
// ptr/layout contract unchanged to `System` and adds only a relaxed
// atomic increment, so the GlobalAlloc invariants are exactly
// System's (no allocation is remapped, resized, or double-freed).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: delegates to `System.dealloc` with the caller's
    // ptr/layout pair unchanged; the caller's contract (ptr from this
    // allocator, same layout) is System's contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: delegates to `System.realloc` with the caller's
    // arguments unchanged; only the count is added.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}
