//! Production-shaped TransferEngine: real pinned worker threads over
//! the in-process fabric.
//!
//! Second runtime behind the shared [`super::traits::TransferEngine`]
//! trait, at full API parity with the DES engine: peer groups,
//! handle-based scatter/barrier, paged writes and per-group NIC
//! rotation all included, with the runtime-independent submission
//! logic (peer groups, imm accounting, recv matching, plan→rkey
//! routing) shared through [`super::core`].
//!
//! Same architecture as the DES engine (§3.4): the app thread enqueues
//! commands onto a queue; one worker per domain group dequeues,
//! shards, posts WRs and polls completion queues in a tight loop,
//! prioritizing new submissions; completions feed imm counters and
//! OnDone notifications. A dedicated watcher thread polls UVM words.
//!
//! This runtime backs the runnable examples and the *measured* CPU
//! overhead numbers (Table 8): each submission records a
//! [`TraceEvent`] span with real monotonic timestamps from
//! `submit_*()` through the last posted WRITE to retirement, and the
//! engine-wide [`EngineMetrics`] ledger (cache-line-padded atomics)
//! counts submissions, per-lane wire traffic and failure
//! attributions. See `util::telemetry` for the counter taxonomy.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::api::{
    MrDesc, MrHandle, NetAddr, Pages, PeerGroupHandle, ScatterDst, TemplatedDst,
};
use super::core::{
    remap_routed, retarget, route_barrier, route_barrier_templated, route_batch_templated,
    route_paged_writes, route_paged_writes_templated, route_scatter, route_scatter_templated,
    route_single_write, route_single_write_templated, route_write_batch, FailoverPolicy, ImmTable,
    NicHealth, PeerGroups, RecvPool, RouteSet, RoutedVec, RoutedWrite, TransferTable,
};
use super::model::Fired;
use super::wire;
use super::traits::{Cx, Notify, OnRecv, OnWatch, RuntimeKind, TransferEngine, UvmWatcher};
use crate::fabric::chaos::ChaosProfile;
use crate::fabric::local::LocalFabric;
use crate::fabric::mem::{DmaBuf, DmaSlice, RKey};
use crate::fabric::nic::{Cqe, CqeKind, NicAddr, QpId, WorkRequest, WrOp};
use crate::fabric::topology::DeviceId;
use crate::util::err::Result;
use crate::util::fasthash::FastMap;
use crate::util::smallvec::SmallVec;
use crate::util::telemetry::{
    Cell64, EngineMetrics, EngineSnapshot, PaddedAtomic, SubmitKind, TraceEvent, TraceOutcome,
    TraceRing, DEFAULT_TRACE_CAP, NO_TRACE,
};

/// [`FailoverPolicy`] packed into an atomic for lock-free reads on the
/// worker threads.
const POLICY_RESUBMIT: u8 = 0;
const POLICY_ERROR_OUT: u8 = 1;

fn policy_code(p: FailoverPolicy) -> u8 {
    match p {
        FailoverPolicy::Resubmit => POLICY_RESUBMIT,
        FailoverPolicy::ErrorOut => POLICY_ERROR_OUT,
    }
}

/// Shared failover state handed to each group's worker: the group's
/// link-health table, the engine-wide policy/metrics ledger, the
/// armed flag that switches in-flight WR tracking on, and the group's
/// gossip neighborhood.
#[derive(Clone)]
struct FailCtx {
    health: Arc<NicHealth>,
    policy: Arc<AtomicU8>,
    metrics: Arc<EngineMetrics<PaddedAtomic>>,
    armed: Arc<AtomicBool>,
    gossip: Arc<Mutex<Vec<NetAddr>>>,
    /// Engine start: death marks are stamped `epoch.elapsed()` so the
    /// remote-probation TTL measures from the actual report time.
    epoch: Instant,
}

/// Everything needed to repost a failed WR on a surviving path.
struct RetryT {
    /// Original egress lane (stable projection base).
    lane: usize,
    /// Lane the WR last actually went out on (`WrError` attribution).
    cur_lane: usize,
    /// The destination region's full route set (remote-NIC failover;
    /// empty for SENDs).
    routes: RouteSet,
    wr: WorkRequest,
    attempts: u8,
}

/// Sender-side completion notification (threaded flavor).
pub enum OnDoneT {
    /// Run on the worker's callback path.
    Callback(Box<dyn FnOnce() + Send>),
    /// Set an atomic flag.
    Flag(Arc<AtomicBool>),
    /// Fire-and-forget.
    Noop,
}

/// Fire a completion notification inline on the calling thread (used
/// for degenerate submissions that post nothing, e.g. empty batches).
fn fire_on_done_t(on_done: OnDoneT) {
    match on_done {
        OnDoneT::Callback(cb) => cb(),
        OnDoneT::Flag(f) => f.store(true, Ordering::Release),
        OnDoneT::Noop => {}
    }
}

/// Number of cache-line-padded lanes in a [`ShardedRotation`]: enough
/// that concurrent submitters on a typical host rarely share a line.
const ROTATION_SHARDS: usize = 8;

/// One full cache line per counter so concurrent bumps on different
/// lanes never false-share.
#[repr(align(64))]
struct PaddedCounter(AtomicUsize);

/// Global round-robin source for per-thread lane assignment.
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// The calling thread's stable lane in every sharded cursor.
    static SHARD_IDX: usize =
        NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % ROTATION_SHARDS;
}

/// The threaded runtime's per-group NIC-rotation cursor, sharded so
/// concurrent submitters stop serializing on one contended cache
/// line: each submitting thread commits on its own padded lane and
/// the cursor value is the sum of the lanes.
///
/// Single-threaded this behaves exactly like [`Rotation`]
/// (`next()` peeks committed + 1; `bump`/`bump_n` commit by 1/N), so
/// rotation-sensitive tests and the batch/loop equivalence contract
/// are unchanged. Under concurrency the peek→commit window is
/// approximate — the same benign race the unsharded cursor already
/// had: it can shift which NIC a racing submission starts on, never
/// correctness.
///
/// [`Rotation`]: super::core::Rotation
struct ShardedRotation {
    shards: [PaddedCounter; ROTATION_SHARDS],
}

impl ShardedRotation {
    fn new() -> Self {
        ShardedRotation {
            shards: std::array::from_fn(|_| PaddedCounter(AtomicUsize::new(0))),
        }
    }

    /// The committed cursor: sum of every lane.
    fn committed(&self) -> usize {
        self.shards
            .iter()
            .map(|c| c.0.load(Ordering::Relaxed))
            .fold(0usize, usize::wrapping_add)
    }

    /// The rotation value the next commit corresponds to (route with
    /// this, commit only once routing succeeded).
    fn next(&self) -> usize {
        self.committed().wrapping_add(1)
    }

    /// Commit one submission on the calling thread's lane.
    fn bump(&self) -> usize {
        self.bump_n(1)
    }

    /// Commit `n` submissions with ONE atomic RMW on the calling
    /// thread's lane (the batch path): an N-entry batch advances the
    /// cursor exactly as N sequential bumps would.
    fn bump_n(&self, n: usize) -> usize {
        SHARD_IDX.with(|i| self.shards[*i].0.fetch_add(n, Ordering::Relaxed));
        self.committed()
    }
}

enum Cmd {
    Writes {
        routed: RoutedVec,
        src: DmaBuf,
        tid: u64,
        submitted_ns: u64,
        kind: SubmitKind,
    },
    Send {
        dst: NicAddr,
        payload: Vec<u8>,
        tid: u64,
    },
    Recvs {
        bufs: Vec<(u64, DmaBuf)>,
    },
    Shutdown,
}

struct GroupShared {
    imm: ImmTable<Box<dyn FnOnce() + Send>>,
    transfers: TransferTable<OnDoneT>,
    recvs: RecvPool,
    /// Receive callback; messages arrive as owned [`Fired`] payloads
    /// (poisoned on recv-pool overflow so the submitter can
    /// distinguish truncation from completion).
    recv_cb: Option<Arc<dyn Fn(Fired) + Send + Sync>>,
    /// Bounded ring of submission spans (drained by `take_traces`,
    /// overflow counted — never an unbounded buffer).
    trace: TraceRing,
    /// In-flight WRs by id, kept only once failover is armed, so a
    /// fabric `WrError` can resubmit them on a surviving NIC.
    retry: FastMap<u64, RetryT>,
}

struct Group {
    nics: Vec<NicAddr>,
    tx: Sender<Cmd>,
    shared: Arc<Mutex<GroupShared>>,
    /// NIC rotation cursor, sharded across per-thread lanes so
    /// concurrent submitters don't contend on one cache line.
    rotation: ShardedRotation,
    /// Link-health table: downed local NICs, observed link partitions
    /// and gossiped-dead remote NICs are all excluded from new
    /// submissions (shared with the group's worker for resubmission
    /// decisions; local bits kept in sync through the fabric hooks).
    health: Arc<NicHealth>,
    /// Health-gossip neighborhood (shared with the worker, which sends
    /// the gossip from its `WrError` handler).
    gossip: Arc<Mutex<Vec<NetAddr>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

struct Inner {
    fabric: LocalFabric,
    node: u16,
    groups: Vec<Group>,
    next_wr: AtomicU64,
    peer_groups: Mutex<PeerGroups>,
    epoch: Instant,
    watchers: Mutex<Vec<(Arc<AtomicU64>, u64, Arc<dyn Fn(u64, u64) + Send + Sync>)>>,
    watcher_stop: Arc<AtomicBool>,
    watcher_thread: Mutex<Option<JoinHandle<()>>>,
    /// Engine-wide failover policy (see [`FailoverPolicy`]).
    policy: Arc<AtomicU8>,
    /// Engine-wide telemetry ledger: submission kinds, per-lane wire
    /// accounting and the always-on transport-error attribution
    /// counters (shared with every group's worker).
    metrics: Arc<EngineMetrics<PaddedAtomic>>,
    /// True once chaos was injected or a health override landed.
    armed: Arc<AtomicBool>,
}

/// The threaded TransferEngine.
#[derive(Clone)]
pub struct ThreadedEngine {
    inner: Arc<Inner>,
}

impl ThreadedEngine {
    /// Create an engine for `node` with `gpus` × `nics_per_gpu` NICs,
    /// registering them in `fabric` and spawning one worker per group.
    pub fn new(fabric: &LocalFabric, node: u16, gpus: u8, nics_per_gpu: u8) -> Self {
        let epoch = Instant::now();
        let policy = Arc::new(AtomicU8::new(POLICY_RESUBMIT));
        let metrics = Arc::new(EngineMetrics::new());
        let armed = Arc::new(AtomicBool::new(false));
        let mut groups = Vec::new();
        for gpu in 0..gpus {
            let nics: Vec<NicAddr> = (0..nics_per_gpu)
                .map(|nic| {
                    let a = NicAddr { node, gpu, nic };
                    fabric.add_nic(a);
                    a
                })
                .collect();
            let health = Arc::new(NicHealth::new(nics.len()));
            // Fabric link-state hooks keep the health table in sync
            // with chaos NicDown/NicUp events.
            for (xi, &a) in nics.iter().enumerate() {
                let h = health.clone();
                let arm = armed.clone();
                fabric.set_health_hook(
                    a,
                    Box::new(move |up| {
                        arm.store(true, Ordering::Release);
                        h.set(xi, up);
                    }),
                );
            }
            let shared = Arc::new(Mutex::new(GroupShared {
                imm: ImmTable::new(),
                transfers: TransferTable::new(),
                recvs: RecvPool::new(),
                recv_cb: None,
                trace: TraceRing::new(DEFAULT_TRACE_CAP),
                retry: FastMap::default(),
            }));
            let (tx, rx) = mpsc::channel::<Cmd>();
            let f = fabric.clone();
            let sh = shared.clone();
            let nics2 = nics.clone();
            let gossip = Arc::new(Mutex::new(Vec::new()));
            let fo = FailCtx {
                health: health.clone(),
                policy: policy.clone(),
                metrics: metrics.clone(),
                armed: armed.clone(),
                gossip: gossip.clone(),
                epoch,
            };
            let worker = std::thread::Builder::new()
                .name(format!("te-worker-n{node}g{gpu}"))
                .spawn(move || worker_loop(f, nics2, sh, rx, epoch, fo))
                .expect("spawn engine worker");
            groups.push(Group {
                nics,
                tx,
                shared,
                rotation: ShardedRotation::new(),
                health,
                gossip,
                worker: Mutex::new(Some(worker)),
            });
        }
        let engine = ThreadedEngine {
            inner: Arc::new(Inner {
                fabric: fabric.clone(),
                node,
                groups,
                next_wr: AtomicU64::new(1),
                peer_groups: Mutex::new(PeerGroups::new()),
                epoch,
                watchers: Mutex::new(Vec::new()),
                watcher_stop: Arc::new(AtomicBool::new(false)),
                watcher_thread: Mutex::new(None),
                policy,
                metrics,
                armed,
            }),
        };
        engine.spawn_watcher_thread();
        engine
    }

    // ------------------------------------------------------------------
    // Transport perturbation (chaos) + NIC health
    // ------------------------------------------------------------------

    /// Install a [`ChaosProfile`] on the shared fabric and arm the
    /// failover bookkeeping. The profile's NicDown/NicUp events are
    /// scheduled on `cx`'s Reactor timer heap; its reorder window (if
    /// any) widens the fabric delivery thread's shuffle window. The
    /// DES-only timing knobs (`extra_jitter`, `reorder_ns`) have no
    /// real-time equivalent here and are ignored, mirroring how NIC
    /// profiles only shape DES timing.
    pub fn inject_chaos(&self, cx: &mut Cx, profile: &ChaosProfile) {
        self.inner.armed.store(true, Ordering::Release);
        // Arm every OTHER engine on the fabric too (their health hooks
        // set their armed flags): a remote NIC death must be
        // resubmittable by senders whose own links never flip.
        self.inner.fabric.arm_all();
        if profile.reorder_window > 0 {
            self.inner.fabric.set_reorder_window(profile.reorder_window);
        }
        let now = cx.now();
        for ev in &profile.nic_events {
            let fabric = self.inner.fabric.clone();
            let ev = *ev;
            cx.after(ev.at.saturating_sub(now), move |_cx: &mut Cx| {
                fabric.set_nic_up(ev.nic, ev.up);
            });
        }
        for ev in &profile.link_events {
            let fabric = self.inner.fabric.clone();
            let ev = *ev;
            cx.after(ev.at.saturating_sub(now), move |_cx: &mut Cx| {
                fabric.set_link_up(ev.src, ev.dst, ev.up);
            });
        }
    }

    /// Engine-level health override for one local NIC (also how the
    /// fabric's link-state hooks report chaos events).
    pub fn set_nic_health(&self, gpu: u8, nic: u8, up: bool) {
        self.inner.armed.store(true, Ordering::Release);
        self.inner.groups[gpu as usize].health.set(nic as usize, up);
    }

    /// Health bitmask of `gpu`'s domain group.
    pub fn nic_health_mask(&self, gpu: u8) -> u64 {
        self.inner.groups[gpu as usize].health.mask()
    }

    /// Effective egress-lane mask of `gpu`'s group toward `remote`
    /// (see the trait docs).
    pub fn link_health_mask(&self, gpu: u8, remote: NicAddr) -> u64 {
        self.inner.groups[gpu as usize].health.link_mask(remote)
    }

    /// Record a belief about a REMOTE NIC's health (the operation a
    /// received gossip message applies; also an operator override).
    pub fn report_remote_health(&self, gpu: u8, remote: NicAddr, up: bool) {
        self.inner.armed.store(true, Ordering::Release);
        let now = self.now_ns();
        self.inner.groups[gpu as usize]
            .health
            .set_remote_at(remote, up, now);
    }

    /// Configure the probation TTL for believed-dead remote NICs on
    /// `gpu`'s group: a gossiped/concluded death mark older than
    /// `ttl_ns` is dropped on the next degraded submission and the
    /// remote is optimistically re-probed. Zero disables (default).
    pub fn set_remote_probe_ttl(&self, gpu: u8, ttl_ns: u64) {
        self.inner.groups[gpu as usize]
            .health
            .set_remote_probe_ttl(ttl_ns);
    }

    /// Configure the health-gossip neighborhood of `gpu`'s group.
    pub fn set_gossip_peers(&self, gpu: u8, peers: Vec<NetAddr>) {
        *self.inner.groups[gpu as usize].gossip.lock().unwrap() = peers;
    }

    /// Select the in-flight failure policy (see the trait docs).
    pub fn set_failover_policy(&self, policy: FailoverPolicy) {
        self.inner.policy.store(policy_code(policy), Ordering::Release);
    }

    /// Transport-level failures observed so far — derived from the
    /// structured error ledger (`wr_err_total + rejected_all_down`),
    /// one source of truth with [`ThreadedEngine::telemetry`].
    pub fn transport_errors(&self) -> u64 {
        self.inner.metrics.transport_errors()
    }

    /// Enable/disable hot-path telemetry: submission-kind counters,
    /// per-lane wire accounting, imm/recv/latency stats and trace
    /// spans. The transport-error ledger and gossip/MR counters ALWAYS
    /// count regardless — `transport_errors()` semantics never depend
    /// on this switch.
    pub fn set_telemetry(&self, on: bool) {
        self.inner.metrics.set_enabled(on);
    }

    /// Resize every group's trace ring (existing spans beyond the new
    /// capacity are dropped oldest-first and counted).
    pub fn set_trace_capacity(&self, cap: usize) {
        for g in &self.inner.groups {
            g.shared.lock().unwrap().trace.set_capacity(cap);
        }
    }

    /// Snapshot the engine-wide counter ledger (lock-free reads of the
    /// padded atomics; `trace_dropped` summed over the groups' rings).
    pub fn telemetry(&self) -> EngineSnapshot {
        let mut snap = self.inner.metrics.snapshot();
        snap.trace_dropped = self
            .inner
            .groups
            .iter()
            .map(|g| g.shared.lock().unwrap().trace.dropped())
            .sum();
        snap
    }

    /// Drain the recorded submission spans from every group's ring
    /// (Table 8 real measurement; chrome-trace export feed).
    pub fn take_traces(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for g in &self.inner.groups {
            out.append(&mut g.shared.lock().unwrap().trace.drain());
        }
        out
    }

    fn spawn_watcher_thread(&self) {
        let inner = self.inner.clone();
        let stop = self.inner.watcher_stop.clone();
        let h = std::thread::Builder::new()
            .name("te-uvm-watcher".into())
            .spawn(move || {
                // GDRCopy-style polling of all registered watch words.
                while !stop.load(Ordering::Relaxed) {
                    {
                        let mut ws = inner.watchers.lock().unwrap();
                        ws.retain_mut(|(word, last, cb)| {
                            // Liveness check BEFORE the value load: a
                            // writer stores then drops its handle. The
                            // acquire fence pairs with the release
                            // decrement in Arc::drop, so once the
                            // count reads 1 the writer's final store
                            // is visible to the load below — checking
                            // in the other order (or without the
                            // fence) could skip the last update and
                            // then reclaim the entry.
                            let gone = Arc::strong_count(word) == 1;
                            if gone {
                                std::sync::atomic::fence(Ordering::Acquire);
                            }
                            let v = word.load(Ordering::Acquire);
                            if v != *last {
                                let old = *last;
                                *last = v;
                                cb(old, v);
                            }
                            // Watcher hygiene for long-lived engines:
                            // reclaim once every external handle is
                            // gone (no writer remains).
                            !gone
                        });
                    }
                    std::thread::yield_now();
                }
            })
            .expect("spawn watcher thread");
        *self.inner.watcher_thread.lock().unwrap() = Some(h);
    }

    /// ns since engine start.
    pub fn now_ns(&self) -> u64 {
        self.inner.epoch.elapsed().as_nanos() as u64
    }

    /// The engine's main address.
    pub fn main_address(&self) -> NetAddr {
        self.group_address(0)
    }

    /// Address of GPU `gpu`'s domain group.
    pub fn group_address(&self, gpu: u8) -> NetAddr {
        NetAddr {
            nics: self.inner.groups[gpu as usize].nics.clone(),
        }
    }

    /// NICs per GPU on this engine.
    pub fn nics_per_gpu(&self) -> u8 {
        self.inner.groups[0].nics.len() as u8
    }

    /// Allocate + register a region on `gpu`.
    pub fn alloc_mr(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        let mem = self.inner.fabric.mem();
        let (buf, rkey0) = mem.alloc(len);
        // The allocation-time rkey is never exposed through this API
        // (remote access goes through reg_mr's per-NIC rkeys); drop it
        // so dereg_mr returns the registry to its pre-alloc size.
        mem.deregister(rkey0);
        self.reg_mr(gpu, &buf)
    }

    /// Allocate + register an **unbacked** (timing-only) region; see
    /// [`crate::fabric::mem::DmaBuf::unbacked`].
    pub fn alloc_mr_unbacked(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        let mem = self.inner.fabric.mem();
        let (buf, rkey0) = mem.alloc_unbacked(len);
        mem.deregister(rkey0);
        self.reg_mr(gpu, &buf)
    }

    /// Register an existing buffer on `gpu`.
    pub fn reg_mr(&self, gpu: u8, buf: &DmaBuf) -> (MrHandle, MrDesc) {
        let mem = self.inner.fabric.mem();
        let rkeys: Vec<_> = self.inner.groups[gpu as usize]
            .nics
            .iter()
            .map(|&n| (n, mem.register(buf).0))
            .collect();
        self.inner.metrics.mr_regs.add(rkeys.len() as u64);
        (
            MrHandle {
                buf: buf.clone(),
                device: DeviceId {
                    node: self.inner.node,
                    gpu,
                },
            },
            MrDesc {
                ptr: buf.base(),
                len: buf.len() as u64,
                rkeys,
            },
        )
    }

    /// Deregister every rkey of `desc` from the fabric's memory
    /// registry (paper Fig 2 `dereg_mr`). Later remote writes through
    /// those rkeys fault; unknown (already-deregistered) rkeys are
    /// ignored, so double-dereg is safe. The backing [`DmaBuf`] is
    /// refcounted and lives as long as any handle does.
    pub fn dereg_mr(&self, desc: &MrDesc) {
        let mem = self.inner.fabric.mem();
        self.inner.metrics.mr_deregs.add(desc.rkeys.len() as u64);
        for &(_, rkey) in &desc.rkeys {
            mem.deregister(RKey(rkey));
        }
    }

    /// Two-sided send (copy-on-submit).
    pub fn submit_send(&self, gpu: u8, addr: &NetAddr, msg: &[u8], on_done: OnDoneT) {
        let tid = self.alloc_transfer(gpu, 1, on_done);
        self.inner.groups[gpu as usize]
            .tx
            .send(Cmd::Send {
                dst: addr.primary(),
                payload: msg.to_vec(),
                tid,
            })
            .expect("worker gone");
    }

    /// Post a rotating pool of `cnt` receive buffers with callback
    /// (owned [`Fired`] per message; `poison` set on truncation).
    pub fn submit_recvs(
        &self,
        gpu: u8,
        len: usize,
        cnt: usize,
        cb: impl Fn(Fired) + Send + Sync + 'static,
    ) {
        let g = &self.inner.groups[gpu as usize];
        let mem = self.inner.fabric.mem();
        self.inner.metrics.recv_posts(cnt as u64);
        let mut bufs = Vec::with_capacity(cnt);
        {
            let mut sh = g.shared.lock().unwrap();
            sh.recv_cb = Some(Arc::new(cb));
            for _ in 0..cnt {
                let id = self.inner.next_wr.fetch_add(1, Ordering::Relaxed);
                let (buf, _) = mem.alloc(len);
                sh.recvs.post(id, buf.clone(), len);
                bufs.push((id, buf));
            }
        }
        g.tx.send(Cmd::Recvs { bufs }).expect("worker gone");
    }

    /// Contiguous one-sided write.
    pub fn submit_single_write(
        &self,
        src: (&MrHandle, u64),
        len: u64,
        dst: (&MrDesc, u64),
        imm: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let (h, src_off) = src;
        let gpu = h.device.gpu;
        let g = &self.inner.groups[gpu as usize];
        let routed = route_single_write(g.nics.len(), g.rotation.next(), src_off, len, dst, imm)?;
        self.dispatch_writes(gpu, h, routed, on_done, submitted_ns, SubmitKind::Single)?;
        g.rotation.bump();
        Ok(())
    }

    /// Paged writes.
    pub fn submit_paged_writes(
        &self,
        page_len: u64,
        src: (&MrHandle, &Pages),
        dst: (&MrDesc, &Pages),
        imm: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let (h, sp) = src;
        let gpu = h.device.gpu;
        let g = &self.inner.groups[gpu as usize];
        let routed = route_paged_writes(g.nics.len(), g.rotation.next(), page_len, sp, dst, imm)?;
        self.dispatch_writes(gpu, h, routed, on_done, submitted_ns, SubmitKind::Paged)?;
        g.rotation.bump();
        Ok(())
    }

    /// Register a peer group for scatter/barrier fast paths.
    pub fn add_peer_group(&self, addrs: Vec<NetAddr>) -> PeerGroupHandle {
        self.inner.peer_groups.lock().unwrap().add(addrs)
    }

    /// The peer list behind a group handle.
    pub fn peer_group(&self, group: PeerGroupHandle) -> Option<Vec<NetAddr>> {
        self.inner
            .peer_groups
            .lock()
            .unwrap()
            .get(group)
            .map(|p| p.to_vec())
    }

    /// Release a peer group's registry entry (paper §3.5: long-lived
    /// engines must free request-scoped groups). Invalidates the
    /// group's template: later templated submissions error.
    pub fn remove_peer_group(&self, group: PeerGroupHandle) -> bool {
        self.inner
            .peer_groups
            .lock()
            .unwrap()
            .remove(group)
            .is_some()
    }

    /// Pre-template the group's work requests on `gpu`'s domain group
    /// (§3.5): resolves rkeys/NIC pairing once and registers the
    /// barrier scratch region, so `submit_*_templated` calls patch
    /// per-call fields only.
    pub fn bind_peer_group_mrs(
        &self,
        gpu: u8,
        group: PeerGroupHandle,
        descs: &[MrDesc],
    ) -> Result<()> {
        // Validate + resolve routes BEFORE allocating the scratch
        // region: a failed bind (stale handle, bad descriptors) must
        // not leak a registered MR. The registry lock is held across
        // both halves so a concurrent remove_peer_group cannot slip
        // between validation and installation (alloc_mr touches only
        // the fabric memory table — no lock-order cycle).
        let fanout = self.inner.groups[gpu as usize].nics.len();
        let mut pg = self.inner.peer_groups.lock().unwrap();
        let peers = pg.prepare_bind(group, fanout, descs)?;
        let (scratch, _) = self.alloc_mr(gpu, 1);
        pg.install_template(group, fanout, peers, scratch)
    }

    /// The group's bound template (registry lock held only for the
    /// `Arc` clone — the hot path never traverses descriptors).
    fn template(&self, group: PeerGroupHandle) -> Result<Arc<crate::engine::core::GroupTemplate>> {
        self.inner.peer_groups.lock().unwrap().template(group)
    }

    /// Scatter to many peers (one WR per destination, NIC-rotated).
    /// The untemplated (ad-hoc) path.
    pub fn submit_scatter(
        &self,
        group: Option<PeerGroupHandle>,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let gpu = src.device.gpu;
        if cfg!(debug_assertions) {
            self.inner
                .peer_groups
                .lock()
                .unwrap()
                .check(group, dsts.len());
        }
        let g = &self.inner.groups[gpu as usize];
        let routed = route_scatter(g.nics.len(), g.rotation.next(), dsts, imm)?;
        self.dispatch_writes(gpu, src, routed, on_done, submitted_ns, SubmitKind::Scatter)?;
        g.rotation.bump();
        Ok(())
    }

    /// Immediate-only barrier to every descriptor's owner. The
    /// untemplated path allocates its scratch source per call.
    pub fn submit_barrier(
        &self,
        gpu: u8,
        group: Option<PeerGroupHandle>,
        dsts: &[MrDesc],
        imm: u32,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        if cfg!(debug_assertions) {
            self.inner
                .peer_groups
                .lock()
                .unwrap()
                .check(group, dsts.len());
        }
        // Route AND health-check BEFORE allocating the scratch source:
        // a rejected barrier (§3.2 mismatch, all NICs down) must not
        // register anything. The check is best-effort on this runtime
        // — a concurrent link flip can still slip between it and
        // dispatch_writes' own re-check — so the dispatch error path
        // below deregisters the scratch explicitly: a rejected barrier
        // leaves no MR behind on either side of the race.
        let g = &self.inner.groups[gpu as usize];
        let routed = route_barrier(g.nics.len(), g.rotation.next(), dsts, imm)?;
        if g.health.up_count() == 0 {
            // An all-NICs-down rejection is a transport failure too:
            // counted in the ledger so scenarios observe the outage.
            self.inner.metrics.rejected_all_down.add(1);
            crate::bail!(
                "all {} NICs of the domain group are down; \
                 submission rejected (see FailoverPolicy docs)",
                g.nics.len()
            );
        }
        let (scratch, scratch_desc) = self.alloc_mr(gpu, 1);
        if let Err(e) =
            self.dispatch_writes(gpu, &scratch, routed, on_done, submitted_ns, SubmitKind::Barrier)
        {
            self.dereg_mr(&scratch_desc);
            return Err(e);
        }
        g.rotation.bump();
        Ok(())
    }

    // ------------------------------------------------------------------
    // §3.5 templated fast path
    // ------------------------------------------------------------------

    /// Templated contiguous write to `peer` of a bound group.
    pub fn submit_single_write_templated(
        &self,
        src: (&MrHandle, u64),
        len: u64,
        group: PeerGroupHandle,
        peer: usize,
        dst_off: u64,
        imm: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let t = self.template(group)?;
        let (h, src_off) = src;
        let routed =
            route_single_write_templated(&t, t.rotation.next(), peer, src_off, len, dst_off, imm)?;
        self.dispatch_writes(h.device.gpu, h, routed, on_done, submitted_ns, SubmitKind::SingleTpl)?;
        t.rotation.bump();
        Ok(())
    }

    /// Templated paged writes to `peer` of a bound group.
    pub fn submit_paged_writes_templated(
        &self,
        page_len: u64,
        src: (&MrHandle, &Pages),
        group: PeerGroupHandle,
        peer: usize,
        dst_pages: &Pages,
        imm: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let t = self.template(group)?;
        let (h, sp) = src;
        let routed = route_paged_writes_templated(
            &t,
            t.rotation.next(),
            peer,
            page_len,
            sp,
            dst_pages,
            imm,
        )?;
        self.dispatch_writes(h.device.gpu, h, routed, on_done, submitted_ns, SubmitKind::PagedTpl)?;
        t.rotation.bump();
        Ok(())
    }

    /// Templated scatter over a bound group: four integers per
    /// destination patched into pre-resolved routes.
    pub fn submit_scatter_templated(
        &self,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let t = self.template(group)?;
        let routed = route_scatter_templated(&t, t.rotation.next(), dsts, imm)?;
        self.dispatch_writes(
            src.device.gpu,
            src,
            routed,
            on_done,
            submitted_ns,
            SubmitKind::ScatterTpl,
        )?;
        t.rotation.bump();
        Ok(())
    }

    /// Templated barrier over a bound group: destinations, routes and
    /// the scratch source all come from the template.
    pub fn submit_barrier_templated(
        &self,
        group: PeerGroupHandle,
        imm: u32,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let t = self.template(group)?;
        let routed = route_barrier_templated(&t, t.rotation.next(), imm);
        let scratch = t.scratch.clone();
        self.dispatch_writes(
            scratch.device.gpu,
            &scratch,
            routed,
            on_done,
            submitted_ns,
            SubmitKind::BarrierTpl,
        )?;
        t.rotation.bump();
        Ok(())
    }

    // ------------------------------------------------------------------
    // Batched write family: one engine crossing per N writes
    // ------------------------------------------------------------------

    /// Batched ad-hoc writes: all of `dsts` routed in one pass against
    /// one rotation peek, handed to the group's worker as ONE command
    /// (one channel send, one lock pass, one transfer), committed with
    /// a single `bump_n`. Entry `i` routes exactly as the `i`-th of N
    /// sequential [`ThreadedEngine::submit_single_write`] calls would,
    /// so the per-NIC WR streams match the loop — only the per-call
    /// overhead collapses. All-or-nothing: a rejected batch routes
    /// nothing and never shifts later NIC assignment.
    pub fn submit_write_batch(
        &self,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm_base: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        if dsts.is_empty() {
            fire_on_done_t(on_done);
            return Ok(());
        }
        let submitted_ns = self.now_ns();
        let gpu = src.device.gpu;
        let g = &self.inner.groups[gpu as usize];
        let routed = route_write_batch(g.nics.len(), g.rotation.next(), dsts, imm_base)?;
        self.dispatch_writes(gpu, src, routed, on_done, submitted_ns, SubmitKind::Batch)?;
        g.rotation.bump_n(dsts.len());
        Ok(())
    }

    /// Batched templated writes over a bound group (§3.5 + batch): the
    /// template is resolved once, every destination is patched against
    /// the same rotation peek, and the group's cursor commits once via
    /// `bump_n` — equivalent to N sequential
    /// [`ThreadedEngine::submit_single_write_templated`] calls but
    /// with one engine crossing and one health-mask snapshot.
    /// `imm_base` (when set) is delivered unchanged with EVERY entry:
    /// the receiver sees one increment per destination, matching
    /// `expect_imm_count(imm, N)`.
    pub fn submit_batch_templated(
        &self,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm_base: Option<u32>,
        on_done: OnDoneT,
    ) -> Result<()> {
        let submitted_ns = self.now_ns();
        let t = self.template(group)?;
        if dsts.is_empty() {
            fire_on_done_t(on_done);
            return Ok(());
        }
        let routed = route_batch_templated(&t, t.rotation.next(), dsts, imm_base)?;
        self.dispatch_writes(
            src.device.gpu,
            src,
            routed,
            on_done,
            submitted_ns,
            SubmitKind::BatchTpl,
        )?;
        t.rotation.bump_n(dsts.len());
        Ok(())
    }

    /// Register an expectation on `gpu`'s imm counter.
    pub fn expect_imm_count(
        &self,
        gpu: u8,
        imm: u32,
        count: u32,
        cb: impl FnOnce() + Send + 'static,
    ) {
        let ready = {
            let mut sh = self.inner.groups[gpu as usize].shared.lock().unwrap();
            sh.imm.expect(imm, count, Box::new(cb))
        };
        if self.inner.metrics.enabled() {
            self.inner.metrics.imm_arms.add(1);
            if ready.is_some() {
                self.inner.metrics.imm_retires.add(1);
            }
        }
        if let Some(cb) = ready {
            cb();
        }
    }

    /// Poll an imm counter value.
    pub fn imm_value(&self, gpu: u8, imm: u32) -> u32 {
        self.inner.groups[gpu as usize]
            .shared
            .lock()
            .unwrap()
            .imm
            .value(imm)
    }

    /// Release counter state for `imm`.
    pub fn free_imm(&self, gpu: u8, imm: u32) {
        self.inner.groups[gpu as usize]
            .shared
            .lock()
            .unwrap()
            .imm
            .free(imm);
    }

    /// Allocate a UVM watcher word; device-side code stores to the
    /// returned atomic, `cb(old, new)` fires from the watcher thread.
    pub fn alloc_uvm_watcher(
        &self,
        cb: impl Fn(u64, u64) + Send + Sync + 'static,
    ) -> Arc<AtomicU64> {
        let word = Arc::new(AtomicU64::new(0));
        self.inner
            .watchers
            .lock()
            .unwrap()
            .push((word.clone(), 0, Arc::new(cb)));
        word
    }

    /// Stop workers and the watcher thread (fabric is left running;
    /// call `LocalFabric::shutdown` separately).
    pub fn shutdown(&self) {
        for g in &self.inner.groups {
            let _ = g.tx.send(Cmd::Shutdown);
            if let Some(h) = g.worker.lock().unwrap().take() {
                let _ = h.join();
            }
        }
        self.inner.watcher_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.inner.watcher_thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    fn alloc_transfer(&self, gpu: u8, remaining: usize, on_done: OnDoneT) -> u64 {
        self.inner.groups[gpu as usize]
            .shared
            .lock()
            .unwrap()
            .transfers
            .begin(remaining, on_done)
    }

    fn dispatch_writes(
        &self,
        gpu: u8,
        src: &MrHandle,
        mut routed: RoutedVec,
        on_done: OnDoneT,
        submitted_ns: u64,
        kind: SubmitKind,
    ) -> Result<()> {
        assert!(!routed.is_empty(), "empty transfer");
        // Unhealthy paths are masked here — at patch time, after
        // routing — so untemplated and templated submissions alike
        // egress only on lanes believed to reach their destination
        // (downed local NICs, observed link partitions and
        // gossiped-dead remote NICs all steer the choice); errs when
        // the group is down locally.
        let g = &self.inner.groups[gpu as usize];
        if !g.health.all_clear() {
            // Probation: lift expired remote death-marks before
            // masking, so a believed-dead remote is optimistically
            // re-probed once its TTL elapses.
            g.health.expire_dead_remotes(self.now_ns());
            if let Err(e) = remap_routed(&mut routed, &g.health) {
                // An all-NICs-down rejection is a transport failure
                // too: count it so scenarios can observe the outage.
                self.inner.metrics.rejected_all_down.add(1);
                return Err(e);
            }
        }
        self.inner.metrics.submission(kind);
        let tid = self.alloc_transfer(gpu, routed.len(), on_done);
        g.tx
            .send(Cmd::Writes {
                routed,
                src: src.buf.clone(),
                tid,
                submitted_ns,
                kind,
            })
            .expect("worker gone");
        Ok(())
    }
}

/// The pinned worker: drain submissions first (paper: "prioritizing
/// the submission of new requests"), then poll CQs.
fn worker_loop(
    fabric: LocalFabric,
    nics: Vec<NicAddr>,
    shared: Arc<Mutex<GroupShared>>,
    rx: mpsc::Receiver<Cmd>,
    epoch: Instant,
    fo: FailCtx,
) {
    let mut next_wr: u64 = 1 << 48; // worker-allocated ids, disjoint from app ids
    let mut cqes: Vec<Cqe> = Vec::with_capacity(64);
    loop {
        // 1) submissions (block briefly when idle)
        match rx.recv_timeout(Duration::from_micros(50)) {
            Ok(Cmd::Shutdown) | Err(RecvTimeoutError::Disconnected) => break,
            Ok(Cmd::Writes {
                routed,
                src,
                tid,
                submitted_ns,
                kind,
            }) => {
                let worker_ns = epoch.elapsed().as_nanos() as u64;
                let n = routed.len();
                let base_id = next_wr;
                next_wr += n as u64;
                // Build the WRs first so the (armed-only) retry
                // entries can be recorded in the same lock pass as the
                // transfer bindings — BEFORE any WR is on the wire, so
                // an instant failure still finds its entry. Inline up
                // to the common fanout: no heap allocation between
                // dequeue and post for small submissions.
                let wrs: SmallVec<(usize, RouteSet, WorkRequest), 4> = routed
                    .into_iter()
                    .enumerate()
                    .map(|(i, w)| {
                        let RoutedWrite { plan: p, route: (dst_nic, rkey), alts } = w;
                        (
                            p.nic,
                            alts,
                            WorkRequest {
                                id: base_id + i as u64,
                                qp: QpId(1),
                                op: WrOp::Write {
                                    dst: dst_nic,
                                    dst_rkey: RKey(rkey),
                                    dst_va: p.dst_va,
                                    src: DmaSlice::new(&src, p.src_off as usize, p.len as usize),
                                    imm: p.imm,
                                },
                                chained: false,
                            },
                        )
                    })
                    .collect();
                // Wire accounting (gated): one bump per WR on its
                // egress lane; the span's bytes are the payload sum.
                let mut bytes = 0u64;
                let mut lane0 = 0u8;
                for (i, (lane, _, wr)) in wrs.iter().enumerate() {
                    if let WrOp::Write { src, .. } = &wr.op {
                        bytes += src.len as u64;
                        fo.metrics.wire(*lane, 1, src.len as u64);
                    }
                    if i == 0 {
                        lane0 = *lane as u8;
                    }
                }
                {
                    let mut sh = shared.lock().unwrap();
                    let armed = fo.armed.load(Ordering::Acquire);
                    for (lane, alts, wr) in &wrs {
                        sh.transfers.bind_wr(wr.id, tid);
                        if armed {
                            sh.retry.insert(
                                wr.id,
                                RetryT {
                                    lane: *lane,
                                    cur_lane: *lane,
                                    routes: alts.clone(),
                                    wr: wr.clone(),
                                    attempts: 0,
                                },
                            );
                        }
                    }
                }
                let mut first_post_ns = 0;
                for (i, (lane, _alts, wr)) in wrs.into_iter().enumerate() {
                    if i == 0 {
                        first_post_ns = epoch.elapsed().as_nanos() as u64;
                    }
                    fabric.post(nics[lane], wr);
                }
                let last_post_ns = epoch.elapsed().as_nanos() as u64;
                // Open the span AFTER the posts (the ring needs the
                // last-post stamp) — safe against completion races
                // because THIS thread also polls the group's CQs, so
                // no CQE for these WRs is handled before this point.
                // On this runtime the app thread enqueues directly:
                // `enqueued` coincides with `submitted`.
                let mut sh = shared.lock().unwrap();
                let seq = if fo.metrics.enabled() {
                    sh.trace.push(TraceEvent {
                        kind,
                        lane: lane0,
                        wrs: n as u32,
                        bytes,
                        submitted: submitted_ns,
                        enqueued: submitted_ns,
                        worker_start: worker_ns,
                        first_post: first_post_ns,
                        last_post: last_post_ns,
                        retired: 0,
                        outcome: TraceOutcome::Posted,
                    })
                } else {
                    NO_TRACE
                };
                sh.transfers.set_trace(tid, seq);
            }
            Ok(Cmd::Send { dst, payload, tid }) => {
                let id = next_wr;
                next_wr += 1;
                let wr = WorkRequest {
                    id,
                    qp: QpId(0),
                    op: WrOp::Send { dst, payload },
                    chained: false,
                };
                {
                    let mut sh = shared.lock().unwrap();
                    sh.transfers.bind_wr(id, tid);
                    if fo.armed.load(Ordering::Acquire) {
                        sh.retry.insert(
                            id,
                            RetryT {
                                lane: 0,
                                cur_lane: 0,
                                routes: RouteSet::default(),
                                wr: wr.clone(),
                                attempts: 0,
                            },
                        );
                    }
                }
                fabric.post(nics[0], wr);
            }
            Ok(Cmd::Recvs { bufs }) => {
                for (id, buf) in bufs {
                    fabric.post(
                        nics[0],
                        WorkRequest {
                            id,
                            qp: QpId(0),
                            op: WrOp::Recv {
                                buf: DmaSlice::whole(&buf),
                            },
                            chained: false,
                        },
                    );
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
        }

        // 2) completions on every NIC of the group
        for &nic in &nics {
            loop {
                cqes.clear();
                fabric.poll_cq(nic, 64, &mut cqes);
                if cqes.is_empty() {
                    break;
                }
                for cqe in cqes.drain(..) {
                    handle_cqe(&fabric, &nics, nic, &shared, cqe, &mut next_wr, &fo);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_cqe(
    fabric: &LocalFabric,
    nics: &[NicAddr],
    nic: NicAddr,
    shared: &Arc<Mutex<GroupShared>>,
    cqe: Cqe,
    next_wr: &mut u64,
    fo: &FailCtx,
) {
    match cqe.kind {
        CqeKind::SendDone | CqeKind::WriteDone => {
            let now = fo.epoch.elapsed().as_nanos() as u64;
            let done = {
                let mut sh = shared.lock().unwrap();
                if fo.armed.load(Ordering::Acquire) {
                    sh.retry.remove(&cqe.wr_id);
                }
                let done = sh.transfers.complete_wr(cqe.wr_id);
                // Last WR of a transfer: retire the span and feed the
                // end-to-end latency histogram from its submit stamp.
                if let Some((_, seq)) = &done {
                    if let Some(sub) = sh.trace.close(*seq, now, TraceOutcome::Retired) {
                        fo.metrics.observe_latency(now.saturating_sub(sub));
                    }
                }
                done
            };
            match done {
                Some((OnDoneT::Callback(cb), _)) => cb(),
                Some((OnDoneT::Flag(f), _)) => f.store(true, Ordering::Release),
                _ => {}
            }
        }
        CqeKind::WrError => {
            // A WR died on a downed NIC or a partitioned link. First
            // ATTRIBUTE the failure — mark the (egress lane →
            // destination NIC) link suspect, and once every lane
            // toward that destination failed, conclude the REMOTE NIC
            // dead and tell the gossip peers. Under Resubmit, repost
            // on the next believed-healthy path — another lane toward
            // the same destination first, then a surviving remote NIC
            // of the region (the failed payload provably did not
            // commit — no duplication possible); otherwise count the
            // error and complete the transfer undelivered so waiters
            // don't hang (trait docs spell out the contract).
            // Ledger invariants (always on): every WrError bumps the
            // total plus exactly one of {link, nic}; a remote-death
            // conclusion bumps `wr_err_remote` ADDITIONALLY; and the
            // error resolves as exactly one of resubmit / error-out.
            fo.metrics.wr_err_total.add(1);
            let entry = shared.lock().unwrap().retry.remove(&cqe.wr_id);
            let mut gossip_dead: Option<NicAddr> = None;
            let retried = match entry {
                Some(mut e) => {
                    let remote = e.wr.op.dst();
                    if remote.is_some() {
                        // Routable WR with a live retry entry: the
                        // failure is attributed to the (lane → remote
                        // NIC) link.
                        fo.metrics.wr_err_link.add(1);
                    } else {
                        // SEND-path WR (no destination NIC to blame a
                        // link on): attributed to the local NIC.
                        fo.metrics.wr_err_nic.add(1);
                    }
                    if let Some(r) = remote {
                        fo.health.set_link(e.cur_lane, r, false);
                        // Conclude remote death only from full link
                        // evidence: one attributed WrError per local
                        // lane (a locally-dead lane proves nothing
                        // about the destination and cannot satisfy
                        // the bar).
                        if fo.health.up_count() > 0
                            && fo.health.all_links_observed_down(r)
                            && fo.health.remote_up(r)
                        {
                            let now = fo.epoch.elapsed().as_nanos() as u64;
                            fo.health.set_remote_at(r, false, now);
                            fo.metrics.wr_err_remote.add(1);
                            gossip_dead = Some(r);
                        }
                    }
                    if fo.policy.load(Ordering::Acquire) == POLICY_RESUBMIT {
                        e.attempts += 1;
                        let cap = (nics.len() + e.routes.len()) as u8;
                        let target = match (e.attempts <= cap, remote) {
                            (true, Some(r)) => {
                                retarget(&fo.health, e.lane, e.attempts as usize, r, &e.routes)
                            }
                            _ => None,
                        };
                        match target {
                            Some((lane, new_route)) => {
                                if let Some((r, rkey)) = new_route {
                                    if let WrOp::Write { dst, dst_rkey, .. } = &mut e.wr.op {
                                        *dst = r;
                                        *dst_rkey = RKey(rkey);
                                    }
                                }
                                // e.lane stays the ORIGINAL lane: the
                                // projection base is stable while the
                                // per-link mask shrinks with each
                                // attributed failure.
                                e.cur_lane = lane;
                                let wr = e.wr.clone();
                                // The repost is real wire traffic:
                                // account it on the retry lane (gated;
                                // SEND retries carry no payload WR and
                                // are not wire-counted).
                                fo.metrics.resubmits.add(1);
                                if let WrOp::Write { src, .. } = &wr.op {
                                    fo.metrics.wire(lane, 1, src.len as u64);
                                }
                                shared.lock().unwrap().retry.insert(cqe.wr_id, e);
                                fabric.post(nics[lane], wr);
                                true
                            }
                            None => false,
                        }
                    } else {
                        false
                    }
                }
                None => {
                    // No retry entry (failover never armed): nothing
                    // links this WR to a route — attribute locally.
                    fo.metrics.wr_err_nic.add(1);
                    false
                }
            };
            // Gossip outside the retry bookkeeping: one control SEND
            // per configured peer (fire-and-forget, peers owning the
            // dead NIC skipped).
            if let Some(r) = gossip_dead {
                let peers = fo.gossip.lock().unwrap().clone();
                let msg = wire::encode_nic_health(r, false);
                for p in peers.iter().filter(|p| !p.nics.contains(&r)) {
                    fo.metrics.gossip_sent.add(1);
                    let id = *next_wr;
                    *next_wr += 1;
                    fabric.post(
                        nics[0],
                        WorkRequest {
                            id,
                            qp: QpId(0),
                            op: WrOp::Send { dst: p.primary(), payload: msg.clone() },
                            chained: false,
                        },
                    );
                }
            }
            if !retried {
                fo.metrics.error_outs.add(1);
                let now = fo.epoch.elapsed().as_nanos() as u64;
                let done = {
                    let mut sh = shared.lock().unwrap();
                    let done = sh.transfers.complete_wr(cqe.wr_id);
                    // A transfer concluded by an errored WR closes its
                    // span as Failed — no latency sample.
                    if let Some((_, seq)) = &done {
                        sh.trace.close(*seq, now, TraceOutcome::Failed);
                    }
                    done
                };
                match done {
                    Some((OnDoneT::Callback(cb), _)) => cb(),
                    Some((OnDoneT::Flag(f), _)) => f.store(true, Ordering::Release),
                    _ => {}
                }
            }
        }
        CqeKind::ImmRecvd { imm, .. } => {
            let waiter = shared.lock().unwrap().imm.on_imm(imm);
            if fo.metrics.enabled() {
                fo.metrics.imm_bumps.add(1);
                if waiter.is_some() {
                    fo.metrics.imm_retires.add(1);
                }
            }
            if let Some(cb) = waiter {
                cb();
            }
        }
        CqeKind::RecvDone { len, .. } => {
            let (msg, cb, repost) = {
                let mut sh = shared.lock().unwrap();
                let new_id = *next_wr;
                *next_wr += 1;
                let (data, buf, overflowed) = sh.recvs.complete(cqe.wr_id, len, new_id);
                // Deliver truncated-and-poisoned rather than panicking:
                // this runs on the worker thread, where a panic would
                // poison the group lock and hang waiters. The poison
                // marker reaches the submitter's callback so it can
                // distinguish truncation from a completed message (the
                // single-threaded DES runtime asserts loudly instead).
                let msg = if overflowed {
                    let diag = RecvPool::overflow_msg(len, data.len());
                    Fired::poisoned(data, diag)
                } else {
                    Fired::bytes(data)
                };
                let cb = sh.recv_cb.clone();
                (msg, cb, (new_id, buf))
            };
            if fo.metrics.enabled() {
                fo.metrics.recv_completed.add(1);
            }
            fo.metrics.recv_posts(1);
            fabric.post(
                nic,
                WorkRequest {
                    id: repost.0,
                    qp: QpId(0),
                    op: WrOp::Recv {
                        buf: DmaSlice::whole(&repost.1),
                    },
                    chained: false,
                },
            );
            // Engine-level control plane: health gossip rides the same
            // recv pool as heartbeats but is consumed HERE — applied
            // to the group's link table, never delivered to
            // application callbacks.
            if wire::is_nic_health(&msg.data) {
                fo.metrics.gossip_received.add(1);
                if let Ok((dead, up)) = wire::decode_nic_health(&msg.data) {
                    fo.armed.store(true, Ordering::Release);
                    // Stamp the gossiped death at receive time so the
                    // probation TTL counts from when THIS group
                    // started believing it.
                    let now = fo.epoch.elapsed().as_nanos() as u64;
                    fo.health.set_remote_at(dead, up, now);
                    fo.metrics.gossip_applied.add(1);
                }
                return;
            }
            if let Some(cb) = cb {
                cb(msg);
            }
        }
    }
}

// ---------------------------------------------------------------------
// The uniform TransferEngine interface over the threaded runtime
// ---------------------------------------------------------------------

impl TransferEngine for ThreadedEngine {
    fn runtime_kind(&self) -> RuntimeKind {
        RuntimeKind::Threaded
    }

    fn group_address(&self, gpu: u8) -> NetAddr {
        ThreadedEngine::group_address(self, gpu)
    }

    fn nics_per_gpu(&self) -> u8 {
        ThreadedEngine::nics_per_gpu(self)
    }

    fn alloc_mr(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        ThreadedEngine::alloc_mr(self, gpu, len)
    }

    fn alloc_mr_unbacked(&self, gpu: u8, len: usize) -> (MrHandle, MrDesc) {
        ThreadedEngine::alloc_mr_unbacked(self, gpu, len)
    }

    fn reg_mr(&self, gpu: u8, buf: &DmaBuf) -> (MrHandle, MrDesc) {
        ThreadedEngine::reg_mr(self, gpu, buf)
    }

    fn dereg_mr(&self, desc: &MrDesc) {
        ThreadedEngine::dereg_mr(self, desc)
    }

    fn submit_send(&self, _cx: &mut Cx, gpu: u8, addr: &NetAddr, msg: &[u8], on_done: Notify) {
        ThreadedEngine::submit_send(self, gpu, addr, msg, on_done.into_threaded());
    }

    fn submit_recvs(&self, _cx: &mut Cx, gpu: u8, len: usize, cnt: usize, on_msg: OnRecv) {
        match on_msg {
            OnRecv::Handler(cb) => {
                ThreadedEngine::submit_recvs(self, gpu, len, cnt, move |m| cb(m))
            }
            OnRecv::Cont(c) => {
                let tx = c.into_sender();
                // Ownership handoff: the extracted payload (and any
                // poison) moves through the wake queue without a copy.
                ThreadedEngine::submit_recvs(self, gpu, len, cnt, move |m| tx.send(m))
            }
        }
    }

    fn submit_single_write(
        &self,
        _cx: &mut Cx,
        src: (&MrHandle, u64),
        len: u64,
        dst: (&MrDesc, u64),
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_single_write(self, src, len, dst, imm, on_done.into_threaded())
    }

    fn submit_paged_writes(
        &self,
        _cx: &mut Cx,
        page_len: u64,
        src: (&MrHandle, &Pages),
        dst: (&MrDesc, &Pages),
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_paged_writes(self, page_len, src, dst, imm, on_done.into_threaded())
    }

    fn add_peer_group(&self, addrs: Vec<NetAddr>) -> PeerGroupHandle {
        ThreadedEngine::add_peer_group(self, addrs)
    }

    fn peer_group(&self, group: PeerGroupHandle) -> Option<Vec<NetAddr>> {
        ThreadedEngine::peer_group(self, group)
    }

    fn remove_peer_group(&self, group: PeerGroupHandle) -> bool {
        ThreadedEngine::remove_peer_group(self, group)
    }

    fn bind_peer_group_mrs(
        &self,
        gpu: u8,
        group: PeerGroupHandle,
        descs: &[MrDesc],
    ) -> Result<()> {
        ThreadedEngine::bind_peer_group_mrs(self, gpu, group, descs)
    }

    fn submit_scatter(
        &self,
        _cx: &mut Cx,
        group: Option<PeerGroupHandle>,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_scatter(self, group, src, dsts, imm, on_done.into_threaded())
    }

    fn submit_write_batch(
        &self,
        _cx: &mut Cx,
        src: &MrHandle,
        dsts: &[ScatterDst],
        imm_base: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_write_batch(self, src, dsts, imm_base, on_done.into_threaded())
    }

    fn submit_barrier(
        &self,
        _cx: &mut Cx,
        gpu: u8,
        group: Option<PeerGroupHandle>,
        dsts: &[MrDesc],
        imm: u32,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_barrier(self, gpu, group, dsts, imm, on_done.into_threaded())
    }

    fn submit_single_write_templated(
        &self,
        _cx: &mut Cx,
        src: (&MrHandle, u64),
        len: u64,
        group: PeerGroupHandle,
        peer: usize,
        dst_off: u64,
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_single_write_templated(
            self,
            src,
            len,
            group,
            peer,
            dst_off,
            imm,
            on_done.into_threaded(),
        )
    }

    fn submit_paged_writes_templated(
        &self,
        _cx: &mut Cx,
        page_len: u64,
        src: (&MrHandle, &Pages),
        group: PeerGroupHandle,
        peer: usize,
        dst_pages: &Pages,
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_paged_writes_templated(
            self,
            page_len,
            src,
            group,
            peer,
            dst_pages,
            imm,
            on_done.into_threaded(),
        )
    }

    fn submit_scatter_templated(
        &self,
        _cx: &mut Cx,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_scatter_templated(self, src, group, dsts, imm, on_done.into_threaded())
    }

    fn submit_batch_templated(
        &self,
        _cx: &mut Cx,
        src: &MrHandle,
        group: PeerGroupHandle,
        dsts: &[TemplatedDst],
        imm_base: Option<u32>,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_batch_templated(
            self,
            src,
            group,
            dsts,
            imm_base,
            on_done.into_threaded(),
        )
    }

    fn submit_barrier_templated(
        &self,
        _cx: &mut Cx,
        group: PeerGroupHandle,
        imm: u32,
        on_done: Notify,
    ) -> Result<()> {
        ThreadedEngine::submit_barrier_templated(self, group, imm, on_done.into_threaded())
    }

    fn expect_imm_count(&self, _cx: &mut Cx, gpu: u8, imm: u32, count: u32, on: Notify) {
        ThreadedEngine::expect_imm_count(self, gpu, imm, count, on.into_send_cb());
    }

    fn imm_value(&self, gpu: u8, imm: u32) -> u32 {
        ThreadedEngine::imm_value(self, gpu, imm)
    }

    fn free_imm(&self, gpu: u8, imm: u32) {
        ThreadedEngine::free_imm(self, gpu, imm)
    }

    fn alloc_uvm_watcher(&self, on: OnWatch) -> UvmWatcher {
        match on {
            OnWatch::Handler(cb) => UvmWatcher::Threaded(ThreadedEngine::alloc_uvm_watcher(
                self,
                move |old, new| cb(old, new),
            )),
            OnWatch::Cont(c) => {
                let tx = c.into_sender();
                UvmWatcher::Threaded(ThreadedEngine::alloc_uvm_watcher(self, move |old, new| {
                    tx.send(Fired::pair(old, new))
                }))
            }
        }
    }

    fn inject_chaos(&self, cx: &mut Cx, profile: &ChaosProfile) {
        ThreadedEngine::inject_chaos(self, cx, profile)
    }

    fn set_nic_health(&self, gpu: u8, nic: u8, up: bool) {
        ThreadedEngine::set_nic_health(self, gpu, nic, up)
    }

    fn nic_health_mask(&self, gpu: u8) -> u64 {
        ThreadedEngine::nic_health_mask(self, gpu)
    }

    fn set_failover_policy(&self, policy: FailoverPolicy) {
        ThreadedEngine::set_failover_policy(self, policy)
    }

    fn transport_errors(&self) -> u64 {
        ThreadedEngine::transport_errors(self)
    }

    fn telemetry(&self) -> EngineSnapshot {
        ThreadedEngine::telemetry(self)
    }

    fn take_traces(&self) -> Vec<TraceEvent> {
        ThreadedEngine::take_traces(self)
    }

    fn set_telemetry(&self, on: bool) {
        ThreadedEngine::set_telemetry(self, on)
    }

    fn set_trace_capacity(&self, cap: usize) {
        ThreadedEngine::set_trace_capacity(self, cap)
    }

    fn link_health_mask(&self, gpu: u8, remote: NicAddr) -> u64 {
        ThreadedEngine::link_health_mask(self, gpu, remote)
    }

    fn report_remote_health(&self, gpu: u8, remote: NicAddr, up: bool) {
        ThreadedEngine::report_remote_health(self, gpu, remote, up)
    }

    fn set_gossip_peers(&self, gpu: u8, peers: Vec<NetAddr>) {
        ThreadedEngine::set_gossip_peers(self, gpu, peers)
    }

    fn set_remote_probe_ttl(&self, gpu: u8, ttl_ns: u64) {
        ThreadedEngine::set_remote_probe_ttl(self, gpu, ttl_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::profile::TransportKind;

    fn wait_flag(f: &AtomicBool) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !f.load(Ordering::Acquire) {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
    }

    #[test]
    fn threaded_single_write_and_imm() {
        let fabric = LocalFabric::new(TransportKind::Srd, 9);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        let (src, _) = a.alloc_mr(0, 1024);
        let (dst_h, dst_d) = b.alloc_mr(0, 1024);
        src.buf.write(0, b"threaded engine");

        let got = Arc::new(AtomicBool::new(false));
        let g = got.clone();
        b.expect_imm_count(0, 50, 1, move || g.store(true, Ordering::Release));
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 15, (&dst_d, 8), Some(50), OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        wait_flag(&got);
        assert_eq!(&dst_h.buf.to_vec()[8..23], b"threaded engine");
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_sharded_large_write() {
        let fabric = LocalFabric::new(TransportKind::Srd, 10);
        let a = ThreadedEngine::new(&fabric, 0, 1, 4);
        let b = ThreadedEngine::new(&fabric, 1, 1, 4);
        let len = 1 << 20;
        let (src, _) = a.alloc_mr(0, len);
        let (dst_h, dst_d) = b.alloc_mr(0, len);
        let pat: Vec<u8> = (0..len).map(|i| (i % 253) as u8).collect();
        src.buf.write(0, &pat);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), len as u64, (&dst_d, 0), None, OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        assert_eq!(dst_h.buf.to_vec(), pat);
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_send_recv_rpc() {
        let fabric = LocalFabric::new(TransportKind::Rc, 11);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let b = ThreadedEngine::new(&fabric, 1, 1, 1);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        b.submit_recvs(0, 128, 4, move |m| {
            assert!(m.poison.is_none());
            assert_eq!(&m.data[..], b"ping");
            h.fetch_add(1, Ordering::Relaxed);
        });
        for _ in 0..8 {
            a.submit_send(0, &b.group_address(0), b"ping", OnDoneT::Noop);
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while hits.load(Ordering::Relaxed) < 8 {
            assert!(
                Instant::now() < deadline,
                "timeout: {}",
                hits.load(Ordering::Relaxed)
            );
            std::thread::yield_now();
        }
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_recv_overflow_poisons_delivery() {
        // An oversized SEND must reach the submitter's callback as a
        // poisoned (truncated) message — not a worker-thread panic and
        // not a silent stderr line the caller can't observe.
        let fabric = LocalFabric::new(TransportKind::Rc, 14);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let b = ThreadedEngine::new(&fabric, 1, 1, 1);
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s = seen.clone();
        b.submit_recvs(0, 8, 2, move |m| {
            s.lock().unwrap().push((m.data.clone(), m.poison.clone()));
        });
        a.submit_send(0, &b.group_address(0), &[7u8; 32], OnDoneT::Noop);
        a.submit_send(0, &b.group_address(0), &[3u8; 4], OnDoneT::Noop);
        let deadline = Instant::now() + Duration::from_secs(5);
        while seen.lock().unwrap().len() < 2 {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
        let v = seen.lock().unwrap().clone();
        let truncated = v.iter().find(|(_, p)| p.is_some()).expect("poisoned msg");
        assert_eq!(truncated.0, vec![7u8; 8], "payload truncated to capacity");
        assert!(
            truncated.1.as_deref().unwrap().contains("overflows"),
            "{:?}",
            truncated.1
        );
        let intact = v.iter().find(|(_, p)| p.is_none()).expect("intact msg");
        assert_eq!(intact.0, vec![3u8; 4]);
        // The engine keeps serving after a truncation (pool re-posted).
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_scatter_and_barrier_via_peer_group() {
        // Parity with the DES engine: handle-based scatter + barrier,
        // counted by expect_imm_count on every peer.
        let fabric = LocalFabric::new(TransportKind::Srd, 21);
        let engines: Vec<ThreadedEngine> =
            (0..4).map(|n| ThreadedEngine::new(&fabric, n, 1, 2)).collect();
        let (src, _) = engines[0].alloc_mr(0, 1024);
        src.buf.write(0, &[3u8; 1024]);
        let peers: Vec<(MrHandle, MrDesc)> =
            (1..4).map(|i| engines[i].alloc_mr(0, 1024)).collect();
        let group = engines[0].add_peer_group(
            (1..4).map(|i| engines[i].group_address(0)).collect(),
        );
        assert_eq!(engines[0].peer_group(group).unwrap().len(), 3);

        let arrived: Vec<Arc<AtomicBool>> =
            (0..3).map(|_| Arc::new(AtomicBool::new(false))).collect();
        for (i, f) in arrived.iter().enumerate() {
            let f = f.clone();
            engines[i + 1].expect_imm_count(0, 40, 1, move || f.store(true, Ordering::Release));
        }
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .enumerate()
            .map(|(i, (_, d))| ScatterDst {
                len: 128,
                src: (i as u64) * 128,
                dst: (d.clone(), 32),
            })
            .collect();
        let done = Arc::new(AtomicBool::new(false));
        engines[0]
            .submit_scatter(Some(group), &src, &dsts, Some(40), OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        for f in &arrived {
            wait_flag(f);
        }
        for (i, (h, _)) in peers.iter().enumerate() {
            assert_eq!(&h.buf.to_vec()[32..32 + 128], &[3u8; 128], "peer {i}");
        }

        // Barrier through the same handle.
        let released: Vec<Arc<AtomicBool>> =
            (0..3).map(|_| Arc::new(AtomicBool::new(false))).collect();
        for (i, f) in released.iter().enumerate() {
            let f = f.clone();
            engines[i + 1].expect_imm_count(0, 41, 1, move || f.store(true, Ordering::Release));
        }
        let descs: Vec<MrDesc> = peers.iter().map(|(_, d)| d.clone()).collect();
        engines[0]
            .submit_barrier(0, Some(group), &descs, 41, OnDoneT::Noop)
            .unwrap();
        for f in &released {
            wait_flag(f);
        }
        for e in &engines {
            e.shutdown();
        }
        fabric.shutdown();
    }

    #[test]
    fn threaded_paged_writes_parity() {
        let fabric = LocalFabric::new(TransportKind::Srd, 22);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        let page = 512u64;
        let (src, _) = a.alloc_mr(0, (page * 4) as usize);
        let (dst_h, dst_d) = b.alloc_mr(0, (page * 8) as usize);
        for i in 0..4u8 {
            src.buf.write((i as u64 * page) as usize, &[i + 1; 32]);
        }
        let dst_idx = vec![6u32, 0, 3, 1];
        let done = Arc::new(AtomicBool::new(false));
        let counted = Arc::new(AtomicBool::new(false));
        let c = counted.clone();
        b.expect_imm_count(0, 8, 4, move || c.store(true, Ordering::Release));
        a.submit_paged_writes(
            page,
            (&src, &Pages::contiguous(0, 4, page)),
            (&dst_d, &Pages { indices: dst_idx.clone(), stride: page, offset: 0 }),
            Some(8),
            OnDoneT::Flag(done.clone()),
        )
        .unwrap();
        wait_flag(&done);
        wait_flag(&counted);
        let v = dst_h.buf.to_vec();
        for (i, &slot) in dst_idx.iter().enumerate() {
            let off = (slot as u64 * page) as usize;
            assert_eq!(v[off..off + 32], [(i as u8) + 1; 32], "page {i} -> slot {slot}");
        }
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn chaos_threaded_dead_destination_errors_once_then_recovers() {
        let fabric = LocalFabric::new(TransportKind::Rc, 77);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        let (src, _) = a.alloc_mr(0, 64);
        let (dst_h, dst_d) = b.alloc_mr(0, 64);
        src.buf.write(0, &[8u8; 64]);
        // Arm failover bookkeeping without changing any health bit.
        a.set_nic_health(0, 0, true);
        // Kill BOTH of b's NICs at the fabric level: every local lane
        // retry must fail, so Resubmit degrades to error-out.
        fabric.set_nic_up(NicAddr { node: 1, gpu: 0, nic: 0 }, false);
        fabric.set_nic_up(NicAddr { node: 1, gpu: 0, nic: 1 }, false);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 64, (&dst_d, 0), Some(3), OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        assert!(a.transport_errors() >= 1, "dead-NIC failures are counted");
        assert_eq!(
            dst_h.buf.to_vec(),
            vec![0u8; 64],
            "nothing committed through a dead NIC (exactly-once)"
        );
        assert_eq!(b.imm_value(0, 3), 0, "ImmCounter stays un-bumped on failure");
        // Recovery: NicUp restores delivery (engine health tables were
        // updated through the fabric hooks both ways).
        fabric.set_nic_up(NicAddr { node: 1, gpu: 0, nic: 0 }, true);
        fabric.set_nic_up(NicAddr { node: 1, gpu: 0, nic: 1 }, true);
        assert_eq!(b.nic_health_mask(0), 0b11);
        let done2 = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 64, (&dst_d, 0), Some(3), OnDoneT::Flag(done2.clone()))
            .unwrap();
        wait_flag(&done2);
        let deadline = Instant::now() + Duration::from_secs(5);
        while b.imm_value(0, 3) < 1 {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
        assert_eq!(dst_h.buf.to_vec(), vec![8u8; 64]);
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn chaos_threaded_health_mask_excludes_nic_from_submissions() {
        let fabric = LocalFabric::new(TransportKind::Srd, 78);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        // Engine-level override only: the fabric stays fully up, so a
        // masked submission must still deliver — just not via NIC 1.
        a.set_nic_health(0, 1, false);
        assert_eq!(a.nic_health_mask(0), 0b01);
        let len = 1 << 20;
        let (src, _) = a.alloc_mr(0, len);
        let (dst_h, dst_d) = b.alloc_mr(0, len);
        let pat: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        src.buf.write(0, &pat);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), len as u64, (&dst_d, 0), None, OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        assert_eq!(dst_h.buf.to_vec(), pat);
        assert_eq!(a.transport_errors(), 0, "masked lanes never hit the dead path");
        // All NICs down: submission errors synchronously.
        a.set_nic_health(0, 0, false);
        let err = a
            .submit_single_write((&src, 0), 64, (&dst_d, 0), None, OnDoneT::Noop)
            .unwrap_err();
        assert!(err.to_string().contains("all 2 NICs"), "{err}");
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn chaos_threaded_link_partition_resubmits_over_surviving_links() {
        // Cut ONE directed link a.nic0 → b.nic0 before submitting:
        // the lane-0 shard fails at delivery, is attributed to exactly
        // that link, and resubmits over a surviving path; the payload
        // arrives complete and the local NIC mask stays full.
        let fabric = LocalFabric::new(TransportKind::Srd, 90);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        a.set_nic_health(0, 0, true); // arm failover bookkeeping
        let (a0, b0) = (
            NicAddr { node: 0, gpu: 0, nic: 0 },
            NicAddr { node: 1, gpu: 0, nic: 0 },
        );
        fabric.set_link_up(a0, b0, false);
        let len = 1 << 20;
        let (src, _) = a.alloc_mr(0, len);
        let (dst_h, dst_d) = b.alloc_mr(0, len);
        let pat: Vec<u8> = (0..len).map(|i| (i % 241) as u8).collect();
        src.buf.write(0, &pat);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), len as u64, (&dst_d, 0), None, OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        assert_eq!(dst_h.buf.to_vec(), pat, "the partition must lose nothing");
        assert!(a.transport_errors() >= 1, "the cut link's shard was observed");
        assert_eq!(a.nic_health_mask(0), 0b11, "no LOCAL NIC died");
        assert_eq!(a.link_health_mask(0, b0), 0b10, "lane 0 masked toward b.nic0 only");
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn chaos_threaded_gossip_masks_dead_remote_for_second_sender() {
        let fabric = LocalFabric::new(TransportKind::Srd, 91);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        let d = ThreadedEngine::new(&fabric, 2, 1, 2);
        a.set_gossip_peers(0, vec![b.group_address(0)]);
        // B's ordinary control-plane recv pool: gossip lands here but
        // is consumed by the engine, never the app callback.
        let app_msgs = Arc::new(AtomicU64::new(0));
        let am = app_msgs.clone();
        b.submit_recvs(0, 64, 4, move |_m| {
            am.fetch_add(1, Ordering::Relaxed);
        });
        // Arm both senders, then cut every ingress link of d's NIC 0
        // (no whole-NIC event: nobody hears about it from the fabric).
        a.set_nic_health(0, 0, true);
        b.set_nic_health(0, 0, true);
        let d0 = NicAddr { node: 2, gpu: 0, nic: 0 };
        for node in [0u16, 1] {
            for nic in 0..2u8 {
                fabric.set_link_up(NicAddr { node, gpu: 0, nic }, d0, false);
            }
        }
        let len = 1 << 20;
        let pat: Vec<u8> = (0..len).map(|i| (i % 239) as u8).collect();
        // Sender A pays the WrError walk, concludes d.nic0 dead,
        // retargets onto d.nic1 and gossips.
        let (src_a, _) = a.alloc_mr(0, len);
        let (dst_ah, dst_ad) = d.alloc_mr(0, len);
        src_a.buf.write(0, &pat);
        let done_a = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src_a, 0), len as u64, (&dst_ad, 0), None, OnDoneT::Flag(done_a.clone()))
            .unwrap();
        wait_flag(&done_a);
        assert_eq!(dst_ah.buf.to_vec(), pat);
        assert!(a.transport_errors() >= 2, "A paid the error round-trips");
        // Wait for the gossip to land in B's table.
        let deadline = Instant::now() + Duration::from_secs(5);
        while b.link_health_mask(0, d0) != 0 {
            assert!(Instant::now() < deadline, "gossip never converged");
            std::thread::yield_now();
        }
        // Sender B then completes its own submit to the same peer over
        // surviving links with ZERO transport errors.
        let (src_b, _) = b.alloc_mr(0, len);
        let (dst_bh, dst_bd) = d.alloc_mr(0, len);
        src_b.buf.write(0, &pat);
        let done_b = Arc::new(AtomicBool::new(false));
        b.submit_single_write((&src_b, 0), len as u64, (&dst_bd, 0), None, OnDoneT::Flag(done_b.clone()))
            .unwrap();
        wait_flag(&done_b);
        assert_eq!(b.transport_errors(), 0, "B never increments transport_errors");
        assert_eq!(dst_bh.buf.to_vec(), pat, "zero lost payload for B");
        assert_eq!(app_msgs.load(Ordering::Relaxed), 0, "gossip is engine-consumed");
        a.shutdown();
        b.shutdown();
        d.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_uvm_watcher() {
        let fabric = LocalFabric::new(TransportKind::Rc, 12);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = seen.clone();
        let word = a.alloc_uvm_watcher(move |old, new| s2.lock().unwrap().push((old, new)));
        let deadline = Instant::now() + Duration::from_secs(5);
        word.store(5, Ordering::Release);
        while seen.lock().unwrap().is_empty() {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
        word.store(9, Ordering::Release);
        while seen.lock().unwrap().len() < 2 {
            assert!(Instant::now() < deadline, "timeout");
            std::thread::yield_now();
        }
        let v = seen.lock().unwrap().clone();
        assert_eq!(v[0], (0, 5));
        assert_eq!(v[1], (5, 9));
        a.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn sharded_rotation_matches_plain_cursor_single_threaded() {
        use crate::engine::core::Rotation;
        let sharded = ShardedRotation::new();
        let plain = Rotation::new();
        for _ in 0..10 {
            assert_eq!(sharded.next(), plain.next());
            assert_eq!(sharded.bump(), plain.bump());
        }
        // A batch commit advances exactly like N single commits.
        assert_eq!(sharded.bump_n(5), plain.bump_n(5));
        assert_eq!(sharded.next(), plain.next());
    }

    #[test]
    fn sharded_rotation_counts_every_concurrent_bump() {
        let rot = Arc::new(ShardedRotation::new());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let r = rot.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        r.bump();
                    }
                    r.bump_n(10);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(rot.committed(), 4 * 1010, "no lost updates across lanes");
    }

    #[test]
    fn threaded_write_batch_delivers_and_counts() {
        let fabric = LocalFabric::new(TransportKind::Srd, 31);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        let (src, _) = a.alloc_mr(0, 4096);
        src.buf.write(0, &[9u8; 4096]);
        let peers: Vec<(MrHandle, MrDesc)> = (0..3).map(|_| b.alloc_mr(0, 1024)).collect();
        let counted = Arc::new(AtomicBool::new(false));
        let c = counted.clone();
        // One increment per batch entry: imm_base rides every WR.
        b.expect_imm_count(0, 60, 3, move || c.store(true, Ordering::Release));
        let dsts: Vec<ScatterDst> = peers
            .iter()
            .enumerate()
            .map(|(i, (_, d))| ScatterDst {
                len: 256,
                src: (i as u64) * 256,
                dst: (d.clone(), 16),
            })
            .collect();
        let done = Arc::new(AtomicBool::new(false));
        a.submit_write_batch(&src, &dsts, Some(60), OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        wait_flag(&counted);
        for (i, (h, _)) in peers.iter().enumerate() {
            assert_eq!(&h.buf.to_vec()[16..16 + 256], &[9u8; 256], "entry {i}");
        }
        // Empty batch: fires OnDone inline, posts nothing.
        let empty_done = Arc::new(AtomicBool::new(false));
        a.submit_write_batch(&src, &[], Some(61), OnDoneT::Flag(empty_done.clone()))
            .unwrap();
        assert!(empty_done.load(Ordering::Acquire));
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_dereg_mr_returns_registry_to_baseline() {
        let fabric = LocalFabric::new(TransportKind::Rc, 32);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let before = fabric.mem().len();
        let (_h, d) = a.alloc_mr(0, 4096);
        assert_eq!(fabric.mem().len(), before + 2, "one rkey per NIC of the group");
        a.dereg_mr(&d);
        assert_eq!(fabric.mem().len(), before, "dereg removes every rkey");
        a.dereg_mr(&d); // double-dereg is safe
        assert_eq!(fabric.mem().len(), before);
        a.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn chaos_threaded_dead_remote_leaves_probation_after_ttl() {
        let fabric = LocalFabric::new(TransportKind::Srd, 33);
        let a = ThreadedEngine::new(&fabric, 0, 1, 2);
        let b = ThreadedEngine::new(&fabric, 1, 1, 2);
        let b0 = NicAddr { node: 1, gpu: 0, nic: 0 };
        let (src, _) = a.alloc_mr(0, 64);
        let (_dh, dd) = b.alloc_mr(0, 64);
        src.buf.write(0, &[5u8; 64]);
        // Believed-dead remote with NO TTL: the mark outlives
        // submissions (remap retargets around it, never lifts it).
        a.report_remote_health(0, b0, false);
        assert_eq!(a.link_health_mask(0, b0), 0, "remote in probation");
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 64, (&dd, 0), None, OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        assert_eq!(a.link_health_mask(0, b0), 0, "without a TTL the belief persists");
        // Arm a 1ns TTL: the mark is long expired by the next
        // submission, which lifts it and re-probes optimistically.
        a.set_remote_probe_ttl(0, 1);
        let done2 = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 64, (&dd, 0), None, OnDoneT::Flag(done2.clone()))
            .unwrap();
        wait_flag(&done2);
        assert_eq!(
            a.link_health_mask(0, b0),
            0b11,
            "probation lifted after TTL: every lane trusted again"
        );
        assert_eq!(a.transport_errors(), 0, "fabric was healthy all along");
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_traces_recorded() {
        let fabric = LocalFabric::new(TransportKind::Rc, 13);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let b = ThreadedEngine::new(&fabric, 1, 1, 1);
        let (src, _) = a.alloc_mr(0, 4096);
        let (_dh, dd) = b.alloc_mr(0, 4096);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 4096, (&dd, 0), None, OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        let traces = a.take_traces();
        assert!(!traces.is_empty());
        let t = &traces[0];
        assert_eq!(t.kind, SubmitKind::Single);
        assert!(t.submitted <= t.worker_start);
        assert!(t.worker_start <= t.first_post);
        assert!(t.first_post <= t.last_post);
        assert!(t.last_post <= t.retired, "span retired after the last post");
        assert_eq!(t.outcome, TraceOutcome::Retired);
        assert_eq!(t.wrs, 1);
        assert_eq!(t.bytes, 4096);
        let snap = a.telemetry();
        assert_eq!(snap.sub_single, 1);
        assert_eq!(snap.total_bytes(), 4096);
        assert_eq!(snap.transport_errors(), 0);
        assert_eq!(snap.trace_dropped, 0);
        assert_eq!(snap.lat_us_pow2.iter().sum::<u64>(), 1, "one latency sample");
        assert!(a.take_traces().is_empty(), "drain consumes the ring");
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }

    #[test]
    fn threaded_telemetry_disable_suppresses_hot_path_counters() {
        let fabric = LocalFabric::new(TransportKind::Rc, 15);
        let a = ThreadedEngine::new(&fabric, 0, 1, 1);
        let b = ThreadedEngine::new(&fabric, 1, 1, 1);
        let (src, _) = a.alloc_mr(0, 64);
        let (_dh, dd) = b.alloc_mr(0, 64);
        a.set_telemetry(false);
        let done = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 64, (&dd, 0), None, OnDoneT::Flag(done.clone()))
            .unwrap();
        wait_flag(&done);
        let snap = a.telemetry();
        assert_eq!(snap.total_submissions(), 0, "kind counters gated off");
        assert_eq!(snap.total_bytes(), 0, "wire accounting gated off");
        assert!(a.take_traces().is_empty(), "no spans captured while off");
        assert_eq!(snap.trace_dropped, 0, "disabled spans are skipped, not dropped");
        // The always-on ledger still works while gated off.
        assert_eq!(snap.transport_errors(), 0);
        a.set_telemetry(true);
        let done2 = Arc::new(AtomicBool::new(false));
        a.submit_single_write((&src, 0), 64, (&dd, 0), None, OnDoneT::Flag(done2.clone()))
            .unwrap();
        wait_flag(&done2);
        assert_eq!(a.telemetry().sub_single, 1, "re-enable resumes counting");
        assert_eq!(a.take_traces().len(), 1);
        a.shutdown();
        b.shutdown();
        fabric.shutdown();
    }
}
