//! Integration: the full disaggregated KvCache flow (§4) on backed
//! buffers — data integrity, cancellation confirmation, heartbeat
//! failure handling, page-pool hygiene.

use fabric_lib::apps::kvcache::{Decoder, Prefiller, ServingWorkload};
use fabric_lib::engine::api::EngineCosts;
use fabric_lib::engine::des_engine::Engine;
use fabric_lib::fabric::gpu::GpuSim;
use fabric_lib::fabric::profile::{GpuProfile, NicProfile};
use fabric_lib::fabric::nic::NicAddr;
use fabric_lib::fabric::simnet::SimNet;
use fabric_lib::fabric::topology::DeviceId;
use fabric_lib::sim::time::MS;
use fabric_lib::sim::Sim;

fn setup() -> (Sim, Engine, Engine, Prefiller, Decoder) {
    let net = SimNet::new(3);
    for node in 0..2u16 {
        for nic in 0..2u8 {
            net.add_nic(NicAddr { node, gpu: 0, nic }, NicProfile::efa());
        }
    }
    let ep = Engine::new(&net, 0, 1, 2, GpuProfile::h200(), EngineCosts::default(), 1);
    let ed = Engine::new(&net, 1, 1, 2, GpuProfile::h200(), EngineCosts::default(), 2);
    let gpu = GpuSim::new(DeviceId { node: 0, gpu: 0 }, GpuProfile::h200());
    let mut sim = Sim::new();
    let w = ServingWorkload::tiny();
    let p = Prefiller::new(&mut sim, &ep, 0, &gpu, w.clone(), 0);
    let d = Decoder::new(&mut sim, &ed, 0, w);
    (sim, ep, ed, p, d)
}

#[test]
fn end_to_end_request_completes_and_frees_pages() {
    let (mut sim, ep, _ed, _p, d) = setup();
    let free0 = d.free_slot_count();
    let input: Vec<u32> = (0..100).collect();
    let id = d.submit_request(&mut sim, &ep.group_address(0), input, 3);
    sim.run();
    let reports = d.reports();
    let reports = reports.borrow();
    assert_eq!(reports.len(), 1);
    let r = reports[0];
    assert_eq!(r.req_id, id);
    assert!(r.transfer_done > r.submitted);
    assert!(r.ttft > r.transfer_done);
    assert!(r.finished > r.ttft);
    assert_eq!(d.free_slot_count(), free0, "pages returned to the pool");
}

#[test]
fn kv_payload_lands_at_allocated_slots() {
    let (mut sim, ep, _ed, p, d) = setup();
    // Pattern the prefiller's KV source.
    let src = p.kv_src_handle();
    let pat: Vec<u8> = (0..src.buf.len()).map(|i| (i % 251) as u8).collect();
    src.buf.write(0, &pat);
    let input: Vec<u32> = (0..48).collect(); // 3 pages of 16 tokens
    d.submit_request(&mut sim, &ep.group_address(0), input, 1);
    sim.run();
    // Decoder KV region must contain nonzero data in exactly the
    // regions of 3 pages × 3 layers (tiny layout: 4096B pages).
    let kv = d.kv_handle();
    let v = kv.buf.to_vec();
    let nonzero_pages = v
        .chunks(4096)
        .filter(|c| c.iter().any(|&b| b != 0))
        .count();
    assert_eq!(nonzero_pages, 9, "3 pages × 3 layers transferred");
}

#[test]
fn cancellation_quarantines_pages_until_ack() {
    let (mut sim, ep, _ed, _p, d) = setup();
    let free0 = d.free_slot_count();
    let input: Vec<u32> = (0..64).collect();
    let id = d.submit_request(&mut sim, &ep.group_address(0), input, 5);
    // Cancel very early, while transfers are in flight.
    let d2 = d.clone();
    sim.after(10_000, move |sim| d2.cancel(sim, id));
    sim.run();
    use fabric_lib::apps::kvcache::decoder::ReqState;
    assert_eq!(d.req_state(id), Some(ReqState::Cancelled), "ack received");
    assert_eq!(d.free_slot_count(), free0, "pages freed only after ack");
}

#[test]
fn dead_prefiller_detected_by_heartbeat_timeout() {
    let (mut sim, ep, _ed, p, d) = setup();
    p.start_heartbeats(&mut sim, vec![d.address()], 2 * MS);
    d.start_monitor(&mut sim, 2 * MS);
    let free0 = d.free_slot_count();
    // Kill the prefiller immediately: the dispatch is never served.
    p.kill();
    let input: Vec<u32> = (0..64).collect();
    let id = d.submit_request(&mut sim, &ep.group_address(0), input, 1);
    // Run long enough for the 30 ms heartbeat timeout to fire.
    sim.run_until(200 * MS);
    use fabric_lib::apps::kvcache::decoder::ReqState;
    assert_eq!(
        d.req_state(id),
        Some(ReqState::Cancelled),
        "request force-cancelled after heartbeat timeout"
    );
    assert_eq!(d.free_slot_count(), free0, "pages reclaimed");
}

#[test]
fn many_concurrent_requests() {
    let (mut sim, ep, _ed, _p, d) = setup();
    for i in 0..6 {
        let input: Vec<u32> = (0..32 + i * 16).collect();
        d.submit_request(&mut sim, &ep.group_address(0), input, 2);
    }
    sim.run();
    assert_eq!(d.reports().borrow().len(), 6, "all requests served");
}
