//! Production systems built on the TransferEngine (paper §4–6).

pub mod kvcache;
pub mod moe;
pub mod rlweights;
