//! pplx-kernels / NVSHMEM-IBRC-like baseline (paper §7.4).
//!
//! The portable comparator: a *generic* host proxy posts one WR per
//! token with fine-grained per-token synchronization over NVLink —
//! an order of magnitude slower than specialized bulk transfers,
//! which is exactly what Fig 9 shows for pplx-kernels. Configured via
//! [`super::rank::Strategy::pplx`].

pub use super::rank::Strategy;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::moe::{run_decode_epoch, MoeConfig, MoeImpl};
    use crate::fabric::profile::NicProfile;

    #[test]
    fn pplx_strategy_contract() {
        let s = Strategy::pplx();
        assert!(!s.gpu_initiated, "generic host proxy");
        assert!(s.per_token_writes);
        assert!(s.proxy_per_wr_ns > 0, "per-WR generic proxy cost");
        assert!(s.nvlink_per_token_ns > 0, "fine-grained NVLink sync");
    }

    #[test]
    fn pplx_is_order_of_magnitude_slower_at_scale() {
        let cfg = MoeConfig::decode(32, 128);
        let ours = run_decode_epoch(&cfg, MoeImpl::Ours, NicProfile::efa(), 2, 3);
        let pplx = run_decode_epoch(&cfg, MoeImpl::Pplx, NicProfile::efa(), 2, 3);
        let (mut o, mut p) = (ours.dispatch, pplx.dispatch);
        let ratio = p.percentile(50.0) as f64 / o.percentile(50.0) as f64;
        assert!(ratio > 4.0, "pplx/ours dispatch ratio {ratio} too small");
    }
}
