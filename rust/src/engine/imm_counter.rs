//! The IMMCOUNTER: order-agnostic completion notification (paper §3.3).
//!
//! All synchronization in fabric-lib reduces to per-immediate counters
//! incremented from completion-queue events. Because the transport
//! gives no ordering guarantees, a receiver that expects N writes
//! simply registers `expect(imm, N)` and is notified when the N-th
//! WRITEIMM (in *any* order) has fully landed — the fabric guarantees
//! payload-before-immediate, so at notification time all N payloads
//! are readable.
//!
//! This component is pure logic shared by both engine runtimes.

use std::collections::HashMap;

/// Outcome of an increment.
#[derive(Debug, PartialEq, Eq)]
pub enum ImmEvent {
    /// Counter advanced; expectation (if any) not yet met.
    Pending,
    /// A registered expectation was satisfied by this increment; the
    /// expectation and counter have been retired.
    Satisfied,
}

struct Slot {
    count: u32,
    expected: Option<u32>,
}

/// Per-immediate counters plus registered expectations.
#[derive(Default)]
pub struct ImmCounter {
    slots: HashMap<u32, Slot>,
}

impl ImmCounter {
    /// Fresh counter table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an expectation: notify when `imm` has been received
    /// `count` times (counting increments that already happened —
    /// writes may land before the receiver registers, which is routine
    /// under one-sided semantics).
    ///
    /// Returns `Satisfied` immediately if already met. Panics if an
    /// expectation is already registered for `imm` (an alloc/free
    /// protocol bug in the caller).
    pub fn expect(&mut self, imm: u32, count: u32) -> ImmEvent {
        let slot = self.slots.entry(imm).or_insert(Slot {
            count: 0,
            expected: None,
        });
        assert!(
            slot.expected.is_none(),
            "imm {imm} already has a registered expectation"
        );
        if slot.count >= count {
            self.slots.remove(&imm);
            return ImmEvent::Satisfied;
        }
        slot.expected = Some(count);
        ImmEvent::Pending
    }

    /// Record one received immediate. Returns `Satisfied` when this
    /// increment completes a registered expectation.
    pub fn increment(&mut self, imm: u32) -> ImmEvent {
        let slot = self.slots.entry(imm).or_insert(Slot {
            count: 0,
            expected: None,
        });
        slot.count += 1;
        if let Some(exp) = slot.expected {
            if slot.count >= exp {
                self.slots.remove(&imm);
                return ImmEvent::Satisfied;
            }
        }
        ImmEvent::Pending
    }

    /// Current count for `imm` (polling interface; GDRCopy-style flag
    /// reads go through the engine which adds visibility latency).
    pub fn value(&self, imm: u32) -> u32 {
        self.slots.get(&imm).map(|s| s.count).unwrap_or(0)
    }

    /// Drop all state for `imm` (the paper's `free_imm`).
    pub fn free(&mut self, imm: u32) {
        self.slots.remove(&imm);
    }

    /// Number of live slots (leak check in tests).
    pub fn live(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expectation_satisfied_in_any_order() {
        let mut c = ImmCounter::new();
        assert_eq!(c.expect(7, 3), ImmEvent::Pending);
        assert_eq!(c.increment(7), ImmEvent::Pending);
        assert_eq!(c.increment(7), ImmEvent::Pending);
        assert_eq!(c.increment(7), ImmEvent::Satisfied);
        // Slot retired.
        assert_eq!(c.live(), 0);
        assert_eq!(c.value(7), 0);
    }

    #[test]
    fn increments_before_expect_count() {
        // One-sided writes can land before the receiver registers.
        let mut c = ImmCounter::new();
        c.increment(9);
        c.increment(9);
        assert_eq!(c.expect(9, 2), ImmEvent::Satisfied);
        assert_eq!(c.live(), 0);
    }

    #[test]
    fn partial_pre_increment() {
        let mut c = ImmCounter::new();
        c.increment(5);
        assert_eq!(c.expect(5, 3), ImmEvent::Pending);
        c.increment(5);
        assert_eq!(c.increment(5), ImmEvent::Satisfied);
    }

    #[test]
    fn independent_imms() {
        let mut c = ImmCounter::new();
        c.expect(1, 1);
        c.expect(2, 2);
        assert_eq!(c.increment(2), ImmEvent::Pending);
        assert_eq!(c.increment(1), ImmEvent::Satisfied);
        assert_eq!(c.increment(2), ImmEvent::Satisfied);
    }

    #[test]
    #[should_panic(expected = "already has a registered expectation")]
    fn double_expect_is_a_bug() {
        let mut c = ImmCounter::new();
        c.expect(3, 2);
        c.expect(3, 1);
    }

    #[test]
    fn free_clears() {
        let mut c = ImmCounter::new();
        c.increment(4);
        c.free(4);
        assert_eq!(c.value(4), 0);
        assert_eq!(c.live(), 0);
    }

    #[test]
    fn value_polling() {
        let mut c = ImmCounter::new();
        for i in 0..10 {
            assert_eq!(c.value(8), i);
            c.increment(8);
        }
    }
}
